"""Tests for the batched K-party engine (``training.train_many``): parity
with K independent ``training.train`` calls, uneven feature widths and
heterogeneous architectures (padded-stack layout), uneven row counts, and
per-party early stopping at different epochs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autoencoder as ae
from repro.core import training


def _toy(n, d, seed, widths=None):
    x = np.random.RandomState(seed).randn(n, d).astype(np.float32)
    params = ae.init_autoencoder(jax.random.PRNGKey(seed),
                                 widths or [d, 16, 8])
    return params, {"x": x}


def _max_leaf_diff(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _solo(params, data, seed, **kw):
    return training.train(params, data, ae.recon_loss, seed=seed, **kw)


def test_masked_recon_loss_equals_recon_loss_without_padding():
    params, data = _toy(32, 6, 0)
    x = jnp.asarray(data["x"])
    batch = {"x": x, "mask": jnp.ones((6,)), "row_w": jnp.ones((32,))}
    a = float(ae.recon_loss(params, {"x": x}))
    b = float(ae.masked_recon_loss(params, batch))
    assert abs(a - b) < 1e-6


def test_parity_uneven_widths_equal_rows():
    """Equal row counts -> every party draws the IDENTICAL device
    permutation as its solo run, so params/losses/epoch counts match the
    sequential path to float tolerance despite the feature padding."""
    kw = dict(batch_size=36, max_epochs=8, patience=8)
    specs, solos = [], []
    for i, d in enumerate([5, 9, 7]):
        params, data = _toy(200, d, i)
        specs.append(training.PartySpec(params, data, seed=i))
        solos.append(_solo(params, data, i, **kw))
    many = training.train_many(specs, ae.masked_recon_loss, **kw)
    for s, m in zip(solos, many):
        assert (s.epochs_run, s.steps_run) == (m.epochs_run, m.steps_run)
        np.testing.assert_allclose(s.train_loss, m.train_loss, atol=1e-4)
        np.testing.assert_allclose(s.val_loss, m.val_loss, atol=1e-4)
        assert _max_leaf_diff(s.params, m.params) < 1e-4


def test_parity_heterogeneous_architectures():
    """g1_active-style and g1_passive-style parties (different hidden AND
    latent widths) stack into one batch: zero-padded weights feed on zero
    inputs and get zero gradients, so each real sub-block still matches its
    solo run."""
    kw = dict(batch_size=32, max_epochs=6, patience=6)
    p1, d1 = _toy(160, 6, 0, widths=[6, 8, 16])
    p2, d2 = _toy(160, 11, 1, widths=[11, 16, 32])
    s1, s2 = _solo(p1, d1, 0, **kw), _solo(p2, d2, 1, **kw)
    m1, m2 = training.train_many(
        [training.PartySpec(p1, d1, 0), training.PartySpec(p2, d2, 1)],
        ae.masked_recon_loss, **kw)
    for s, m in zip((s1, s2), (m1, m2)):
        assert [l.shape for l in jax.tree.leaves(s.params)] == \
            [l.shape for l in jax.tree.leaves(m.params)]
        assert _max_leaf_diff(s.params, m.params) < 1e-4


def test_uneven_row_counts_statistical_parity():
    """Row-padded parties draw a filtered permutation (different batch
    order than solo) but must land in the same val-loss neighbourhood with
    per-party step accounting intact."""
    kw = dict(batch_size=32, max_epochs=8, patience=8)
    p1, d1 = _toy(150, 6, 0)
    p2, d2 = _toy(260, 6, 1)
    s1, s2 = _solo(p1, d1, 0, **kw), _solo(p2, d2, 1, **kw)
    m1, m2 = training.train_many(
        [training.PartySpec(p1, d1, 0), training.PartySpec(p2, d2, 1)],
        ae.masked_recon_loss, **kw)
    # party 2 is unpadded (max rows) -> exact parity incl. step counts
    assert (s2.epochs_run, s2.steps_run) == (m2.epochs_run, m2.steps_run)
    assert _max_leaf_diff(s2.params, m2.params) < 1e-4
    # party 1 is row-padded -> its own step budget, statistical parity
    assert m1.steps_run == m1.epochs_run * (135 // 32)
    assert abs(s1.val_loss[-1] - m1.val_loss[-1]) < 0.1 * max(
        s1.val_loss[-1], 1e-3)


def test_per_party_early_stopping_at_different_epochs():
    """A near-constant-data party plateaus and stops well before a
    random-data party; each party's stop epoch must match its solo run and
    its histories truncate at its own stop."""
    kw = dict(batch_size=25, max_epochs=40, patience=3)
    rng = np.random.RandomState(0)
    d_easy = {"x": np.full((125, 4), 0.5, np.float32)
              + 1e-3 * rng.randn(125, 4).astype(np.float32)}
    p_easy = ae.init_autoencoder(jax.random.PRNGKey(0), [4, 8, 4])
    p_hard, d_hard = _toy(125, 4, 1, widths=[4, 8, 4])
    s_easy = _solo(p_easy, d_easy, 0, **kw)
    s_hard = _solo(p_hard, d_hard, 1, **kw)
    m_easy, m_hard = training.train_many(
        [training.PartySpec(p_easy, d_easy, 0),
         training.PartySpec(p_hard, d_hard, 1)],
        ae.masked_recon_loss, **kw)
    assert m_easy.epochs_run == s_easy.epochs_run
    assert m_hard.epochs_run == s_hard.epochs_run
    assert m_easy.epochs_run != m_hard.epochs_run
    for m in (m_easy, m_hard):
        assert len(m.train_loss) == len(m.val_loss) == m.epochs_run
    # the early-stopped party's best params match its solo run: frozen
    # stepping after its stop must not leak into the returned snapshot
    assert _max_leaf_diff(s_easy.params, m_easy.params) < 1e-4


def test_single_party_degenerates_to_train():
    kw = dict(batch_size=64, max_epochs=5, patience=5)
    params, data = _toy(128, 7, 3)
    s = _solo(params, data, 3, **kw)
    (m,) = training.train_many([training.PartySpec(params, data, 3)],
                               ae.masked_recon_loss, **kw)
    assert (s.epochs_run, s.steps_run) == (m.epochs_run, m.steps_run)
    assert _max_leaf_diff(s.params, m.params) < 1e-4
