"""Tier-1 suite configuration.

The default (quick) path must finish in minutes on a small CPU container:
multi-minute end-to-end paths are marked ``@pytest.mark.slow`` and skipped
unless ``--runslow`` is given, and tests that sweep training epochs take the
``quick_epochs`` fixture so the quick path shrinks ``max_epochs``.
"""
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="run tests marked slow (full-epoch end-to-end paths)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-minute end-to-end path; needs --runslow")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip = pytest.mark.skip(reason="slow path: pass --runslow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(scope="session")
def quick_epochs_module(request) -> int:
    """max_epochs budget for trained-to-convergence assertions: generous
    under --runslow, small in the default quick path.  Session-scoped so
    module-scoped fixtures (e.g. a sweep shared by several tests) can
    depend on it."""
    return 60 if request.config.getoption("--runslow") else 12


@pytest.fixture
def quick_epochs(quick_epochs_module) -> int:
    return quick_epochs_module
