"""Tier-1 suite configuration.

The default (quick) path must finish in minutes on a small CPU container:
multi-minute end-to-end paths are marked ``@pytest.mark.slow`` and skipped
unless ``--runslow`` is given, and tests that sweep training epochs take the
``quick_epochs`` fixture so the quick path shrinks ``max_epochs``.
"""
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="run tests marked slow (full-epoch end-to-end paths)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-minute end-to-end path; needs --runslow")
    config.addinivalue_line(
        "markers", "needs_devices(n): requires >= n jax devices; "
        "auto-skipped otherwise (fake host devices with "
        "XLA_FLAGS=--xla_force_host_platform_device_count=N)")


def pytest_collection_modifyitems(config, items):
    runslow = config.getoption("--runslow")
    n_dev = None                      # import jax only if a test needs it
    for item in items:
        if "slow" in item.keywords and not runslow:
            item.add_marker(pytest.mark.skip(
                reason="slow path: pass --runslow to run"))
        marker = item.get_closest_marker("needs_devices")
        if marker is not None:
            if n_dev is None:
                import jax
                n_dev = jax.device_count()
            need = marker.args[0] if marker.args else 2
            if n_dev < need:
                item.add_marker(pytest.mark.skip(
                    reason=f"needs {need} jax devices, have {n_dev}; "
                    f"set XLA_FLAGS=--xla_force_host_platform_device_"
                    f"count={need} before jax initializes"))


@pytest.fixture(scope="session")
def quick_epochs_module(request) -> int:
    """max_epochs budget for trained-to-convergence assertions: generous
    under --runslow, small in the default quick path.  Session-scoped so
    module-scoped fixtures (e.g. a sweep shared by several tests) can
    depend on it."""
    return 60 if request.config.getoption("--runslow") else 12


@pytest.fixture
def quick_epochs(quick_epochs_module) -> int:
    return quick_epochs_module
