"""Per-architecture smoke tests: reduced config, one forward + one train
step + (where supported) one decode step on CPU; asserts shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.models import model as M
from repro.optim.adam import AdamW
from repro.sharding.policy import init_params
from repro.train.loop import make_train_step

ARCHS = [a for a in ARCH_IDS if a != "apcvfl-paper"]
B, S = 2, 64


def _inputs(cfg, key):
    if cfg.family == "audio":
        return {"frames": jax.random.normal(key, (B, S, cfg.d_model)),
                "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    d = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        d["image_embeds"] = jax.random.normal(
            key, (B, cfg.n_image_tokens, cfg.d_model))
    return d


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = get_smoke(arch)
    assert cfg.n_layers <= 4 and cfg.d_model <= 512 and cfg.n_experts <= 4
    key = jax.random.PRNGKey(0)
    params = init_params(M.schema(cfg), key, jnp.float32)
    lg, aux = M.logits(params, cfg, _inputs(cfg, key))
    assert lg.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(lg).any())
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(1)
    fns = make_train_step(cfg, AdamW(lr=1e-3))
    params, opt = fns.init(key)
    batch = _inputs(cfg, key)
    p2, opt2, metrics = jax.jit(fns.step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(p2)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = get_smoke(arch)
    if not M.supports_decode(cfg):
        pytest.skip("encoder-only: no decode step (documented skip)")
    key = jax.random.PRNGKey(2)
    params = init_params(M.schema(cfg), key, jnp.float32)
    img = (jax.random.normal(key, (B, cfg.n_image_tokens, cfg.d_model))
           if cfg.family == "vlm" else None)
    cache = M.init_cache(params, cfg, B, 16, image_embeds=img)
    tok = jax.random.randint(key, (B,), 0, cfg.vocab_size)
    logits, cache2 = M.decode(params, cfg, tok, cache, jnp.int32(0))
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", [
    # one attention-family representative stays in the quick path; the other
    # families run under --runslow (their decode parity is also pinned at the
    # unit level: test_model_units mamba/mlstm decode-vs-chunked, and
    # test_decode_step smokes every arch)
    "internlm2-20b",
    pytest.param("zamba2-2.7b", marks=pytest.mark.slow),
    pytest.param("qwen3-moe-30b-a3b", marks=pytest.mark.slow),
    pytest.param("xlstm-350m", marks=pytest.mark.slow),
])
def test_decode_matches_forward(arch):
    """Step-by-step decode reproduces the parallel forward logits."""
    cfg = get_smoke(arch)
    if cfg.n_experts:
        # capacity-based MoE drops tokens under load; give the test enough
        # capacity that forward (N=B*S) and decode (N=B) route identically
        cfg = cfg.with_(capacity_factor=float(cfg.n_experts
                                              / cfg.experts_per_token))
    key = jax.random.PRNGKey(3)
    params = init_params(M.schema(cfg), key, jnp.float32)
    T = 16
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    full, _ = M.logits(params, cfg, {"tokens": tokens})
    cache = M.init_cache(params, cfg, B, T)
    errs = []
    for t in range(T):
        lg, cache = M.decode(params, cfg, tokens[:, t], cache, jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg - full[:, t]))))
    assert max(errs) < 2e-3, errs


def test_full_configs_param_counts():
    """The full (assigned) configs match their nameplate sizes."""
    from repro.configs import get_config
    specs = {"internlm2-20b": (17e9, 23e9), "internlm2-1.8b": (1.5e9, 2.2e9),
             "yi-6b": (5e9, 7e9), "nemotron-4-15b": (13e9, 18e9),
             "kimi-k2-1t-a32b": (0.95e12, 1.1e12)}
    for arch, (lo, hi) in specs.items():
        n = M.count_params_analytic(get_config(arch))
        assert lo < n < hi, (arch, n)
    # MoE active params: kimi ~32B active
    n_act = M.count_active_params(get_config("kimi-k2-1t-a32b"))
    assert 28e9 < n_act < 36e9, n_act
