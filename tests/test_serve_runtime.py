"""Live serving runtime tests (``repro.serve.runtime``): deterministic
arrival processes, the SLO micro-batching scheduler and its admission
control, the multi-tenant registry's shared-jit-cache promise and
bit-identical parity vs solo engines, and the representation-cache
lifecycle (refresh on re-export, stale caches degrading to active-only).

One tiny model is trained once per module (1 epoch — runtime correctness
does not depend on convergence); three tenants are exported from it with
different serving-head budgets, which makes them genuinely distinct
models of identical architecture (the shared-executable case)."""
import numpy as np
import pytest

from repro.analysis import guards
from repro.core import pipeline
from repro.experiments.specs import ScenarioSpec
from repro.experiments.sweeps import build_scenario
from repro.serve import runtime as rt
from repro.serve import vfl as sv


@pytest.fixture(scope="module")
def trained():
    sc = build_scenario(ScenarioSpec(dataset="bcw", n_aligned=120,
                                     n_active_features=5, seed=0))
    result = pipeline.run_apcvfl(sc, seed=0, max_epochs=1)
    return sc, result


@pytest.fixture(scope="module")
def bundles(trained):
    sc, result = trained
    return {f"t{k}": sv.export_bundle(result, sc, head_steps=steps)
            for k, steps in enumerate((60, 120, 180))}


def _registry(bundles):
    reg = rt.TenantRegistry()
    for name, b in bundles.items():
        reg.register(name, b)
    return reg


def _timed(sc, n, *, tenant, seed, t0_ms=0.0, max_rows=8, **kw):
    return rt.make_timed_stream(sc.active.x, sc.active.ids, n,
                                tenant=tenant, seed=seed, t0_ms=t0_ms,
                                max_rows=max_rows, **kw)


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

def test_poisson_arrivals_deterministic_rate_and_order():
    a = rt.poisson_arrivals(4000, 200.0, seed=3)
    b = rt.poisson_arrivals(4000, 200.0, seed=3)
    assert np.array_equal(a, b)                      # seeded = replayable
    assert np.all(np.diff(a) >= 0)                   # nondecreasing clock
    gaps = np.diff(np.concatenate([[0.0], a]))
    assert abs(gaps.mean() - 5.0) < 0.5              # 200 req/s = 5 ms gap
    c = rt.poisson_arrivals(10, 200.0, seed=4, t0_ms=1000.0)
    assert c[0] >= 1000.0


def test_bursty_arrivals_concentrate_in_on_windows():
    times = rt.bursty_arrivals(2000, rate_on_rps=1000.0, rate_off_rps=10.0,
                               on_ms=100.0, off_ms=100.0, seed=5)
    assert np.all(np.diff(times) >= 0)
    # window phase: [0,100) on, [100,200) off, ... — the ON share of a
    # 100:1 rate ratio must dominate
    phase = np.floor(times / 100.0).astype(int) % 2
    on_frac = float((phase == 0).mean())
    assert on_frac > 0.9
    # a zero OFF rate is a true lull: every arrival lands in an ON window
    quiet = rt.bursty_arrivals(500, rate_on_rps=1000.0, rate_off_rps=0.0,
                               on_ms=50.0, off_ms=50.0, seed=6)
    assert np.all((np.floor(quiet / 50.0).astype(int) % 2) == 0)


def test_arrival_validation_and_stream_builder(trained):
    sc, _ = trained
    with pytest.raises(ValueError, match="rate must be positive"):
        rt.poisson_arrivals(5, 0.0)
    with pytest.raises(ValueError, match="negative n"):
        rt.poisson_arrivals(-1, 10.0)
    with pytest.raises(ValueError, match="window lengths"):
        rt.bursty_arrivals(5, rate_on_rps=10.0, rate_off_rps=1.0,
                           on_ms=0.0, off_ms=10.0)
    with pytest.raises(ValueError, match="unknown arrival"):
        _timed(sc, 5, tenant="t0", seed=0, arrivals="uniform")
    stream = _timed(sc, 50, tenant="t0", seed=0, rate_rps=100.0)
    assert all(tr.tenant == "t0" for tr in stream)
    assert [tr.req.rid for tr in stream] == list(range(50))
    ts = [tr.t_arrival_ms for tr in stream]
    assert ts == sorted(ts)
    # merge is a stable global sort across tenants
    other = _timed(sc, 50, tenant="t1", seed=1, rate_rps=100.0)
    merged = rt.merge_streams(stream, other)
    assert len(merged) == 100
    mts = [tr.t_arrival_ms for tr in merged]
    assert mts == sorted(mts)


# ---------------------------------------------------------------------------
# scheduler: bucket-fill dispatch, SLO deadlines, admission control
# ---------------------------------------------------------------------------

def test_backlog_coalesces_to_largest_bucket(bundles, trained):
    """Everything arrives at once -> the scheduler must fill the largest
    warm bucket per dispatch, not dribble out one request at a time."""
    sc, _ = trained
    reg = _registry(bundles)
    # every request exactly 8 rows -> exact fill arithmetic
    x8 = np.asarray(sc.active.x[:8], np.float32)
    stream = [rt.TimedRequest(sv.ServeRequest(i, x8, None), "t0", 0.0)
              for i in range(40)]
    runtime = rt.ServingRuntime(reg, rt.RuntimeConfig(slo_ms=100.0),
                                service_model=lambda rows: 1.0)
    report = runtime.run(stream)
    assert report["served"] == 40 and report["shed_requests"] == 0
    # 320 rows / 256-row max bucket -> one full batch + one 64-row batch
    assert [d.rows for d in runtime.dispatch_log] == [256, 64]
    assert report["rows"] == 320


def test_slo_deadline_forces_partial_dispatch(bundles, trained):
    """Sparse arrivals never fill a bucket — the queueing budget (half
    the SLO) must force partial batches out in time.  The deterministic
    service model makes the assertion exact: every end-to-end latency
    stays within wait-budget + blocking + service."""
    sc, _ = trained
    reg = _registry(bundles)
    stream = _timed(sc, 60, tenant="t0", seed=8, rate_rps=50.0,
                    max_rows=4)
    cfg = rt.RuntimeConfig(slo_ms=50.0)        # wait budget 25 ms
    runtime = rt.ServingRuntime(reg, cfg, service_model=lambda rows: 2.0)
    report = runtime.run(stream)
    assert report["served"] == 60
    # partial batches happened (nothing close to the 256-row bucket)
    assert max(d.rows for d in runtime.dispatch_log) < reg.bucketer.max
    # queueing <= wait budget + one blocking dispatch; e2e within SLO
    assert report["latency_ms"]["queue"]["max"] <= 25.0 + 2.0 + 1e-6
    assert report["slo"]["attainment"] == 1.0
    assert report["latency_ms"]["service"]["max"] == 2.0


def test_admission_control_sheds_past_queue_bound(bundles, trained):
    """A flood past the per-tenant row bound is refused at admission:
    shed requests get no logits and are excluded from latency series;
    admitted requests still complete."""
    sc, _ = trained
    reg = _registry(bundles)
    x4 = np.asarray(sc.active.x[:4], np.float32)
    stream = [rt.TimedRequest(sv.ServeRequest(i, x4, None), "t0", 0.0)
              for i in range(200)]               # 800 rows at t=0
    cfg = rt.RuntimeConfig(slo_ms=100.0, max_queue_rows=300)
    runtime = rt.ServingRuntime(reg, cfg, service_model=lambda rows: 1.0)
    report = runtime.run(stream)
    assert report["shed_requests"] > 0
    assert report["served"] + report["shed_requests"] == 200
    assert report["shed_rate"] == pytest.approx(
        report["shed_requests"] / 200, abs=1e-4)
    shed = [tr for tr in stream if tr.shed]
    assert all(tr.req.logits is None for tr in shed)
    served = [tr for tr in stream if not tr.shed]
    assert all(tr.req.logits is not None and len(tr.req.logits) == 4
               for tr in served)
    assert report["latency_ms"]["queue"]["count"] == len(served)
    # per-tenant stats carry the shed accounting too
    assert report["tenants"]["t0"]["shed_requests"] == len(shed)
    assert reg["t0"].stats.shed_rows == 4 * len(shed)


def test_unknown_tenant_and_duplicate_register_raise(bundles, trained):
    sc, _ = trained
    reg = _registry(bundles)
    with pytest.raises(ValueError, match="already registered"):
        reg.register("t0", bundles["t0"])
    runtime = rt.ServingRuntime(reg, service_model=lambda rows: 1.0)
    ghost = _timed(sc, 3, tenant="nobody", seed=0)
    with pytest.raises(ValueError, match="unregistered tenants"):
        runtime.run(ghost)


# ---------------------------------------------------------------------------
# multi-tenant registry: shared jit cache + parity vs solo engines
# ---------------------------------------------------------------------------

def test_tenant_n_plus_1_warms_with_zero_compiles(bundles):
    """The shared-jit-cache promise: the first tenant pays the bucket
    compiles, every further same-architecture tenant warms for free."""
    reg = rt.TenantRegistry()
    names = list(bundles)
    reg.register(names[0], bundles[names[0]])
    reg[names[0]].warmup()
    with guards.compile_counter(budget=0,
                                label="incremental tenant warmup"):
        for n in names[1:]:
            reg.register(n, bundles[n])
            reg[n].warmup()
    sizes = reg.jit_cache_sizes()
    n_buckets = len(reg.bucketer.buckets)
    assert 0 < sizes["active"] <= n_buckets      # shared across 3 tenants
    assert 0 < sizes["collab"] <= n_buckets


def test_multi_tenant_serving_bit_identical_to_solo(bundles, trained):
    """Three tenants behind one bucketer/jit cache, mixed Poisson and
    bursty arrivals: every dispatched micro-batch must equal a fresh
    SOLO engine's output bit-for-bit, and per-tenant accounting must add
    up to the overall report."""
    sc, _ = trained
    reg = _registry(bundles)
    reg.warmup()
    streams = [
        _timed(sc, 40, tenant="t0", seed=11, rate_rps=300.0),
        _timed(sc, 40, tenant="t1", seed=12, rate_rps=300.0),
        _timed(sc, 40, tenant="t2", seed=13, arrivals="bursty",
               rate_rps=300.0),
    ]
    runtime = rt.ServingRuntime(reg, rt.RuntimeConfig(slo_ms=200.0))
    report = runtime.run(rt.merge_streams(*streams))
    assert report["served"] == 120
    parity = rt.verify_dispatch_parity(runtime, bundles)
    assert set(parity) == {"t0", "t1", "t2"}
    for name, p in parity.items():
        assert p["batches"] > 0, name
        assert p["bit_identical"], (name, p)
        assert p["max_abs_diff"] == 0.0
    assert sum(t["rows"] for t in report["tenants"].values()) \
        == report["rows"]
    assert sum(t["dispatches"] for t in report["tenants"].values()) \
        == report["dispatches"]
    # the registry's compiled shapes stay within the shared bucket set
    assert report["compiled"]["distinct_batch_shapes"] \
        <= len(reg.bucketer.buckets)


def test_report_schema_queue_and_service_separate(bundles, trained):
    sc, _ = trained
    reg = _registry(bundles)
    stream = _timed(sc, 30, tenant="t1", seed=14, rate_rps=100.0)
    runtime = rt.ServingRuntime(reg, rt.RuntimeConfig(slo_ms=150.0),
                                service_model=lambda rows: 3.0)
    report = runtime.run(stream)
    lat = report["latency_ms"]
    for series in ("queue", "service", "end_to_end"):
        for key in ("count", "mean", "max", "p50", "p90", "p99"):
            assert key in lat[series], (series, key)
    assert lat["queue"]["count"] == lat["service"]["count"] == 30
    # e2e = queue + service, so its percentiles dominate service's
    assert lat["end_to_end"]["p50"] >= lat["service"]["p50"]
    assert report["slo"]["offered"] == 30
    assert report["virtual_elapsed_ms"] > 0
    assert report["tenants"]["t1"]["latency_ms"]["queue"]["count"] == 30
    # idle tenants report empty-but-valid blocks
    assert report["tenants"]["t0"]["latency_ms"]["queue"]["count"] == 0


# ---------------------------------------------------------------------------
# representation-cache lifecycle
# ---------------------------------------------------------------------------

@pytest.fixture()
def collab_probe(trained, bundles):
    """Feature rows whose ids ARE in the representation cache."""
    sc, _ = trained
    b = bundles["t0"]
    ids = np.asarray(b.cache_ids[:8])
    pos = {int(v): k for k, v in enumerate(np.asarray(sc.active.ids))}
    x = np.asarray(sc.active.x[[pos[int(i)] for i in ids]], np.float32)
    return x, ids


def test_reexport_refreshes_cache_bit_identically_and_bumps_version(
        trained, bundles, collab_probe):
    """A fresh training round (same seed/data -> deterministic engine)
    re-exports the same latents: refresh must install them bit-identically
    and bump the version, leaving predictions unchanged."""
    sc, _ = trained
    x, ids = collab_probe
    engine = sv.VFLServingEngine(bundles["t0"])
    before = engine.predict(x, ids)
    assert engine.cache_version == 1
    result2 = pipeline.run_apcvfl(sc, seed=0, max_epochs=1)   # fresh round
    bundle2 = sv.export_bundle(result2, sc, head_steps=60)
    assert np.array_equal(np.asarray(bundle2.cache_z),
                          np.asarray(bundles["t0"].cache_z))
    v = engine.refresh_cache(bundle2.cache_ids, bundle2.cache_z)
    assert v == 2 and engine.cache_version == 2
    assert not engine.cache.stale
    assert np.array_equal(np.asarray(engine.cache.z),
                          np.asarray(bundles["t0"].cache_z))
    after = engine.predict(x, ids)
    assert np.array_equal(before, after)


def test_stale_cache_serves_active_only_and_counts_misses(
        bundles, collab_probe):
    """Passive dropout: after invalidate, requests for cached ids MUST
    NOT see the old latents — they fall back to the active-only path
    (bit-identical to predict_active), count as misses, and raise no
    exception.  A later refresh restores collaborative serving."""
    x, ids = collab_probe
    engine = sv.VFLServingEngine(bundles["t0"])
    collab = engine.predict(x, ids)
    assert engine.cache.hits == len(ids)
    engine.invalidate_cache()
    assert engine.cache.stale
    engine.cache.hits = engine.cache.misses = 0
    stale = engine.predict(x, ids)                  # no exception
    assert engine.cache.hits == 0
    assert engine.cache.misses == len(ids)          # counted, not hidden
    active_only = engine.predict_active(x)
    assert np.array_equal(stale, active_only)       # never old latents
    assert not np.array_equal(stale, collab)        # paths truly differ
    engine.refresh_cache(bundles["t0"].cache_ids, bundles["t0"].cache_z)
    restored = engine.predict(x, ids)
    assert np.array_equal(restored, collab)
    assert engine.cache.version == 2


def test_missing_latents_fall_back_per_row(bundles, collab_probe):
    """Rows whose ids were never exported (missing latents) go active-
    only row-wise while cached neighbors stay collaborative."""
    x, ids = collab_probe
    engine = sv.VFLServingEngine(bundles["t0"])
    mixed_ids = ids.copy()
    mixed_ids[::2] = -(np.arange(len(ids[::2])) + 10)   # unknown users
    out = engine.predict(x, mixed_ids)
    known = np.nonzero(mixed_ids >= 0)[0]
    missing = np.nonzero(mixed_ids < 0)[0]
    want_known = engine.predict(x[known], ids[known])
    want_missing = engine.predict_active(x[missing])
    assert np.max(np.abs(out[known] - want_known)) < 1e-4
    assert np.max(np.abs(out[missing] - want_missing)) < 1e-4


def test_lifecycle_on_active_only_bundle(bundles):
    """Engines without a collaborative path: invalidate is a no-op,
    refresh is a loud error (there is no cache to refresh)."""
    b = bundles["t0"]
    bundle = sv.ModelBundle(meta=dict(b.meta), g3=b.g3,
                            head_active=b.head_active,
                            x_mean=b.x_mean, x_scale=b.x_scale)
    assert not bundle.supports_collaborative
    engine = sv.VFLServingEngine(bundle)
    assert engine.cache_version is None
    engine.invalidate_cache()                       # harmless no-op
    with pytest.raises(ValueError, match="no cache to refresh"):
        engine.refresh_cache(np.asarray([1]), np.zeros((1, 4), np.float32))


def test_runtime_serves_through_stale_cache_gracefully(
        bundles, trained):
    """The dropout scenario end-to-end: invalidate one tenant's cache
    mid-fleet, run a stream with cache-eligible ids — every request is
    served (active-only), nothing raises, misses are counted."""
    sc, _ = trained
    reg = _registry(bundles)
    reg["t1"].invalidate_cache()
    streams = [_timed(sc, 25, tenant=t, seed=20 + k, rate_rps=200.0,
                      p_known=0.9)
               for k, t in enumerate(("t0", "t1"))]
    runtime = rt.ServingRuntime(reg, rt.RuntimeConfig(slo_ms=200.0),
                                service_model=lambda rows: 2.0)
    report = runtime.run(rt.merge_streams(*streams))
    assert report["served"] == 50
    assert reg["t1"].cache.hits == 0                # stale: no hit ever
    assert reg["t1"].cache.misses > 0
    assert reg["t0"].cache.hits > 0                 # healthy tenant kept
    assert set(reg["t1"].stats.dispatches) == {"active"}
