"""jaxlint subsystem tests.

Static layer: every rule R001-R007 fires on its bad fixture and stays
silent on the matching good one (the good fixtures encode the repo's
sanctioned idioms: kw-only statics, shape-derived branching, pad-to-
multiple grids, rebind-after-donate).  Baseline suppression round-trips,
and the real tree lints clean against the committed baseline.

Runtime layer: the engine contracts from ANALYSIS_budgets.json are
asserted for real — one accounted host sync per ``train`` fit and per
``train_lanes`` fit at zero warm compiles, zero compiles on a warmed
serve bucket, implicit device->host conversions trapped at the call
site, and engine pytrees all in the float32/int32 family.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import guards
from repro.analysis.lint import (apply_baseline, lint_paths, lint_source,
                                 load_baseline, write_baseline)

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir))
BASELINE = os.path.join(REPO, "src", "repro", "analysis", "baseline.json")


def rules_of(findings):
    return sorted({f.rule for f in findings})


def lint_snippet(code):
    return lint_source(textwrap.dedent(code))


# ---------------------------------------------------------------------------
# static rules: bad fixture fires, good fixture is silent
# ---------------------------------------------------------------------------

def test_r001_host_call_fires_on_np_in_jitted_body():
    bad = lint_snippet("""
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return np.log(x) + 1.0
    """)
    assert "R001" in rules_of(bad)


def test_r001_silent_on_static_hyperparam_cast():
    good = lint_snippet("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x, *, scale: float = 2.0):
            return jnp.log(x) * float(scale)
    """)
    assert "R001" not in rules_of(good)


def test_r001_fires_on_item_sync():
    bad = lint_snippet("""
        import jax

        @jax.jit
        def f(x):
            return x.sum().item()
    """)
    assert "R001" in rules_of(bad)


def test_r002_fires_on_traced_branch():
    bad = lint_snippet("""
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
    """)
    assert "R002" in rules_of(bad)


def test_r002_silent_on_static_and_shape_branches():
    good = lint_snippet("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x, *, mode: str = "a"):
            if mode == "a":
                return x
            if x.ndim == 1:
                return -x
            n = len([k for k in x.shape])
            for i in range(n):
                if i < n - 1:
                    x = x + 1.0
            return jnp.where(x > 0, x, -x)
    """)
    assert "R002" not in rules_of(good)


def test_r002_propagates_tracedness_through_scan_body():
    bad = lint_snippet("""
        import jax

        def body(carry, x):
            if x > 0:
                carry = carry + x
            return carry, x

        def run(xs):
            return jax.lax.scan(body, 0.0, xs)
    """)
    assert "R002" in rules_of(bad)


def test_r003_fires_on_dict_literal_to_jit():
    bad = lint_snippet("""
        import jax

        @jax.jit
        def f(x, opts):
            return x * opts["s"]

        def call(x):
            return f(x, {"s": 2})
    """)
    assert "R003" in rules_of(bad)


def test_r003_silent_when_param_is_static():
    good = lint_snippet("""
        import jax
        from functools import partial

        @partial(jax.jit, static_argnames=("opts",))
        def f(x, opts):
            return x * 2.0

        def call(x):
            return f(x, {"s": 2})
    """)
    assert "R003" not in rules_of(good)


def test_r004_fires_on_use_after_donate():
    bad = lint_snippet("""
        import jax
        from functools import partial

        @partial(jax.jit, donate_argnums=(0,))
        def step(state, x):
            return state + x

        def run(state, xs):
            out = step(state, xs)
            return state + out
    """)
    assert "R004" in rules_of(bad)


def test_r004_silent_on_rebind_idiom_and_loop():
    good = lint_snippet("""
        import jax
        from functools import partial

        @partial(jax.jit, donate_argnums=(0,))
        def step(state, x):
            return state + x

        def run(state, xs):
            for x in xs:
                state = step(state, x)
            return state
    """)
    assert "R004" not in rules_of(good)


def test_r004_fires_on_loop_carried_donation():
    bad = lint_snippet("""
        import jax
        from functools import partial

        @partial(jax.jit, donate_argnums=(0,))
        def step(state, x):
            return state + x

        def run(state, xs):
            for x in xs:
                out = step(state, x)
            return out
    """)
    assert "R004" in rules_of(bad)


def test_r005_fires_on_key_reuse():
    bad = lint_snippet("""
        import jax

        def init(key):
            a = jax.random.normal(key, (3,))
            b = jax.random.normal(key, (3,))
            return a + b
    """)
    assert "R005" in rules_of(bad)


def test_r005_silent_on_split_fold_in_and_exclusive_branches():
    good = lint_snippet("""
        import jax

        def init(key, kind: str):
            if kind == "a":
                return jax.random.normal(key, (3,))
            return jax.random.uniform(key, (3,))

        def epochs(base_key, n: int):
            outs = []
            for e in range(n):
                k = jax.random.fold_in(base_key, e)
                outs.append(jax.random.normal(k, (3,)))
            return outs

        def pair(key):
            k1, k2 = jax.random.split(key)
            return jax.random.normal(k1, (3,)) + jax.random.normal(k2, (3,))
    """)
    assert "R005" not in rules_of(good)


def test_r006_fires_on_unguarded_grid_floordiv():
    bad = lint_snippet("""
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def run(x, block: int):
            return pl.pallas_call(
                kernel, grid=(x.shape[0] // block,),
                out_shape=None)(x)
    """)
    assert "R006" in rules_of(bad)


def test_r006_silent_with_pad_or_assert_guard():
    good = lint_snippet("""
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def run_padded(x, block: int):
            pad = (-x.shape[0]) % block
            xp = jnp.pad(x, ((0, pad),))
            n = x.shape[0] + pad
            return pl.pallas_call(
                kernel, grid=(n // block,), out_shape=None)(xp)

        def run_asserted(x, block: int):
            assert x.shape[0] % block == 0
            return pl.pallas_call(
                kernel, grid=(x.shape[0] // block,), out_shape=None)(x)
    """)
    assert "R006" not in rules_of(good)


def test_r007_fires_on_dtypeless_creation_in_traced_code():
    bad = lint_snippet("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            return x + jnp.arange(4)
    """)
    assert "R007" in rules_of(bad)


def test_r007_silent_with_explicit_dtype_and_outside_trace():
    good = lint_snippet("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            return x + jnp.arange(4, dtype=jnp.int32)

        def host_setup():
            return jnp.arange(4)
    """)
    assert "R007" not in rules_of(good)


def test_loss_name_convention_traces_losses_not_factories():
    findings = lint_snippet("""
        import numpy as np

        def recon_loss(params, batch):
            return np.mean(batch)

        def make_loss(lam: float):
            lam = float(lam)
            def loss(params, batch):
                return batch.sum() * lam
            return loss
    """)
    assert [f.symbol for f in findings if f.rule == "R001"] == ["recon_loss"]


# ---------------------------------------------------------------------------
# baseline machinery
# ---------------------------------------------------------------------------

BAD_SRC = """
import jax
import numpy as np

@jax.jit
def f(x):
    return np.log(x)
"""


def test_baseline_suppression_round_trips(tmp_path):
    findings = lint_source(BAD_SRC)
    assert findings
    path = str(tmp_path / "baseline.json")
    write_baseline(path, findings)
    assert apply_baseline(findings, load_baseline(path)) == []
    # a NEW occurrence beyond the frozen count still fails
    doubled = findings + findings
    assert len(apply_baseline(doubled, load_baseline(path))) == len(findings)
    # justifications survive a rewrite
    data = json.load(open(path))
    for e in data["entries"]:
        e["justification"] = "kept on purpose"
    json.dump(data, open(path, "w"))
    write_baseline(path, findings)
    data = json.load(open(path))
    assert all(e["justification"] == "kept on purpose"
               for e in data["entries"])


def test_repo_lints_clean_against_committed_baseline():
    findings = lint_paths(["src/repro"], root=REPO, baseline_path=BASELINE)
    assert findings == [], "\n".join(
        f"{f.file}:{f.line} {f.rule} {f.message}" for f in findings)


def test_lint_cli_exits_zero_and_emits_json():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.lint", "--json"],
        cwd=REPO, env=env, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(proc.stdout)
    assert payload["total"] == 0


# ---------------------------------------------------------------------------
# runtime guards: units
# ---------------------------------------------------------------------------

def test_compile_counter_counts_cold_not_warm():
    @jax.jit
    def poly(x):
        return x * x + 3.0 * x

    x = jnp.full((5, 7), 2.0, jnp.float32)   # shape unique to this test
    with guards.compile_counter() as cold:
        poly(x).block_until_ready()
    assert cold.count >= 1
    with guards.compile_counter(budget=0, label="warm poly"):
        poly(x).block_until_ready()


def test_compile_counter_budget_violation_raises():
    @jax.jit
    def poly(x):
        return x + 1.0

    with pytest.raises(guards.CompileBudgetError):
        with guards.compile_counter(budget=0, label="cold poly"):
            poly(jnp.full((3, 11), 1.0, jnp.float32)).block_until_ready()


def test_no_host_sync_traps_implicit_conversions():
    arr = jnp.ones((4,), jnp.float32)
    for convert in (lambda: np.asarray(arr),
                    lambda: float(arr.sum()),
                    lambda: arr.sum().item(),
                    lambda: arr.tolist()):
        with pytest.raises(guards.HostSyncError):
            with guards.no_host_sync():
                convert()
    # interposition is fully undone outside the block
    assert float(arr.sum()) == 4.0
    assert np.asarray(arr).shape == (4,)


def test_no_host_sync_budgets_explicit_device_get():
    arr = jnp.ones((4,), jnp.float32)
    with guards.no_host_sync(allowed=1) as tally:
        host = jax.device_get(arr)
    assert tally.device_gets == 1 and host.shape == (4,)
    with pytest.raises(guards.HostSyncError):
        with guards.no_host_sync(allowed=0):
            jax.device_get(arr)


def test_audit_dtypes_accepts_engine_family_rejects_others():
    good = {"w": jnp.zeros((2, 2), jnp.float32),
            "step": jnp.zeros((), jnp.int32),
            "mask": jnp.zeros((3,), bool)}
    guards.audit_dtypes(good)
    with pytest.raises(guards.DtypeAuditError):
        guards.audit_dtypes({"w": np.zeros((2,), np.float64)})
    with pytest.raises(guards.DtypeAuditError):
        guards.audit_dtypes({"lr": 0.1})      # python scalar leaf


def test_budgets_file_has_contract_keys():
    budgets = guards.load_budgets()
    assert budgets["train_fit"] == {"warm_compiles": 0, "host_syncs": 1}
    assert budgets["train_lanes_fit"]["host_syncs"] == 1
    assert budgets["serve_stream"]["max_batch_shapes"] == 6
    assert budgets["load_stream"] == {"warm_compiles": 0,
                                      "slo_attainment_min": 0.99}
    assert "float32" in budgets["engine_dtypes"]


# ---------------------------------------------------------------------------
# runtime guards: the engine contracts themselves
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_train_setup():
    from repro.core import autoencoder as ae
    key = jax.random.PRNGKey(0)
    params = ae.init_autoencoder(key, [16, 8, 4, 8, 16])
    x = np.random.RandomState(0).rand(96, 16).astype(np.float32)
    return ae, params, x


def test_train_fit_budget_one_sync_zero_warm_compiles(tiny_train_setup):
    from repro.core import training
    ae, params, x = tiny_train_setup
    budget = guards.load_budgets()["train_fit"]
    kw = dict(max_epochs=3, patience=3, batch_size=32)
    training.train(params, {"x": x}, ae.recon_loss, seed=0, **kw)  # compile
    with guards.compile_counter(budget=budget["warm_compiles"],
                                label="warm train fit"), \
         guards.no_host_sync(allowed=budget["host_syncs"],
                             label="warm train fit") as tally:
        result = training.train(params, {"x": x}, ae.recon_loss, seed=1,
                                **kw)
    assert tally.device_gets == budget["host_syncs"]
    guards.audit_dtypes(result.params, label="train fit params")


def test_train_lanes_fit_budget_one_sync_zero_warm_compiles(
        tiny_train_setup):
    from repro.core import training
    ae, params, x = tiny_train_setup
    budget = guards.load_budgets()["train_lanes_fit"]
    lanes = [training.LaneSpec(params, {"x": x}, seed=s) for s in (0, 1)]
    kw = dict(max_epochs=3, patience=3, batch_size=32)
    training.train_lanes(lanes, ae.masked_recon_loss, **kw)       # compile
    lanes2 = [training.LaneSpec(params, {"x": x}, seed=s) for s in (2, 3)]
    with guards.compile_counter(budget=budget["warm_compiles"],
                                label="warm lanes fit"), \
         guards.no_host_sync(allowed=budget["host_syncs"],
                             label="warm lanes fit") as tally:
        results = training.train_lanes(lanes2, ae.masked_recon_loss, **kw)
    assert tally.device_gets == budget["host_syncs"]
    for r in results:
        guards.audit_dtypes(r.params, label="lane fit params")


@pytest.fixture(scope="module")
def served():
    from repro.core import pipeline
    from repro.experiments.specs import ScenarioSpec
    from repro.experiments.sweeps import build_scenario
    from repro.serve import vfl as sv
    sc = build_scenario(ScenarioSpec(dataset="bcw", n_aligned=120,
                                     n_active_features=5, seed=0))
    result = pipeline.run_apcvfl(sc, seed=0, max_epochs=2)
    engine = sv.VFLServingEngine(sv.export_bundle(result, sc))
    engine.warmup()
    return engine


def test_warmed_serve_bucket_zero_compiles_one_sync_per_dispatch(served):
    budget = guards.load_budgets()["serve_bucket_warm"]
    x = np.random.RandomState(3).rand(
        5, served._mean.shape[0]).astype(np.float32)
    with guards.compile_counter(budget=budget["warm_compiles"],
                                label="warm serve bucket"), \
         guards.no_host_sync(allowed=budget["host_syncs_per_dispatch"],
                             label="warm serve bucket") as tally:
        logits = served.predict_active(x)
    assert logits.shape[0] == 5
    assert tally.device_gets == budget["host_syncs_per_dispatch"]


def test_warmed_serve_stream_stays_within_shape_budget(served):
    budget = guards.load_budgets()["serve_stream"]
    rng = np.random.RandomState(4)
    with guards.compile_counter(budget=0, label="warm serve stream"):
        for n in (1, 2, 3, 5, 8, 13, 21):
            served.predict_active(
                rng.rand(n, served._mean.shape[0]).astype(np.float32))
    shapes = served.compiled_shapes()
    assert shapes["distinct_batch_shapes"] <= budget["max_batch_shapes"]
