"""Int8-quantized serving tests (``repro.serve.quant``): per-channel
symmetric quantization round-trip bounds, the fused int8 matmul kernel vs
its jnp oracle, kernel-path vs pre-dequantized engine-path agreement, the
PINNED fp32-vs-int8 parity bounds on a real trained bundle, and the
shared-jit-cache promise (an int8 engine warms for free after fp32).

One small model is trained once per module (2 epochs — quantization
parity does not depend on convergence; under-trained bundles are in fact
the worst case the bounds were measured against) and every test reuses it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import guards
from repro.core import pipeline
from repro.experiments.specs import ScenarioSpec
from repro.experiments.sweeps import build_scenario
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.ref import int8_matmul_ref
from repro.serve import quant
from repro.serve import vfl as sv


@pytest.fixture(scope="module")
def trained():
    sc = build_scenario(ScenarioSpec(dataset="bcw", n_aligned=120,
                                     n_active_features=5, seed=0))
    result = pipeline.run_apcvfl(sc, seed=0, max_epochs=2)
    bundle = sv.export_bundle(result, sc)
    return sc, result, bundle


# ---------------------------------------------------------------------------
# quantize/dequantize round-trip
# ---------------------------------------------------------------------------

def test_quantize_weight_roundtrip_error_bound():
    """Symmetric 7-bit rounding: per-element error <= scale[c]/2, with
    the per-OUTPUT-channel scale (axis=0 max of |w|)."""
    rng = np.random.RandomState(0)
    w = (rng.randn(64, 16) * rng.rand(16)[None, :]).astype(np.float32)
    w_q, scale = quant.quantize_weight(w)
    assert w_q.dtype == np.int8 and scale.shape == (16,)
    np.testing.assert_allclose(scale, np.abs(w).max(axis=0) / 127.0,
                               rtol=1e-6)
    err = np.abs(quant.dequantize_weight(w_q, scale) - w)
    assert np.all(err <= scale[None, :] / 2 + 1e-7)


def test_quantize_weight_zero_column_exact():
    w = np.zeros((8, 3), np.float32)
    w[:, 1] = np.linspace(-1, 1, 8)
    w_q, scale = quant.quantize_weight(w)
    assert scale[0] == 1.0 and scale[2] == 1.0   # no divide-by-zero
    deq = quant.dequantize_weight(w_q, scale)
    assert np.all(deq[:, 0] == 0.0) and np.all(deq[:, 2] == 0.0)


def test_quantize_weight_rejects_non_2d():
    with pytest.raises(ValueError, match="2-D"):
        quant.quantize_weight(np.zeros((4,), np.float32))


def test_enc_layers_rejects_deep_encoders():
    enc = {f"w{i}": np.zeros((4, 4)) for i in range(3)}
    enc.update({f"b{i}": np.zeros((4,)) for i in range(3)})
    with pytest.raises(ValueError, match="2-layer"):
        quant._enc_layers({"enc": enc})


# ---------------------------------------------------------------------------
# fused int8 matmul kernel vs oracle
# ---------------------------------------------------------------------------

def _int8_inputs(key, B, d, c):
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (B, d))
    wf = jax.random.normal(ks[1], (d, c))
    w_q, scale = quant.quantize_weight(np.asarray(wf))
    b = jax.random.normal(ks[2], (c,)) * 0.1
    return x, jnp.asarray(w_q), jnp.asarray(scale), b


@pytest.mark.parametrize("B,d,c,bb", [
    (128, 32, 8, 64),    # rows divide the block
    (200, 64, 16, 128),  # padding path (200 -> 256)
    (5, 30, 4, 128),     # tiny serve-shaped batch, B < block_b
])
@pytest.mark.parametrize("act", ["none", "selu"])
def test_int8_matmul_kernel_vs_ref(B, d, c, bb, act):
    x, w_q, scale, b = _int8_inputs(jax.random.PRNGKey(B + d), B, d, c)
    out = int8_matmul(x, w_q, scale, b, act=act, block_b=bb,
                      interpret=True)
    ref = int8_matmul_ref(x, w_q, scale, b)
    if act == "selu":
        ref = jax.nn.selu(ref)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_int8_matmul_rejects_bad_inputs():
    x, w_q, scale, b = _int8_inputs(jax.random.PRNGKey(1), 8, 4, 2)
    with pytest.raises(TypeError, match="int8"):
        int8_matmul(x, w_q.astype(jnp.float32), scale, b, interpret=True)
    with pytest.raises(ValueError, match="act"):
        int8_matmul(x, w_q, scale, b, act="gelu", interpret=True)


# ---------------------------------------------------------------------------
# quantized serving engine
# ---------------------------------------------------------------------------

def test_engine_rejects_unknown_quantize(trained):
    _, _, bundle = trained
    with pytest.raises(ValueError, match="int8"):
        sv.VFLServingEngine(bundle, quantize="int4")


def test_int8_kernel_path_matches_dequant_engine_path(trained):
    """``int8_active_apply`` (dequant-in-tile kernels) and the engine's
    pre-dequantized fast path compute the same fp32 math — logits must
    agree to float tolerance on real rows."""
    sc, _, bundle = trained
    x = np.asarray(sc.active.x[:64], np.float32)
    eng = sv.VFLServingEngine(bundle, quantize="int8")
    via_engine = eng.predict_active(x)
    via_kernel = np.asarray(quant.int8_active_apply(eng.quant_params,
                                                    jnp.asarray(x)))
    np.testing.assert_allclose(via_kernel, via_engine, atol=1e-5,
                               rtol=1e-5)


def test_quantized_engine_within_pinned_bounds(trained):
    """The shipped error bar: parity_report on real rows must sit inside
    the module's pinned bounds (measured-with-headroom, module docstring),
    and the export must actually compress the serving weights."""
    sc, _, bundle = trained
    rep = quant.parity_report(bundle, sc.active.x, sc.active.y,
                              n_classes=sc.n_classes)
    assert rep["scheme"] == "int8-symmetric-per-channel"
    assert rep["compression"] > 3.0          # ~3.9x weight-bytes measured
    assert rep["max_abs_logit_delta"] <= quant.MAX_LOGIT_DELTA, rep
    assert rep["rel_logit_delta"] <= quant.MAX_REL_LOGIT_DELTA, rep
    assert rep["f1_macro_delta"] <= quant.MAX_F1_DELTA, rep
    assert rep["accuracy_delta"] <= quant.MAX_F1_DELTA, rep


def test_int8_engine_shares_fp32_jit_cache(trained):
    """The CPU fast path's whole point: the dequantized pytree has the
    SAME structure and shapes as the fp32 path, so an int8 engine after a
    warmed fp32 engine compiles NOTHING."""
    sc, _, bundle = trained
    x = np.asarray(sc.active.x[:32], np.float32)
    fp32 = sv.VFLServingEngine(bundle)
    fp32.predict_active(x)                   # warm the shared jit cache
    q = sv.VFLServingEngine(bundle, quantize="int8")
    assert (jax.tree_util.tree_structure(q._p_active)
            == jax.tree_util.tree_structure(fp32._p_active))
    with guards.compile_counter(budget=0, label="int8 twin predict"):
        lq = q.predict_active(x)
    assert lq.shape == fp32.predict_active(x).shape
