"""Online VFL inference subsystem tests (``repro.serve.vfl``): ModelBundle
checkpoint round-trip, serving parity against the training-time evaluator,
the batch bucketer's compile-count promise, representation-cache routing,
the ``serve_smoke`` experiment record, and example-spec validity.

One small model is trained once per module (2 epochs — serving correctness
does not depend on convergence) and every test reuses it.
"""
import glob
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.core import autoencoder as ae
from repro.core import classifier as clf
from repro.core import pipeline
from repro.experiments import ExperimentSpec, MethodSpec, get_method, sweep
from repro.experiments.specs import ScenarioSpec
from repro.experiments.sweeps import build_scenario
from repro.serve import vfl as sv

SPEC_DIR = os.path.join(os.path.dirname(__file__), os.pardir, "examples",
                        "specs")


@pytest.fixture(scope="module")
def trained():
    sc = build_scenario(ScenarioSpec(dataset="bcw", n_aligned=120,
                                     n_active_features=5, seed=0))
    result = pipeline.run_apcvfl(sc, seed=0, max_epochs=2)
    bundle = sv.export_bundle(result, sc)
    return sc, result, bundle


# ---------------------------------------------------------------------------
# export + checkpoint round-trip
# ---------------------------------------------------------------------------

def test_export_captures_all_active_party_state(trained):
    sc, result, bundle = trained
    assert bundle.supports_collaborative
    assert set(result.params) == {"g3", "g1_active", "g2"}
    assert len(bundle.cache_ids) == sc.n_aligned
    assert bundle.cache_z.shape[0] == sc.n_aligned
    assert bundle.meta["n_classes"] == sc.n_classes
    assert bundle.meta["z_dim"] == result.z_dim


def test_bundle_roundtrip_bit_identical_predictions(trained, tmp_path):
    sc, _, bundle = trained
    path = str(tmp_path / "bundle")
    bundle.save(path)
    loaded = sv.ModelBundle.load(path)
    x = sc.active.x[:50]
    ids = np.concatenate([bundle.cache_ids[:10],
                          -np.arange(1, 41, dtype=np.int64)])
    a = sv.VFLServingEngine(bundle).predict(x, ids)
    b = sv.VFLServingEngine(loaded).predict(x, ids)
    assert np.array_equal(a, b)                  # bit-identical, both paths
    assert loaded.cache_ids.dtype == np.int64    # ids survive un-downcast
    assert np.array_equal(loaded.cache_ids, bundle.cache_ids)


def test_ckpt_load_tree_roundtrip(tmp_path):
    tree = {"a": {"w0": np.arange(6, dtype=np.float32).reshape(2, 3)},
            "ids": np.asarray([5, 7, 1 << 40], np.int64)}
    path = str(tmp_path / "t")
    ckpt.save(path, tree, meta={"k": 1})
    got, side = ckpt.load_tree(path)
    assert side["meta"] == {"k": 1}
    assert np.array_equal(got["a"]["w0"], tree["a"]["w0"])
    assert got["ids"].dtype == np.int64          # host arrays: no downcast
    assert np.array_equal(got["ids"], tree["ids"])


# ---------------------------------------------------------------------------
# serving parity (the acceptance bound: 1e-6 vs the training-time eval)
# ---------------------------------------------------------------------------

def test_active_path_matches_pipeline_eval_logits(trained):
    sc, result, bundle = trained
    engine = sv.VFLServingEngine(bundle)
    x = np.asarray(sc.active.x[:77], np.float32)       # not a bucket size
    got = engine.predict_active(x)
    want = np.asarray(clf.logreg_logits(
        bundle.head_active, ae.encode(result.params["g3"],
                                      jnp.asarray(x))))
    assert np.max(np.abs(got - want)) < 1e-6


def test_collaborative_path_matches_joint_teacher(trained):
    sc, result, bundle = trained
    engine = sv.VFLServingEngine(bundle)
    ids = bundle.cache_ids[:12]
    pos = {int(v): i for i, v in enumerate(np.asarray(sc.active.ids))}
    rows = np.asarray([pos[int(i)] for i in ids])
    x = np.asarray(sc.active.x[rows], np.float32)
    got = engine.predict(x, ids)
    za = ae.encode(result.params["g1_active"], jnp.asarray(x))
    zj = jnp.concatenate([za, jnp.asarray(bundle.cache_z[:12])],
                         axis=1).astype(jnp.float32)
    want = np.asarray(clf.logreg_logits(
        bundle.head_joint, ae.encode(result.params["g2"], zj)))
    assert np.max(np.abs(got - want)) < 1e-4     # cross-batch-shape noise
    assert engine.cache.hits == 12 and engine.cache.misses == 0


def test_scaler_is_applied(trained):
    sc, _, bundle = trained
    import dataclasses
    x = np.asarray(sc.active.x[:20], np.float32)
    mean = np.full(x.shape[1], 2.5, np.float32)
    scale = np.full(x.shape[1], 3.0, np.float32)
    scaled = dataclasses.replace(bundle, x_mean=mean, x_scale=scale)
    got = sv.VFLServingEngine(scaled).predict_active(x * scale + mean)
    want = sv.VFLServingEngine(bundle).predict_active(x)
    assert np.max(np.abs(got - want)) < 1e-3


# ---------------------------------------------------------------------------
# bucketer + routing
# ---------------------------------------------------------------------------

def test_bucketer_fit_and_split():
    b = sv.BatchBucketer((16, 32, 64))
    assert b.fit(1) == 16 and b.fit(16) == 16 and b.fit(17) == 32
    assert b.split(5) == [(0, 5, 16)]
    assert b.split(64) == [(0, 64, 64)]
    assert b.split(150) == [(0, 64, 64), (64, 64, 64), (128, 22, 32)]
    with pytest.raises(ValueError, match="exceeds largest"):
        b.fit(65)
    with pytest.raises(ValueError, match="positive"):
        sv.BatchBucketer(())


def test_bucketer_split_boundaries():
    """Edge cases of the dispatch chunker: the empty batch, exact bucket
    edges, one-over edges, oversize remainders, and the negative-count
    caller bug (which used to emit a bogus negative-row dispatch)."""
    b = sv.BatchBucketer((16, 32, 64))
    assert b.split(0) == []                        # valid empty batch
    assert b.split(1) == [(0, 1, 16)]
    assert b.split(16) == [(0, 16, 16)]            # exact smallest edge
    assert b.split(17) == [(0, 17, 32)]            # one over an edge
    assert b.split(65) == [(0, 64, 64), (64, 1, 16)]
    # max-multiple: no empty tail dispatch
    assert b.split(128) == [(0, 64, 64), (64, 64, 64)]
    assert b.split(129) == [(0, 64, 64), (64, 64, 64), (128, 1, 16)]
    with pytest.raises(ValueError, match="negative row count"):
        b.split(-5)
    # every dispatch covers its rows exactly once, in order
    for n in (0, 1, 31, 64, 100, 200, 321):
        chunks = sv.BatchBucketer((16, 32, 64)).split(n)
        covered = 0
        for start, rows, bucket in chunks:
            assert start == covered and 0 < rows <= bucket
            assert bucket in (16, 32, 64)
            covered += rows
        assert covered == n


def test_serve_stream_records_queue_and_service_separately(trained):
    """The shared serve.metrics schema from the backlog driver: queueing
    (backlog wait) and service (dispatch wall) as separate pairwise
    series, e2e their sum, and the flat p50/p99 keys still aliasing the
    service series for PR-5 consumers."""
    sc, _, bundle = trained
    engine = sv.VFLServingEngine(bundle)
    reqs = sv.make_request_stream(sc.active.x, sc.active.ids, 40, seed=9,
                                  max_rows=10)
    stats = sv.serve_stream(engine, reqs)
    lat = stats["latency_ms"]
    for series in ("queue", "service", "end_to_end"):
        for key in ("count", "mean", "max", "p50", "p90", "p99"):
            assert key in lat[series], (series, key)
        assert lat[series]["count"] == 40
    assert len(engine.stats.queue_ms) == len(engine.stats.service_ms) == 40
    # backlog drain: later dispatches waited longer, first waited ~0
    assert engine.stats.queue_ms[0] <= engine.stats.queue_ms[-1]
    e2e = engine.stats.e2e_ms()
    assert e2e == [q + s for q, s in zip(engine.stats.queue_ms,
                                         engine.stats.service_ms)]
    assert stats["latency_ms_p50"] == round(engine.stats.percentile_ms(50), 3)
    # the pairwise-append contract is enforced, not assumed
    engine.stats.queue_ms.append(1.0)
    with pytest.raises(ValueError, match="pairwise"):
        engine.stats.e2e_ms()


def test_mixed_stream_compiles_bounded_shapes(trained):
    """The bucketer promise: whatever the request-size mix, distinct
    dispatched batch shapes stay within the bucket set (and so does the
    XLA executable count per path)."""
    sc, _, bundle = trained
    engine = sv.VFLServingEngine(bundle)
    reqs = sv.make_request_stream(sc.active.x, sc.active.ids, 150, seed=2,
                                  max_rows=60, p_known=0.4)
    stats = sv.serve_stream(engine, reqs)
    n_buckets = len(engine.bucketer.buckets)
    assert stats["compiled"]["distinct_batch_shapes"] <= n_buckets
    for sizes in stats["compiled"]["by_path"].values():
        assert set(sizes) <= set(engine.bucketer.buckets)
    for path, n in stats["jit_cache_sizes"].items():
        assert n <= n_buckets, (path, n)
    assert stats["rows"] == sum(len(r.x) for r in reqs)
    assert all(r.logits is not None and len(r.logits) == len(r.x)
               for r in reqs)


def test_predict_routes_rows_in_order(trained):
    """Mixed known/unknown ids: each row's logits must equal its own
    path's output, reassembled in request-row order."""
    sc, _, bundle = trained
    engine = sv.VFLServingEngine(bundle)
    ids = np.asarray([int(bundle.cache_ids[0]), -1,
                      int(bundle.cache_ids[1]), -2, -3], np.int64)
    pos = {int(v): i for i, v in enumerate(np.asarray(sc.active.ids))}
    rows = np.asarray([pos.get(int(i), 0) for i in ids])
    x = np.asarray(sc.active.x[rows], np.float32)
    got = engine.predict(x, ids)
    known = np.asarray([0, 2])
    unknown = np.asarray([1, 3, 4])
    want_known = engine.predict(x[known], ids[known])
    want_unknown = engine.predict_active(x[unknown])
    assert np.max(np.abs(got[known] - want_known)) < 1e-4
    assert np.max(np.abs(got[unknown] - want_unknown)) < 1e-4


def test_coalesced_anonymous_request_keeps_neighbor_cache_routing(trained):
    """Regression: an id-carrying request coalesced with an ids=None
    request must keep its collaborative routing (anonymous rows ride
    along under the never-matching filler id) — predictions must not
    depend on queue neighbors."""
    sc, _, bundle = trained
    pos = {int(v): i for i, v in enumerate(np.asarray(sc.active.ids))}
    ids = bundle.cache_ids[:4]
    rows = np.asarray([pos[int(i)] for i in ids])
    known = sv.ServeRequest(0, np.asarray(sc.active.x[rows], np.float32),
                            np.asarray(ids))
    anon = sv.ServeRequest(1, np.asarray(sc.active.x[:3], np.float32),
                           None)
    engine = sv.VFLServingEngine(bundle)
    sv.serve_stream(engine, [known, anon])       # one coalesced group
    solo_engine = sv.VFLServingEngine(bundle)
    want = solo_engine.predict(known.x, known.ids)
    assert np.max(np.abs(known.logits - want)) < 1e-4
    assert engine.cache.hits == 4                # routing really happened


def test_empty_batch_returns_empty_logits(trained):
    sc, _, bundle = trained
    engine = sv.VFLServingEngine(bundle)
    out = engine.predict_active(np.zeros((0, sc.active.x.shape[1])))
    assert out.shape == (0, sc.n_classes)
    out = engine.predict(np.zeros((0, sc.active.x.shape[1])),
                         np.zeros((0,), np.int64))
    assert out.shape == (0, sc.n_classes)


def test_serving_boundary_validation(trained):
    """ids/rows length mismatch and degenerate scalers are loud errors,
    never silent garbage predictions."""
    sc, _, bundle = trained
    engine = sv.VFLServingEngine(bundle)
    with pytest.raises(ValueError, match="ids for"):
        engine.predict(np.asarray(sc.active.x[:4], np.float32),
                       bundle.cache_ids[:2])
    import dataclasses
    bad = np.ones(sc.active.x.shape[1], np.float32)
    bad[0] = 0.0
    with pytest.raises(ValueError, match="finite and nonzero"):
        sv.VFLServingEngine(dataclasses.replace(bundle, x_scale=bad))


def test_bundle_without_collab_artifacts_serves_active_only(trained):
    sc, _, _ = trained
    result = pipeline.run_apcvfl(sc, seed=0, max_epochs=1, ablation=True)
    bundle = sv.export_bundle(result, sc)
    assert not bundle.supports_collaborative
    engine = sv.VFLServingEngine(bundle)
    ids = np.asarray(sc.active.ids[:5])          # ids given, no cache ->
    out = engine.predict(sc.active.x[:5], ids)   # active-only fallback
    assert out.shape == (5, sc.n_classes)
    assert engine.cache is None
    assert set(engine.stats.dispatches) == {"active"}


# ---------------------------------------------------------------------------
# experiment-layer integration + example specs
# ---------------------------------------------------------------------------

def test_serve_smoke_method_registered():
    entry = get_method("serve_smoke")
    assert entry.supports_multiparty
    assert "max_epochs" in entry.accepts


def test_serve_smoke_record_from_spec():
    spec = ExperimentSpec(
        name="serve", dataset="bcw", aligned=(120,), seeds=(0,),
        methods=(MethodSpec("serve_smoke"),),
        overrides={"max_epochs": 1})
    (r,) = sweep(spec)
    assert r.metrics["serve_parity_max_abs"] < 1e-6      # acceptance bound
    assert r.metrics["serve_batch_shapes"] <= 6.0
    assert r.metrics["serve_rows_per_s"] > 0
    assert 0.0 <= r.metrics["serve_cache_hit_rate"] <= 1.0
    assert "accuracy" in r.metrics                       # training metrics
    rec = r.to_record()                                  # tidy row works
    assert rec["serve_rows_per_s"] == r.metrics["serve_rows_per_s"]


def test_all_example_specs_parse_and_name_known_methods():
    paths = sorted(glob.glob(os.path.join(SPEC_DIR, "*.json")))
    assert len(paths) >= 4                    # incl. the serving spec
    for p in paths:
        with open(p) as fh:
            spec = ExperimentSpec.from_json(fh.read())
        assert spec.methods, p
        for m in spec.methods:
            get_method(m.method)              # raises on unknown names
