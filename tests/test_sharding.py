"""Sharding policy unit tests: logical-axis resolution, divisibility
fallback, cache auto-sharding, batch specs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_smoke
from repro.models import model as M
from repro.sharding import policy
from repro.sharding.policy import ParamDef


def test_resolve_single_pod():
    spec = policy.resolve(("fsdp", "tp"), ("data", "model"))
    assert spec == P("data", "model")


def test_resolve_multi_pod_dp_and_ep():
    axes = ("pod", "data", "model")
    assert policy.resolve(("dp", None), axes) == P(("pod", "data"), None)
    assert policy.resolve(("ep", "fsdp", None), axes) == \
        P(("pod", "model"), "data", None)


def test_resolve_lane_axis():
    """The VFL lane engine's logical axis: leading lane dim sharded, the
    per-lane payload dims replicated."""
    assert policy.resolve(("lane", None, None), ("lane", "data")) == \
        P("lane", None, None)
    assert policy.resolve(("lane", "dp"), ("lane", "data")) == \
        P("lane", "data")


def test_batch_pspec():
    assert policy.batch_pspec(("data", "model")) == "data"
    assert policy.batch_pspec(("pod", "data", "model")) == ("pod", "data")
    assert policy.batch_pspec(("lane", "data")) == "data"


class _FakeMesh:
    """Stand-in with more devices than the host has — _divisible only
    reads axis_names and devices.shape."""
    def __init__(self, shape, axes):
        self.axis_names = axes
        self.devices = np.empty(shape)


def test_divisible_drops_odd_dims():
    mesh = _FakeMesh((4, 2), ("lane", "data"))
    spec = P("lane", "data")
    # 8 lanes / 4 divides, 10 rows / 2 divides -> spec survives
    assert policy._divisible((8, 10), spec, mesh) == P("lane", "data")
    # 6 lanes / 4 doesn't divide -> lane dropped; rows keep theirs
    assert policy._divisible((6, 10), spec, mesh) == P(None, "data")
    # odd rows -> data dropped independently
    assert policy._divisible((8, 7), spec, mesh) == P("lane", None)


def test_divisible_one_device_mesh_keeps_spec():
    mesh = _FakeMesh((1, 1), ("lane", "data"))
    assert policy._divisible((3, 7), P("lane", "data"), mesh) == \
        P("lane", "data")


def test_divisible_short_spec_pads_with_none():
    mesh = _FakeMesh((4,), ("lane",))
    assert policy._divisible((8, 5, 3), P("lane"), mesh) == \
        P("lane", None, None)


def test_divisible_fallback_on_tiny_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"w": ParamDef((6, 10), ("fsdp", "tp"))}
    sh = policy.sharding_tree(tree, mesh)
    # mesh axes of size 1 always divide; spec survives
    assert sh["w"].spec == P("data", "model")


def test_stack_adds_layer_dim():
    s = policy.stack({"w": ParamDef((4, 8), ("fsdp", "tp"))}, 12)
    assert s["w"].shape == (12, 4, 8)
    assert s["w"].axes == (None, "fsdp", "tp")


def test_abstract_params_match_init_shapes():
    cfg = get_smoke("yi-6b")
    sch = M.schema(cfg)
    abstract = policy.abstract_params(sch, jnp.float32)
    real = policy.init_params(sch, jax.random.PRNGKey(0), jnp.float32)
    za = jax.tree.leaves(abstract)
    zr = jax.tree.leaves(real)
    assert len(za) == len(zr)
    for a, r in zip(za, zr):
        assert a.shape == r.shape and a.dtype == r.dtype


def test_full_config_schema_divisible_by_production_mesh():
    """Every full-size param with tp/ep sharding must divide 16 (model) —
    guards against configs that cannot lower on the production mesh."""
    sizes = {"data": 16, "model": 16}
    for arch in ["internlm2-20b", "kimi-k2-1t-a32b", "qwen3-moe-30b-a3b",
                 "zamba2-2.7b", "nemotron-4-15b"]:
        sch = M.schema(get_config(arch))
        for d in jax.tree.leaves(sch, is_leaf=policy.is_def):
            spec = policy.resolve(d.axes, ("data", "model"))
            for dim, ent in zip(d.shape, tuple(spec)):
                if ent is None:
                    continue
                names = ent if isinstance(ent, tuple) else (ent,)
                total = int(np.prod([sizes[n] for n in names]))
                assert dim % total == 0, (arch, d.shape, d.axes)


def test_cache_pspecs_sharding_choices():
    from repro.serve.decode import cache_pspecs
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16))
    cache = {"k": jax.ShapeDtypeStruct((48, 128, 32768, 8, 128), jnp.bfloat16)}
    specs = cache_pspecs(cache, FakeMesh(), batch=128)
    # batch dim -> data; kv-heads (8) don't divide 16 -> slots dim -> model
    assert specs["k"] == P(None, "data", "model", None, None)
