"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.distill_loss import fused_distill_rows
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lane_mlp import fused_lane_mlp2, fused_mlp2
from repro.kernels.probe import probe_grad_step
from repro.kernels.ref import (flash_attention_ref, fused_distill_loss_ref,
                               mlp2_ref, probe_grad_ref, ssd_chunk_ref)


@pytest.mark.parametrize("S,hd,bq,bk", [
    (128, 64, 64, 64),
    (256, 64, 128, 64),
    (256, 128, 64, 128),
    (512, 32, 128, 128),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 128), (False, 0)])
def test_flash_attention_sweep(S, hd, bq, bk, causal, window):
    key = jax.random.PRNGKey(S + hd)
    B, H = 1, 2
    q, k, v = [jax.random.normal(kk, (B, H, S, hd))
               for kk in jax.random.split(key, 3)]
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    key = jax.random.PRNGKey(7)
    B, H, S, hd = 2, 2, 128, 64
    q, k, v = [jax.random.normal(kk, (B, H, S, hd)).astype(dtype)
               for kk in jax.random.split(key, 3)]
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_model_layout_wrapper():
    key = jax.random.PRNGKey(3)
    B, S, H, hd = 2, 128, 4, 32
    q, k, v = [jax.random.normal(kk, (B, S, H, hd))
               for kk in jax.random.split(key, 3)]
    out = ops.flash_attention(q, k, v, causal=True)
    ref = jnp.swapaxes(flash_attention_ref(
        *(jnp.swapaxes(t, 1, 2) for t in (q, k, v)), causal=True), 1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("B,D,M", [(64, 8, 32), (200, 23, 256), (300, 5, 128)])
@pytest.mark.parametrize("kind", ["mse", "mae"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_distill_sweep(B, D, M, kind, dtype):
    key = jax.random.PRNGKey(B + M)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, D)).astype(dtype)
    xh = jax.random.normal(ks[1], (B, D)).astype(dtype)
    z = jax.random.normal(ks[2], (B, M)).astype(dtype)
    zt = jax.random.normal(ks[3], (B, M)).astype(dtype)
    mask = (jax.random.uniform(ks[4], (B,)) > 0.4).astype(jnp.float32)
    rows = fused_distill_rows(x, xh, z, zt, mask, lam=0.05, kind=kind,
                              interpret=True)
    got = jnp.mean(rows)
    ref = fused_distill_loss_ref(x, xh, z, zt, mask, lam=0.05, kind=kind)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    assert abs(float(got) - float(ref)) < tol


@pytest.mark.parametrize("kind", ["mse", "mae"])
def test_fused_distill_grads_match_reference(kind):
    """The closed-form custom VJP (Eq. 5 backward) must match autodiff
    through the pure-jnp oracle w.r.t. every differentiable input."""
    key = jax.random.PRNGKey(17)
    ks = jax.random.split(key, 5)
    B, D, M = 200, 23, 16
    x = jax.random.normal(ks[0], (B, D))
    xh = jax.random.normal(ks[1], (B, D))
    z = jax.random.normal(ks[2], (B, M))
    zt = jax.random.normal(ks[3], (B, M))
    mask = (jax.random.uniform(ks[4], (B,)) > 0.4).astype(jnp.float32)

    def fused(x, xh, z, zt, m):
        return jnp.mean(fused_distill_rows(x, xh, z, zt, m, lam=0.05,
                                           kind=kind, interpret=True))

    def ref(x, xh, z, zt, m):
        return fused_distill_loss_ref(x, xh, z, zt, m, lam=0.05, kind=kind)

    got = jax.grad(fused, argnums=(0, 1, 2, 3, 4))(x, xh, z, zt, mask)
    want = jax.grad(ref, argnums=(0, 1, 2, 3, 4))(x, xh, z, zt, mask)
    for g, w, name in zip(got, want, ("x", "x_hat", "z", "z_t", "mask")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-6,
                                   rtol=1e-5, err_msg=name)


def test_distill_kernel_trains_under_value_and_grad():
    """ROADMAP bug: the kernel path used to raise under autodiff.  One
    value_and_grad step of the full make_loss(use_kernel=True) closure
    must now run and agree with the reference closure's gradients."""
    from repro.core import autoencoder as ae
    from repro.core import distill
    key = jax.random.PRNGKey(3)
    params = ae.init_autoencoder(key, [12, 16, 8])
    batch = {"x": jax.random.normal(key, (64, 12)),
             "z_teacher": jax.random.normal(key, (64, 8)),
             "aligned": (jax.random.uniform(key, (64,)) > 0.5).astype(
                 jnp.float32)}
    vk, gk = jax.value_and_grad(distill.make_loss(use_kernel=True))(
        params, batch)
    vr, gr = jax.value_and_grad(distill.make_loss(use_kernel=False))(
        params, batch)
    assert abs(float(vk) - float(vr)) < 1e-6
    for a, b in zip(jax.tree.leaves(gk), jax.tree.leaves(gr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                                   rtol=1e-4)


def test_fused_distill_unaligned_rows_ignore_teacher():
    """Rows with mask=0 must be pure reconstruction loss (Eq. 5 case 2)."""
    key = jax.random.PRNGKey(9)
    ks = jax.random.split(key, 4)
    B, D, M = 96, 10, 16
    x = jax.random.normal(ks[0], (B, D))
    xh = jax.random.normal(ks[1], (B, D))
    z = jax.random.normal(ks[2], (B, M))
    mask = jnp.zeros((B,))
    a = ops.fused_distill_loss(x, xh, z, jnp.zeros_like(z), mask)
    b = ops.fused_distill_loss(x, xh, z, 1e6 * jnp.ones_like(z), mask)
    assert abs(float(a) - float(b)) < 1e-6


def test_ssd_chunked_vs_sequential_ref():
    """The chunked (matmul-form) SSD must equal the sequential recurrence."""
    from repro.configs import get_smoke
    from repro.models.mamba2 import ssd_chunked
    cfg = get_smoke("zamba2-2.7b")
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 4)
    B, S, H, P = 2, 64, cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[0], (B, S, G, N))
    y, _ = ssd_chunked(cfg, x, dt, A, Bm, Cm)   # multiplies x*dt internally
    ref = ssd_chunk_ref(x, dt, A, Bm, Cm)       # dt*B*x in the recurrence
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("Lc,N,P", [(32, 8, 16), (64, 16, 32), (128, 16, 64)])
def test_ssd_intra_chunk_kernel(Lc, N, P):
    """Pallas SSD intra-chunk kernel vs dense decay-matrix reference."""
    from repro.kernels.ssd_chunk import ssd_intra_chunk
    key = jax.random.PRNGKey(Lc + N)
    ks = jax.random.split(key, 4)
    G = 4
    a = -jax.nn.softplus(jax.random.normal(ks[0], (G, Lc)))
    B = jax.random.normal(ks[1], (G, Lc, N))
    C = jax.random.normal(ks[2], (G, Lc, N))
    x = jax.random.normal(ks[3], (G, Lc, P))
    y, st = ssd_intra_chunk(a, B, C, x, interpret=True)
    cs = jnp.cumsum(a, axis=1)
    Lmat = jnp.where(np.tril(np.ones((Lc, Lc), bool)),
                     jnp.exp(cs[:, :, None] - cs[:, None, :]), 0.0)
    scores = jnp.einsum("gln,gsn->gls", C, B)
    y_ref = jnp.einsum("gls,gsp->glp", scores * Lmat, x)
    st_ref = jnp.einsum("gsn,gs,gsp->gnp", B,
                        jnp.exp(cs[:, -1:] - cs), x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               atol=2e-4, rtol=2e-4)


def test_ssd_kernel_composes_full_scan():
    """Kernel intra-chunk + host inter-chunk recurrence == sequential SSD."""
    from repro.kernels.ssd_chunk import ssd_intra_chunk
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 5)
    B_, S, H, P, N, Lc = 2, 64, 3, 16, 8, 16
    Nc = S // Lc
    x = jax.random.normal(ks[0], (B_, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B_, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B_, S, 1, N))
    Cm = jax.random.normal(ks[4], (B_, S, 1, N))

    ref = ssd_chunk_ref(x, dt, A, Bm, Cm)

    # assemble via kernel: flatten (B, Nc, H) -> grid
    ch = lambda t: t.reshape((B_, Nc, Lc) + t.shape[2:])
    a = ch(dt * A)                                    # (B,Nc,Lc,H)
    xdt = ch(x * dt[..., None])                       # (B,Nc,Lc,H,P)
    Bh = jnp.repeat(ch(Bm), H, axis=3)
    Ch = jnp.repeat(ch(Cm), H, axis=3)
    g = lambda t: jnp.moveaxis(t, 3, 2).reshape((B_ * Nc * H,) + t.shape[2:3] + t.shape[4:]) \
        if t.ndim == 5 else jnp.moveaxis(t, 3, 2).reshape(B_ * Nc * H, Lc)
    y_i, st = ssd_intra_chunk(g(a), g(Bh), g(Ch), g(xdt), interpret=True)
    y_i = jnp.moveaxis(y_i.reshape(B_, Nc, H, Lc, P), 2, 3)
    st = st.reshape(B_, Nc, H, N, P)

    cs = jnp.cumsum(a, axis=2)
    chunk_decay = jnp.exp(cs[:, :, -1, :])            # (B,Nc,H)

    def body(h, inp):
        s, dec = inp
        h_out = h
        return h * dec[:, :, None, None] + s, h_out

    _, h_prev = jax.lax.scan(body, jnp.zeros((B_, H, N, P)),
                             (jnp.moveaxis(st, 1, 0),
                              jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)
    y_x = jnp.einsum("bclhn,bchnp,bclh->bclhp", Ch, h_prev, jnp.exp(cs))
    y = (y_i + y_x).reshape(B_, S, H, P)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# lane-blocked fused 2-layer MLP (kernels.lane_mlp)
# ---------------------------------------------------------------------------

def _mlp2_inputs(key, B, din, dh, dout):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, din))
    w0 = jax.random.normal(ks[1], (din, dh)) / np.sqrt(din)
    b0 = jax.random.normal(ks[2], (dh,)) * 0.1
    w1 = jax.random.normal(ks[3], (dh, dout)) / np.sqrt(dh)
    b1 = jax.random.normal(ks[4], (dout,)) * 0.1
    return x, w0, b0, w1, b1


@pytest.mark.parametrize("B,din,dh,dout,bb", [
    (128, 6, 8, 4, 64),       # rows divide the block
    (200, 30, 64, 128, 128),  # padding path (200 -> 256)
    (96, 5, 64, 128, 128),    # B < block_b (single padded tile)
])
@pytest.mark.parametrize("final_act", [False, True])
def test_fused_mlp2_sweep(B, din, dh, dout, bb, final_act):
    args = _mlp2_inputs(jax.random.PRNGKey(B + din), B, din, dh, dout)
    out = fused_mlp2(*args, final_act=final_act, block_b=bb, interpret=True)
    ref = mlp2_ref(*args, final_act=final_act)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("final_act", [False, True])
def test_fused_mlp2_grads_match_autodiff(final_act):
    """The closed-form VJP (module docstring chain rule) must match
    autodiff through the jnp oracle w.r.t. every input — this is the
    exactness the lane engine's value_and_grad training relies on."""
    args = _mlp2_inputs(jax.random.PRNGKey(21), 200, 10, 16, 8)

    def fused(*a):
        return jnp.mean(jnp.square(fused_mlp2(*a, final_act=final_act,
                                              block_b=64, interpret=True)))

    def oracle(*a):
        return jnp.mean(jnp.square(mlp2_ref(*a, final_act=final_act)))

    got = jax.grad(fused, argnums=(0, 1, 2, 3, 4))(*args)
    want = jax.grad(oracle, argnums=(0, 1, 2, 3, 4))(*args)
    for g, w, name in zip(got, want, ("x", "w0", "b0", "w1", "b1")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-6,
                                   rtol=1e-5, err_msg=name)


def test_fused_lane_mlp2_dead_lanes_exact_zero():
    """Stacked-lane form: the vmap-prepended lane grid must reproduce each
    live lane's per-lane result and render dead (live=0) lanes as exact
    zeros — the invariant the lane-padded engine depends on."""
    key = jax.random.PRNGKey(4)
    L, B, din, dh, dout = 4, 96, 6, 8, 4
    per_lane = [_mlp2_inputs(k, B, din, dh, dout)
                for k in jax.random.split(key, L)]
    xs, w0s, b0s, w1s, b1s = (jnp.stack(t) for t in zip(*per_lane))
    live = jnp.asarray([1.0, 1.0, 0.0, 1.0])
    out = fused_lane_mlp2(xs, w0s, b0s, w1s, b1s, live, block_b=64,
                          interpret=True)
    assert np.all(np.asarray(out[2]) == 0.0)
    for i in (0, 1, 3):
        np.testing.assert_allclose(np.asarray(out[i]),
                                   np.asarray(mlp2_ref(*per_lane[i])),
                                   atol=2e-5, rtol=2e-5)


def test_lane_mlp_kernel_recon_loss_trains_under_value_and_grad():
    """One value_and_grad step of the lane-engine loss with the fused
    reconstruction path must agree with the jnp closure's gradients."""
    from repro.core import autoencoder as ae
    key = jax.random.PRNGKey(6)
    params = ae.init_autoencoder(key, [12, 16, 8])
    batch = {"x": jax.random.normal(key, (64, 12)),
             "mask": jnp.ones((12,)),
             "row_w": (jax.random.uniform(key, (64,)) > 0.3).astype(
                 jnp.float32)}
    vk, gk = jax.value_and_grad(ae.make_masked_recon_loss(True))(
        params, batch)
    vr, gr = jax.value_and_grad(ae.make_masked_recon_loss(False))(
        params, batch)
    assert abs(float(vk) - float(vr)) < 1e-6
    for a, b in zip(jax.tree.leaves(gk), jax.tree.leaves(gr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                                   rtol=1e-4)


# ---------------------------------------------------------------------------
# fused probe step (kernels.probe)
# ---------------------------------------------------------------------------

def _probe_inputs(key, n, d, c):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (n, d))
    w = jax.random.normal(ks[1], (d, c)) * 0.1
    b = jax.random.normal(ks[2], (c,)) * 0.1
    y = jax.random.randint(ks[3], (n,), 0, c)
    rw = (jax.random.uniform(ks[4], (n,)) > 0.3).astype(jnp.float32)
    return w, b, x, y, rw


@pytest.mark.parametrize("n,d,c,bb", [
    (128, 16, 2, 64),    # rows divide the block
    (300, 33, 4, 128),   # padding path (300 -> 384)
    (96, 8, 3, 128),     # n < block_b
])
def test_probe_grad_step_sweep(n, d, c, bb):
    args = _probe_inputs(jax.random.PRNGKey(n + d), n, d, c)
    got = probe_grad_step(*args, block_b=bb, interpret=True)
    want = probe_grad_ref(*args)
    for a, b, name in zip(got, want, ("loss", "dW", "db")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                                   rtol=2e-5, err_msg=name)


def test_probe_grad_step_vmapped_fold_lanes():
    """k folds as vmap lanes (in_axes=(0, 0, None, None, 0), the
    classifier's fold-blocked layout): each lane must equal its solo
    reference — shared x/y, per-fold weights and row masks."""
    key = jax.random.PRNGKey(12)
    k, n, d, c = 5, 200, 16, 3
    _, _, x, y, _ = _probe_inputs(key, n, d, c)
    ks = jax.random.split(jax.random.PRNGKey(13), k)
    ws = jnp.stack([jax.random.normal(kk, (d, c)) * 0.1 for kk in ks])
    bs = jnp.stack([jax.random.normal(kk, (c,)) * 0.1 for kk in ks])
    rws = jnp.stack([(jax.random.uniform(kk, (n,)) > 0.4).astype(
        jnp.float32) for kk in ks])
    got = jax.vmap(
        lambda w, b, rw: probe_grad_step(w, b, x, y, rw, block_b=64,
                                         interpret=True),
        in_axes=(0, 0, 0))(ws, bs, rws)
    for i in range(k):
        want = probe_grad_ref(ws[i], bs[i], x, y, rws[i])
        for a, b, name in zip((got[0][i], got[1][i], got[2][i]), want,
                              ("loss", "dW", "db")):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-5, rtol=2e-5,
                                       err_msg=f"fold{i}/{name}")


def test_probe_zero_weight_rows_exactly_inert():
    """rw=0 rows (a fold's test rows / padding) must not influence the
    step at all — corrupting their features changes nothing."""
    key = jax.random.PRNGKey(9)
    w, b, x, y, rw = _probe_inputs(key, 160, 12, 4)
    dead = np.asarray(rw) == 0.0
    x_bad = np.asarray(x).copy()
    x_bad[dead] = 1e6
    a = probe_grad_step(w, b, x, y, rw, interpret=True)
    bb = probe_grad_step(w, b, jnp.asarray(x_bad), y, rw, interpret=True)
    for u, v in zip(a, bb):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v))


def test_kfold_cv_kernel_path_matches_reference():
    """classifier.kfold_cv(use_kernel=True) routes every fold's 300 Adam
    steps through the fused probe kernel; the CV metrics must land within
    float-accumulation distance of the jnp path."""
    from repro.core import classifier as clf
    rng = np.random.RandomState(0)
    n, d, c = 120, 8, 2
    x = rng.randn(n, d).astype(np.float32)
    y = (x[:, 0] + 0.5 * rng.randn(n) > 0).astype(np.int64)
    ref = clf.kfold_cv(x, y, c, k=5, seed=0, use_kernel=False)
    ker = clf.kfold_cv(x, y, c, k=5, seed=0, use_kernel=True)
    for key_ in ref:
        assert abs(ref[key_] - ker[key_]) < 0.02, (key_, ref, ker)


@pytest.mark.parametrize("W,hd,bw,window", [
    (64, 32, 16, 0), (128, 64, 64, 0), (128, 64, 32, 48), (256, 128, 128, 0),
])
def test_decode_attention_kernel(W, hd, bw, window):
    """One-token cache attention kernel vs masked softmax reference."""
    from repro.kernels.decode_attention import decode_attention
    key = jax.random.PRNGKey(W + hd)
    ks = jax.random.split(key, 3)
    BH = 4
    q = jax.random.normal(ks[0], (BH, hd))
    k = jax.random.normal(ks[1], (BH, W, hd))
    v = jax.random.normal(ks[2], (BH, W, hd))
    pos = jnp.int32(W * 3 // 4)
    slot_pos = jnp.where(jnp.arange(W) <= int(pos), jnp.arange(W),
                         -1).astype(jnp.int32)
    out = decode_attention(q, k, v, slot_pos, pos, window=window,
                           block_w=bw, interpret=True)
    s = jnp.einsum("bd,bwd->bw", q, k) / np.sqrt(hd)
    ok = (slot_pos >= 0) & (slot_pos <= pos)
    if window:
        ok &= slot_pos > pos - window
    s = jnp.where(ok, s, -1e30)
    ref = jnp.einsum("bw,bwd->bd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_matches_model_decode_path():
    """ops.decode_attention == models.attention.decode_attention softmax."""
    from repro.kernels import ops as kops
    from repro.models.attention import _gqa_expand
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    B, H, K, W, hd = 2, 4, 2, 64, 32
    q = jax.random.normal(ks[0], (B, H, hd))
    kc = jax.random.normal(ks[1], (B, W, K, hd))
    vc = jax.random.normal(ks[2], (B, W, K, hd))
    pos = jnp.int32(50)
    slot_pos = jnp.where(jnp.arange(W) <= 50, jnp.arange(W), -1).astype(jnp.int32)
    ke = _gqa_expand(kc, H, K)
    ve = _gqa_expand(vc, H, K)
    out = kops.decode_attention(q, ke, ve, slot_pos, pos)
    s = jnp.einsum("bhd,bwhd->bhw", q, ke) / np.sqrt(hd)
    s = jnp.where((slot_pos >= 0) & (slot_pos <= pos), s, -1e30)
    ref = jnp.einsum("bhw,bwhd->bhd", jax.nn.softmax(s, -1), ve)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
