"""Unit tests for the shared zero-padding utilities (``core.padding``):
pad_to growth/no-op/shrink, pad_stack shape and structure rules, the
zero-weight-row index padding, and the k-fold probe built on top of it."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import classifier as clf
from repro.core import padding


def test_pad_to_grows_with_zeros():
    a = jnp.arange(6, dtype=jnp.float32).reshape(2, 3)
    out = padding.pad_to(a, (4, 5))
    assert out.shape == (4, 5)
    np.testing.assert_array_equal(np.asarray(out[:2, :3]), np.asarray(a))
    assert float(jnp.abs(out[2:]).sum()) == 0.0
    assert float(jnp.abs(out[:, 3:]).sum()) == 0.0


def test_pad_to_noop_returns_same_array():
    a = jnp.ones((3, 4))
    assert padding.pad_to(a, (3, 4)) is a


def test_pad_to_refuses_to_shrink():
    with pytest.raises(ValueError, match="cannot shrink"):
        padding.pad_to(jnp.ones((4, 4)), (2, 4))


def test_pad_stack_pads_each_leaf_to_max_shape():
    trees = [{"w": jnp.ones((2, 3)), "b": jnp.ones((3,))},
             {"w": jnp.full((4, 2), 2.0), "b": jnp.full((5,), 2.0)}]
    out = padding.pad_stack(trees)
    assert out["w"].shape == (2, 4, 3)
    assert out["b"].shape == (2, 5)
    # lane 0's real sub-block survives; its padding is zero
    np.testing.assert_array_equal(np.asarray(out["w"][0, :2, :3]),
                                  np.ones((2, 3)))
    assert float(jnp.abs(out["w"][0, 2:, :]).sum()) == 0.0
    assert float(jnp.abs(out["b"][0, 3:]).sum()) == 0.0
    # results live on device as jax arrays
    assert isinstance(out["w"], jax.Array)


def test_pad_stack_rejects_mismatched_structures():
    with pytest.raises(ValueError, match="share one"):
        padding.pad_stack([{"w": jnp.ones(2)},
                           {"w": jnp.ones(2), "b": jnp.ones(1)}])


def test_pad_index_rows_zero_weight_slots():
    idx, w = padding.pad_index_rows(
        [np.array([5, 3]), np.array([7, 1, 2])])
    assert idx.shape == (2, 3) and w.shape == (2, 3)
    assert idx.dtype == np.int32 and w.dtype == np.float32
    np.testing.assert_array_equal(idx[0], [5, 3, 0])
    np.testing.assert_array_equal(w[0], [1.0, 1.0, 0.0])
    np.testing.assert_array_equal(idx[1], [7, 1, 2])
    np.testing.assert_array_equal(w[1], [1.0, 1.0, 1.0])


def test_pad_index_rows_min_len():
    idx, w = padding.pad_index_rows([np.array([4])], min_len=4)
    assert idx.shape == (1, 4)
    np.testing.assert_array_equal(w[0], [1.0, 0.0, 0.0, 0.0])


def test_fold_arrays_partition_and_weights():
    """classifier._fold_arrays (now built on pad_index_rows) still yields a
    disjoint exhaustive k-fold partition with inert padded slots."""
    n, k, seed = 23, 4, 7
    tr_idx, tr_w, te_idx, folds, te_lens = clf._fold_arrays(n, k, seed)
    assert tr_idx.shape == tr_w.shape
    assert sum(te_lens) == n
    all_te = np.concatenate(folds)
    assert sorted(all_te.tolist()) == list(range(n))
    for i in range(k):
        tr_real = tr_idx[i][tr_w[i] > 0]
        te_real = folds[i]
        assert len(tr_real) + len(te_real) == n
        assert not set(tr_real.tolist()) & set(te_real.tolist())
        # padded train slots are weight-0 pointers at row 0
        assert np.all(tr_idx[i][tr_w[i] == 0] == 0)
