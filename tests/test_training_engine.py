"""Tests for the device-resident scan training engine: parity with the
legacy per-batch loop, early stopping, epoch callbacks, compilation caching,
and the comm wire-size fix that rides along."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autoencoder as ae
from repro.core import comm
from repro.core import distill
from repro.core import training


def _toy(n=256, d=12, seed=0):
    x = np.random.RandomState(seed).randn(n, d).astype(np.float32)
    params = ae.init_autoencoder(jax.random.PRNGKey(seed), [d, 16, 8])
    return params, {"x": x}


def _max_leaf_diff(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# parity with the legacy loop (the reference oracle)
# ---------------------------------------------------------------------------

def test_parity_full_batch_exact():
    """With one full batch per epoch the row order inside the batch cannot
    matter, so scan engine and legacy loop must agree numerically: same
    losses, same params, same epoch/step counts."""
    params, data = _toy()
    kw = dict(batch_size=10_000, max_epochs=8, patience=8, seed=3)
    r_scan = training.train(params, data, ae.recon_loss, **kw)
    r_leg = training.train_legacy(params, data, ae.recon_loss, **kw)
    assert r_scan.epochs_run == r_leg.epochs_run
    assert r_scan.steps_run == r_leg.steps_run == 8
    np.testing.assert_allclose(r_scan.train_loss, r_leg.train_loss, atol=1e-5)
    np.testing.assert_allclose(r_scan.val_loss, r_leg.val_loss, atol=1e-5)
    assert _max_leaf_diff(r_scan.params, r_leg.params) < 1e-4


def test_parity_minibatch_converges_alike():
    """Mini-batch orders differ (device vs host RNG) so params are only
    statistically equal: both engines must reach the same validation loss
    neighbourhood with identical step accounting on divisible sizes."""
    params, data = _toy(n=200, d=8, seed=1)
    # n_tr = 180, divisible by 36 -> both engines run 5 steps/epoch
    kw = dict(batch_size=36, max_epochs=12, patience=12, seed=1)
    r_scan = training.train(params, data, ae.recon_loss, **kw)
    r_leg = training.train_legacy(params, data, ae.recon_loss, **kw)
    assert r_scan.steps_run == r_leg.steps_run == 12 * 5
    assert abs(r_scan.val_loss[-1] - r_leg.val_loss[-1]) < 0.1 * max(
        r_leg.val_loss[-1], 1e-3)


def test_scan_drops_remainder_legacy_runs_it():
    params, data = _toy(n=110, d=4)     # n_tr = 99, bs 32 -> 3 full + 3 rest
    kw = dict(batch_size=32, max_epochs=2, patience=99, seed=0)
    assert training.train(params, data, ae.recon_loss, **kw).steps_run == 6
    assert training.train_legacy(params, data, ae.recon_loss,
                                 **kw).steps_run == 8


# ---------------------------------------------------------------------------
# early stopping + histories
# ---------------------------------------------------------------------------

def test_early_stopping_on_plateau():
    """lr=0 never improves after the first epoch's best, so training stops
    after exactly patience further epochs."""
    params, data = _toy(n=64, d=4)
    r = training.train(params, data, ae.recon_loss, batch_size=16,
                       max_epochs=50, patience=3, lr=0.0, seed=0)
    assert r.epochs_run == 1 + 3
    assert len(r.train_loss) == len(r.val_loss) == r.epochs_run
    # with lr=0 params never move: best == initial
    assert _max_leaf_diff(r.params, params) == 0.0


def test_best_params_returned_not_last():
    """The returned params are the best-val snapshot, immune to the
    engine's buffer donation in later epochs."""
    params, data = _toy(n=128, d=6, seed=2)
    seen = []
    r = training.train(params, data, ae.recon_loss, batch_size=32,
                       max_epochs=8, patience=99, seed=2,
                       epoch_callback=lambda e, p, tl, vl: seen.append(vl))
    best_epoch = int(np.argmin(r.val_loss))
    assert r.val_loss[best_epoch] == min(seen)
    # snapshot buffers are alive and usable after training returned
    assert np.isfinite(np.asarray(ae.encode(r.params,
                                            jnp.asarray(data["x"][:4])))).all()


def test_epoch_callback_params_survive_donation():
    """Regression: callback params must be defensive copies — stashing them
    across epochs and reading them after training used to hit the engine's
    donated (deleted) buffers."""
    params, data = _toy(n=96, d=5)
    stashed = []
    r = training.train(params, data, ae.recon_loss, batch_size=32,
                       max_epochs=4, patience=99, seed=0,
                       epoch_callback=lambda e, p, tl, vl: stashed.append(p))
    assert len(stashed) == r.epochs_run
    for p in stashed:   # every stashed snapshot still readable post-training
        z = np.asarray(ae.encode(p, jnp.asarray(data["x"][:3])))
        assert np.isfinite(z).all()
    # snapshots are distinct per epoch, not one aliased buffer
    assert _max_leaf_diff(stashed[0], stashed[-1]) > 0.0


def test_epoch_callback_invoked_per_epoch():
    params, data = _toy(n=96, d=5)
    calls = []

    def cb(epoch, p, tl, vl):
        # params must be usable synchronously (donated next epoch)
        z = ae.encode(p, jnp.asarray(data["x"][:2]))
        calls.append((epoch, float(jnp.sum(z)), tl, vl))

    r = training.train(params, data, ae.recon_loss, batch_size=32,
                       max_epochs=5, patience=99, seed=0, epoch_callback=cb)
    assert [c[0] for c in calls] == list(range(r.epochs_run))
    assert all(np.isfinite(c[1:]).all() for c in [np.asarray(c[1:])
                                                  for c in calls])


# ---------------------------------------------------------------------------
# compilation caching: make_loss closures share one engine
# ---------------------------------------------------------------------------

def test_make_loss_closures_share_compiled_engine():
    l1 = distill.make_loss(lam=0.07, kind="mae")
    l2 = distill.make_loss(lam=0.07, kind="mae")
    l3 = distill.make_loss(lam=0.08, kind="mae")
    assert l1 is not l2
    assert training.get_engine(l1) is training.get_engine(l2)
    assert training.get_engine(l1) is not training.get_engine(l3)


def test_no_recompilation_across_make_loss_instances():
    """Two make_loss() closures with equal hyperparameters and equal data
    shapes must hit the same jit cache entry (zero new compilations)."""
    d, m = 6, 4
    x = np.random.RandomState(0).randn(120, d).astype(np.float32)
    data = {"x": x, "z_teacher": np.zeros((120, m), np.float32),
            "aligned": np.ones((120,), np.float32)}
    params = ae.init_autoencoder(jax.random.PRNGKey(0), [d, 8, m])
    kw = dict(batch_size=32, max_epochs=2, patience=99, seed=0)

    engine = training.get_engine(distill.make_loss(lam=0.11))
    if not hasattr(engine, "_cache_size"):   # private jax API; guard it
        pytest.skip("this jax version has no PjitFunction._cache_size")
    training.train(params, data, distill.make_loss(lam=0.11), **kw)
    misses = engine._cache_size()
    assert misses >= 1
    training.train(params, data, distill.make_loss(lam=0.11), **kw)
    assert engine._cache_size() == misses   # no fresh compilation


# ---------------------------------------------------------------------------
# comm: wire size follows the dtype, analytic formulas stay float32
# ---------------------------------------------------------------------------

def test_send_array_uses_dtype_itemsize():
    ch = comm.Channel()
    ch.send_array("f32", np.zeros((10, 3), np.float32))
    ch.send_array("f64", np.zeros((10, 3), np.float64))
    ch.send_array("f16", jnp.zeros((8,), jnp.float16))
    assert ch.log[0][1] == 30 * 4
    assert ch.log[1][1] == 30 * 8
    assert ch.log[2][1] == 8 * 2
