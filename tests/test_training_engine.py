"""Tests for the device-resident scan training engine: the stored-trace
oracle (committed loss trajectory), early stopping, epoch callbacks,
compilation caching, and the comm wire-size fix that rides along."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autoencoder as ae
from repro.core import comm
from repro.core import distill
from repro.core import training

TRACE_PATH = pathlib.Path(__file__).parent / "data" / "train_trace.json"


def _toy(n=256, d=12, seed=0):
    x = np.random.RandomState(seed).randn(n, d).astype(np.float32)
    params = ae.init_autoencoder(jax.random.PRNGKey(seed), [d, 16, 8])
    return params, {"x": x}


def _max_leaf_diff(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# stored-trace oracle: the committed trajectory of the (now retired) live
# parity runs.  Any semantic change to the host split, device permutation,
# loss, or Adam math moves these losses far beyond float noise.
# ---------------------------------------------------------------------------

def _trace_runs():
    """The oracle workloads.  ``tests/make_train_trace.py`` replays exactly
    these to (re)generate ``tests/data/train_trace.json``."""
    runs = {}
    params, data = _toy()
    # one full batch/epoch: row order inside the batch cannot matter
    runs["full_batch"] = (params, data,
                          dict(batch_size=10_000, max_epochs=8, patience=8,
                               seed=3))
    params, data = _toy(n=200, d=8, seed=1)
    # n_tr = 180, divisible by 36 -> 5 steps/epoch, real mini-batch path
    runs["minibatch"] = (params, data,
                         dict(batch_size=36, max_epochs=12, patience=12,
                              seed=1))
    return runs


def test_engine_matches_stored_trace():
    trace = json.loads(TRACE_PATH.read_text())
    for name, (params, data, kw) in _trace_runs().items():
        r = training.train(params, data, ae.recon_loss, **kw)
        want = trace[name]
        assert r.epochs_run == want["epochs_run"], name
        assert r.steps_run == want["steps_run"], name
        np.testing.assert_allclose(r.train_loss, want["train_loss"],
                                   rtol=2e-3, atol=1e-5, err_msg=name)
        np.testing.assert_allclose(r.val_loss, want["val_loss"],
                                   rtol=2e-3, atol=1e-5, err_msg=name)


def test_scan_drops_remainder():
    """Static batch shapes: the epoch runs n_tr // bs full batches and
    drops the remainder rows of the permutation."""
    params, data = _toy(n=110, d=4)     # n_tr = 99, bs 32 -> 3 full + 3 rest
    kw = dict(batch_size=32, max_epochs=2, patience=99, seed=0)
    assert training.train(params, data, ae.recon_loss, **kw).steps_run == 6


# ---------------------------------------------------------------------------
# early stopping + histories
# ---------------------------------------------------------------------------

def test_early_stopping_on_plateau():
    """lr=0 never improves after the first epoch's best, so training stops
    after exactly patience further epochs."""
    params, data = _toy(n=64, d=4)
    r = training.train(params, data, ae.recon_loss, batch_size=16,
                       max_epochs=50, patience=3, lr=0.0, seed=0)
    assert r.epochs_run == 1 + 3
    assert len(r.train_loss) == len(r.val_loss) == r.epochs_run
    # with lr=0 params never move: best == initial
    assert _max_leaf_diff(r.params, params) == 0.0


def test_best_params_returned_not_last():
    """The returned params are the best-val snapshot, immune to the
    engine's buffer donation in later epochs."""
    params, data = _toy(n=128, d=6, seed=2)
    seen = []
    r = training.train(params, data, ae.recon_loss, batch_size=32,
                       max_epochs=8, patience=99, seed=2,
                       epoch_callback=lambda e, p, tl, vl: seen.append(vl))
    best_epoch = int(np.argmin(r.val_loss))
    assert r.val_loss[best_epoch] == min(seen)
    # snapshot buffers are alive and usable after training returned
    assert np.isfinite(np.asarray(ae.encode(r.params,
                                            jnp.asarray(data["x"][:4])))).all()


def test_epoch_callback_params_survive_donation():
    """Regression: callback params must be defensive copies — stashing them
    across epochs and reading them after training used to hit the engine's
    donated (deleted) buffers."""
    params, data = _toy(n=96, d=5)
    stashed = []
    r = training.train(params, data, ae.recon_loss, batch_size=32,
                       max_epochs=4, patience=99, seed=0,
                       epoch_callback=lambda e, p, tl, vl: stashed.append(p))
    assert len(stashed) == r.epochs_run
    for p in stashed:   # every stashed snapshot still readable post-training
        z = np.asarray(ae.encode(p, jnp.asarray(data["x"][:3])))
        assert np.isfinite(z).all()
    # snapshots are distinct per epoch, not one aliased buffer
    assert _max_leaf_diff(stashed[0], stashed[-1]) > 0.0


def test_epoch_callback_invoked_per_epoch():
    params, data = _toy(n=96, d=5)
    calls = []

    def cb(epoch, p, tl, vl):
        # params must be usable synchronously (donated next epoch)
        z = ae.encode(p, jnp.asarray(data["x"][:2]))
        calls.append((epoch, float(jnp.sum(z)), tl, vl))

    r = training.train(params, data, ae.recon_loss, batch_size=32,
                       max_epochs=5, patience=99, seed=0, epoch_callback=cb)
    assert [c[0] for c in calls] == list(range(r.epochs_run))
    assert all(np.isfinite(c[1:]).all() for c in [np.asarray(c[1:])
                                                  for c in calls])


# ---------------------------------------------------------------------------
# compilation caching: make_loss closures share one engine
# ---------------------------------------------------------------------------

def test_make_loss_closures_share_compiled_engine():
    l1 = distill.make_loss(lam=0.07, kind="mae")
    l2 = distill.make_loss(lam=0.07, kind="mae")
    l3 = distill.make_loss(lam=0.08, kind="mae")
    assert l1 is not l2
    assert training.get_engine(l1) is training.get_engine(l2)
    assert training.get_engine(l1) is not training.get_engine(l3)
    assert training.get_fit_engine(l1) is training.get_fit_engine(l2)
    assert training.get_fit_engine(l1) is not training.get_fit_engine(l3)
    # epochwise and fused engines live under distinct cache tags
    assert training.get_fit_engine(l1) is not training.get_engine(l1)


def test_no_recompilation_across_make_loss_instances():
    """Two make_loss() closures with equal hyperparameters and equal data
    shapes must hit the same jit cache entry (zero new compilations)."""
    d, m = 6, 4
    x = np.random.RandomState(0).randn(120, d).astype(np.float32)
    data = {"x": x, "z_teacher": np.zeros((120, m), np.float32),
            "aligned": np.ones((120,), np.float32)}
    params = ae.init_autoencoder(jax.random.PRNGKey(0), [d, 8, m])
    kw = dict(batch_size=32, max_epochs=2, patience=99, seed=0)

    engine = training.get_fit_engine(distill.make_loss(lam=0.11))
    if not hasattr(engine, "_cache_size"):   # private jax API; guard it
        pytest.skip("this jax version has no PjitFunction._cache_size")
    training.train(params, data, distill.make_loss(lam=0.11), **kw)
    misses = engine._cache_size()
    assert misses >= 1
    training.train(params, data, distill.make_loss(lam=0.11), **kw)
    assert engine._cache_size() == misses   # no fresh compilation


# ---------------------------------------------------------------------------
# fused scan-of-scans engine vs the epochwise parity oracle
# ---------------------------------------------------------------------------

def test_fused_matches_epochwise_on_trace_workloads():
    """The fused whole-fit engine must reproduce the per-epoch-loop engine
    EXACTLY on the stored-trace workloads: same early-stop epoch count,
    same step count, float-identical histories and best-val params (both
    paths run the identical per-epoch computation; only the early-stop
    bookkeeping moved on device)."""
    for name, (params, data, kw) in _trace_runs().items():
        fused = training.train(params, data, ae.recon_loss, **kw)
        loop = training.train_epochwise(params, data, ae.recon_loss, **kw)
        assert fused.epochs_run == loop.epochs_run, name
        assert fused.steps_run == loop.steps_run, name
        np.testing.assert_allclose(fused.train_loss, loop.train_loss,
                                   rtol=1e-6, err_msg=name)
        np.testing.assert_allclose(fused.val_loss, loop.val_loss,
                                   rtol=1e-6, err_msg=name)
        assert _max_leaf_diff(fused.params, loop.params) < 1e-6, name


def test_fused_matches_epochwise_early_stop():
    """Early-stop epoch counts agree on a genuinely-stopping workload."""
    params, data = _toy(n=64, d=4)
    kw = dict(batch_size=16, max_epochs=50, patience=3, lr=0.0, seed=0)
    fused = training.train(params, data, ae.recon_loss, **kw)
    loop = training.train_epochwise(params, data, ae.recon_loss, **kw)
    assert fused.epochs_run == loop.epochs_run == 1 + 3
    assert _max_leaf_diff(fused.params, loop.params) == 0.0


def test_fused_lanes_match_epochwise_lanes():
    """train_lanes (fused) vs train_lanes_epochwise on uneven lanes:
    exact epoch counts, float-identical params and histories per lane."""
    specs = []
    for i, (n, d) in enumerate([(120, 6), (90, 4), (150, 5)]):
        x = np.random.RandomState(10 + i).randn(n, d).astype(np.float32)
        p = ae.init_autoencoder(jax.random.PRNGKey(20 + i), [d, 8, 4])
        specs.append(training.LaneSpec(p, {"x": x}, seed=i))
    kw = dict(batch_size=16, max_epochs=25, patience=4)
    fused = training.train_lanes(specs, ae.masked_recon_loss, **kw)
    loop = training.train_lanes_epochwise(specs, ae.masked_recon_loss, **kw)
    for i, (f, l) in enumerate(zip(fused, loop)):
        assert f.epochs_run == l.epochs_run, i
        assert f.steps_run == l.steps_run, i
        np.testing.assert_allclose(f.train_loss, l.train_loss, rtol=1e-6)
        np.testing.assert_allclose(f.val_loss, l.val_loss, rtol=1e-6)
        assert _max_leaf_diff(f.params, l.params) < 1e-6, i


def test_fused_fit_is_single_dispatch(monkeypatch):
    """<=1 host sync per fit: the whole fit goes through exactly one call
    of the fused engine (the epoch loop lives inside the jitted scan)."""
    params, data = _toy(n=120, d=5)
    calls = []
    real = training.get_fit_engine

    def spy(loss_fn, *, lr=1e-3):
        engine = real(loss_fn, lr=lr)

        def wrapped(*a, **k):
            calls.append(k.get("max_epochs"))
            return engine(*a, **k)
        return wrapped

    monkeypatch.setattr(training, "get_fit_engine", spy)
    r = training.train(params, data, ae.recon_loss, batch_size=32,
                       max_epochs=9, patience=99, seed=0)
    assert r.epochs_run == 9
    assert calls == [9]


def test_fused_lanes_fit_is_single_dispatch(monkeypatch):
    params, data = _toy(n=120, d=5)
    calls = []
    real = training.get_lanes_fit_engine

    def spy(loss_fn, *, lr=1e-3):
        engine = real(loss_fn, lr=lr)

        def wrapped(*a, **k):
            calls.append(k.get("max_epochs"))
            return engine(*a, **k)
        return wrapped

    monkeypatch.setattr(training, "get_lanes_fit_engine", spy)
    rs = training.train_lanes(
        [training.LaneSpec(params, data, 0),
         training.LaneSpec(params, data, 1)],
        ae.masked_recon_loss, batch_size=32, max_epochs=7, patience=99)
    assert [r.epochs_run for r in rs] == [7, 7]
    assert calls == [7]


# ---------------------------------------------------------------------------
# comm: wire size follows the dtype, analytic formulas stay float32
# ---------------------------------------------------------------------------

def test_send_array_uses_dtype_itemsize():
    ch = comm.Channel()
    ch.send_array("f32", np.zeros((10, 3), np.float32))
    ch.send_array("f64", np.zeros((10, 3), np.float64))
    ch.send_array("f16", jnp.zeros((8,), jnp.float16))
    assert ch.log[0][1] == 30 * 4
    assert ch.log[1][1] == 30 * 8
    assert ch.log[2][1] == 8 * 2
