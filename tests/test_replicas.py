"""Replica-lane execution tests: vmapped-seed parity against the
sequential per-seed path (pipeline + sweep layers), the vmapped k-fold
classifier, the bincount f1 rewrite, the lanes distill loss, and the
``supports_replicas`` registry surface.

Tolerance discipline (same as ``test_train_many``): engine-level outputs
(params, epoch counts, comm bytes) match exactly or to float tolerance;
downstream metrics get a CV-noise band (0.03) because the linear probe
amplifies float-level z differences near its decision boundary — the PR-2
precedent for vmapped-vs-sequential protocol comparisons.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autoencoder as ae
from repro.core import classifier as clf
from repro.core import distill
from repro.core import pipeline
from repro.experiments import (ExperimentSpec, MethodSpec, get_method,
                               register_replicas, sweep)
from repro.experiments.registry import MethodEntry
from repro.experiments.specs import ScenarioSpec
from repro.experiments.sweeps import build_scenario

METRIC_TOL = 0.03     # CV-noise band for probe metrics (module docstring)


def _max_leaf_diff(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# metrics: bincount f1 + vmapped k-fold
# ---------------------------------------------------------------------------

def _f1_scores_loop(y_true, y_pred, n_classes):
    """The pre-vectorization implementation (4 passes per class), kept
    here as the parity reference for the bincount rewrite."""
    tp = np.zeros(n_classes)
    fp = np.zeros(n_classes)
    fn = np.zeros(n_classes)
    support = np.zeros(n_classes)
    for c in range(n_classes):
        tp[c] = np.sum((y_pred == c) & (y_true == c))
        fp[c] = np.sum((y_pred == c) & (y_true != c))
        fn[c] = np.sum((y_pred != c) & (y_true == c))
        support[c] = np.sum(y_true == c)
    denom = 2 * tp + fp + fn
    f1c = np.where(denom > 0, 2 * tp / np.maximum(denom, 1), 0.0)
    micro_d = 2 * tp.sum() + fp.sum() + fn.sum()
    return {
        "accuracy": float(np.mean(y_true == y_pred)),
        "f1_micro": float(2 * tp.sum() / micro_d) if micro_d else 0.0,
        "f1_macro": float(np.mean(f1c)),
        "f1_weighted": float(np.sum(f1c * support) / max(support.sum(), 1)),
        "f1_binary": float(f1c[1]) if n_classes == 2 else float(np.mean(f1c)),
    }


@pytest.mark.parametrize("n_classes", [2, 4])
def test_f1_scores_bincount_matches_loop(n_classes):
    rng = np.random.RandomState(0)
    y_true = rng.randint(0, n_classes, 400)
    y_pred = rng.randint(0, n_classes, 400)
    got = clf.f1_scores(y_true, y_pred, n_classes)
    want = _f1_scores_loop(y_true, y_pred, n_classes)
    assert got.keys() == want.keys()
    for k in want:
        assert abs(got[k] - want[k]) < 1e-12, k


def test_f1_scores_empty_class():
    """A class absent from both y_true and y_pred gets f1=0 (not NaN) in
    both implementations."""
    y = np.array([0, 0, 1, 1])
    p = np.array([0, 1, 1, 0])
    got = clf.f1_scores(y, p, 3)
    want = _f1_scores_loop(y, p, 3)
    for k in want:
        assert abs(got[k] - want[k]) < 1e-12 and np.isfinite(got[k])


def test_kfold_cv_matches_per_fold_reference():
    """The single-jit vmapped k-fold (zero-weight-padded folds) must match
    k sequential fit_logreg fits on the identical fold assignment."""
    rng = np.random.RandomState(1)
    x = rng.randn(317, 6).astype(np.float32)   # 317 % 10 != 0: uneven folds
    y = (x[:, 0] + 0.5 * x[:, 1] + 0.2 * rng.randn(317) > 0).astype(np.int64)
    got = clf.kfold_cv(x, y, 2, k=10, seed=3)

    perm = np.random.RandomState(3).permutation(len(x))
    folds = np.array_split(perm, 10)
    accs = []
    for i in range(10):
        te = folds[i]
        tr = np.concatenate([folds[j] for j in range(10) if j != i])
        params = clf.fit_logreg(jnp.asarray(x[tr]), jnp.asarray(y[tr]), 2)
        pred = clf.predict(params, x[te])
        accs.append(clf.f1_scores(y[te], pred, 2))
    want = {k: float(np.mean([a[k] for a in accs])) for k in accs[0]}
    for k in want:
        assert abs(got[k] - want[k]) < 0.01, (k, got[k], want[k])


def test_kfold_cv_many_matches_per_seed():
    rng = np.random.RandomState(2)
    xs = [rng.randn(143, 5).astype(np.float32) for _ in range(3)]
    ys = [(x[:, 0] > 0).astype(np.int64) for x in xs]
    many = clf.kfold_cv_many(xs, ys, 2, k=5, seeds=[4, 5, 6])
    for x, y, s, got in zip(xs, ys, [4, 5, 6], many):
        want = clf.kfold_cv(x, y, 2, k=5, seed=s)
        for k in want:
            assert abs(got[k] - want[k]) < 0.01, (s, k)


# ---------------------------------------------------------------------------
# lanes distill loss
# ---------------------------------------------------------------------------

def test_make_lanes_loss_equals_make_loss_without_padding():
    key = jax.random.PRNGKey(0)
    params = ae.init_autoencoder(key, [8, 16, 4])
    x = jax.random.normal(key, (32, 8))
    batch = {"x": x, "z_teacher": jax.random.normal(key, (32, 4)),
             "aligned": (jax.random.uniform(key, (32,)) > 0.4).astype(
                 jnp.float32)}
    a = float(distill.make_loss(lam=0.5, kind="mae")(params, batch))
    b = float(distill.make_lanes_loss(lam=0.5, kind="mae")(
        params, {**batch, "mask": jnp.ones((8,)),
                 "row_w": jnp.ones((32,))}))
    assert abs(a - b) < 1e-6


@pytest.mark.parametrize("kind", ["mse", "mae"])
def test_make_lanes_loss_kernel_matches_reference(kind):
    """The fused-kernel lanes loss (Pallas fwd + closed-form VJP) must
    equal the reference lanes formula in value AND gradient, including
    under feature padding and zero-weight rows (the masked-column rescale
    trick is exact for 0/1 masks)."""
    key = jax.random.PRNGKey(7)
    params = ae.init_autoencoder(key, [10, 16, 4])
    x = jax.random.normal(key, (24, 10))
    fm = jnp.asarray([1.0] * 7 + [0.0] * 3)          # 3 padded columns
    rw = jnp.asarray([1.0] * 20 + [0.0] * 4)         # 4 padded rows
    batch = {"x": x * fm, "z_teacher": jax.random.normal(key, (24, 4)),
             "aligned": (jax.random.uniform(key, (24,)) > 0.4).astype(
                 jnp.float32),
             "mask": fm, "row_w": rw}
    ref_fn = distill.make_lanes_loss(lam=0.3, kind=kind)
    ker_fn = distill.make_lanes_loss(lam=0.3, kind=kind, use_kernel=True)
    vr, gr = jax.value_and_grad(ref_fn)(params, batch)
    vk, gk = jax.value_and_grad(ker_fn)(params, batch)
    assert abs(float(vr) - float(vk)) < 1e-6
    assert _max_leaf_diff(gr, gk) < 1e-5
    assert ref_fn.cache_key != ker_fn.cache_key    # distinct engines


# ---------------------------------------------------------------------------
# replicated pipeline parity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def replica_cells():
    seeds = [0, 1]
    scs = [build_scenario(ScenarioSpec(dataset="bcw", n_aligned=120,
                                       n_active_features=5, seed=s))
           for s in seeds]
    return scs, seeds


def test_run_apcvfl_replicated_matches_sequential(replica_cells):
    scs, seeds = replica_cells
    kw = dict(max_epochs=3)
    seq = [pipeline.run_apcvfl(sc, seed=s, **kw)
           for sc, s in zip(scs, seeds)]
    rep = pipeline.run_apcvfl_replicated(scs, seeds=seeds, **kw)
    assert [r.seed for r in rep] == seeds
    for a, b in zip(seq, rep):
        # engine-level guarantees are exact / float-tolerance
        assert a.epochs == b.epochs
        assert a.comm == b.comm
        assert a.rounds == b.rounds and a.z_dim == b.z_dim
        assert _max_leaf_diff(a.params["g3"], b.params["g3"]) < 1e-4
        for k in a.metrics:
            assert abs(a.metrics[k] - b.metrics[k]) < METRIC_TOL, (k,)


def test_run_apcvfl_replicated_single_shared_scenario(replica_cells):
    """One scenario shared by every seed is the documented sugar; the
    seeds still differentiate init/splits so results differ."""
    scs, _ = replica_cells
    rep = pipeline.run_apcvfl_replicated(scs[0], seeds=[0, 1], max_epochs=2)
    assert len(rep) == 2
    assert rep[0].metrics != rep[1].metrics or \
        rep[0].epochs != rep[1].epochs


def test_aligned_only_replicated_matches_sequential(replica_cells):
    scs, seeds = replica_cells
    kw = dict(max_epochs=3, test_size=30)
    seq = [pipeline.run_apcvfl_aligned_only(sc, seed=s, **kw)
           for sc, s in zip(scs, seeds)]
    rep = pipeline.run_apcvfl_aligned_only_replicated(scs, seeds=seeds,
                                                      **kw)
    for a, b in zip(seq, rep):
        assert a.epochs == b.epochs and a.comm == b.comm
        assert _max_leaf_diff(a.params["g2"], b.params["g2"]) < 1e-4
        for k in a.metrics:
            assert abs(a.metrics[k] - b.metrics[k]) < METRIC_TOL, (k,)


def test_replicated_seed_scenario_count_mismatch_raises(replica_cells):
    scs, _ = replica_cells
    with pytest.raises(ValueError, match="scenarios for"):
        pipeline.run_apcvfl_replicated(scs, seeds=[0], max_epochs=2)


def test_run_apcvfl_replicated_use_kernel_runs_lanes(replica_cells):
    """ROADMAP follow-up: use_kernel seed groups used to fall back to
    sequential protocol runs.  With the kernel's custom VJP the lanes
    path trains the fused Eq. 5 directly and must match the sequential
    kernel path like any other replica run."""
    scs, seeds = replica_cells
    kw = dict(max_epochs=2, use_kernel=True)
    rep = pipeline.run_apcvfl_replicated(scs, seeds=seeds, **kw)
    seq = [pipeline.run_apcvfl(sc, seed=s, **kw)
           for sc, s in zip(scs, seeds)]
    for a, b in zip(seq, rep):
        assert a.epochs == b.epochs and a.comm == b.comm
        assert _max_leaf_diff(a.params["g3"], b.params["g3"]) < 1e-4
        for k in a.metrics:
            assert abs(a.metrics[k] - b.metrics[k]) < METRIC_TOL, (k,)


# ---------------------------------------------------------------------------
# K-party replica lanes (ROADMAP follow-up: run_apcvfl_k seed groups)
# ---------------------------------------------------------------------------

def test_run_apcvfl_k_replicated_matches_sequential():
    from repro.core import multiparty
    from repro.data.synthetic import make_dataset
    ds = make_dataset("bcw", seed=0)
    sc = multiparty.make_scenario_k(ds, n_parties=3, n_active_features=5,
                                    n_aligned=120, seed=0)
    seeds = [0, 1]
    seq = [multiparty.run_apcvfl_k(sc, seed=s, max_epochs=2)
           for s in seeds]
    rep = multiparty.run_apcvfl_k_replicated(sc, seeds=seeds, max_epochs=2)
    assert [r.seed for r in rep] == seeds
    for a, b in zip(seq, rep):
        assert a.epochs == b.epochs           # incl. per-passive g1 lanes
        assert a.comm == b.comm               # K-1 links, byte-identical
        assert a.rounds == b.rounds and a.z_dim == b.z_dim
        assert _max_leaf_diff(a.params["g3"], b.params["g3"]) < 1e-4
        for k in a.metrics:
            assert abs(a.metrics[k] - b.metrics[k]) < METRIC_TOL, (k,)


def test_sweep_kparty_seed_groups_use_replica_dispatch(monkeypatch):
    """A K>2 seed group must route through run_apcvfl_k_replicated (one
    lanes dispatch), not the sequential per-seed fallback."""
    from repro.core import multiparty
    calls = {"n": 0}
    real = multiparty.run_apcvfl_k_replicated

    def spy(*a, **k):
        calls["n"] += 1
        return real(*a, **k)

    monkeypatch.setattr(multiparty, "run_apcvfl_k_replicated", spy)
    spec = ExperimentSpec(
        name="k-replica", dataset="bcw", aligned=(120,), n_parties=(3,),
        seeds=(0, 1), methods=(MethodSpec("apcvfl"),),
        overrides={"max_epochs": 1})
    results = sweep(spec)
    assert calls["n"] == 1                     # whole group, one dispatch
    assert [r.seed for r in results] == [0, 1]


# ---------------------------------------------------------------------------
# sweep-layer parity: the acceptance grid (2 methods x 2 aligned x 3 seeds)
# ---------------------------------------------------------------------------

def test_sweep_replicated_matches_sequential_acceptance_grid():
    spec = ExperimentSpec(
        name="replica-parity", dataset="bcw", aligned=(120, 100),
        seeds=(0, 1, 2),
        methods=(MethodSpec("local"), MethodSpec("apcvfl")),
        overrides={"max_epochs": 2})
    rep = sweep(spec)
    seq = sweep(dataclasses.replace(spec, replicate=False))
    # identical run order and coordinates regardless of dispatch path
    assert [(r.method, r.seed, tuple(sorted(r.scenario.items())))
            for r in rep] == \
           [(r.method, r.seed, tuple(sorted(r.scenario.items())))
            for r in seq]
    for a, b in zip(rep, seq):
        assert a.comm == b.comm and a.epochs == b.epochs
        for k in a.metrics:
            assert abs(a.metrics[k] - b.metrics[k]) < METRIC_TOL, \
                (a.method, a.seed, k)


# ---------------------------------------------------------------------------
# registry surface
# ---------------------------------------------------------------------------

def test_supports_replicas_flags():
    assert get_method("apcvfl").supports_replicas
    assert get_method("apcvfl_aligned_only").supports_replicas
    for name in ("local", "splitnn", "vfedtrans", "inversion"):
        assert not get_method(name).supports_replicas


def test_register_replicas_errors():
    with pytest.raises(KeyError, match="not registered"):
        register_replicas("no_such_method")(lambda *a, **k: [])
    with pytest.raises(ValueError, match="already has a replicated"):
        register_replicas("apcvfl")(lambda *a, **k: [])
    # the entry stays frozen data
    assert isinstance(get_method("apcvfl"), MethodEntry)


def test_replicated_runner_result_count_checked(monkeypatch):
    """A replicated runner returning the wrong number of results is a
    loud error, not silently misattributed seeds."""
    import repro.experiments.registry as reg
    entry = reg._REGISTRY["apcvfl"]
    monkeypatch.setitem(
        reg._REGISTRY, "apcvfl",
        dataclasses.replace(entry, replicated_fn=lambda sc, m, seeds: []))
    spec = ExperimentSpec(
        name="bad-rep", dataset="bcw", aligned=(100,), seeds=(0, 1),
        methods=(MethodSpec("apcvfl"),), overrides={"max_epochs": 1})
    with pytest.raises(RuntimeError, match="returned 0 results"):
        sweep(spec)


# ---------------------------------------------------------------------------
# the inversion attack as a registered method
# ---------------------------------------------------------------------------

def test_inversion_method_runs_from_spec():
    spec = ExperimentSpec(
        name="privacy", dataset="bcw", aligned=(150,), seeds=(0,),
        methods=(MethodSpec("inversion", params={"n_aux": 30}),
                 MethodSpec("inversion", label="inversion-rich",
                            params={"n_aux": 300})),
        overrides={"max_epochs": 20})
    results = sweep(spec)
    assert [r.method for r in results] == ["inversion", "inversion-rich"]
    for r in results:
        assert {"r2_mean", "attack_mse", "baseline_mse"} <= set(r.metrics)
        assert r.rounds == 1                  # rides on the one exchange
        assert r.comm["uplink_bytes"] > 0
    # more auxiliary pairs leak at least as much (paper-sharpening claim)
    assert results[1].metrics["r2_mean"] >= results[0].metrics["r2_mean"]
