"""Mesh-sharded lane engine tests: lane-mesh construction and validation,
sharded-vs-unsharded ``train_lanes`` parity (lane axis, row axis, and the
replicated pipeline on top), the ``ExperimentSpec.devices`` dispatch path,
and the streaming scale generator.

Multi-device tests are marked ``needs_devices(n)`` and auto-skip on the
default 1-device CPU; CI's multidevice job runs them under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.  Parity bands
follow ``tests/test_replicas.py``: engine-level outputs exact / float
tolerance, probe metrics a 0.03 CV-noise band.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autoencoder as ae
from repro.core import pipeline, training
from repro.core.training import LaneSpec
from repro.data import scale
from repro.experiments import ExperimentSpec, MethodSpec, sweep
from repro.experiments.specs import ScenarioSpec
from repro.experiments.sweeps import build_scenario
from repro.launch import mesh as meshlib

METRIC_TOL = 0.03


def _max_leaf_diff(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# mesh construction + validation
# ---------------------------------------------------------------------------

def test_make_lane_mesh_axis_names():
    m = meshlib.make_lane_mesh(lane=1, data=1)
    assert m.axis_names == ("lane", "data")


def test_make_lane_mesh_too_many_devices_names_the_fix():
    want = jax.device_count() * 2
    with pytest.raises(ValueError) as ei:
        meshlib.make_lane_mesh(lane=want)
    msg = str(ei.value)
    assert f"needs {want} devices" in msg
    assert f"xla_force_host_platform_device_count={want}" in msg


def test_make_local_mesh_too_many_devices():
    with pytest.raises(ValueError, match="needs"):
        meshlib.make_local_mesh(data=jax.device_count() * 2)


@pytest.mark.parametrize("bad", [0, -1, 1.5, "2", None])
def test_make_lane_mesh_rejects_non_positive_axes(bad):
    with pytest.raises(ValueError, match="positive int"):
        meshlib.make_lane_mesh(lane=bad)


# ---------------------------------------------------------------------------
# sharded train_lanes parity
# ---------------------------------------------------------------------------

def _uneven_lanes(n_lanes=3):
    """Lanes with different row counts and widths — exercises both the
    per-lane zero padding and (on a mesh) the lane-axis padding to a
    device multiple (3 real lanes on a 4-device lane axis)."""
    rng = np.random.RandomState(0)
    shapes = [(120, 6), (90, 4), (150, 5)][:n_lanes]
    lanes = []
    for i, (n, d) in enumerate(shapes):
        x = jnp.asarray(rng.randn(n, d).astype(np.float32))
        params = ae.init_autoencoder(jax.random.PRNGKey(10 + i),
                                     [d, 8, 4])
        lanes.append(LaneSpec(params, {"x": x}, seed=i))
    return lanes


def _assert_lane_results_match(a, b, *, tol=1e-6):
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        assert ra.epochs_run == rb.epochs_run
        assert ra.steps_run == rb.steps_run
        np.testing.assert_allclose(ra.train_loss, rb.train_loss,
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(ra.val_loss, rb.val_loss,
                                   rtol=1e-6, atol=1e-7)
        assert _max_leaf_diff(ra.params, rb.params) < tol


@pytest.mark.needs_devices(4)
def test_train_lanes_sharded_matches_unsharded():
    """Same jitted engine, inputs device_put across a 4-device lane axis
    (3 real lanes -> 1 dead padded lane): results must match the
    single-device run to float tolerance."""
    kw = dict(batch_size=16, max_epochs=6, patience=4, lr=1e-3)
    base = training.train_lanes(_uneven_lanes(), ae.masked_recon_loss,
                                **kw)
    m = meshlib.make_lane_mesh(lane=4)
    sharded = training.train_lanes(_uneven_lanes(), ae.masked_recon_loss,
                                   mesh=m, **kw)
    _assert_lane_results_match(base, sharded)


@pytest.mark.needs_devices(4)
def test_train_lanes_kernel_path_mesh_parity():
    """The fused lane-MLP kernel path (use_kernel=True; Pallas interpret
    mode on CPU) must shard across a 4-device lane mesh with the same
    parity as the jnp path: the vmap-prepended lane grid has to survive
    shard_map partitioning, dead padded lanes included."""
    kw = dict(batch_size=16, max_epochs=4, patience=3, lr=1e-3)
    loss = ae.make_masked_recon_loss(use_kernel=True)
    base = training.train_lanes(_uneven_lanes(), loss, **kw)
    m = meshlib.make_lane_mesh(lane=4)
    sharded = training.train_lanes(_uneven_lanes(), loss, mesh=m, **kw)
    _assert_lane_results_match(base, sharded)


@pytest.mark.needs_devices(4)
@pytest.mark.parametrize("rows", [128, 130])
def test_train_lanes_row_sharded_parity(rows):
    """lane=2 x data=2 with shard_rows: 128 rows divide the data axis,
    130 don't (policy._divisible silently drops row sharding) — parity
    must hold either way."""
    rng = np.random.RandomState(1)
    lanes = [LaneSpec(ae.init_autoencoder(jax.random.PRNGKey(20 + i),
                                          [6, 8, 4]),
                      {"x": jnp.asarray(
                          rng.randn(rows, 6).astype(np.float32))},
                      seed=i)
             for i in range(2)]
    kw = dict(batch_size=16, max_epochs=4, patience=3, lr=1e-3)
    base = training.train_lanes(lanes, ae.masked_recon_loss, **kw)
    m = meshlib.make_lane_mesh(lane=2, data=2)
    sharded = training.train_lanes(lanes, ae.masked_recon_loss, mesh=m,
                                   shard_rows=True, **kw)
    _assert_lane_results_match(base, sharded)


@pytest.mark.needs_devices(4)
def test_run_apcvfl_replicated_mesh_parity():
    """The whole protocol through a lane mesh: engine-level outputs exact,
    probe metrics within the replica CV band (test_replicas discipline)."""
    seeds = [0, 1]
    scs = [build_scenario(ScenarioSpec(dataset="bcw", n_aligned=120,
                                       n_active_features=5, seed=s))
           for s in seeds]
    kw = dict(max_epochs=3)
    base = pipeline.run_apcvfl_replicated(scs, seeds=seeds, **kw)
    m = meshlib.make_lane_mesh(lane=4)
    meshed = pipeline.run_apcvfl_replicated(scs, seeds=seeds, mesh=m, **kw)
    for a, b in zip(base, meshed):
        assert a.epochs == b.epochs
        assert a.comm == b.comm
        assert a.rounds == b.rounds and a.z_dim == b.z_dim
        assert _max_leaf_diff(a.params["g3"], b.params["g3"]) < 1e-4
        for k in a.metrics:
            assert abs(a.metrics[k] - b.metrics[k]) < METRIC_TOL, (k,)


# ---------------------------------------------------------------------------
# ExperimentSpec.devices dispatch
# ---------------------------------------------------------------------------

def test_spec_devices_json_roundtrip():
    spec = ExperimentSpec(name="m", methods=(MethodSpec("apcvfl"),),
                          devices={"lane": 2, "data": 2})
    back = ExperimentSpec.from_json(spec.to_json())
    assert back == spec and back.devices == {"lane": 2, "data": 2}


def test_spec_devices_unknown_axis_rejected():
    spec = ExperimentSpec(name="bad", methods=(MethodSpec("local"),),
                          devices={"model": 2})
    with pytest.raises(ValueError, match="unknown mesh axes"):
        sweep(spec)


def test_spec_devices_non_positive_rejected():
    spec = ExperimentSpec(name="bad", methods=(MethodSpec("local"),),
                          devices={"lane": 0})
    with pytest.raises(ValueError, match="positive int"):
        sweep(spec)


def test_spec_devices_too_many_raises_before_any_run():
    """The mesh is built (and validated) before any scenario or model —
    a device shortfall fails fast with the XLA_FLAGS recipe."""
    spec = ExperimentSpec(
        name="big", methods=(MethodSpec("apcvfl"),),
        devices={"lane": jax.device_count() * 2})
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        sweep(spec)


def test_sweep_threads_mesh_into_replicated_runner(monkeypatch):
    """devices={} keeps legacy runner signatures working; a non-empty
    devices dict delivers the built mesh as mesh= to the runner."""
    import repro.experiments.registry as reg
    from repro.experiments.registry import get_method
    from repro.experiments.results import RunResult

    get_method("apcvfl")                       # force adapter registration
    seen = {}

    def spy(scenarios, mspec, *, seeds, mesh=None):
        seen["mesh"] = mesh
        return [RunResult(method="apcvfl", metrics={}, rounds=0,
                          seed=s) for s in seeds]

    entry = reg._REGISTRY["apcvfl"]
    monkeypatch.setitem(reg._REGISTRY, "apcvfl",
                        dataclasses.replace(entry, replicated_fn=spy))
    spec = ExperimentSpec(name="spy", dataset="bcw", aligned=(100,),
                          seeds=(0, 1), methods=(MethodSpec("apcvfl"),),
                          devices={"lane": 1})
    sweep(spec)
    assert seen["mesh"] is not None
    assert seen["mesh"].axis_names == ("lane", "data")

    def legacy(scenarios, mspec, seeds):       # no mesh kwarg at all
        seen["legacy"] = True
        return [RunResult(method="apcvfl", metrics={}, rounds=0,
                          seed=s) for s in seeds]

    monkeypatch.setitem(reg._REGISTRY, "apcvfl",
                        dataclasses.replace(entry, replicated_fn=legacy))
    sweep(dataclasses.replace(spec, devices={}))
    assert seen.get("legacy")


# ---------------------------------------------------------------------------
# streaming scale generator
# ---------------------------------------------------------------------------

def test_scale_party_shape_dtype_residency():
    x = scale.make_scale_party(1000, n_features=6, n_latent=4, seed=3)
    assert isinstance(x, jax.Array)
    assert x.shape == (1000, 6) and x.dtype == jnp.float32
    # approximately standardized by construction
    assert abs(float(x.mean())) < 0.1
    assert 0.7 < float(x.std()) < 1.3


def test_scale_party_deterministic_and_blocked():
    a = scale.make_scale_party(700, n_features=5, block_rows=256, seed=1)
    b = scale.make_scale_party(700, n_features=5, block_rows=256, seed=1)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = scale.make_scale_party(700, n_features=5, block_rows=256, seed=2)
    assert float(jnp.max(jnp.abs(a - c))) > 0.1


def test_scale_parties_share_latents():
    """Vertical partition semantics: with zero feature noise, party p's
    feature j and party p+1's feature j-1 read the same latent mix —
    identical columns prove all parties draw one shared z per row."""
    kw = dict(n_features=4, n_latent=4, noise=0.0, seed=5)
    p0 = scale.make_scale_party(300, party=0, **kw)
    p1 = scale.make_scale_party(300, party=1, **kw)
    np.testing.assert_allclose(np.asarray(p0[:, 1]), np.asarray(p1[:, 0]),
                               rtol=1e-6)
    assert float(jnp.max(jnp.abs(p0 - p1))) > 0.1   # views still differ


def test_scale_lanes_shapes_and_training():
    lanes = scale.make_scale_lanes(512, 2, n_features=6,
                                   widths=[6, 8, 4], seeds=(0, 1))
    assert len(lanes) == 4                     # parties x seeds
    assert all(lane.data["x"].shape == (512, 6) for lane in lanes)
    assert len({lane.seed for lane in lanes}) == 4
    rs = training.train_lanes(lanes, ae.masked_recon_loss, batch_size=128,
                              max_epochs=2, patience=2)
    assert len(rs) == 4
    for r in rs:
        assert r.epochs_run >= 1
        assert np.isfinite(r.train_loss).all()


def test_scale_lanes_width_mismatch_rejected():
    with pytest.raises(ValueError, match="must equal n_features"):
        scale.make_scale_lanes(64, 2, n_features=6, widths=[5, 8, 4])
