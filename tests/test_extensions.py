"""Tests for the beyond-paper extensions: K>2 participants, the serving
engine, prefill-with-cache, schedules/grad-accumulation, privacy attack."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.data.synthetic import make_dataset
from repro.models import model as M
from repro.sharding.policy import init_params


def test_multiparty_k3_single_round_per_link():
    from repro.core.multiparty import make_scenario_k, run_apcvfl_k
    ds = make_dataset("bcw", seed=2)
    sc = make_scenario_k(ds, n_parties=3, n_active_features=5,
                         n_aligned=150, seed=2)
    assert len(sc.passives) == 2
    # feature spaces disjoint and complete
    total = sc.active.x.shape[1] + sum(p.x.shape[1] for p in sc.passives)
    assert total == ds.x.shape[1]
    r = run_apcvfl_k(sc, max_epochs=6)
    for ch in r.channels:
        data = [w for w, _ in ch.log if w.startswith("step1")]
        assert len(data) == 1          # one exchange per passive link
    assert r.z_dim == 256
    assert 0 <= r.metrics["accuracy"] <= 1


def test_prefill_with_cache_matches_decode():
    from repro.models.transformer import decoder_prefill_with_cache
    cfg = get_smoke("yi-6b")
    key = jax.random.PRNGKey(0)
    params = init_params(M.schema(cfg), key, jnp.float32)
    B, S = 2, 10
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    lg, cache = decoder_prefill_with_cache(params, cfg, tokens, 16)
    full, _ = M.logits(params, cfg, {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, -1]),
                               atol=1e-4)
    nxt = jnp.argmax(lg, -1)
    lg2, _ = M.decode(params, cfg, nxt, cache, jnp.int32(S))
    full2, _ = M.logits(params, cfg,
                        {"tokens": jnp.concatenate([tokens, nxt[:, None]], 1)})
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(full2[:, -1]),
                               atol=1e-3)


def test_engine_completes_all_requests():
    from repro.serve.engine import Engine, Request
    cfg = get_smoke("internlm2-1.8b")
    params = init_params(M.schema(cfg), jax.random.PRNGKey(0), jnp.float32)
    eng = Engine(params, cfg, batch=2, n_slots=48, prefill_len=8)
    rng = np.random.RandomState(0)
    for rid in range(5):
        eng.submit(Request(rid, rng.randint(0, cfg.vocab_size, 6)
                           .astype(np.int32), max_new=4))
    stats = eng.run()
    assert stats.completed == 5
    assert stats.tokens_out >= 5 * 4
    assert stats.prefills == 5


def test_grad_accumulation_matches_full_batch():
    from repro.optim.schedule import accumulate_grads
    cfg = get_smoke("internlm2-1.8b")
    params = init_params(M.schema(cfg), jax.random.PRNGKey(0), jnp.float32)
    from repro.train.loop import task_loss
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                          0, cfg.vocab_size)}
    loss_fn = lambda p, b: task_loss(p, cfg, b)
    (l1, _), g1 = accumulate_grads(loss_fn, 1)(params, batch)
    (l2, _), g2 = accumulate_grads(loss_fn, 2)(params, batch)
    assert abs(float(l1) - float(l2)) < 1e-4
    a = jax.tree.leaves(g1)[0]
    b = jax.tree.leaves(g2)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_warmup_cosine_schedule_shape():
    from repro.optim.schedule import warmup_cosine
    lr = warmup_cosine(1e-3, 10, 100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.int32(100))) < 2e-4   # decayed near final_frac
    assert float(lr(jnp.int32(5))) < 1e-3     # mid-warmup


def test_inversion_attack_learns_with_aux_data():
    from repro.core.privacy import inversion_attack
    rng = np.random.RandomState(0)
    x = rng.randn(600, 6).astype(np.float32)
    w = rng.randn(6, 32).astype(np.float32)
    z = np.tanh(x @ w)                     # invertible-ish representation
    rep = inversion_attack(z, x, n_aux=300, max_epochs=60)
    assert rep.r2_mean > 0.5               # attacker succeeds with aux pairs
    rep_small = inversion_attack(z, x, n_aux=8, max_epochs=30)
    assert rep_small.r2_mean < rep.r2_mean  # less aux -> less leakage
