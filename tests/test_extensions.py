"""Tests for the beyond-paper extensions: K>2 participants, the serving
engine, prefill-with-cache, schedules/grad-accumulation, privacy attack."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.data.synthetic import make_dataset
from repro.models import model as M
from repro.sharding.policy import init_params


def test_multiparty_k3_single_round_per_link():
    from repro.core.multiparty import make_scenario_k, run_apcvfl_k
    ds = make_dataset("bcw", seed=2)
    sc = make_scenario_k(ds, n_parties=3, n_active_features=5,
                         n_aligned=150, seed=2)
    assert len(sc.passives) == 2
    # feature spaces disjoint and complete
    total = sc.active.x.shape[1] + sum(p.x.shape[1] for p in sc.passives)
    assert total == ds.x.shape[1]
    r = run_apcvfl_k(sc, max_epochs=6)
    for ch in r.channels:
        data = [t for t in ch.log if t.stage == "step1"]
        assert len(data) == 1          # one exchange per passive link
    assert r.z_dim == 256
    assert 0 <= r.metrics["accuracy"] <= 1
    # every g1 stage trained (batched engine reports per-party epochs)
    assert all(r.epochs[k] >= 1 for k in
               ("g1_active", "g1_passive0", "g1_passive1"))


def test_multiparty_psi_charges_full_active_upload():
    """Each PSI link is a real pairwise PSI: the active party uploads its
    FULL hashed ID set on every link, not the already-shrunk running
    intersection (which leaked the other links' results and under-counted
    bytes)."""
    from repro.core.multiparty import align_k, make_scenario_k
    ds = make_dataset("bcw", seed=3)
    sc = make_scenario_k(ds, n_parties=4, n_active_features=5,
                         n_aligned=100, seed=3)
    common, channels = align_k(sc.active.ids, [p.ids for p in sc.passives])
    for ch, p in zip(channels, sc.passives):
        by_name = {t.what: t.nbytes for t in ch.log}
        assert by_name["psi/hashes_a"] == len(sc.active.ids) * 32
        assert by_name["psi/hashes_b"] == len(p.ids) * 32
    # alignment itself is the global intersection: common ids at every party
    for p in sc.passives:
        assert set(common.tolist()) <= set(p.ids.tolist())
    assert set(common.tolist()) <= set(sc.active.ids.tolist())
    assert len(common) == sc.n_aligned


def test_multiparty_psi_bytes_monotone_in_k():
    """More links -> strictly more PSI traffic under faithful accounting."""
    from repro.core.multiparty import align_k, make_scenario_k
    ds = make_dataset("bcw", seed=4)
    totals = []
    for k in (2, 3, 4):
        sc = make_scenario_k(ds, n_parties=k, n_active_features=5,
                             n_aligned=100, seed=4)
        _, channels = align_k(sc.active.ids, [p.ids for p in sc.passives])
        totals.append(sum(ch.total_bytes for ch in channels))
    assert totals[0] < totals[1] < totals[2]


def test_prefill_with_cache_matches_decode():
    from repro.models.transformer import decoder_prefill_with_cache
    cfg = get_smoke("yi-6b")
    key = jax.random.PRNGKey(0)
    params = init_params(M.schema(cfg), key, jnp.float32)
    B, S = 2, 10
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    lg, cache = decoder_prefill_with_cache(params, cfg, tokens, 16)
    full, _ = M.logits(params, cfg, {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, -1]),
                               atol=1e-4)
    nxt = jnp.argmax(lg, -1)
    lg2, _ = M.decode(params, cfg, nxt, cache, jnp.int32(S))
    full2, _ = M.logits(params, cfg,
                        {"tokens": jnp.concatenate([tokens, nxt[:, None]], 1)})
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(full2[:, -1]),
                               atol=1e-3)


def test_engine_completes_all_requests():
    from repro.serve.engine import Engine, Request
    cfg = get_smoke("internlm2-1.8b")
    params = init_params(M.schema(cfg), jax.random.PRNGKey(0), jnp.float32)
    eng = Engine(params, cfg, batch=2, n_slots=48, prefill_len=8)
    rng = np.random.RandomState(0)
    for rid in range(5):
        eng.submit(Request(rid, rng.randint(0, cfg.vocab_size, 6)
                           .astype(np.int32), max_new=4))
    stats = eng.run()
    assert stats.completed == 5
    assert stats.tokens_out >= 5 * 4
    assert stats.prefills == 5


def test_grad_accumulation_matches_full_batch():
    from repro.optim.schedule import accumulate_grads
    cfg = get_smoke("internlm2-1.8b")
    params = init_params(M.schema(cfg), jax.random.PRNGKey(0), jnp.float32)
    from repro.train.loop import task_loss
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                          0, cfg.vocab_size)}
    loss_fn = lambda p, b: task_loss(p, cfg, b)
    (l1, _), g1 = accumulate_grads(loss_fn, 1)(params, batch)
    (l2, _), g2 = accumulate_grads(loss_fn, 2)(params, batch)
    assert abs(float(l1) - float(l2)) < 1e-4
    a = jax.tree.leaves(g1)[0]
    b = jax.tree.leaves(g2)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_warmup_cosine_schedule_shape():
    from repro.optim.schedule import warmup_cosine
    lr = warmup_cosine(1e-3, 10, 100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.int32(100))) < 2e-4   # decayed near final_frac
    assert float(lr(jnp.int32(5))) < 1e-3     # mid-warmup


def test_inversion_attack_learns_with_aux_data():
    from repro.core.privacy import inversion_attack
    rng = np.random.RandomState(0)
    x = rng.randn(600, 6).astype(np.float32)
    w = rng.randn(6, 32).astype(np.float32)
    z = np.tanh(x @ w)                     # invertible-ish representation
    rep = inversion_attack(z, x, n_aux=300, max_epochs=60)
    assert rep.r2_mean > 0.5               # attacker succeeds with aux pairs
    rep_small = inversion_attack(z, x, n_aux=8, max_epochs=30)
    assert rep_small.r2_mean < rep.r2_mean  # less aux -> less leakage
