"""(Re)generate the stored-trace oracle for the scan training engine.

Run from the repo root after an INTENTIONAL semantic change to the engine
(split, permutation, loss, or optimizer math)::

    PYTHONPATH=src:tests python tests/make_train_trace.py

The workloads replayed here are defined once, in
``tests/test_training_engine.py::_trace_runs`` — this script only records
what the engine produces, so generator and test can never drift apart.
"""
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent))

from test_training_engine import TRACE_PATH, _trace_runs  # noqa: E402

from repro.core import autoencoder as ae                  # noqa: E402
from repro.core import training                           # noqa: E402


def main() -> None:
    trace = {}
    for name, (params, data, kw) in _trace_runs().items():
        r = training.train(params, data, ae.recon_loss, **kw)
        trace[name] = {"epochs_run": r.epochs_run, "steps_run": r.steps_run,
                       "train_loss": r.train_loss, "val_loss": r.val_loss}
        print(f"{name}: {r.epochs_run} epochs, {r.steps_run} steps, "
              f"final val {r.val_loss[-1]:.6f}")
    TRACE_PATH.parent.mkdir(parents=True, exist_ok=True)
    TRACE_PATH.write_text(json.dumps(trace, indent=1) + "\n")
    print(f"wrote {TRACE_PATH}")


if __name__ == "__main__":
    main()
