"""Robustness & privacy subsystem tests (``repro.robustness``): exchange
transforms and their wire accounting, the sigma=0 bit-identity pin, the
attack registry's shared leakage schema, fault plans (JSON round-trip,
training-time injection, mid-stream serving injection), and the
``n_aux`` clamp-warning regression."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm, pipeline, privacy
from repro.experiments.specs import ScenarioSpec
from repro.experiments.sweeps import build_scenario
from repro.robustness import attacks, defense, faults
from repro.serve import runtime as rt
from repro.serve import vfl as sv

EPOCHS = 2          # subsystem correctness does not depend on convergence


@pytest.fixture(scope="module")
def sc():
    return build_scenario(ScenarioSpec(dataset="bcw", n_aligned=120,
                                       n_active_features=5, seed=0))


@pytest.fixture(scope="module")
def base_run(sc):
    return pipeline.run_apcvfl(sc, seed=0, max_epochs=EPOCHS)


def _trees_equal(a, b) -> bool:
    return jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda x, y: bool(jnp.array_equal(x, y)), a, b))


# ---------------------------------------------------------------------------
# defense transforms
# ---------------------------------------------------------------------------

def test_make_transform_identity_when_all_off():
    assert defense.make_transform() is None
    assert defense.make_transform(sigma=0.0, clip=None, quantize=None) is None
    t = defense.make_transform(sigma=1.0, quantize="int8")
    assert isinstance(t, defense.Chain) and len(t.stages) == 2
    assert isinstance(defense.make_transform(sigma=1.0),
                      defense.ClippedNoise)
    assert isinstance(defense.make_transform(quantize="sign"),
                      defense.Quantize)
    with pytest.raises(ValueError, match="mechanism"):
        defense.make_transform(sigma=1.0, mechanism="uniform")
    with pytest.raises(ValueError, match="quantize mode"):
        defense.make_transform(quantize="int4")
    with pytest.raises(ValueError, match="clip must be positive"):
        defense.make_transform(clip=-1.0)


def test_apcvfl_dp_sigma0_bit_identical_to_plain(sc, base_run):
    """The satellite pin: every defense off means the EXACT undefended
    code path — params, metrics, and comm accounting all bit-equal."""
    dp = defense.run_apcvfl_dp(sc, sigma=0.0, seed=0, max_epochs=EPOCHS)
    assert dp.method == "apcvfl_dp"
    assert _trees_equal(base_run.params, dp.params)
    for k, v in base_run.metrics.items():
        assert dp.metrics[k] == v
    assert dp.comm == base_run.comm       # bytes, stages, dtypes identical
    assert dp.metrics["dp_sigma"] == 0.0
    assert dp.metrics["exchange_bytes"] \
        == base_run.comm["by_stage"]["step1"]


def test_clipped_noise_clips_and_is_seed_deterministic():
    z = jnp.asarray(np.random.RandomState(0).randn(32, 8) * 5.0,
                    jnp.float32)
    clip_only = defense.ClippedNoise(sigma=0.0, clip=1.0)
    ch = comm.Channel()
    out = clip_only.exchange(ch, "step1/Z", z, seed=3)
    norms = np.linalg.norm(np.asarray(out), axis=1)
    assert np.all(norms <= 1.0 + 1e-5)
    assert ch.summary()["by_dtype"] == {"float32": 32 * 8 * 4}

    noisy = defense.ClippedNoise(sigma=1.0, clip=1.0)
    a = noisy.exchange(comm.Channel(), "step1/Z", z, seed=3)
    b = noisy.exchange(comm.Channel(), "step1/Z", z, seed=3)
    assert np.array_equal(np.asarray(a), np.asarray(b))   # seeded
    c = noisy.exchange(comm.Channel(), "step1/Z", z, seed=4)
    d = noisy.exchange(comm.Channel(), "step1/Z", z, seed=3, link=1)
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    assert not np.array_equal(np.asarray(a), np.asarray(d))  # per-link


def test_quantize_wire_bytes_and_dtypes():
    z = jnp.asarray(np.random.RandomState(1).randn(10, 4), jnp.float32)
    ch = comm.Channel()
    out = defense.Quantize("int8").exchange(ch, "step1/Z", z, seed=0)
    s = ch.summary()
    assert s["by_dtype"] == {"int8": 10 * 4, "float32": 4 * 4}
    assert s["total_bytes"] == 40 + 16      # 4x smaller than 160 fp32
    # dequantized output is close and fp32
    assert out.dtype == jnp.float32
    assert float(jnp.max(jnp.abs(out - z))) < float(jnp.max(jnp.abs(z))) / 64

    ch2 = comm.Channel()
    out2 = defense.Quantize("sign").exchange(ch2, "step1/Z", z, seed=0)
    s2 = ch2.summary()
    assert s2["by_dtype"] == {"sign1": 5, "float32": 16}   # ceil(40/8)
    assert np.array_equal(np.sign(np.asarray(out2)), np.sign(np.asarray(z)))


def test_exchange_array_and_normalize_contract():
    ch = comm.Channel()
    z = jnp.ones((6, 2), jnp.float32)
    got = comm.exchange_array(ch, "step1/Z", z)     # transform=None: as-is
    assert got is z
    assert ch.summary()["by_dtype"] == {"float32": 48}
    t = defense.Quantize("int8")
    assert comm.normalize_exchange(None, 3) == [None, None, None]
    assert comm.normalize_exchange(t, 2) == [t, t]
    assert comm.normalize_exchange([None, t], 2) == [None, t]
    with pytest.raises(ValueError, match="exchange"):
        comm.normalize_exchange([t], 2)


def test_dp_frontier_lanes_match_sequential(sc):
    """Per-lane exchange keys derive from the SEED, so a defended lane of
    the replicated path reproduces the sequential defended run."""
    seq = defense.run_apcvfl_dp(sc, sigma=2.0, clip=1.0, seed=0,
                                max_epochs=EPOCHS)
    lanes = defense.dp_frontier(sc, [0.0, 2.0], clip=1.0, seed=0,
                                max_epochs=EPOCHS)
    assert [r.metrics["dp_sigma"] for r in lanes] == [0.0, 2.0]
    # comm accounting is exact (eager bookkeeping, not lane-padded)
    assert lanes[1].comm == seq.comm
    for r in lanes:
        assert r.method == "apcvfl_dp"
        assert 0.0 <= r.metrics["accuracy"] <= 1.0
    # defended lane tracks the sequential defended run's metrics within
    # replica-lane tolerance
    assert lanes[1].metrics["accuracy"] == pytest.approx(
        seq.metrics["accuracy"], abs=0.05)


def test_apcvfl_dp_quantized_kparty_accounts_every_link():
    from repro.core import multiparty
    from repro.data.synthetic import make_dataset
    ds = make_dataset("bcw", seed=0)
    sck = multiparty.make_scenario_k(ds, n_parties=3, n_active_features=5,
                                     n_aligned=100, seed=0)
    r = defense.run_apcvfl_dp(sck, quantize="int8", seed=0,
                              max_epochs=1)
    assert len(r.channels) == 2
    for ch in r.channels:                   # each passive link quantized
        assert ch.bytes_by_dtype().get("int8", 0) > 0


# ---------------------------------------------------------------------------
# n_aux clamp warning (satellite regression)
# ---------------------------------------------------------------------------

def test_effective_n_aux_warns_loudly_and_records(sc):
    with pytest.warns(RuntimeWarning, match="clamped"):
        assert privacy.effective_n_aux(10_000, 120) == 100
    assert privacy.effective_n_aux(64, 120) == 64     # no warning path
    with pytest.warns(RuntimeWarning, match="n_aux=1 clamped to 2"):
        privacy.effective_n_aux(1, 120)
    with pytest.warns(RuntimeWarning, match="clamped"):
        r = privacy.run_inversion(sc, n_aux=10_000, max_epochs=1, seed=0)
    assert r.metrics["n_aux"] == 100.0               # 120 aligned - 20
    assert r.metrics["n_aux_requested"] == 10_000.0
    assert r.metrics["n_aux_clamped"] == 1.0
    r2 = privacy.run_inversion(sc, n_aux=32, max_epochs=1, seed=0)
    assert r2.metrics["n_aux_clamped"] == 0.0


# ---------------------------------------------------------------------------
# attack registry
# ---------------------------------------------------------------------------

def test_attack_registry_schema_and_errors():
    assert attacks.available_attacks() == ("inversion", "label_leak",
                                           "membership")
    with pytest.raises(KeyError, match="unknown attack"):
        attacks.get_attack("gradient_leak")
    with pytest.raises(ValueError, match="already registered"):
        attacks.register_attack("inversion")(lambda s: None)


def test_attacks_share_leakage_schema_and_defense_closes_them(sc):
    ts = [None, defense.make_transform(sigma=8.0)]
    surfaces = attacks.build_surfaces(sc, ts, seed=0, max_epochs=EPOCHS)
    assert len(surfaces) == 2
    reports = []
    for s in surfaces:
        reps = {n: attacks.run_attack(n, s, seed=0)
                for n in attacks.available_attacks()}
        reports.append(reps)
        for rep in reps.values():
            m = rep.metrics()
            assert {"leakage", "success", "baseline",
                    "n_aux"} <= set(m)
            assert 0.0 <= m["leakage"] <= 1.0
    clean, defended = reports
    # undefended membership is ~total: aligned rows match their own
    # exchanged latents at distance zero
    assert clean["membership"].leakage >= 0.9
    assert defended["membership"].leakage < clean["membership"].leakage
    assert defended["inversion"].leakage <= clean["inversion"].leakage
    # comm parity: the undefended surface's channel matches run_apcvfl's
    # exchange accounting (same stage bytes)
    assert surfaces[0].channel.summary()["by_stage"]["step1"] \
        == 120 * surfaces[0].z_exch.shape[1] * 4


def test_attack_run_wrappers_emit_runresults(sc):
    r = attacks.run_attack_membership(sc, sigma=0.0, seed=0, max_epochs=1)
    assert r.method == "attack_membership"
    assert r.metrics["leakage"] >= 0.9 and r.metrics["dp_sigma"] == 0.0
    assert r.comm["by_stage"]["step1"] > 0


# ---------------------------------------------------------------------------
# fault plans
# ---------------------------------------------------------------------------

def test_fault_plan_json_round_trip_and_validation(tmp_path):
    plan = faults.FaultPlan(name="p", seed=7, events=(
        faults.FaultEvent(kind="dropout", t_ms=100.0, tenant="a"),
        faults.FaultEvent(kind="stale", stage="exchange", epochs=2),
        faults.FaultEvent(kind="recover", t_ms=50.0, tenant="a"),
    ))
    assert faults.FaultPlan.from_json(plan.to_json()) == plan
    path = tmp_path / "plan.json"
    plan.save(str(path))
    assert faults.FaultPlan.load(str(path)) == plan
    assert json.loads(plan.to_json())["events"][0]["kind"] == "dropout"
    # serving events come back time-sorted
    assert [e.t_ms for e in plan.serving_events()] == [50.0, 100.0]
    assert len(plan.training_events()) == 1
    with pytest.raises(ValueError, match="kind"):
        faults.FaultEvent(kind="meteor", t_ms=1.0)
    with pytest.raises(ValueError, match="exactly one trigger"):
        faults.FaultEvent(kind="dropout")
    with pytest.raises(ValueError, match="exactly one trigger"):
        faults.FaultEvent(kind="dropout", t_ms=1.0, stage="exchange")
    with pytest.raises(ValueError, match="serving-time"):
        faults.FaultEvent(kind="recover", stage="exchange")
    with pytest.raises(ValueError, match="unknown keys"):
        faults.FaultEvent.from_dict({"kind": "dropout", "t_ms": 1.0,
                                     "speed": 9})


def test_training_fault_dropout_is_the_ablation(sc):
    plan = faults.FaultPlan("d", events=(
        faults.FaultEvent(kind="dropout", stage="exchange"),))
    r = faults.run_faulted_apcvfl(sc, plan, seed=0, max_epochs=EPOCHS)
    abl = pipeline.run_apcvfl(sc, seed=0, max_epochs=EPOCHS, ablation=True)
    assert r.method == "apcvfl_faulted"
    assert r.metrics["fault_dropout"] == 1.0
    assert r.rounds == 0                       # no exchange ever happened
    assert _trees_equal(r.params, abl.params)


def test_training_fault_stale_and_drift_flags(sc):
    stale = faults.run_faulted_apcvfl(
        sc, faults.FaultPlan("s", events=(
            faults.FaultEvent(kind="stale", stage="exchange", epochs=1),)),
        seed=0, max_epochs=EPOCHS)
    assert stale.metrics["fault_stale"] == 1.0 and stale.rounds == 1
    drift = faults.run_faulted_apcvfl(
        sc, faults.FaultPlan("dr", events=(
            faults.FaultEvent(kind="drift", stage="exchange", drift=0.5),)),
        seed=0, max_epochs=EPOCHS)
    assert drift.metrics["fault_drift"] == 1.0 and drift.rounds == 1
    for r in (stale, drift):
        assert 0.0 <= r.metrics["accuracy"] <= 1.0
        # the wire still carried one full fp32 latent exchange
        assert r.comm["by_stage"]["step1"] > 0


# ---------------------------------------------------------------------------
# serving-time injection (deterministic virtual clock)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serving(sc, base_run):
    bundle = sv.export_bundle(base_run, sc, head_steps=60)
    reg = rt.TenantRegistry()
    reg.register("t0", bundle)
    reg.register("t1", bundle)
    reg.warmup()
    return reg, bundle


def _known_stream(sc, n, *, tenant, t0_ms=0.0, gap_ms=10.0):
    """n single-row requests with REAL ids (cache candidates) arriving on
    a fixed grid — fully deterministic collab routing."""
    ids = np.asarray(sc.active.ids[:n], np.int64)
    x = np.asarray(sc.active.x[:n], np.float32)
    return [rt.TimedRequest(
        sv.ServeRequest(i, x[i:i + 1], ids[i:i + 1]), tenant,
        t0_ms + gap_ms * i) for i in range(n)]


def test_midstream_fault_degrades_then_recovers(sc, serving):
    reg, bundle = serving
    reg.reset_stats()
    stream = _known_stream(sc, 40, tenant="t0")       # arrivals at 0..390
    plan = faults.FaultPlan("mid", events=(
        faults.FaultEvent(kind="dropout", t_ms=150.0, tenant="t0"),
        faults.FaultEvent(kind="recover", t_ms=250.0, tenant="t0"),
    ))
    runtime = rt.ServingRuntime(reg, rt.RuntimeConfig(slo_ms=50.0),
                                service_model=lambda rows: 1.0)
    report = runtime.run(stream, faults=plan)
    assert report["served"] == 40
    fb = report["faults"]["tenants"]["t0"]
    assert report["faults"]["events_applied"] == 2
    assert fb["kinds"] == ["dropout"]
    assert fb["faulted_at_ms"] == 150.0
    assert fb["recovered_at_ms"] == 250.0
    # the invariant: while faulted, NEVER a collaborative dispatch
    assert fb["collab_dispatches_while_faulted"] == 0
    assert not fb["faulted"] and not fb["cache_stale"]
    assert fb["cache_version"] == 2                   # recover bumped it
    stats = reg["t0"].stats
    # collab served before the fault AND after recovery; active-only
    # dispatches happened in between (the degrade path)
    assert stats.dispatches.get("collab", 0) > 0
    assert stats.dispatches.get("active", 0) > 0


def test_fault_without_recover_leaves_cache_stale(sc, serving):
    reg, bundle = serving
    reg.reset_stats()
    stream = _known_stream(sc, 20, tenant="t1")
    plan = faults.FaultPlan("stale", events=(
        faults.FaultEvent(kind="stale", t_ms=95.0, tenant="t1"),))
    runtime = rt.ServingRuntime(reg, rt.RuntimeConfig(slo_ms=50.0),
                                service_model=lambda rows: 1.0)
    report = runtime.run(stream, faults=plan)
    fb = report["faults"]["tenants"]["t1"]
    assert fb["faulted"] and fb["cache_stale"]
    assert fb["collab_dispatches_while_faulted"] == 0
    # degraded requests were actually served (active-only), none dropped
    assert report["served"] == 20
    # restore for other tests sharing the module-scoped registry
    reg["t1"].refresh_cache(bundle.cache_ids, bundle.cache_z)


def test_fault_plan_unknown_tenant_rejected_early(sc, serving):
    reg, _ = serving
    runtime = rt.ServingRuntime(reg, service_model=lambda rows: 1.0)
    plan = faults.FaultPlan("ghost", events=(
        faults.FaultEvent(kind="dropout", t_ms=1.0, tenant="nobody"),))
    with pytest.raises(ValueError, match="unregistered tenants"):
        runtime.run(_known_stream(sc, 3, tenant="t0"), faults=plan)


def test_events_past_stream_end_still_apply(sc, serving):
    reg, bundle = serving
    reg.reset_stats()
    stream = _known_stream(sc, 5, tenant="t0")        # ends ~t=40
    plan = faults.FaultPlan("late", events=(
        faults.FaultEvent(kind="dropout", t_ms=10_000.0, tenant="t0"),))
    runtime = rt.ServingRuntime(reg, rt.RuntimeConfig(slo_ms=50.0),
                                service_model=lambda rows: 1.0)
    report = runtime.run(stream, faults=plan)
    assert report["faults"]["events_applied"] == 1
    assert report["faults"]["tenants"]["t0"]["cache_stale"]
    # no serving happened while faulted, so no violations possible
    assert report["faults"]["tenants"]["t0"][
        "collab_dispatches_while_faulted"] == 0
    reg["t0"].refresh_cache(bundle.cache_ids, bundle.cache_z)


# ---------------------------------------------------------------------------
# spec integration
# ---------------------------------------------------------------------------

def test_privacy_frontier_spec_parses_and_methods_registered():
    from repro.experiments.registry import get_method
    from repro.experiments.specs import ExperimentSpec
    with open("examples/specs/privacy_frontier.json") as fh:
        spec = ExperimentSpec.from_dict(json.load(fh))
    names = {m.method for m in spec.methods}
    assert {"apcvfl", "apcvfl_dp", "attack_inversion",
            "attack_membership", "attack_label_leak"} <= names
    for m in spec.methods:
        get_method(m.method)               # registered + params validated
