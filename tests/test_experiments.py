"""Tests for the declarative experiment API: spec serialization, registry
dispatch, sweep determinism, the uniform-RunResult acceptance path, the
refactor regression (spec-driven apcvfl == direct call), and the measured
vs analytic communication cross-check."""
import dataclasses

import numpy as np
import pytest

from repro.core import comm, pipeline, splitnn, vfedtrans
from repro.data.synthetic import make_dataset
from repro.data.vertical import make_scenario
from repro.experiments import (ExperimentSpec, MethodSpec, RunResult,
                               ScenarioSpec, available_methods,
                               build_scenario, get_method, sweep, tidy)


# ---------------------------------------------------------------------------
# spec serialization
# ---------------------------------------------------------------------------

def test_spec_json_round_trip():
    spec = ExperimentSpec(
        name="rt", dataset="credit", aligned=(5000, 0.25),
        n_parties=(2, 3), n_active_features=4, seeds=(0, 1, 2),
        methods=(MethodSpec("local"),
                 MethodSpec("apcvfl", label="ablation",
                            params={"ablation": True, "lam": 0.5})),
        overrides={"max_epochs": 7})
    back = ExperimentSpec.from_json(spec.to_json())
    assert back == spec
    assert isinstance(back.aligned, tuple)
    assert isinstance(back.methods[1], MethodSpec)
    assert back.methods[1].params == {"ablation": True, "lam": 0.5}


def test_spec_from_dict_rejects_unknown_keys():
    with pytest.raises(ValueError, match="unknown keys"):
        ExperimentSpec.from_dict({"name": "x", "methdos": []})
    with pytest.raises(ValueError, match="unknown keys"):
        MethodSpec.from_dict({"method": "local", "prams": {}})


def test_method_string_sugar_and_frozen():
    spec = ExperimentSpec.from_dict({"name": "s", "methods": ["local"]})
    assert spec.methods == (MethodSpec("local"),)
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.name = "other"


def test_scenario_spec_aligned_fraction():
    s = ScenarioSpec(dataset="bcw", n_aligned=0.5)
    assert s.resolve_aligned(500) == 250
    assert ScenarioSpec(dataset="bcw", n_aligned=120).resolve_aligned(500) \
        == 120


# ---------------------------------------------------------------------------
# registry dispatch
# ---------------------------------------------------------------------------

def test_registry_has_builtin_methods():
    assert {"local", "apcvfl", "apcvfl_aligned_only", "splitnn",
            "vfedtrans"} <= set(available_methods())


def test_unknown_method_raises_with_registered_names():
    with pytest.raises(KeyError, match="registered methods:.*apcvfl"):
        get_method("no_such_method")


def test_sweep_validates_before_running():
    bad = ExperimentSpec(name="bad", methods=(MethodSpec("nope"),))
    with pytest.raises(KeyError, match="unknown method"):
        sweep(bad)
    k3 = ExperimentSpec(name="k3", n_parties=(3,),
                        methods=(MethodSpec("splitnn"),))
    with pytest.raises(ValueError, match="2-party"):
        sweep(k3)
    with pytest.raises(ValueError, match="no methods"):
        sweep(ExperimentSpec(name="empty"))
    with pytest.raises(ValueError, match="n_parties must all be >= 2"):
        sweep(ExperimentSpec(name="k1", n_parties=(1,),
                             methods=(MethodSpec("local"),)))
    dup = ExperimentSpec(name="dup",
                         methods=(MethodSpec("apcvfl"),
                                  MethodSpec("apcvfl",
                                             params={"lam": 0.5})))
    with pytest.raises(ValueError, match="duplicate method label"):
        sweep(dup)
    # param names are checked eagerly against each runner's signature:
    # a typo'd param or an override one method can't take fails BEFORE
    # any scenario is built or model trained
    typo = ExperimentSpec(name="typo",
                          methods=(MethodSpec("apcvfl",
                                              params={"lamda": 0.5}),))
    with pytest.raises(ValueError, match="does not accept params"):
        sweep(typo)
    bad_override = ExperimentSpec(name="bo",
                                  methods=(MethodSpec("apcvfl"),
                                           MethodSpec("splitnn")),
                                  overrides={"lam": 0.01})
    with pytest.raises(ValueError, match="'splitnn' does not accept"):
        sweep(bad_override)


def test_apcvfl_k_signature_matches_2party():
    """The apcvfl adapter dispatches one param set to run_apcvfl (K=2) or
    run_apcvfl_k (K>2): their keyword surfaces must stay identical, since
    eager validation checks against the 2-party signature."""
    import inspect

    from repro.core.multiparty import run_apcvfl_k

    def kwargs_of(fn):
        return {p.name for p in
                list(inspect.signature(fn).parameters.values())[1:]}

    assert kwargs_of(pipeline.run_apcvfl) == kwargs_of(run_apcvfl_k)


def test_kparty_grid_runs_apcvfl_variants():
    """K>2 cells run through the same spec path, including the ablation
    variant (regression: run_apcvfl_k used to lack the ablation kwarg, so
    a K-party ablation grid crashed mid-sweep)."""
    spec = ExperimentSpec(
        name="k3", dataset="bcw", aligned=(100,), n_parties=(3,), seeds=(0,),
        methods=(MethodSpec("local"), MethodSpec("apcvfl"),
                 MethodSpec("apcvfl", label="ablation",
                            params={"ablation": True})),
        overrides={"max_epochs": 2})
    results = sweep(spec)
    assert [r.scenario["n_parties"] for r in results] == [3, 3, 3]
    full = next(r for r in results if r.method == "apcvfl")
    abl = next(r for r in results if r.method == "ablation")
    assert len(full.channels) == 2               # one link per passive
    assert full.rounds == 1 and abl.rounds == 0  # ablation: no exchange
    assert abl.comm["by_stage"].keys() == {"psi"}
    assert full.z_dim == abl.z_dim == 256


# ---------------------------------------------------------------------------
# the acceptance path: one sweep, every method, uniform records
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_results(quick_epochs_module):
    from repro.launch.experiment import smoke_spec
    spec = dataclasses.replace(
        smoke_spec(), overrides={"max_epochs": quick_epochs_module})
    return spec, sweep(spec)


def test_smoke_spec_uniform_runresults(smoke_results):
    spec, results = smoke_results
    assert len(results) == len(spec.methods)
    labels = [r.method for r in results]
    assert set(labels) >= {"local", "apcvfl", "splitnn", "vfedtrans"}
    for r in results:
        assert isinstance(r, RunResult)
        assert 0.0 <= r.metrics["accuracy"] <= 1.0
        assert set(r.comm) == {"total_bytes", "total_mb", "transfers",
                               "uplink_bytes", "downlink_bytes", "by_stage",
                               "by_dtype"}
        assert r.scenario["dataset"] == "bcw"
        assert r.scenario["n_aligned"] == 120
    rec_keys = [set(rec) for rec in tidy(results)]
    assert all(k == rec_keys[0] for k in rec_keys)   # tidy: same columns


def test_sweep_reuses_scenario_across_methods(smoke_results):
    """All methods of one grid cell see the SAME partition: equal aligned
    rows and equal PSI traffic on every method that runs PSI."""
    _, results = smoke_results
    psi_bytes = {r.method: r.comm["by_stage"].get("psi")
                 for r in results if r.channels}
    vals = {v for v in psi_bytes.values() if v is not None}
    assert len(vals) == 1, psi_bytes


def test_apcvfl_via_spec_matches_direct_call(smoke_results):
    """Refactor regression: the registry/spec path is the SAME computation
    as the pre-refactor direct call — identical metrics at equal seeds."""
    spec, results = smoke_results
    via_spec = next(r for r in results if r.method == "apcvfl")
    sc = build_scenario(ScenarioSpec(dataset="bcw", n_aligned=120,
                                     n_active_features=5, seed=0))
    direct = pipeline.run_apcvfl(sc, seed=0,
                                 max_epochs=spec.overrides["max_epochs"])
    for k, v in direct.metrics.items():
        assert abs(via_spec.metrics[k] - v) < 1e-9
    assert via_spec.comm == direct.comm


def test_sweep_seed_determinism():
    spec = ExperimentSpec(
        name="det", dataset="bcw", aligned=(100,), seeds=(0, 1),
        methods=(MethodSpec("local"), MethodSpec("apcvfl")),
        overrides={"max_epochs": 2})
    a = tidy(sweep(spec))
    b = tidy(sweep(spec))
    assert a == b
    # different seeds produce different partitions -> different rows
    assert a[0]["seed"] == 0 and a[2]["seed"] == 1
    assert a[0] != dict(a[2], seed=0)


# ---------------------------------------------------------------------------
# measured channel vs analytic Appendix-E footprints
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cross_check_scenario():
    ds = make_dataset("bcw", seed=5)
    return make_scenario(ds, n_active_features=5, n_aligned=150, seed=5)


def test_splitnn_channel_matches_analytic_footprint(cross_check_scenario):
    r = splitnn.run_splitnn(cross_check_scenario, max_epochs=3, test_size=50,
                            seed=5)
    epochs = r.epochs["splitnn"]
    n_tr = 150 - 50
    want = comm.splitnn_footprint_bytes(epochs, n_tr, batch_size=128)
    assert r.comm["by_stage"]["train"] == want
    # forward embeddings up, gradients down — exactly Eq. 7 / Eq. 8
    by_what = {t.what: t for t in r.channel.log}
    fwd = by_what["train/forward_embeddings"]
    bwd = by_what["train/backward_gradients"]
    assert fwd.nbytes == comm.splitnn_forward_bytes(epochs, n_tr)
    assert fwd.direction == "uplink"
    assert bwd.nbytes == comm.splitnn_backprop_bytes(epochs, n_tr, 128)
    assert bwd.direction == "downlink"
    assert r.comm["uplink_bytes"] == (fwd.nbytes
                                      + by_what["psi/hashes_b"].nbytes)
    assert r.comm["downlink_bytes"] == (bwd.nbytes
                                        + by_what["psi/hashes_a"].nbytes)
    assert r.rounds == comm.splitnn_rounds(epochs, n_tr, 128)


def test_vfedtrans_channel_matches_analytic_footprint(cross_check_scenario):
    sc = cross_check_scenario
    r = vfedtrans.run_vfedtrans(sc, max_epochs=2, seed=5)
    x_t = sc.active.x.shape[1]
    x_d = sc.passive.x.shape[1]
    want = comm.vfedtrans_footprint_bytes(sc.n_aligned, x_t, x_d)
    assert r.comm["by_stage"]["fedsvd"] == want
    assert r.rounds == comm.VFEDTRANS_ROUNDS
    assert r.z_dim == x_t + x_d              # the FedSVD dim constraint


def test_channel_summary_directions_and_stages():
    ch = comm.Channel()
    ch.send("psi/hashes_a", 100, direction="downlink")
    ch.send("psi/hashes_b", 80, direction="uplink")
    ch.send_array("step1/Z", np.zeros((10, 4), np.float32),
                  direction="uplink")
    s = ch.summary()
    assert s["total_bytes"] == 100 + 80 + 160
    assert s["uplink_bytes"] == 80 + 160
    assert s["downlink_bytes"] == 100
    assert s["by_stage"] == {"psi": 180, "step1": 160}
    assert s["by_dtype"] == {"float32": 340}   # send() defaults to fp32
    assert s["transfers"] == 3
    # aggregation across links sums bytes and merges stages
    agg = comm.summarize([ch, ch])
    assert agg["total_bytes"] == 2 * s["total_bytes"]
    assert agg["by_stage"]["psi"] == 360
