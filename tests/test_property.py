"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dev dependency (requirements-dev.txt); skip rather "
           "than error the whole -x run")
from hypothesis import given, settings, strategies as st

from repro.core import comm
from repro.core import classifier as clf
from repro.core.psi import psi
from repro.models.common import causal_mask, rope

SETTINGS = dict(max_examples=25, deadline=None)


# --- PSI --------------------------------------------------------------------

@given(st.sets(st.integers(0, 10**6), max_size=40),
       st.sets(st.integers(0, 10**6), max_size=40))
@settings(**SETTINGS)
def test_psi_matches_set_intersection(sa, sb):
    a = np.array(sorted(sa), np.int64)
    b = np.array(sorted(sb), np.int64)
    common, ia, ib = psi(a, b)
    assert set(common.tolist()) == (sa & sb)
    if len(common):
        np.testing.assert_array_equal(a[ia], common)
        np.testing.assert_array_equal(b[ib], common)


# --- communication formulas (Appendix E) -------------------------------------

@given(st.integers(1, 10**5), st.integers(1, 10**5))
@settings(**SETTINGS)
def test_apcvfl_footprint_linear(n1, n2):
    f = comm.apcvfl_footprint_bytes
    assert f(n1) + f(n2) == f(n1 + n2)        # exactly linear in |D_A|


@given(st.integers(1, 2000), st.integers(1, 50), st.integers(1, 200),
       st.integers(1, 512))
@settings(**SETTINGS)
def test_splitnn_footprint_monotone(n, e, extra, bs):
    f = comm.splitnn_footprint_bytes
    assert f(e, n + extra, bs) >= f(e, n, bs)
    assert f(e + 1, n, bs) > f(e, n, bs)


@given(st.integers(100, 5000), st.integers(1, 30), st.integers(1, 30))
@settings(**SETTINGS)
def test_vfedtrans_superlinear(n, xt, xd):
    f = comm.vfedtrans_footprint_bytes
    # doubling |D_A| more than doubles the footprint (the |D_A|^2 mask);
    # holds once n > (x_t + x_d) / 2, always true in the paper's range
    assert f(2 * n, xt, xd) > 2 * f(n, xt, xd)


@given(st.integers(100, 20000))
@settings(**SETTINGS)
def test_apcvfl_cheaper_than_vfedtrans_at_scale(n):
    # paper Fig. 6: APC-VFL's footprint is below VFedTrans' for every
    # tested |D_A| (x_t=5, x_d=10 as in MIMIC-III partitions)
    if n >= 150:   # tiny |D_A| could favor the masks; paper range is >=100
        assert (comm.apcvfl_footprint_bytes(n)
                < comm.vfedtrans_footprint_bytes(n, 5, 10))


# --- metrics ------------------------------------------------------------------

@given(st.integers(2, 5), st.integers(10, 60), st.integers(0, 10**6))
@settings(**SETTINGS)
def test_f1_bounds_and_perfect(nc, n, seed):
    rng = np.random.RandomState(seed % 2**32)
    y = rng.randint(0, nc, n)
    m = clf.f1_scores(y, y, nc)
    assert m["accuracy"] == 1.0 and abs(m["f1_micro"] - 1.0) < 1e-9
    yp = rng.randint(0, nc, n)
    m2 = clf.f1_scores(y, yp, nc)
    for v in m2.values():
        assert 0.0 <= v <= 1.0


# --- model invariants ---------------------------------------------------------

@given(st.integers(1, 64), st.integers(1, 8))
@settings(max_examples=10, deadline=None)
def test_rope_preserves_norm(pos, half_pairs):
    hd = 2 * half_pairs
    key = jax.random.PRNGKey(pos)
    x = jax.random.normal(key, (1, 1, 1, hd))
    p = jnp.full((1, 1), pos)
    y = rope(x, p, theta=1e4)
    np.testing.assert_allclose(float(jnp.linalg.norm(y)),
                               float(jnp.linalg.norm(x)), rtol=1e-5)


@given(st.integers(2, 32), st.integers(1, 16))
@settings(max_examples=10, deadline=None)
def test_causal_mask_structure(S, w):
    m = np.asarray(causal_mask(S, window=w))
    for i in range(S):
        for j in range(S):
            visible = (j <= i) and (i - j < w)
            assert (m[i, j] == 0.0) == visible


# --- MoE routing --------------------------------------------------------------

@given(st.integers(0, 10**6))
@settings(max_examples=8, deadline=None)
def test_moe_combine_weights_normalized(seed):
    """Top-k routing weights renormalize to 1 => with enough capacity the
    MoE output is a convex combination of expert outputs (bounded norm)."""
    from repro.configs import get_smoke
    from repro.models.ffn import moe, schema_moe
    from repro.sharding.policy import init_params
    cfg = get_smoke("qwen3-moe-30b-a3b").with_(capacity_factor=2.0)
    key = jax.random.PRNGKey(seed % 2**32)
    p = init_params(schema_moe(cfg), key, jnp.float32)
    x = jax.random.normal(key, (2, 8, cfg.d_model))
    y, aux = moe(p, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    # Switch aux loss E*sum(me*ce) hovers near 1 for near-uniform routing
    assert 0.3 < float(aux) < float(cfg.n_experts)
