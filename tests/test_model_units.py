"""Focused unit tests for model-zoo building blocks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import attention, mamba2, xlstm
from repro.models.common import norm_apply, rope, schema_norm
from repro.sharding.policy import init_params


def test_rope_relative_property():
    """RoPE inner products depend only on relative positions."""
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
    def dot_at(pq, pk):
        qr = rope(q, jnp.full((1, 1), pq), 1e4)
        kr = rope(k, jnp.full((1, 1), pk), 1e4)
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(3, 7) - dot_at(13, 17)) < 1e-4   # same offset 4
    assert abs(dot_at(0, 4) - dot_at(20, 24)) < 1e-4


def test_gqa_expand_replicates_heads():
    kv = jnp.arange(2 * 3 * 2 * 4, dtype=jnp.float32).reshape(2, 3, 2, 4)
    out = attention._gqa_expand(kv, 6, 2)
    assert out.shape == (2, 3, 6, 4)
    for g in range(2):
        for r in range(3):
            np.testing.assert_array_equal(out[:, :, g * 3 + r], kv[:, :, g])


def test_rmsnorm_scale_invariance_direction():
    p = {"scale": jnp.ones((8,))}
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8))
    y1 = norm_apply(p, x)
    y2 = norm_apply(p, 10.0 * x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_sliding_window_attention_ignores_distant_past():
    """With window w, perturbing tokens more than w back leaves the final
    position's attention output unchanged."""
    cfg = get_smoke("internlm2-20b").with_(sliding_window=8)
    key = jax.random.PRNGKey(0)
    p = init_params(attention.schema_attention(cfg), key, jnp.float32)
    S, d = 32, cfg.d_model
    x = jax.random.normal(key, (1, S, d))
    positions = jnp.arange(S)[None]
    out1 = attention.attention(p, cfg, x, positions=positions, window=8)
    x2 = x.at[:, :S - 9].set(jax.random.normal(jax.random.PRNGKey(9),
                                               (1, S - 9, d)))
    out2 = attention.attention(p, cfg, x2, positions=positions, window=8)
    np.testing.assert_allclose(np.asarray(out1[:, -1]),
                               np.asarray(out2[:, -1]), atol=1e-4)


def test_mamba_decode_matches_chunked_forward():
    cfg = get_smoke("zamba2-2.7b")
    key = jax.random.PRNGKey(2)
    p = init_params(mamba2.schema_mamba_block(cfg), key, jnp.float32)
    B, S = 2, 32
    x = jax.random.normal(key, (B, S, cfg.d_model)) * 0.5
    full = mamba2.mamba_block(p, cfg, x)
    st = mamba2.init_state(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        y, st = mamba2.mamba_decode(p, cfg, x[:, t:t + 1], st)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               atol=5e-3, rtol=5e-3)


def test_mlstm_decode_matches_chunked_forward():
    cfg = get_smoke("xlstm-350m")
    key = jax.random.PRNGKey(3)
    p = init_params(xlstm.schema_mlstm(cfg), key, jnp.float32)
    B, S = 2, 64
    x = jax.random.normal(key, (B, S, cfg.d_model)) * 0.5
    full = xlstm.mlstm_block(p, cfg, x)
    st = xlstm.mlstm_init_state(cfg, B)
    outs = []
    for t in range(S):
        y, st = xlstm.mlstm_decode(p, cfg, x[:, t:t + 1], st)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               atol=5e-3, rtol=5e-3)


def test_moe_capacity_drops_are_bounded():
    """With capacity factor 1.0 and balanced-ish routing, most tokens are
    served; with huge capacity, y is identical to a rerun (determinism)."""
    from repro.models.ffn import moe, schema_moe
    cfg = get_smoke("qwen3-moe-30b-a3b").with_(capacity_factor=8.0)
    key = jax.random.PRNGKey(4)
    p = init_params(schema_moe(cfg), key, jnp.float32)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    y1, _ = moe(p, cfg, x)
    y2, _ = moe(p, cfg, x)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    # tokens served: output rows should be mostly nonzero
    nz = np.mean(np.abs(np.asarray(y1)).sum(-1) > 1e-6)
    assert nz > 0.95


def test_decode_cache_slot_rolling():
    """Sliding-window decode reuses slots: writing past W wraps around."""
    cfg = get_smoke("internlm2-20b")
    key = jax.random.PRNGKey(5)
    p = init_params(attention.schema_attention(cfg), key, jnp.float32)
    B, W = 1, 4
    cache = attention.init_cache(cfg, B, W, jnp.float32)
    for t in range(6):
        x = jax.random.normal(jax.random.PRNGKey(t), (B, 1, cfg.d_model))
        _, cache = attention.decode_attention(p, cfg, x, cache,
                                              jnp.int32(t), window=W)
    sp = np.asarray(cache.slot_pos)
    assert set(sp.tolist()) == {4, 5, 2, 3}   # slots 0,1 overwritten
