"""Unit + integration tests for the APC-VFL core: the four-step pipeline,
Eq. 5 loss semantics, PSI, FedSVD losslessness, comm accounting vs the
paper's analytic formulas (Appendix E)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autoencoder as ae
from repro.core import classifier as clf
from repro.core import comm
from repro.core import distill
from repro.core import fedsvd
from repro.core import pipeline
from repro.core.psi import psi
from repro.data.synthetic import make_dataset
from repro.data.vertical import make_scenario


# ---------------------------------------------------------------------------
# PSI
# ---------------------------------------------------------------------------

def test_psi_intersection():
    a = np.array([5, 9, 1, 7, 3], np.int64)
    b = np.array([2, 7, 5, 8], np.int64)
    common, ia, ib = psi(a, b)
    assert set(common.tolist()) == {5, 7}
    np.testing.assert_array_equal(a[ia], common)
    np.testing.assert_array_equal(b[ib], common)


def test_psi_counts_bytes():
    ch = comm.Channel()
    psi(np.arange(10, dtype=np.int64), np.arange(5, 15, dtype=np.int64),
        channel=ch)
    assert ch.total_bytes == (10 + 10) * 32


def test_psi_rejects_duplicate_ids():
    """The salted-hash table would silently collapse duplicates (dict
    overwrite), corrupting idx_a/idx_b — a loud error is required."""
    dup = np.array([1, 2, 2, 3], np.int64)
    uniq = np.array([2, 3, 4], np.int64)
    with pytest.raises(ValueError, match="unique IDs"):
        psi(dup, uniq)
    with pytest.raises(ValueError, match="unique IDs"):
        psi(uniq, dup)
    # unique inputs still fine
    common, _, _ = psi(uniq, np.array([3, 4, 5], np.int64))
    assert set(common.tolist()) == {3, 4}


# ---------------------------------------------------------------------------
# Eq. 5 loss
# ---------------------------------------------------------------------------

def test_distill_loss_reduces_to_reconstruction_when_unaligned():
    key = jax.random.PRNGKey(0)
    params = ae.init_autoencoder(key, [8, 16, 4])
    x = jax.random.normal(key, (32, 8))
    batch0 = {"x": x, "z_teacher": jnp.zeros((32, 4)),
              "aligned": jnp.zeros((32,))}
    batch1 = {"x": x, "z_teacher": 100 + jnp.zeros((32, 4)),
              "aligned": jnp.zeros((32,))}
    l0 = distill.distill_loss(params, batch0)
    l1 = distill.distill_loss(params, batch1)
    assert float(jnp.abs(l0 - l1)) < 1e-6
    rec = ae.recon_loss(params, {"x": x})
    assert float(jnp.abs(l0 - rec)) < 1e-6


def test_distill_loss_lambda_scaling():
    key = jax.random.PRNGKey(1)
    params = ae.init_autoencoder(key, [8, 16, 4])
    x = jax.random.normal(key, (32, 8))
    batch = {"x": x, "z_teacher": jnp.ones((32, 4)),
             "aligned": jnp.ones((32,))}
    rec = float(ae.recon_loss(params, {"x": x}))
    l1 = float(distill.distill_loss(params, batch, lam=1.0))
    l2 = float(distill.distill_loss(params, batch, lam=2.0))
    # distill part doubles
    assert abs((l2 - rec) - 2 * (l1 - rec)) < 1e-5


def test_distill_loss_kernel_path_matches():
    key = jax.random.PRNGKey(2)
    params = ae.init_autoencoder(key, [8, 16, 4])
    x = jax.random.normal(key, (40, 8))
    batch = {"x": x, "z_teacher": jax.random.normal(key, (40, 4)),
             "aligned": (jax.random.uniform(key, (40,)) > 0.5).astype(jnp.float32)}
    a = distill.distill_loss(params, batch, use_kernel=False)
    b = distill.distill_loss(params, batch, use_kernel=True)
    assert abs(float(a) - float(b)) < 1e-5


# ---------------------------------------------------------------------------
# FedSVD
# ---------------------------------------------------------------------------

def test_fedsvd_lossless():
    rng = np.random.RandomState(0)
    Xa = rng.randn(50, 4).astype(np.float32)
    Xp = rng.randn(50, 7).astype(np.float32)
    res = fedsvd.fedsvd(Xa, Xp, seed=0)
    X = np.concatenate([Xa, Xp], axis=1)
    U_direct, S_direct, _ = np.linalg.svd(X, full_matrices=False)
    np.testing.assert_allclose(res.S, S_direct, atol=1e-4)
    # left factors match up to per-column sign
    dots = np.abs(np.sum(res.U * U_direct, axis=0))
    np.testing.assert_allclose(dots, np.ones_like(dots), atol=1e-3)


def test_fedsvd_rounds_and_bytes():
    rng = np.random.RandomState(1)
    Xa, Xp = rng.randn(30, 3).astype(np.float32), rng.randn(30, 5).astype(np.float32)
    res = fedsvd.fedsvd(Xa, Xp, seed=0)
    assert res.rounds == comm.VFEDTRANS_ROUNDS == 5
    assert res.channel.total_bytes == comm.vfedtrans_footprint_bytes(30, 3, 5)


# ---------------------------------------------------------------------------
# comm accounting vs paper Appendix E
# ---------------------------------------------------------------------------

def test_apcvfl_footprint_matches_paper_table2():
    # Table 2: 10K aligned -> 9.73 "MB" (paper uses MiB): 10000*256*4 bytes
    assert comm.apcvfl_footprint_bytes(10000) == 10000 * 256 * 4
    assert abs(comm.apcvfl_footprint_bytes(10000) / 2**20 - 9.766) < 0.01
    # linear scaling (paper Fig. 6)
    assert comm.apcvfl_footprint_bytes(5000) * 2 == comm.apcvfl_footprint_bytes(10000)


def test_splitnn_formula_consistency():
    e, n, bs = 10, 1000, 128
    fwd = comm.splitnn_forward_bytes(e, n)
    bwd = comm.splitnn_backprop_bytes(e, n, bs)
    assert fwd == e * n * 256 * 4
    assert bwd == e * 8 * (128 * 256 + 256) * 4
    assert comm.splitnn_footprint_bytes(e, n, bs) == fwd + bwd
    assert comm.splitnn_rounds(e, n, bs) == 2 * e * 8


def test_vfedtrans_quadratic_growth():
    f1 = comm.vfedtrans_footprint_bytes(1000, 5, 10)
    f2 = comm.vfedtrans_footprint_bytes(2000, 5, 10)
    assert f2 > 3.5 * f1  # dominated by the 2|D_A|^2 term


# ---------------------------------------------------------------------------
# classifier / metrics
# ---------------------------------------------------------------------------

def test_f1_scores_hand_example():
    y_true = np.array([0, 0, 1, 1, 1])
    y_pred = np.array([0, 1, 1, 1, 0])
    m = clf.f1_scores(y_true, y_pred, 2)
    assert abs(m["accuracy"] - 0.6) < 1e-9
    # class1: tp=2 fp=1 fn=1 -> f1 = 2*2/(4+1+1)
    assert abs(m["f1_binary"] - 2 * 2 / 6) < 1e-9


def test_logreg_learns_separable():
    rng = np.random.RandomState(0)
    x = rng.randn(400, 4).astype(np.float32)
    y = (x[:, 0] + x[:, 1] > 0).astype(np.int64)
    m = clf.kfold_cv(x, y, 2, k=5)
    assert m["accuracy"] > 0.93


# ---------------------------------------------------------------------------
# pipeline integration (tiny but real end-to-end run)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_scenario():
    ds = make_dataset("bcw", seed=1)
    return make_scenario(ds, n_active_features=5, n_aligned=150, seed=1)


def test_apcvfl_end_to_end(tiny_scenario, quick_epochs):
    r = pipeline.run_apcvfl(tiny_scenario, max_epochs=quick_epochs)
    assert r.rounds == 1                       # the headline claim
    # measured exchange == analytic Eq. 6 footprint (+ PSI hashes)
    assert r.comm["by_stage"]["step1"] == comm.apcvfl_footprint_bytes(
        tiny_scenario.n_aligned)
    # the one data exchange is uplink (passive -> active): the channel's
    # uplink total is step1 plus the PSI reply hashes
    psi_reply = [t.nbytes for t in r.channel.log
                 if t.what == "psi/hashes_b"]
    assert r.comm["uplink_bytes"] == (r.comm["by_stage"]["step1"]
                                      + sum(psi_reply))
    assert 0.0 <= r.metrics["accuracy"] <= 1.0
    assert r.z_dim == 256                      # M3 == M2 (Table 3)


def test_apcvfl_beats_local_with_converged_training(tiny_scenario,
                                                    quick_epochs):
    """Qualitative paper claim on the synthetic data: the federated
    representation beats the raw local probe (here with the aligned-only
    variant which uses the full joint latents)."""
    local = pipeline.run_local_baseline(tiny_scenario)
    joint = pipeline.run_apcvfl_aligned_only(tiny_scenario,
                                             max_epochs=quick_epochs,
                                             test_size=30)
    assert joint.metrics["accuracy"] > local["accuracy"] - 0.05


@pytest.mark.slow
def test_apcvfl_paper_epoch_budget(tiny_scenario):
    """Full paper budget (<=200 epochs, early stopping with patience 10):
    the complete four-step protocol converges and beats the local probe."""
    local = pipeline.run_local_baseline(tiny_scenario)
    r = pipeline.run_apcvfl(tiny_scenario)          # paper defaults
    assert r.metrics["accuracy"] > local["accuracy"] - 0.05
    assert all(e <= 200 for e in r.epochs.values())
