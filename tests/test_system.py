"""End-to-end behaviour tests for the paper's system."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm, pipeline
from repro.data.synthetic import make_dataset
from repro.data.vertical import make_scenario

ENV = dict(os.environ, PYTHONPATH="src")


@pytest.fixture(scope="module")
def scenario():
    ds = make_dataset("bcw", seed=3)
    return make_scenario(ds, n_active_features=3, n_aligned=200, seed=3)


def test_single_communication_round(scenario):
    """Headline claim: APC-VFL needs exactly ONE data exchange, and its
    size follows Eq. 6 exactly."""
    r = pipeline.run_apcvfl(scenario, max_epochs=8)
    assert r.rounds == 1
    data = [t for t in r.channel.log if t.stage != "psi"]
    assert len(data) == 1
    assert data[0].nbytes == comm.apcvfl_footprint_bytes(scenario.n_aligned)
    assert data[0].direction == "uplink"       # passive -> active


def test_active_party_inference_is_independent(scenario):
    """After training, inference uses ONLY g3 + classifier on active data —
    no passive-party state is referenced."""
    from repro.core import autoencoder as ae
    r = pipeline.run_apcvfl(scenario, max_epochs=8)
    g3 = r.params["g3"]
    z = ae.encode(g3, jnp.asarray(scenario.active.x[:10]))
    assert z.shape == (10, r.z_dim)
    assert np.isfinite(np.asarray(z)).all()


def test_unaligned_samples_used_in_training(scenario):
    """The student autoencoder trains on the FULL active dataset (aligned +
    unaligned) — the capability missing from SplitNN/FedCVT."""
    n_total = len(scenario.active.x)
    assert n_total > scenario.n_aligned   # scenario really has unaligned rows
    r = pipeline.run_apcvfl(scenario, max_epochs=8)
    assert 0.0 <= r.metrics["accuracy"] <= 1.0


def test_encoder_quality_probe_algorithm1(scenario):
    """Appendix F Algorithm 1 runs and reports the equivalence gap."""
    out = pipeline.train_encoder_with_probe(
        scenario.active.x, scenario.active.y, scenario.n_classes,
        [scenario.active.x.shape[1], 32, 64], max_epochs=3, k=3)
    assert len(out["history"]["probe"]) == 3
    assert np.isfinite(out["gap"])


def test_lm_training_loop_improves():
    """The distributed-runtime training path optimizes a real objective."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "internlm2-1.8b", "--smoke", "--steps", "30", "--batch", "4",
         "--seq", "64"], capture_output=True, text=True, env=ENV)
    assert out.returncode == 0, out.stderr[-2000:]
    assert '"improved": true' in out.stdout


def test_checkpoint_roundtrip():
    from repro.checkpoint import ckpt
    from repro.configs import get_smoke
    from repro.models import model as M
    from repro.sharding.policy import init_params
    cfg = get_smoke("internlm2-1.8b")
    params = init_params(M.schema(cfg), jax.random.PRNGKey(0), jnp.float32)
    ckpt.save("/tmp/test_ckpt.npz", {"params": params}, step=7)
    back = ckpt.restore("/tmp/test_ckpt.npz", {"params": params})
    a = jax.tree.leaves(params)
    b = jax.tree.leaves(back["params"])
    assert all(np.allclose(np.asarray(x), np.asarray(y)) for x, y in zip(a, b))


def test_dryrun_single_combo_subprocess():
    """One real multi-device lowering (512 fake devices) as a system test."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "internlm2-1.8b", "--shape", "decode_32k", "--out",
         "/tmp/test_dryrun"], capture_output=True, text=True, env=ENV)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "wrote" in out.stdout
