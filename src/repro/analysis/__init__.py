"""jaxlint: static analysis + runtime guards for the engine's
compile/sync/dtype contracts.

Two layers (see ``analysis/README.md`` for the rules reference):

* :mod:`repro.analysis.lint` — an AST lint pass over the package with
  JAX/Pallas-specific rules (R001-R007: host calls in traced code, traced
  branching, jit static-arg hygiene, donated-buffer reuse, PRNG key reuse,
  Pallas grid arithmetic, dtype hygiene), gated by a committed baseline
  (``analysis/baseline.json``).  Pure stdlib ``ast`` — running the linter
  never initializes a JAX backend.

* :mod:`repro.analysis.guards` — runtime context managers proving the
  contracts the linter can only approximate: ``compile_counter`` (actual
  XLA compilations per entry point), ``no_host_sync`` (device->host
  transfers per fit / per predict), ``audit_dtypes`` (engine pytrees stay
  in the float32/int32 family), against budgets committed in
  ``ANALYSIS_budgets.json``.

CLI: ``python -m repro.launch.lint`` (``--json``, ``--diff``,
``--baseline-update``).
"""
from __future__ import annotations

# lint is import-light (stdlib only); guards imports jax and is pulled in
# lazily so `python -m repro.launch.lint` stays backend-free.
from repro.analysis.lint import (Finding, lint_paths, lint_source,
                                 load_baseline, write_baseline)

__all__ = ["Finding", "lint_paths", "lint_source", "load_baseline",
           "write_baseline"]
