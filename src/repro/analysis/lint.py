"""jaxlint static layer: an AST index of the package that knows which
function bodies run *traced* (inside ``jax.jit`` / ``vmap`` / ``lax.scan``
/ ``pallas_call``), plus the lint driver and the baseline machinery.

Why an index and not per-file regexes: every rule that matters here is a
property of *traced* code ("no ``np.*`` inside a jitted body", "no Python
``if`` on a traced value"), and tracedness is non-local — a loss function
defined in ``core/autoencoder.py`` is traced because ``core/training.py``
closes a jitted scan over it.  So the linter parses the whole package
once, marks traced roots, and propagates tracedness across modules
through resolvable references before any rule runs:

* **roots** — defs decorated/wrapped with a tracing transform
  (``jax.jit``, ``partial(jax.jit, ...)``, ``jax.vmap``, ``jax.grad``,
  ``jax.custom_vjp``), defs passed as arguments to a tracing call
  (``lax.scan``/``cond``/``while_loop``/``switch``, ``pl.pallas_call``,
  ``jax.jit(self._impl)``), defs passed to the training engine's
  loss-consuming entry points (``training.train*`` / ``get_*engine``),
  and — by repo convention — defs named ``loss`` / ``*_loss`` (losses are
  always consumed by a jitted engine);
* **propagation** — a def lexically nested in a traced def is traced; any
  function *referenced* inside a traced body is traced, resolved through
  each module's import aliases (``ae.encode`` in a traced loss marks
  ``repro.core.autoencoder.encode``), iterated to a fixpoint.

Staticness convention: names listed in a jit's ``static_argnames`` (or
``static_argnums``) and **keyword-only parameters** are treated as static
Python values — the repo-wide idiom for hyperparameters threaded into
jitted/Pallas code — so branching on them is legal (R002) and converting
them with ``float()``/``int()`` is legal (R001).

The baseline (``analysis/baseline.json``) freezes pre-existing debt by
fingerprint ``(rule, file, symbol, code-line)`` — line *numbers* are not
part of the identity, so unrelated edits don't churn it — and every entry
carries a one-line justification.  New violations (fingerprints not in
the baseline, or more occurrences than the baseline count) fail the lint.
"""
from __future__ import annotations

import ast
import json
import os
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# what counts as "enters a trace"
# ---------------------------------------------------------------------------

# transforms whose function-valued arguments run traced
TRACING_CALLS = {
    "jax.jit", "jax.pmap", "jax.vmap", "jax.grad", "jax.value_and_grad",
    "jax.jacfwd", "jax.jacrev", "jax.hessian", "jax.checkpoint",
    "jax.remat", "jax.custom_vjp", "jax.custom_jvp", "jax.closure_convert",
    "jax.lax.scan", "jax.lax.cond", "jax.lax.while_loop", "jax.lax.switch",
    "jax.lax.map", "jax.lax.fori_loop", "jax.lax.associative_scan",
    "jax.lax.custom_root", "jax.experimental.pallas.pallas_call",
    "jax.experimental.shard_map.shard_map",
}

# repo entry points that trace their function-valued arguments (the loss):
# the engine contract of repro.core.training
ENGINE_CALLS = {
    f"repro.core.training.{name}" for name in (
        "train", "train_epochwise", "train_lanes", "train_lanes_epochwise",
        "train_many", "get_engine", "get_fit_engine", "get_lanes_engine",
        "get_lanes_fit_engine", "get_many_engine")
}

# defs with these names are traced by convention: losses are consumed by
# the jitted engines even when no call site is statically resolvable.
# Factory prefixes are excluded (make_loss BUILDS a loss on the host).
LOSS_NAME_SUFFIX = "loss"
FACTORY_PREFIXES = ("make_", "build_", "get_", "create_")

# parameters that are static Python config by repo convention, either by
# name or by scalar annotation (see analysis/README.md "conventions")
STATIC_PARAM_NAMES = {"cfg", "config", "spec", "hp", "mesh", "mesh_axes"}
STATIC_ANNOTATIONS = {"int", "str", "bool", "float"}

# factory functions whose RETURN VALUE is a jitted callable donating these
# positional argument indices (R004 tracks variables assigned from them)
DONATING_FACTORIES = {
    "repro.core.training.get_engine": (0, 1),
    "repro.core.training.get_lanes_engine": (0, 1),
    "repro.core.training.get_many_engine": (0, 1),
}


# ---------------------------------------------------------------------------
# findings + baseline
# ---------------------------------------------------------------------------

@dataclass
class Finding:
    rule: str                 # "R001"..."R007"
    file: str                 # repo-relative path
    line: int                 # 1-indexed
    symbol: str               # enclosing function qualname ("" = module)
    message: str
    hint: str = ""
    code: str = ""            # stripped source line (fingerprint component)

    @property
    def fingerprint(self) -> Tuple[str, str, str, str]:
        return (self.rule, self.file, self.symbol, self.code)

    def to_dict(self) -> dict:
        return {"rule": self.rule, "file": self.file, "line": self.line,
                "symbol": self.symbol, "message": self.message,
                "hint": self.hint, "code": self.code}


def load_baseline(path: str) -> Dict[Tuple[str, str, str, str], int]:
    """fingerprint -> allowed occurrence count."""
    if not os.path.exists(path):
        return {}
    with open(path) as fh:
        data = json.load(fh)
    out: Dict[Tuple[str, str, str, str], int] = {}
    for e in data.get("entries", []):
        fp = (e["rule"], e["file"], e["symbol"], e["code"])
        out[fp] = out.get(fp, 0) + int(e.get("count", 1))
    return out


def write_baseline(path: str, findings: Sequence[Finding]) -> None:
    """Freeze ``findings`` as the new baseline, keeping the justification
    of any entry whose fingerprint survives."""
    old = {}
    if os.path.exists(path):
        with open(path) as fh:
            for e in json.load(fh).get("entries", []):
                old[(e["rule"], e["file"], e["symbol"], e["code"])] = \
                    e.get("justification", "")
    counts: Dict[Tuple[str, str, str, str], Finding] = {}
    n: Dict[Tuple[str, str, str, str], int] = {}
    for f in findings:
        counts.setdefault(f.fingerprint, f)
        n[f.fingerprint] = n.get(f.fingerprint, 0) + 1
    entries = []
    for fp, f in sorted(counts.items()):
        entries.append({
            "rule": f.rule, "file": f.file, "symbol": f.symbol,
            "code": f.code, "count": n[fp],
            "justification": old.get(fp, "TODO: justify or fix"),
        })
    with open(path, "w") as fh:
        json.dump({"_": "jaxlint baseline: frozen pre-existing findings "
                        "(see analysis/README.md); regenerate with "
                        "python -m repro.launch.lint --baseline-update",
                   "entries": entries}, fh, indent=1)
        fh.write("\n")


def apply_baseline(findings: Sequence[Finding],
                   baseline: Dict[Tuple[str, str, str, str], int]
                   ) -> List[Finding]:
    """Drop findings covered by the baseline; occurrences beyond an
    entry's count still fail (a NEW copy of an old sin is a new sin)."""
    budget = dict(baseline)
    out = []
    for f in findings:
        if budget.get(f.fingerprint, 0) > 0:
            budget[f.fingerprint] -= 1
        else:
            out.append(f)
    return out


# ---------------------------------------------------------------------------
# the module index
# ---------------------------------------------------------------------------

class FuncInfo:
    """One function/lambda definition and what the linter knows about it."""

    __slots__ = ("node", "module", "qualname", "parent", "class_name",
                 "traced", "traced_reason", "is_jit_root", "static_names",
                 "donate_argnums", "children")

    def __init__(self, node, module: "ModuleIndex", qualname: str,
                 parent: Optional["FuncInfo"], class_name: Optional[str]):
        self.node = node
        self.module = module
        self.qualname = qualname
        self.parent = parent
        self.class_name = class_name
        self.traced = False
        self.traced_reason = ""
        self.is_jit_root = False
        self.static_names: set = set()
        self.donate_argnums: Tuple[int, ...] = ()
        self.children: Dict[str, "FuncInfo"] = {}

    # -- parameters ---------------------------------------------------------

    @property
    def arg_names(self) -> List[str]:
        a = self.node.args
        return ([x.arg for x in getattr(a, "posonlyargs", [])]
                + [x.arg for x in a.args] + [x.arg for x in a.kwonlyargs])

    @property
    def kwonly_names(self) -> List[str]:
        return [x.arg for x in self.node.args.kwonlyargs]

    def conventional_static_params(self) -> set:
        """Params static by repo convention: keyword-only, named like
        config (``cfg`` etc.), or annotated with a Python scalar type
        (``pad: int``, ``kind: str`` — hyperparameters, not tracers)."""
        out = set(self.kwonly_names)
        a = self.node.args
        for arg in (list(getattr(a, "posonlyargs", [])) + list(a.args)
                    + list(a.kwonlyargs)):
            if arg.arg in STATIC_PARAM_NAMES:
                out.add(arg.arg)
            ann = arg.annotation
            if isinstance(ann, ast.Name) and ann.id in STATIC_ANNOTATIONS:
                out.add(arg.arg)
        return out

    def effective_static(self) -> set:
        """Static names visible in this body: own static_argnames +
        conventionally-static params, plus every ancestor's — nested defs
        close over the outer statics."""
        names, fi = set(), self
        while fi is not None:
            names |= fi.static_names
            names |= fi.conventional_static_params()
            fi = fi.parent
        return names

    def __repr__(self):
        return (f"<FuncInfo {self.module.modpath}:{self.qualname}"
                f"{' traced' if self.traced else ''}>")


class ModuleIndex(ast.NodeVisitor):
    """Parse one module: definitions, import aliases, source lines."""

    def __init__(self, abspath: str, relpath: str, modpath: str,
                 source: str):
        self.abspath = abspath
        self.relpath = relpath
        self.modpath = modpath          # e.g. "repro.core.training"
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=relpath)
        self.imports: Dict[str, str] = {}     # local alias -> dotted path
        self.funcs: Dict[ast.AST, FuncInfo] = {}
        self.top_names: Dict[str, FuncInfo] = {}
        self.methods: Dict[Tuple[str, str], FuncInfo] = {}
        self._func_stack: List[FuncInfo] = []
        self._class_stack: List[str] = []
        self.visit(self.tree)

    def code_line(self, node) -> str:
        try:
            return self.lines[node.lineno - 1].strip()
        except Exception:
            return ""

    # -- collection ---------------------------------------------------------

    def visit_Import(self, node):
        for a in node.names:
            self.imports[a.asname or a.name.split(".")[0]] = \
                a.name if a.asname else a.name.split(".")[0]

    def visit_ImportFrom(self, node):
        if node.level:                      # relative imports: not used here
            return
        for a in node.names:
            self.imports[a.asname or a.name] = f"{node.module}.{a.name}"

    def _enter_def(self, node, name: str):
        parent = self._func_stack[-1] if self._func_stack else None
        cls = self._class_stack[-1] if self._class_stack else None
        qual = f"{parent.qualname}.{name}" if parent else \
            (f"{cls}.{name}" if cls else name)
        fi = FuncInfo(node, self, qual, parent, cls)
        self.funcs[node] = fi
        if parent is not None:
            parent.children[name] = fi
        elif cls is not None:
            self.methods[(cls, name)] = fi
        else:
            self.top_names[name] = fi
        return fi

    def visit_FunctionDef(self, node):
        self._visit_def(node, node.name)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node):
        fi = self._enter_def(node, "<lambda>")
        self._func_stack.append(fi)
        self.generic_visit(node)
        self._func_stack.pop()

    def _visit_def(self, node, name):
        fi = self._enter_def(node, name)
        self._func_stack.append(fi)
        self.generic_visit(node)
        self._func_stack.pop()

    def visit_ClassDef(self, node):
        self._class_stack.append(node.name)
        self.generic_visit(node)
        self._class_stack.pop()

    # -- name resolution ----------------------------------------------------

    def dotted(self, node) -> Optional[str]:
        """Resolve an expression to a dotted external path through the
        module's import aliases: ``jnp.mean`` -> ``jax.numpy.mean``,
        ``pl.pallas_call`` -> ``jax.experimental.pallas.pallas_call``,
        bare builtins to their own name.  None when unresolvable."""
        if isinstance(node, ast.Name):
            return self.imports.get(node.id, node.id)
        if isinstance(node, ast.Attribute):
            base = self.dotted(node.value)
            return f"{base}.{node.attr}" if base else None
        return None

    def resolve_local(self, node, scope: Optional[FuncInfo]
                      ) -> Optional[FuncInfo]:
        """Resolve a Name/Attribute to a def in THIS module: enclosing
        scopes' nested defs, module top level, or ``self.method``."""
        if isinstance(node, ast.Name):
            fi = scope
            while fi is not None:
                if node.id in fi.children:
                    return fi.children[node.id]
                fi = fi.parent
            return self.top_names.get(node.id)
        if isinstance(node, ast.Attribute) and \
                isinstance(node.value, ast.Name) and node.value.id == "self":
            fi = scope
            while fi is not None and fi.class_name is None:
                fi = fi.parent
            if fi is not None:
                return self.methods.get((fi.class_name, node.attr))
        return None


# ---------------------------------------------------------------------------
# the project index (cross-module fixpoint)
# ---------------------------------------------------------------------------

class ProjectIndex:
    """All modules of a lint run, with tracedness propagated to fixpoint."""

    def __init__(self, modules: Dict[str, ModuleIndex]):
        self.modules = modules
        self._mark_roots()
        self._propagate()

    # -- helpers shared with the rules --------------------------------------

    def resolve_ref(self, mod: ModuleIndex, node,
                    scope: Optional[FuncInfo]) -> Optional[FuncInfo]:
        """Resolve a reference to a FuncInfo, same-module first, then
        cross-module through import aliases (``ae.encode``,
        ``from m import f``)."""
        fi = mod.resolve_local(node, scope)
        if fi is not None:
            return fi
        dotted = mod.dotted(node)
        if not dotted or "." not in dotted:
            return None
        modpath, name = dotted.rsplit(".", 1)
        target = self.modules.get(modpath)
        if target is not None:
            return target.top_names.get(name)
        return None

    def traced_functions(self) -> Iterable[Tuple[ModuleIndex, FuncInfo]]:
        for mod in self.modules.values():
            for fi in mod.funcs.values():
                if fi.traced:
                    yield mod, fi

    def all_functions(self) -> Iterable[Tuple[ModuleIndex, FuncInfo]]:
        for mod in self.modules.values():
            for fi in mod.funcs.values():
                yield mod, fi

    def own_body_nodes(self, fi: FuncInfo) -> Iterable[ast.AST]:
        """Walk a function's body WITHOUT descending into nested defs
        (each def is examined exactly once, findings attributed to the
        innermost function)."""
        body = fi.node.body
        stack = list(body) if isinstance(body, list) else [body]
        while stack:
            node = stack.pop()
            yield node
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.Lambda)):
                    continue
                stack.append(child)

    # -- root marking -------------------------------------------------------

    def _jit_meta_from_call(self, mod: ModuleIndex, call: ast.Call,
                            fi: FuncInfo) -> None:
        """Record static_argnames/argnums + donate_argnums from a jit(...)
        or partial(jax.jit, ...) expression onto ``fi``."""
        fi.is_jit_root = True
        for kw in call.keywords:
            if kw.arg in ("static_argnames", "static_argnums",
                          "donate_argnums"):
                vals = []
                elts = kw.value.elts if isinstance(
                    kw.value, (ast.Tuple, ast.List)) else [kw.value]
                for e in elts:
                    if isinstance(e, ast.Constant):
                        vals.append(e.value)
                if kw.arg == "static_argnames":
                    fi.static_names |= {v for v in vals if isinstance(v, str)}
                elif kw.arg == "static_argnums":
                    names = fi.arg_names
                    for v in vals:
                        if isinstance(v, int) and v < len(names):
                            fi.static_names.add(names[v])
                else:
                    fi.donate_argnums = tuple(
                        v for v in vals if isinstance(v, int))

    def _mark_roots(self) -> None:
        self._worklist: List[FuncInfo] = []
        for mod in self.modules.values():
            for node, fi in mod.funcs.items():
                # convention: losses run inside the jitted engines
                # (factories like make_loss build one on the host — skip)
                name = fi.qualname.rsplit(".", 1)[-1]
                if (name == LOSS_NAME_SUFFIX
                        or name.endswith("_" + LOSS_NAME_SUFFIX)) and \
                        not name.startswith(FACTORY_PREFIXES):
                    self._mark(fi, "loss-name convention")
                # decorators
                for dec in getattr(node, "decorator_list", []):
                    d = mod.dotted(dec)
                    if d in TRACING_CALLS:
                        fi.is_jit_root = d == "jax.jit"
                        self._mark(fi, f"decorated @{d}")
                    elif isinstance(dec, ast.Call):
                        dc = mod.dotted(dec.func)
                        if dc in TRACING_CALLS:
                            if dc == "jax.jit":
                                self._jit_meta_from_call(mod, dec, fi)
                            self._mark(fi, f"decorated @{dc}(...)")
                        elif dc == "functools.partial" and dec.args and \
                                mod.dotted(dec.args[0]) in TRACING_CALLS:
                            if mod.dotted(dec.args[0]) == "jax.jit":
                                self._jit_meta_from_call(mod, dec, fi)
                            self._mark(fi, "decorated @partial(jit, ...)")
            # call sites: jax.jit(f) / lax.scan(f, ...) / train(_, loss)
            for call in ast.walk(mod.tree):
                if not isinstance(call, ast.Call):
                    continue
                d = mod.dotted(call.func)
                if d not in TRACING_CALLS and d not in ENGINE_CALLS:
                    continue
                scope = self._enclosing(mod, call)
                # engine entry points trace only their LOSS argument —
                # epoch_callback etc. are host-side hooks
                if d in ENGINE_CALLS:
                    candidates = list(call.args) + [
                        k.value for k in call.keywords
                        if k.arg and "loss" in k.arg]
                else:
                    candidates = list(call.args) + [k.value for k in
                                                    call.keywords]
                for arg in candidates:
                    target = None
                    if isinstance(arg, ast.Lambda):
                        target = mod.funcs.get(arg)
                    elif isinstance(arg, (ast.Name, ast.Attribute)):
                        target = self.resolve_ref(mod, arg, scope)
                    if target is not None:
                        if d == "jax.jit":
                            self._jit_meta_from_call(mod, call, target)
                        self._mark(target, f"passed to {d}")

    def _enclosing(self, mod: ModuleIndex, node) -> Optional[FuncInfo]:
        """Innermost FuncInfo whose body contains ``node`` (by position)."""
        best, best_span = None, None
        for fnode, fi in mod.funcs.items():
            if not hasattr(fnode, "lineno") or not hasattr(node, "lineno"):
                continue
            end = getattr(fnode, "end_lineno", fnode.lineno)
            if fnode.lineno <= node.lineno <= end:
                span = end - fnode.lineno
                if best_span is None or span < best_span:
                    best, best_span = fi, span
        return best

    def _mark(self, fi: FuncInfo, reason: str) -> None:
        if not fi.traced:
            fi.traced = True
            fi.traced_reason = reason
            self._worklist.append(fi)

    # -- propagation --------------------------------------------------------

    def _propagate(self) -> None:
        while self._worklist:
            fi = self._worklist.pop()
            # lexically nested defs run traced
            for child in fi.children.values():
                self._mark(child, f"nested in traced {fi.qualname}")
            # any function referenced inside the traced body is traced
            for node in ast.walk(fi.node):
                if isinstance(node, (ast.Name, ast.Attribute)) and \
                        isinstance(getattr(node, "ctx", None), ast.Load):
                    target = self.resolve_ref(fi.module, node, fi)
                    if target is not None and target is not fi:
                        self._mark(target,
                                   f"referenced by traced {fi.qualname}")


# ---------------------------------------------------------------------------
# the driver
# ---------------------------------------------------------------------------

def _modpath_for(relpath: str) -> str:
    """src/repro/core/training.py -> repro.core.training"""
    p = relpath.replace(os.sep, "/")
    for prefix in ("src/",):
        if p.startswith(prefix):
            p = p[len(prefix):]
    p = p[:-3] if p.endswith(".py") else p
    if p.endswith("/__init__"):
        p = p[: -len("/__init__")]
    return p.replace("/", ".")


def _collect_files(paths: Sequence[str], root: str) -> List[str]:
    files = []
    for p in paths:
        ap = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isdir(ap):
            for dirpath, _, names in os.walk(ap):
                files.extend(os.path.join(dirpath, n)
                             for n in names if n.endswith(".py"))
        elif ap.endswith(".py"):
            files.append(ap)
    return sorted(set(files))


def build_index(paths: Sequence[str], root: str) -> ProjectIndex:
    modules: Dict[str, ModuleIndex] = {}
    for ap in _collect_files(paths, root):
        rel = os.path.relpath(ap, root)
        with open(ap) as fh:
            source = fh.read()
        try:
            mod = ModuleIndex(ap, rel, _modpath_for(rel), source)
        except SyntaxError:
            continue                      # not this linter's job
        modules[mod.modpath] = mod
    return ProjectIndex(modules)


def run_rules(project: ProjectIndex,
              report_files: Optional[set] = None) -> List[Finding]:
    """Run every registered rule; ``report_files`` (repo-relative paths)
    restricts REPORTING, not indexing — cross-module tracedness always
    sees the full project (this is what makes ``--diff`` sound)."""
    from repro.analysis import rules as rules_pkg
    findings: List[Finding] = []
    for rule in rules_pkg.ALL_RULES:
        findings.extend(rule.check(project))
    if report_files is not None:
        findings = [f for f in findings if f.file in report_files]
    findings.sort(key=lambda f: (f.file, f.line, f.rule))
    return findings


def lint_paths(paths: Sequence[str], *, root: str,
               baseline_path: Optional[str] = None,
               report_files: Optional[set] = None) -> List[Finding]:
    """Index ``paths`` under ``root`` and return non-baselined findings."""
    project = build_index(paths, root)
    findings = run_rules(project, report_files)
    if baseline_path:
        findings = apply_baseline(findings, load_baseline(baseline_path))
    return findings


def lint_source(source: str, *, modpath: str = "fixture",
                filename: str = "fixture.py") -> List[Finding]:
    """Lint a source snippet in isolation (the test-fixture entry point)."""
    mod = ModuleIndex(filename, filename, modpath, source)
    project = ProjectIndex({modpath: mod})
    return run_rules(project)
