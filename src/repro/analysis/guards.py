"""jaxlint runtime layer: prove the contracts the static rules can only
approximate, against budgets committed in ``ANALYSIS_budgets.json``.

``compile_counter``
    Counts *actual* XLA compilations via ``jax.monitoring``'s
    ``/jax/core/compile/backend_compile_duration`` event — one event per
    backend compile, zero on cache hits.  This is the ground truth the
    warmed-path budgets (train fit: 0, serve bucket steady state: 0) are
    asserted against.

``no_host_sync``
    Proves the one-device->host-transfer-per-fit contract.
    ``jax.transfer_guard`` alone is NOT sufficient: on the CPU backend
    device->host transfers are zero-copy and the guard never fires (it is
    still applied here as a second layer for real accelerator backends).
    So the guard intercepts at the Python boundary instead: implicit
    conversions (``np.asarray``/``float()``/``bool()``/``.item()``/
    ``.tolist()`` on a ``jax.Array``) raise ``HostSyncError`` at the call
    site; explicit ``jax.device_get`` — the engine's one sanctioned sync
    idiom — is counted and checked against ``allowed`` on exit.

``audit_dtypes``
    Asserts every leaf of an engine pytree stays in the float32/int32
    family — the dtype contract R007 pins statically at creation sites.

Not thread-safe and not reentrant (the interpositions are process-global
state); guards are test/bench instrumentation, not production wrappers.
"""
from __future__ import annotations

import json
import os
import threading
from contextlib import contextmanager
from typing import Iterable, Optional

import jax

COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_lock = threading.Lock()
_compile_events = 0
_listener_registered = False


class GuardError(AssertionError):
    """Base class: a runtime contract was violated."""


class CompileBudgetError(GuardError):
    pass


class HostSyncError(GuardError):
    pass


class DtypeAuditError(GuardError):
    pass


# ---------------------------------------------------------------------------
# compile counting
# ---------------------------------------------------------------------------

def _ensure_listener() -> None:
    # jax.monitoring has no per-listener unregistration, so exactly one
    # process-global listener is registered on first use and kept forever;
    # counters snapshot the global count instead of subscribing/unsubscribing.
    global _listener_registered
    with _lock:
        if _listener_registered:
            return

        def _on_event_duration(event, duration, **kwargs):
            global _compile_events
            if event == COMPILE_EVENT:
                _compile_events += 1

        jax.monitoring.register_event_duration_secs_listener(
            _on_event_duration)
        _listener_registered = True


class CompileTally:
    def __init__(self, start: int):
        self._start = start

    @property
    def count(self) -> int:
        return _compile_events - self._start


@contextmanager
def compile_counter(budget: Optional[int] = None, *, label: str = ""):
    """Count XLA backend compilations inside the block.  With ``budget``,
    raise :class:`CompileBudgetError` on exit if the count exceeds it."""
    _ensure_listener()
    tally = CompileTally(_compile_events)
    yield tally
    if budget is not None and tally.count > budget:
        raise CompileBudgetError(
            f"{label or 'block'}: {tally.count} XLA compilations, "
            f"budget is {budget} — a shape/dtype/static-arg change is "
            f"defeating the jit cache")


# ---------------------------------------------------------------------------
# host-sync accounting
# ---------------------------------------------------------------------------

class SyncTally:
    def __init__(self):
        self.device_gets = 0


# conversion dunders that force a device->host materialization.  These
# patch cleanly on the pybind11 ArrayImpl (heap type: setattr updates the
# C slots).  NOTE ``np.asarray(jax_array)`` does NOT route through
# ``__array__`` — numpy takes the C buffer protocol — so the numpy
# module-level entry points are patched as well; that pair is exactly the
# stray-conversion idiom this repo's host code uses.
_SYNC_ATTRS = ("__array__", "__float__", "__int__", "__bool__",
               "__index__", "__complex__", "item", "tolist")
_NUMPY_FUNCS = ("asarray", "array", "ascontiguousarray", "asanyarray")


@contextmanager
def no_host_sync(allowed: int = 0, *, label: str = ""):
    """Forbid device->host transfers inside the block except ``allowed``
    explicit ``jax.device_get`` calls.

    Implicit conversions raise :class:`HostSyncError` at the offending
    call site (best possible traceback); explicit ``jax.device_get`` is
    counted and the total is checked on exit.
    """
    from jax._src import array as _array_mod

    array_cls = _array_mod.ArrayImpl
    tally = SyncTally()
    in_device_get = threading.local()

    def _blocked(name):
        orig = getattr(array_cls, name, None)

        def wrapper(self, *a, **k):
            if getattr(in_device_get, "flag", False):
                return orig(self, *a, **k)
            raise HostSyncError(
                f"{label or 'block'}: implicit device->host sync via "
                f"jax.Array.{name} — route host reads through one "
                f"accounted jax.device_get")

        return orig, wrapper

    orig_device_get = jax.device_get

    def counting_device_get(x):
        tally.device_gets += 1
        in_device_get.flag = True
        try:
            return orig_device_get(x)
        finally:
            in_device_get.flag = False

    import numpy as np

    def _np_guard(fname, orig_fn):
        def wrapper(a, *args, **kwargs):
            if isinstance(a, array_cls) and \
                    not getattr(in_device_get, "flag", False):
                raise HostSyncError(
                    f"{label or 'block'}: implicit device->host sync via "
                    f"np.{fname}(jax.Array) — route host reads through "
                    f"one accounted jax.device_get")
            return orig_fn(a, *args, **kwargs)
        return wrapper

    patched = {}
    for name in _SYNC_ATTRS:
        if hasattr(array_cls, name):
            orig, wrapper = _blocked(name)
            try:
                setattr(array_cls, name, wrapper)
            except (AttributeError, TypeError):
                continue
            patched[name] = orig
    np_patched = {}
    for fname in _NUMPY_FUNCS:
        orig_fn = getattr(np, fname, None)
        if orig_fn is not None:
            np_patched[fname] = orig_fn
            setattr(np, fname, _np_guard(fname, orig_fn))
    jax.device_get = counting_device_get
    try:
        # no-op on CPU (zero-copy d2h), real teeth on accelerators
        with jax.transfer_guard_device_to_host("disallow"):
            yield tally
    finally:
        jax.device_get = orig_device_get
        for name, orig in patched.items():
            setattr(array_cls, name, orig)
        for fname, orig_fn in np_patched.items():
            setattr(np, fname, orig_fn)
    if tally.device_gets > allowed:
        raise HostSyncError(
            f"{label or 'block'}: {tally.device_gets} jax.device_get "
            f"syncs, budget is {allowed} — the engine contract is one "
            f"accounted sync per fit")


# ---------------------------------------------------------------------------
# dtype audit
# ---------------------------------------------------------------------------

ENGINE_DTYPES = frozenset({"float32", "int32", "uint32", "bool"})


def audit_dtypes(tree, allowed: Iterable[str] = ENGINE_DTYPES, *,
                 label: str = "") -> None:
    """Raise :class:`DtypeAuditError` if any leaf of ``tree`` has a dtype
    outside ``allowed`` (default: the engine's float32/int32 family)."""
    allowed = frozenset(allowed)
    bad = []
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    for path, leaf in leaves:
        dtype = getattr(leaf, "dtype", None)
        if dtype is None:        # python scalar leaf: a weak-type seed
            bad.append((jax.tree_util.keystr(path),
                        type(leaf).__name__ + " (python scalar)"))
        elif dtype.name not in allowed:
            bad.append((jax.tree_util.keystr(path), dtype.name))
    if bad:
        listing = ", ".join(f"{p or '<root>'}: {d}" for p, d in bad[:8])
        raise DtypeAuditError(
            f"{label or 'pytree'}: {len(bad)} leaves outside "
            f"{sorted(allowed)} — {listing}")


# ---------------------------------------------------------------------------
# budgets
# ---------------------------------------------------------------------------

BUDGETS_FILENAME = "ANALYSIS_budgets.json"


def repo_root() -> str:
    """Nearest ancestor of this file holding ANALYSIS_budgets.json."""
    d = os.path.dirname(os.path.abspath(__file__))
    while True:
        if os.path.exists(os.path.join(d, BUDGETS_FILENAME)):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            raise FileNotFoundError(
                f"{BUDGETS_FILENAME} not found above {__file__}")
        d = parent


def load_budgets() -> dict:
    with open(os.path.join(repo_root(), BUDGETS_FILENAME)) as fh:
        return json.load(fh)
