"""Rule registry.  Each rule module exposes ``RULE`` (id), ``TITLE``,
``HINT``, and ``check(project) -> list[Finding]``; the driver in
:mod:`repro.analysis.lint` runs them in id order.  Adding a rule =
adding a module here and listing it in ``ALL_RULES``.
"""
from __future__ import annotations

from repro.analysis.rules import (r001_host_calls, r002_traced_branch,
                                  r003_jit_static_args, r004_donation,
                                  r005_key_reuse, r006_pallas_grid,
                                  r007_dtype_hygiene)

ALL_RULES = [r001_host_calls, r002_traced_branch, r003_jit_static_args,
             r004_donation, r005_key_reuse, r006_pallas_grid,
             r007_dtype_hygiene]

RULE_DOCS = {m.RULE: (m.TITLE, m.HINT) for m in ALL_RULES}

__all__ = ["ALL_RULES", "RULE_DOCS"]
