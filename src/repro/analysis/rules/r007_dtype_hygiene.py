"""R007 — dtype hygiene in traced code.

Array creation without an explicit ``dtype`` yields weak-typed (or
platform-default) results: ``jnp.arange(n)`` is weak int, ``jnp.asarray
(True)`` is weak bool, and a stray ``float64`` literal upgrades a whole
engine pytree when ``jax_enable_x64`` is on.  The engine contract is
float32/int32 end-to-end (pinned at runtime by
``repro.analysis.guards.audit_dtypes``); statically, every creation op
inside traced code must say its dtype, and ``float64`` must not appear
at all.
"""
from __future__ import annotations

import ast

from repro.analysis.lint import Finding
from repro.analysis.rules._taint import walk_no_defs

RULE = "R007"
TITLE = "array creation without explicit dtype in traced code"
HINT = ("pass dtype= explicitly (jnp.float32 / jnp.int32 / jnp.bool_) so "
        "weak-type promotion cannot change the engine pytree's dtypes")

# creation ops that default to weak/platform dtypes; value = index into
# positional args at which dtype may be passed positionally (None: kwarg
# only in practice)
CREATE = {
    "zeros": 1, "ones": 1, "empty": 1, "full": 2,
    "asarray": 1, "array": 1, "arange": None, "linspace": None, "eye": None,
}
NAMESPACES = ("jax.numpy.", "numpy.")
F64 = {"jax.numpy.float64", "numpy.float64", "jax.numpy.complex128"}


def _has_dtype(call, pos_index):
    if any(kw.arg == "dtype" for kw in call.keywords):
        return True
    return pos_index is not None and len(call.args) > pos_index


def check(project):
    out = []
    for mod, fi in project.traced_functions():
        for node in walk_no_defs(fi.node):
            if node is not fi.node and isinstance(
                    node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(node, (ast.Name, ast.Attribute)):
                d = mod.dotted(node)
                if d in F64:
                    out.append(Finding(
                        rule=RULE, file=mod.relpath, line=node.lineno,
                        symbol=fi.qualname,
                        message=f"`{d}` in traced engine code — the engine "
                                f"contract is float32/int32",
                        hint=HINT, code=mod.code_line(node)))
            if not isinstance(node, ast.Call):
                continue
            d = mod.dotted(node.func)
            if not d:
                continue
            for ns in NAMESPACES:
                if d.startswith(ns) and d[len(ns):] in CREATE:
                    if not _has_dtype(node, CREATE[d[len(ns):]]):
                        out.append(Finding(
                            rule=RULE, file=mod.relpath, line=node.lineno,
                            symbol=fi.qualname,
                            message=f"`{d.split('.')[-1]}` without an "
                                    f"explicit dtype in traced code "
                                    f"({fi.traced_reason})",
                            hint=HINT, code=mod.code_line(node)))
                    break
    return out
