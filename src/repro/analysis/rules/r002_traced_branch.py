"""R002 — Python control flow on a traced value.

``if`` / ``while`` / ternary on a traced value raises
``TracerBoolConversionError`` at trace time (or worse, silently bakes in
one branch when the value happens to be concrete on the first call).
Branching on static hyperparameters (``static_argnames``, keyword-only
params) and on trace-time facts (``x.ndim``, ``len(params)``) is legal
and not flagged.
"""
from __future__ import annotations

import ast

from repro.analysis.lint import Finding
from repro.analysis.rules._taint import FnScanner, stmt_exprs, walk_no_defs

RULE = "R002"
TITLE = "Python branch on a traced value"
HINT = ("use jax.lax.cond / lax.select / jnp.where for data-dependent "
        "control flow, or make the flag a static_argnames/keyword-only "
        "hyperparameter")


class _Scanner(FnScanner):

    def on_stmt(self, s):
        if isinstance(s, (ast.If, ast.While)) and self.tainted(s.test):
            kind = "if" if isinstance(s, ast.If) else "while"
            self._report(s.test, f"Python `{kind}` on a traced value")
        for expr in stmt_exprs(s):
            for node in walk_no_defs(expr):
                if isinstance(node, ast.IfExp) and self.tainted(node.test):
                    self._report(node.test,
                                 "ternary condition on a traced value")

    def _report(self, node, msg):
        self.findings.append(Finding(
            rule=RULE, file=self.mod.relpath, line=node.lineno,
            symbol=self.fi.qualname,
            message=f"{msg} ({self.fi.traced_reason})",
            hint=HINT, code=self.mod.code_line(node)))


def check(project):
    out = []
    for mod, fi in project.traced_functions():
        out.extend(_Scanner(project, mod, fi).run())
    return out
