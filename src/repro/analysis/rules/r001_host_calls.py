"""R001 — host-library call on a traced value.

``np.*`` / ``math.*`` / ``float()`` / ``.item()`` / ``bool()`` /
``jax.device_get`` applied to a traced value inside jitted/scanned/
vmapped code forces a device->host sync (or a trace-time error), turning
the one-sync-per-fit engine contract into one-sync-per-step.  Host calls
on *static* values (hyperparameters, shapes) are legal trace-time
arithmetic and are not flagged.
"""
from __future__ import annotations

import ast

from repro.analysis.lint import Finding
from repro.analysis.rules._taint import FnScanner, stmt_exprs, walk_no_defs

RULE = "R001"
TITLE = "host-library call on a traced value"
HINT = ("stay in jax.numpy/lax inside traced code; if a host value is "
        "really needed, return it and convert after the jitted call "
        "(one accounted jax.device_get)")

HOST_PREFIXES = ("numpy.", "math.", "scipy.")
CAST_BUILTINS = {"float", "int", "bool", "complex"}
SYNC_METHODS = {"item", "tolist", "block_until_ready"}
SYNC_FUNCS = {"jax.device_get"}


class _Scanner(FnScanner):

    def on_stmt(self, s):
        for expr in stmt_exprs(s):
            for node in walk_no_defs(expr):
                if isinstance(node, ast.Call):
                    self._call(node)

    def _call(self, call):
        d = self.mod.dotted(call.func)
        args = list(call.args) + [k.value for k in call.keywords]
        any_tainted = any(self.tainted(a) for a in args)
        bad = None
        if d and d.startswith(HOST_PREFIXES) and any_tainted:
            bad = f"{d.split('.')[0]}.* call"
        elif d in CAST_BUILTINS and any_tainted:
            bad = f"{d}() cast"
        elif d in SYNC_FUNCS and any_tainted:
            bad = f"{d}()"
        elif (isinstance(call.func, ast.Attribute)
              and call.func.attr in SYNC_METHODS
              and self.tainted(call.func.value)):
            bad = f".{call.func.attr}()"
        if bad:
            self.findings.append(Finding(
                rule=RULE, file=self.mod.relpath, line=call.lineno,
                symbol=self.fi.qualname,
                message=f"{bad} on a traced value inside traced code "
                        f"({self.fi.traced_reason})",
                hint=HINT, code=self.mod.code_line(call)))


def check(project):
    out = []
    for mod, fi in project.traced_functions():
        out.extend(_Scanner(project, mod, fi).run())
    return out
