"""R005 — ``jax.random`` key consumed twice without split/fold_in.

Every ``jax.random.*`` consumer (samplers AND ``split`` itself — JAX's
contract is that a key is used exactly once, for anything) burns the key
it is given.  Passing the same key name to a second consumer yields
correlated randomness: identical dropout masks across layers, identical
permutations across epochs.  Rebinding the name (``key, sub =
jax.random.split(key)``) resets it; ``fold_in(key, step)`` does NOT
consume (deriving many streams from one base key is its whole point —
the engine's per-epoch idiom).  Mutually exclusive ``if`` branches are
analyzed independently, and loop bodies are scanned twice so
per-iteration sampling from an un-resplit key surfaces.
"""
from __future__ import annotations

import ast

from repro.analysis.lint import Finding
from repro.analysis.rules._taint import FnScanner, stmt_exprs, walk_no_defs

RULE = "R005"
TITLE = "jax.random key reused without split/fold_in"
HINT = ("derive a fresh key per consumer: `key, sub = jax.random."
        "split(key)` or `jax.random.fold_in(key, step)`")

# non-consuming jax.random functions: creators take a seed (an int), not
# a key, and fold_in(key, step) is the SANCTIONED way to derive many
# streams from one base key (the engine's per-epoch idiom) — neither
# burns a key
NON_CONSUMING = {"PRNGKey", "key", "wrap_key_data", "key_data", "clone",
                 "fold_in"}


class _Scanner(FnScanner):

    LOOP_PASSES = 2

    def __init__(self, project, mod, fi):
        super().__init__(project, mod, fi)
        self.consumed = {}     # key var name -> line of first consumption
        self._reported = set()

    def on_stmt(self, s):
        for expr in stmt_exprs(s):
            for node in walk_no_defs(expr):
                if isinstance(node, ast.Call):
                    self._call(node)

    def _call(self, call):
        d = self.mod.dotted(call.func)
        if not d or not d.startswith("jax.random."):
            return
        fn = d.rsplit(".", 1)[-1]
        if fn in NON_CONSUMING:
            return
        if not call.args or not isinstance(call.args[0], ast.Name):
            return
        name = call.args[0].id
        if name in self.consumed:
            key = (name, call.lineno)
            if key not in self._reported:
                self._reported.add(key)
                self.findings.append(Finding(
                    rule=RULE, file=self.mod.relpath, line=call.lineno,
                    symbol=self.fi.qualname,
                    message=f"key `{name}` consumed by jax.random.{fn} but "
                            f"already consumed at line "
                            f"{self.consumed[name]}",
                    hint=HINT, code=self.mod.code_line(call)))
        else:
            self.consumed[name] = call.lineno

    def on_rebind(self, name):
        self.consumed.pop(name, None)

    def fork_state(self):
        state = super().fork_state()
        state["consumed"] = dict(self.consumed)
        return state

    def restore_state(self, state):
        super().restore_state(state)
        self.consumed = dict(state["consumed"])

    def merge_state(self, other):
        super().merge_state(other)
        self.consumed.update(other["consumed"])


def check(project):
    out = []
    for mod, fi in project.all_functions():
        out.extend(_Scanner(project, mod, fi).run())
    return out
