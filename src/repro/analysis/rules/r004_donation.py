"""R004 — donated buffer reused after a ``donate_argnums`` call.

The engine donates ``(params, opt_state)`` into each jitted epoch step so
XLA can update in place.  Reading the donated Python name afterwards hits
a deleted buffer (``RuntimeError: Array has been deleted``) — or, in a
loop, passes a dead buffer back into the next iteration.  The fix is the
engine's own idiom: rebind the name from the call's results
(``params, opt_state, ... = engine(params, opt_state, ...)``).

Tracked donors: defs with ``donate_argnums`` (decorator or ``jax.jit``
call site) and variables holding the result of the known donating engine
factories (``get_engine`` / ``get_lanes_engine`` / ``get_many_engine``).
Loop bodies are scanned twice so iteration-carried reuse surfaces.
"""
from __future__ import annotations

import ast

from repro.analysis.lint import DONATING_FACTORIES, Finding
from repro.analysis.rules._taint import (FnScanner, assigned_names,
                                         stmt_exprs, walk_no_defs)

RULE = "R004"
TITLE = "donated buffer reused after donate_argnums call"
HINT = ("rebind the name from the call's results "
        "(`params, opt_state, ... = step(params, opt_state, ...)`); a "
        "donated buffer is deleted on dispatch")


class _Scanner(FnScanner):

    LOOP_PASSES = 2

    def __init__(self, project, mod, fi):
        super().__init__(project, mod, fi)
        self.donated = {}      # var name -> line where it was donated
        self.engines = {}      # var name -> donate positions of its callee
        self._reported = set()

    def on_stmt(self, s):
        exprs = stmt_exprs(s)
        # 1) uses of already-donated names (old state — before this
        #    statement's own rebinds clear anything)
        for expr in exprs:
            for node in walk_no_defs(expr):
                if isinstance(node, ast.Name) and \
                        isinstance(node.ctx, ast.Load) and \
                        node.id in self.donated:
                    key = (node.id, node.lineno)
                    if key not in self._reported:
                        self._reported.add(key)
                        self.findings.append(Finding(
                            rule=RULE, file=self.mod.relpath,
                            line=node.lineno, symbol=self.fi.qualname,
                            message=f"`{node.id}` used after being donated "
                                    f"at line {self.donated[node.id]}",
                            hint=HINT, code=self.mod.code_line(node)))
        # 2) new donations in this statement
        for expr in exprs:
            for node in walk_no_defs(expr):
                if isinstance(node, ast.Call):
                    self._maybe_donate(node)
        # 3) engine-factory bindings (`eng = get_engine(...)`)
        if isinstance(s, ast.Assign) and isinstance(s.value, ast.Call):
            d = self.mod.dotted(s.value.func)
            positions = DONATING_FACTORIES.get(d) or DONATING_FACTORIES.get(
                f"{self.mod.modpath}.{d}" if d else "")
            if positions:
                for name in assigned_names(s.targets):
                    self.engines[name] = positions

    def _maybe_donate(self, call):
        positions = None
        if isinstance(call.func, ast.Name) and call.func.id in self.engines:
            positions = self.engines[call.func.id]
        else:
            target = self.project.resolve_ref(self.mod, call.func, self.fi)
            if target is not None and target.donate_argnums:
                positions = target.donate_argnums
        if not positions:
            return
        for i in positions:
            if i < len(call.args) and isinstance(call.args[i], ast.Name):
                self.donated[call.args[i].id] = call.lineno

    def on_rebind(self, name):
        self.donated.pop(name, None)
        self.engines.pop(name, None)

    def fork_state(self):
        state = super().fork_state()
        state["donated"] = dict(self.donated)
        state["engines"] = dict(self.engines)
        return state

    def restore_state(self, state):
        super().restore_state(state)
        self.donated = dict(state["donated"])
        self.engines = dict(state["engines"])

    def merge_state(self, other):
        super().merge_state(other)
        self.donated.update(other["donated"])
        self.engines.update(other["engines"])


def check(project):
    out = []
    for mod, fi in project.all_functions():
        out.extend(_Scanner(project, mod, fi).run())
    return out
