"""R003 — unhashable / dict-typed argument to a jitted function without
``static_argnames``.

A ``dict`` / ``list`` / ``set`` literal passed per-call to a jitted
function is a retrace bomb: every distinct Python value is a new trace
(and dict-of-scalars args never hit the jit cache at all).  Either
declare the parameter in ``static_argnames`` (hashable config) or pass
device arrays (a pytree of ``jnp`` arrays is fine — it is the *literal
containers of Python scalars rebuilt per call* that this rule targets).
Mutable default values on jitted defs are flagged for the same reason.
"""
from __future__ import annotations

import ast

from repro.analysis.lint import Finding

RULE = "R003"
TITLE = "unhashable arg to jitted function without static_argnames"
HINT = ("add the parameter to static_argnames (and make the value "
        "hashable), or pass device arrays instead of per-call Python "
        "containers")

UNHASHABLE = (ast.Dict, ast.List, ast.Set,
              ast.ListComp, ast.SetComp, ast.DictComp)


def _params(fi):
    names = fi.arg_names
    return names[1:] if names and names[0] == "self" else names


def check(project):
    out = []
    # call sites of known jit roots
    for mod in project.modules.values():
        for call in ast.walk(mod.tree):
            if not isinstance(call, ast.Call):
                continue
            scope = project._enclosing(mod, call)
            target = project.resolve_ref(mod, call.func, scope)
            if target is None or not target.is_jit_root:
                continue
            params = _params(target)
            for i, a in enumerate(call.args):
                name = params[i] if i < len(params) else None
                if isinstance(a, UNHASHABLE) and \
                        (name is None or name not in target.static_names):
                    out.append(Finding(
                        rule=RULE, file=mod.relpath, line=a.lineno,
                        symbol=(scope.qualname if scope else ""),
                        message=f"{type(a).__name__} literal passed to "
                                f"jitted `{target.qualname}` "
                                f"(param `{name or '?'}` is not static)",
                        hint=HINT, code=mod.code_line(a)))
            for kw in call.keywords:
                if kw.arg and isinstance(kw.value, UNHASHABLE) and \
                        kw.arg not in target.static_names:
                    out.append(Finding(
                        rule=RULE, file=mod.relpath, line=kw.value.lineno,
                        symbol=(scope.qualname if scope else ""),
                        message=f"{type(kw.value).__name__} literal passed "
                                f"to jitted `{target.qualname}` "
                                f"(param `{kw.arg}` is not static)",
                        hint=HINT, code=mod.code_line(kw.value)))
    # mutable defaults on jitted defs
    for mod, fi in project.all_functions():
        if not fi.is_jit_root or isinstance(fi.node, ast.Lambda):
            continue
        a = fi.node.args
        pos = [x.arg for x in getattr(a, "posonlyargs", [])] + \
              [x.arg for x in a.args]
        for name, default in zip(pos[len(pos) - len(a.defaults):],
                                 a.defaults):
            if isinstance(default, UNHASHABLE) and \
                    name not in fi.static_names:
                out.append(Finding(
                    rule=RULE, file=mod.relpath, line=default.lineno,
                    symbol=fi.qualname,
                    message=f"mutable default for param `{name}` of "
                            f"jitted `{fi.qualname}`",
                    hint=HINT, code=mod.code_line(default)))
        for kwarg, default in zip(a.kwonlyargs, a.kw_defaults):
            if default is not None and isinstance(default, UNHASHABLE) and \
                    kwarg.arg not in fi.static_names:
                out.append(Finding(
                    rule=RULE, file=mod.relpath, line=default.lineno,
                    symbol=fi.qualname,
                    message=f"mutable default for param `{kwarg.arg}` of "
                            f"jitted `{fi.qualname}`",
                    hint=HINT, code=mod.code_line(default)))
    return out
