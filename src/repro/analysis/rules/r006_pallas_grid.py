"""R006 — Pallas grid floor-division without a divisibility guard.

``grid=(B // block_b,)`` silently drops the last partial tile whenever
``block_b`` does not divide ``B`` — rows past the last full block are
never touched by the kernel.  The repo's two sanctioned idioms are
padding to a multiple first (``pad = (-B) % block_b``) and asserting
divisibility (``assert S % block_q == 0``); both leave a ``%`` by the
same divisor in the enclosing function, which is what this rule looks
for.  A floor-divided grid axis with no matching ``%`` guard anywhere in
the function is flagged.
"""
from __future__ import annotations

import ast

from repro.analysis.lint import Finding
from repro.analysis.rules._taint import walk_no_defs

RULE = "R006"
TITLE = "Pallas grid floor-division without divisibility guard"
HINT = ("pad the array to a multiple of the block first "
        "(`pad = (-n) % block`) or `assert n % block == 0` before "
        "building the grid")

PALLAS_CALL = "jax.experimental.pallas.pallas_call"


def _grid_exprs(call):
    for kw in call.keywords:
        if kw.arg == "grid":
            if isinstance(kw.value, (ast.Tuple, ast.List)):
                yield from kw.value.elts
            else:
                yield kw.value


def check(project):
    out = []
    for mod in project.modules.values():
        for call in ast.walk(mod.tree):
            if not isinstance(call, ast.Call) or \
                    mod.dotted(call.func) != PALLAS_CALL:
                continue
            scope = project._enclosing(mod, call)
            scope_node = scope.node if scope is not None else mod.tree
            # divisors guarded by a `%` anywhere in the enclosing function
            guarded = set()
            assigns = {}
            for n in walk_no_defs(scope_node) if scope is None else \
                    ast.walk(scope_node):
                if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Mod):
                    guarded.add(ast.dump(n.right))
                if isinstance(n, ast.Assign) and len(n.targets) == 1 and \
                        isinstance(n.targets[0], ast.Name):
                    assigns[n.targets[0].id] = n.value
            for elt in _grid_exprs(call):
                # follow one level of `G = A // B` indirection
                expr = elt
                if isinstance(expr, ast.Name) and expr.id in assigns:
                    expr = assigns[expr.id]
                for node in ast.walk(expr):
                    if isinstance(node, ast.BinOp) and \
                            isinstance(node.op, ast.FloorDiv) and \
                            ast.dump(node.right) not in guarded:
                        out.append(Finding(
                            rule=RULE, file=mod.relpath, line=elt.lineno,
                            symbol=(scope.qualname if scope else ""),
                            message="grid axis uses `//` with no `%` "
                                    "divisibility guard in the enclosing "
                                    "function — a partial tile would be "
                                    "silently dropped",
                            hint=HINT, code=mod.code_line(elt)))
    return out
