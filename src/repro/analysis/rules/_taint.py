"""Shared per-function scan machinery for the rules.

``FnScanner`` walks ONE function body in statement order (never entering
nested defs — each def gets its own scanner run, so findings land on the
innermost function) while tracking a *traced-value taint* set:

* seed: the function's non-static parameters (for traced functions);
  static = ``static_argnames`` + keyword-only params (repo convention);
* propagate through assignments: a name assigned from a tainted
  expression is tainted, a name reassigned from a static one is cleared;
* static extractors break the chain: ``len(...)``, ``range(...)``,
  ``.shape`` / ``.ndim`` / ``.dtype`` / ``.size`` are concrete Python
  values *at trace time* even when applied to tracers — without this,
  every ``for i in range(len(params))`` would be a false positive.

Loop bodies can be scanned twice (``LOOP_PASSES = 2``) so loop-carried
hazards — a key consumed each iteration without resplitting, a buffer
donated in iteration *i* and passed again in *i+1* — surface on the
second pass.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

# attribute reads that yield static Python values even on tracers
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize", "nbytes"}
# calls that yield static Python values regardless of their arguments
STATIC_CALLS = {"len", "range", "isinstance", "hasattr", "getattr", "type",
                "str", "repr", "id", "callable"}
# host-library namespaces: their results live on the host (R001's problem,
# not taint's — don't keep propagating device taint through them)
HOST_PREFIXES = ("numpy.", "math.", "scipy.")


def stmt_exprs(s: ast.stmt) -> List[ast.expr]:
    """The expressions belonging to the statement ITSELF (headers only
    for compound statements; bodies are walked as their own statements)."""
    if isinstance(s, ast.Assign):
        return [s.value] + list(s.targets)
    if isinstance(s, ast.AnnAssign):
        return [x for x in (s.value, s.target) if x is not None]
    if isinstance(s, ast.AugAssign):
        return [s.value, s.target]
    if isinstance(s, ast.Expr):
        return [s.value]
    if isinstance(s, ast.Return):
        return [s.value] if s.value is not None else []
    if isinstance(s, (ast.If, ast.While)):
        return [s.test]
    if isinstance(s, (ast.For, ast.AsyncFor)):
        return [s.iter]
    if isinstance(s, (ast.With, ast.AsyncWith)):
        return [i.context_expr for i in s.items]
    if isinstance(s, ast.Assert):
        return [s.test] + ([s.msg] if s.msg else [])
    if isinstance(s, ast.Raise):
        return [x for x in (s.exc, s.cause) if x is not None]
    if isinstance(s, ast.Delete):
        return list(s.targets)
    return []


def walk_no_defs(node: ast.AST) -> Iterable[ast.AST]:
    """ast.walk that does not descend into nested function definitions
    (they are scanned by their own FuncInfo pass)."""
    stack = [node]
    while stack:
        n = stack.pop()
        yield n
        for c in ast.iter_child_nodes(n):
            if isinstance(c, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
                continue
            stack.append(c)


class FnScanner:
    """Statement-ordered scan of one function with taint tracking.

    Subclasses override ``on_stmt`` (called once per statement, BEFORE
    the statement's own assignments update the taint environment — so a
    use-before-rebind in the same statement is seen with the old state)
    and append to ``self.findings``.
    """

    LOOP_PASSES = 1

    def __init__(self, project, mod, fi):
        self.project = project
        self.mod = mod
        self.fi = fi
        self.static = fi.effective_static()
        self.traced = (
            {n for n in fi.arg_names if n not in self.static}
            if fi.traced else set())
        self.findings: list = []

    # -- taint --------------------------------------------------------------

    def tainted(self, node) -> bool:
        if node is None:
            return False
        if isinstance(node, ast.Name):
            return node.id in self.traced
        if isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return False
            return self.tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.tainted(node.value) or self.tainted(node.slice)
        if isinstance(node, ast.Call):
            d = self.mod.dotted(node.func)
            if d in STATIC_CALLS:
                return False
            if d and d.startswith(HOST_PREFIXES):
                return False
            if d and d.startswith("jax."):
                return True
            # resolved defs propagate their arguments' taint (a model
            # helper applied to static config yields a static value)
            target = self.project.resolve_ref(self.mod, node.func, self.fi)
            if target is not None:
                return (any(self.tainted(a) for a in node.args)
                        or any(self.tainted(k.value)
                               for k in node.keywords))
            return (self.tainted(node.func)
                    or any(self.tainted(a) for a in node.args)
                    or any(self.tainted(k.value) for k in node.keywords))
        if isinstance(node, ast.BinOp):
            return self.tainted(node.left) or self.tainted(node.right)
        if isinstance(node, ast.BoolOp):
            return any(self.tainted(v) for v in node.values)
        if isinstance(node, ast.Compare):
            # identity checks (`x is None`) are static at trace time
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False
            return (self.tainted(node.left)
                    or any(self.tainted(c) for c in node.comparators))
        if isinstance(node, ast.UnaryOp):
            return self.tainted(node.operand)
        if isinstance(node, ast.IfExp):
            return (self.tainted(node.test) or self.tainted(node.body)
                    or self.tainted(node.orelse))
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any(self.tainted(e) for e in node.elts)
        if isinstance(node, ast.Dict):
            return (any(self.tainted(k) for k in node.keys if k)
                    or any(self.tainted(v) for v in node.values))
        if isinstance(node, ast.Starred):
            return self.tainted(node.value)
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            return any(self.tainted(g.iter) for g in node.generators)
        if isinstance(node, ast.Lambda):
            return False
        if isinstance(node, (ast.JoinedStr, ast.FormattedValue)):
            return False
        if isinstance(node, ast.Slice):
            return any(self.tainted(x)
                       for x in (node.lower, node.upper, node.step) if x)
        return False

    # -- driving ------------------------------------------------------------

    def run(self) -> list:
        body = self.fi.node.body
        if not isinstance(body, list):      # lambda: body is an expression
            ret = ast.Return(value=body)
            ast.copy_location(ret, body)
            body = [ret]
        self._stmts(body)
        return self.findings

    def _stmts(self, stmts) -> None:
        for s in stmts:
            self._stmt(s)

    def _stmt(self, s) -> None:
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.ClassDef)):
            return
        self.on_stmt(s)
        if isinstance(s, ast.Assign):
            self._assign(s.targets, self.tainted(s.value))
        elif isinstance(s, ast.AnnAssign) and s.value is not None:
            self._assign([s.target], self.tainted(s.value))
        elif isinstance(s, ast.AugAssign):
            if self.tainted(s.value):
                self._assign([s.target], True)
        elif isinstance(s, (ast.For, ast.AsyncFor)):
            self._assign([s.target], self.tainted(s.iter))
            for _ in range(self.LOOP_PASSES):
                self._stmts(s.body)
            self._stmts(s.orelse)
            return
        elif isinstance(s, ast.While):
            for _ in range(self.LOOP_PASSES):
                self._stmts(s.body)
            self._stmts(s.orelse)
            return
        if isinstance(s, ast.If):
            # branches are mutually exclusive: analyze each from the same
            # entry state, then merge (a key consumed in the `if` arm was
            # NOT consumed on the `elif` path); a branch that terminates
            # (`if ...: return` dispatch chains) contributes nothing to
            # the fall-through state
            entry = self.fork_state()
            self._stmts(s.body)
            after_body = self.fork_state()
            self.restore_state(entry)
            self._stmts(s.orelse)
            body_term = _terminates(s.body)
            orelse_term = _terminates(s.orelse)
            if body_term and orelse_term:
                self.restore_state(entry)
            elif orelse_term:
                self.restore_state(after_body)
            elif not body_term:
                self.merge_state(after_body)
        elif isinstance(s, (ast.With, ast.AsyncWith)):
            self._stmts(s.body)
        elif isinstance(s, ast.Try):
            self._stmts(s.body)
            for h in s.handlers:
                self._stmts(h.body)
            self._stmts(s.orelse)
            self._stmts(s.finalbody)

    def _assign(self, targets, is_tainted: bool) -> None:
        for name in assigned_names(targets):
            if is_tainted:
                self.traced.add(name)
            else:
                self.traced.discard(name)
            self.on_rebind(name)

    # -- subclass hooks -----------------------------------------------------

    def on_stmt(self, s) -> None:            # pragma: no cover - interface
        pass

    def on_rebind(self, name: str) -> None:  # pragma: no cover - interface
        pass

    # branch-state fork/merge: base tracks the taint set; subclasses with
    # extra flow state (donated buffers, consumed keys) extend all three
    def fork_state(self):
        return {"traced": set(self.traced)}

    def restore_state(self, state) -> None:
        self.traced = set(state["traced"])

    def merge_state(self, other) -> None:
        self.traced |= other["traced"]


def _terminates(stmts) -> bool:
    return bool(stmts) and isinstance(
        stmts[-1], (ast.Return, ast.Raise, ast.Break, ast.Continue))


def assigned_names(targets) -> List[str]:
    out: List[str] = []
    stack = list(targets)
    while stack:
        t = stack.pop()
        if isinstance(t, ast.Name):
            out.append(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            stack.extend(t.elts)
        elif isinstance(t, ast.Starred):
            stack.append(t.value)
    return out
