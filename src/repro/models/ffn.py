"""Feed-forward blocks: dense (swiglu / squared-relu / gelu) and MoE.

The MoE uses a scatter-based capacity dispatch (sort-free rank computation
via scatter-add counters) rather than the one-hot (tokens, experts, capacity)
einsum: the dense dispatch mask is O(N*E*C) and does not fit HBM at
(1M tokens x 384 experts); the scatter form is O(N*k) index traffic plus the
inherent (E*C, d) expert buffer, and GSPMD lowers the expert-sharded scatter
to an all-to-all — exactly the collective a hand-written expert-parallel
implementation would issue.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.sharding.policy import ParamDef


def schema_ffn(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.ffn_type == "swiglu":
        return {
            "w_gate": ParamDef((d, f), ("fsdp", "tp")),
            "w_up": ParamDef((d, f), ("fsdp", "tp")),
            "w_down": ParamDef((f, d), ("tp", "fsdp")),
        }
    return {  # squared_relu | gelu: plain 2-matrix MLP
        "w_in": ParamDef((d, f), ("fsdp", "tp")),
        "w_out": ParamDef((f, d), ("tp", "fsdp")),
    }


def ffn(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.ffn_type == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
        return h @ p["w_down"]
    h = x @ p["w_in"]
    if cfg.ffn_type == "squared_relu":        # nemotron-4 [arXiv:2402.16819]
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    return h @ p["w_out"]


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------

def schema_moe(cfg: ModelConfig) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamDef((d, E), ("fsdp", None), dtype="float32"),
        "w_gate": ParamDef((E, d, f), ("ep", "fsdp", None)),
        "w_up": ParamDef((E, d, f), ("ep", "fsdp", None)),
        "w_down": ParamDef((E, f, d), ("ep", None, "fsdp")),
    }


def moe(p: dict, cfg: ModelConfig, x: jax.Array):
    """x: (B, S, d) -> (y (B,S,d), aux_loss scalar fp32).

    Top-k softmax routing with per-expert capacity C = ceil(N*k/E * cf);
    overflow tokens are dropped (contribute zero), standard Switch behaviour.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    N = B * S
    if cfg.seq_parallel and cfg.mesh_axes:
        # under sequence parallelism the residual stream is seq-sharded on
        # the tp axis; the scatter dispatch into the expert-sharded buffer
        # would otherwise lower to per-layer collective-permute storms
        # (measured: 66 -> 2753 GB/dev on kimi train). Re-shard tokens to
        # batch-only before routing so dispatch crosses only the ep axis.
        from jax.sharding import PartitionSpec as P
        from repro.sharding.policy import batch_pspec
        x = jax.lax.with_sharding_constraint(
            x, P(batch_pspec(cfg.mesh_axes), None, None))
    xt = x.reshape(N, d)

    logits = (xt.astype(jnp.float32) @ p["router"])               # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                          # (N, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)           # renormalize

    # Switch aux load-balance loss: E * sum_e fraction_tokens_e * mean_prob_e
    me = jnp.mean(probs, axis=0)                                  # (E,)
    onehot_top1 = jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(onehot_top1, axis=0)
    aux = E * jnp.sum(me * ce)

    # --- dispatch ----------------------------------------------------------
    C = int(np.ceil(N * k / E * cfg.capacity_factor))
    eid = topi.reshape(N * k)                                     # (Nk,)
    w = topw.reshape(N * k).astype(x.dtype)
    tok = jnp.repeat(jnp.arange(N, dtype=jnp.int32), k)

    # rank of each entry within its expert, in entry order
    order = jnp.argsort(eid)                                      # stable
    eid_s = eid[order]
    counts = jnp.zeros((E,), jnp.int32).at[eid].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    rank_s = jnp.arange(N * k, dtype=jnp.int32) - starts[eid_s]
    tok_s = tok[order]
    w_s = w[order]
    valid = rank_s < C
    dest = jnp.where(valid, eid_s * C + rank_s, E * C)            # E*C = drop slot

    buf = jnp.zeros((E * C + 1, d), x.dtype).at[dest].set(xt[tok_s])
    ein = buf[:-1].reshape(E, C, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ein, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", ein, p["w_up"])
    eout = jnp.einsum("ecf,efd->ecd", h, p["w_down"])             # (E, C, d)

    # Combine formulated as a SCATTER-ADD from the expert-sharded buffer
    # into token order — NOT a gather. GSPMD cannot shard a gather whose
    # operand is expert-sharded (it replicates the (E*C, d) buffer on every
    # device: measured 112 TB/device on kimi-k2 train), whereas the mirror
    # scatter lowers like the dispatch direction (~3 TB). We scatter each
    # slot's weighted output row to its owning token; dropped entries land
    # in the N-th (trash) row.
    tok_of_slot = jnp.full((E * C + 1,), N, jnp.int32).at[dest].set(tok_s)
    w_of_slot = jnp.zeros((E * C + 1,), x.dtype).at[dest].set(w_s)
    flat = eout.reshape(E * C, d)
    contrib = flat * w_of_slot[:-1, None]
    y = jnp.zeros((N + 1, d), x.dtype).at[tok_of_slot[:-1]].add(contrib)[:-1]
    return y.reshape(B, S, d), aux.astype(jnp.float32)
