"""GQA self-attention and cross-attention with KV-cache decode.

Sharding: q/k/v/o projections are tensor-parallel along the flat head dim
(``tp``) and FSDP along d_model (``fsdp``).  With ``replicate_kv=True`` the
KV projections stay replicated along tp — a beyond-paper perf knob that
removes the K/V all-gather GSPMD otherwise inserts when n_kv_heads does not
divide the tp axis (see EXPERIMENTS.md section "Perf").

Decode uses a slot-position cache: ``k/v`` of shape (B, W, K, hd) plus an
int32 ``slot_pos`` (W,) recording the absolute position written in each slot
(-1 = empty).  Full-attention decode is the special case W = seq_len; the
sliding-window variant rolls slots with ``pos % W``.  RoPE is applied at
write time so slot order never matters.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.common import NEG_INF, causal_mask, rope
from repro.sharding.policy import ParamDef


class KVCache(NamedTuple):
    k: jax.Array          # (B, W, K, hd)
    v: jax.Array          # (B, W, K, hd)
    slot_pos: jax.Array   # (W,) int32, -1 = empty


def schema_attention(cfg: ModelConfig, cross: bool = False) -> dict:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    kv_tp = None if cfg.replicate_kv else "tp"
    s = {
        "wq": ParamDef((d, H * hd), ("fsdp", "tp")),
        "wk": ParamDef((d, K * hd), ("fsdp", kv_tp)),
        "wv": ParamDef((d, K * hd), ("fsdp", kv_tp)),
        "wo": ParamDef((H * hd, d), ("tp", "fsdp")),
    }
    return s


def _split_heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    return x.reshape(x.shape[:-1] + (n, hd))


def _gqa_expand(kv: jax.Array, H: int, K: int) -> jax.Array:
    """(B, S, K, hd) -> (B, S, H, hd) by repeating each kv head H//K times."""
    if H == K:
        return kv
    B, S, _, hd = kv.shape
    kv = jnp.broadcast_to(kv[:, :, :, None, :], (B, S, K, H // K, hd))
    return kv.reshape(B, S, H, hd)


def _sdpa(q, k, v, bias, softmax_bf16: bool = False) -> jax.Array:
    """q: (B,S,H,hd), k/v: (B,T,H,hd), bias broadcastable to (B,H,S,T).

    softmax_bf16 halves every (S, S) HBM tensor (scores/probs chain) at the
    cost of ~2 decimal digits in the probabilities (max-subtracted, so
    stable); the fp32 path is the default."""
    hd = q.shape[-1]
    if softmax_bf16:
        scale = jnp.asarray(1.0 / np.sqrt(hd), q.dtype)
        scores = jnp.einsum("bshd,bthd->bhst", q * scale, k)   # bf16 S^2
        scores = scores + bias.astype(scores.dtype)
        m = jnp.max(scores.astype(jnp.float32), axis=-1, keepdims=True)
        p = jnp.exp(scores - m.astype(scores.dtype))
        probs = p / jnp.sum(p, axis=-1, keepdims=True).astype(p.dtype)
        return jnp.einsum("bhst,bthd->bshd", probs, v)
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd) + bias
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v)
    return out


def _sdpa_chunked(q, k, v, *, causal: bool, window: int, chunk: int) -> jax.Array:
    """Online-softmax attention scanned over kv chunks — the flash-attention
    recurrence expressed in XLA: no (S, S) score tensor ever reaches HBM,
    only (S, chunk) tiles live inside the scan body.  This is the pure-JAX
    twin of ``kernels/flash_attention.py`` (which is the TPU Pallas version)
    and is what the dry-run lowers, so the roofline memory term reflects the
    fused behaviour. q/k/v: (B, S, H, hd)."""
    B, S, H, hd = q.shape
    nchunks = S // chunk
    assert S % chunk == 0
    qf = q.astype(jnp.float32) / np.sqrt(hd)
    kc = jnp.moveaxis(k.astype(jnp.float32).reshape(B, nchunks, chunk, H, hd), 1, 0)
    vc = jnp.moveaxis(v.astype(jnp.float32).reshape(B, nchunks, chunk, H, hd), 1, 0)
    rows = jnp.arange(S, dtype=jnp.int32)

    def body(carry, inp):
        m, l, acc = carry                      # (B,H,S), (B,H,S), (B,S,H,hd)
        j, kj, vj = inp
        s = jnp.einsum("bshd,bthd->bhst", qf, kj)          # (B,H,S,chunk)
        cols = j * chunk + jnp.arange(chunk, dtype=jnp.int32)
        ok = jnp.ones((S, chunk), bool)
        if causal:
            ok &= cols[None, :] <= rows[:, None]
        if window:
            ok &= (rows[:, None] - cols[None, :]) < window
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(ok, p, 0.0)
        l = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhst,bthd->bshd", p, vj)
        acc = acc * jnp.moveaxis(alpha, 1, 2)[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    a0 = jnp.zeros((B, S, H, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(nchunks, dtype=jnp.int32), kc, vc))
    out = acc / jnp.maximum(jnp.moveaxis(l, 1, 2), 1e-30)[..., None]
    return out.astype(q.dtype)


def attention(p: dict, cfg: ModelConfig, x: jax.Array, *,
              positions: jax.Array, window: int = 0) -> jax.Array:
    """Full-sequence (train / prefill) causal self-attention."""
    B, S, _ = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = _split_heads(x @ p["wq"], H, hd)
    k = _split_heads(x @ p["wk"], K, hd)
    v = _split_heads(x @ p["wv"], K, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if cfg.use_flash_kernel and cfg.causal:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, _gqa_expand(k, H, K), _gqa_expand(v, H, K),
                                   causal=True, window=window)
    elif cfg.attn_chunk and S > cfg.attn_chunk:
        out = _sdpa_chunked(q, _gqa_expand(k, H, K), _gqa_expand(v, H, K),
                            causal=cfg.causal, window=window,
                            chunk=cfg.attn_chunk)
    else:
        bias = causal_mask(S, window) if cfg.causal else jnp.zeros((S, S), jnp.float32)
        out = _sdpa(q, _gqa_expand(k, H, K), _gqa_expand(v, H, K), bias,
                    softmax_bf16=cfg.softmax_bf16)
    return out.reshape(B, S, H * hd) @ p["wo"]


def cross_attention(p: dict, cfg: ModelConfig, x: jax.Array,
                    kv_feats: jax.Array) -> jax.Array:
    """x: (B,S,d) attends to kv_feats (B,T,d). No mask, no rope."""
    B, S, _ = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = _split_heads(x @ p["wq"], H, hd)
    k = _split_heads(kv_feats @ p["wk"], K, hd)
    v = _split_heads(kv_feats @ p["wv"], K, hd)
    out = _sdpa(q, _gqa_expand(k, H, K), _gqa_expand(v, H, K), 0.0)
    return out.reshape(B, S, H * hd) @ p["wo"]


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, n_slots: int,
               dtype=jnp.bfloat16) -> KVCache:
    K, hd = cfg.n_kv_heads, cfg.hd
    return KVCache(
        k=jnp.zeros((batch, n_slots, K, hd), dtype),
        v=jnp.zeros((batch, n_slots, K, hd), dtype),
        slot_pos=jnp.full((n_slots,), -1, jnp.int32),
    )


def decode_attention(p: dict, cfg: ModelConfig, x: jax.Array, cache: KVCache,
                     pos: jax.Array, window: int = 0):
    """One-token decode. x: (B, 1, d); pos: scalar int32 (current position).

    Returns (out (B,1,d), updated cache)."""
    B = x.shape[0]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    W = cache.k.shape[1]
    q = _split_heads(x @ p["wq"], H, hd)
    k_new = _split_heads(x @ p["wk"], K, hd)
    v_new = _split_heads(x @ p["wv"], K, hd)
    posb = jnp.broadcast_to(pos, (B, 1))
    q = rope(q, posb, cfg.rope_theta)
    k_new = rope(k_new, posb, cfg.rope_theta)

    slot = pos % W if window else pos
    k = jax.lax.dynamic_update_slice(cache.k, k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new, (0, slot, 0, 0))
    slot_pos = jax.lax.dynamic_update_slice(cache.slot_pos, pos[None], (slot,))

    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if window:
        valid &= slot_pos > pos - window
    bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)  # (W,)
    out = _sdpa(q, _gqa_expand(k, H, K), _gqa_expand(v, H, K), bias)
    out = out.reshape(B, 1, H * hd) @ p["wo"]
    return out, KVCache(k, v, slot_pos)


class CrossKV(NamedTuple):
    k: jax.Array   # (B, T, K, hd)
    v: jax.Array


def cross_kv(p: dict, cfg: ModelConfig, kv_feats: jax.Array) -> CrossKV:
    K, hd = cfg.n_kv_heads, cfg.hd
    return CrossKV(_split_heads(kv_feats @ p["wk"], K, hd),
                   _split_heads(kv_feats @ p["wv"], K, hd))


def decode_cross_attention(p: dict, cfg: ModelConfig, x: jax.Array,
                           ckv: CrossKV) -> jax.Array:
    B = x.shape[0]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = _split_heads(x @ p["wq"], H, hd)
    out = _sdpa(q, _gqa_expand(ckv.k, H, K), _gqa_expand(ckv.v, H, K), 0.0)
    return out.reshape(B, 1, H * hd) @ p["wo"]
