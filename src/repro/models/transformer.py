"""Transformer stacks: dense / MoE decoder, encoder-only, and VLM
(cross-attention groups, llama-3.2-vision style).

All homogeneous layer stacks are scanned (``jax.lax.scan`` over stacked
params) so HLO size / compile time stays flat in depth — required to lower
48-61-layer configs on the CPU host in the dry-run.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models.common import (embed, norm_apply, schema_embed, schema_norm,
                                 seq_shard, unembed)
from repro.sharding.policy import ParamDef, stack


# ---------------------------------------------------------------------------
# one decoder block (self-attn + mlp/moe)
# ---------------------------------------------------------------------------

def schema_block(cfg: ModelConfig, moe: bool = False) -> dict:
    return {
        "ln1": schema_norm(cfg.d_model, cfg.norm),
        "attn": attn.schema_attention(cfg),
        "ln2": schema_norm(cfg.d_model, cfg.norm),
        "mlp": ffn_mod.schema_moe(cfg) if moe else ffn_mod.schema_ffn(cfg),
    }


def _is_moe(cfg: ModelConfig) -> bool:
    return cfg.n_experts > 0


def block_fwd(p: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
              window: int):
    h = norm_apply(p["ln1"], x, cfg.norm)
    x = x + attn.attention(p["attn"], cfg, h, positions=positions, window=window)
    h = norm_apply(p["ln2"], x, cfg.norm)
    if _is_moe(cfg):
        y, aux = ffn_mod.moe(p["mlp"], cfg, h)
    else:
        y, aux = ffn_mod.ffn(p["mlp"], cfg, h), jnp.zeros((), jnp.float32)
    return x + y, aux


def block_decode(p: dict, cfg: ModelConfig, x: jax.Array, cache: attn.KVCache,
                 pos: jax.Array, window: int):
    h = norm_apply(p["ln1"], x, cfg.norm)
    a, cache = attn.decode_attention(p["attn"], cfg, h, cache, pos, window)
    x = x + a
    h = norm_apply(p["ln2"], x, cfg.norm)
    if _is_moe(cfg):
        y, _ = ffn_mod.moe(p["mlp"], cfg, h)
    else:
        y = ffn_mod.ffn(p["mlp"], cfg, h)
    return x + y, cache


# ---------------------------------------------------------------------------
# dense / MoE decoder (and bidirectional encoder) stack
# ---------------------------------------------------------------------------

def schema_decoder(cfg: ModelConfig) -> dict:
    s = {
        "embed": schema_embed(cfg.vocab_size, cfg.d_model),
        "blocks": stack(schema_block(cfg, moe=_is_moe(cfg)), cfg.n_layers),
        "ln_f": schema_norm(cfg.d_model, cfg.norm),
    }
    if cfg.family == "audio":   # frames arrive pre-embedded; no token table
        s["embed"] = {"out": s["embed"]["out"]}
    return s


def _scan_blocks(params_blocks, cfg, x, positions, window):
    def body(carry, lp):
        x, aux = carry
        fn = block_fwd
        if cfg.remat:
            fn = jax.checkpoint(block_fwd, static_argnums=(1, 4))
        x = seq_shard(x, cfg)
        x, a = fn(lp, cfg, x, positions, window)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params_blocks)
    return x, aux


def decoder_hidden(params: dict, cfg: ModelConfig, inputs: dict):
    """Token (or pre-embedded frame) inputs -> final hidden states + moe aux."""
    if cfg.family == "audio":
        x = inputs["frames"].astype(jnp.dtype(cfg.dtype))
        B, S = x.shape[0], x.shape[1]
    else:
        tokens = inputs["tokens"]
        B, S = tokens.shape
        x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x, aux = _scan_blocks(params["blocks"], cfg, x, positions,
                          cfg.sliding_window)
    x = norm_apply(params["ln_f"], x, cfg.norm)
    return x, aux


def decoder_logits(params: dict, cfg: ModelConfig, inputs: dict):
    x, aux = decoder_hidden(params, cfg, inputs)
    return unembed(params["embed"], x), aux


def block_fwd_cache(p: dict, cfg: ModelConfig, x: jax.Array,
                    positions: jax.Array, window: int):
    """block_fwd that also emits the roped K/V for cache prefill."""
    h = norm_apply(p["ln1"], x, cfg.norm)
    B, S, _ = h.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    from repro.models.common import causal_mask, rope
    q = (h @ p["attn"]["wq"]).reshape(B, S, H, hd)
    k = (h @ p["attn"]["wk"]).reshape(B, S, K, hd)
    v = (h @ p["attn"]["wv"]).reshape(B, S, K, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    bias = causal_mask(S, window)
    o = attn._sdpa(q, attn._gqa_expand(k, H, K), attn._gqa_expand(v, H, K),
                   bias)
    x = x + o.reshape(B, S, H * hd) @ p["attn"]["wo"]
    h = norm_apply(p["ln2"], x, cfg.norm)
    if _is_moe(cfg):
        y, _ = ffn_mod.moe(p["mlp"], cfg, h)
    else:
        y = ffn_mod.ffn(p["mlp"], cfg, h)
    return x + y, (k, v)


def decoder_prefill_with_cache(params: dict, cfg: ModelConfig,
                               tokens: jax.Array, n_slots: int):
    """Prompt forward that RETURNS the KV cache ready for decode.
    tokens: (B, S) with S <= n_slots. Returns (last_logits (B,V), KVCache
    stacked over layers)."""
    B, S = tokens.shape
    assert S <= n_slots
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(x, lp):
        x, kv = block_fwd_cache(lp, cfg, x, positions, cfg.sliding_window)
        return x, kv

    x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
    x = norm_apply(params["ln_f"], x, cfg.norm)
    logits = unembed(params["embed"], x)[:, -1]

    pad = n_slots - S
    dtype = jnp.dtype(cfg.dtype)
    padkv = lambda t: jnp.pad(t.astype(dtype),
                              ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    slot_pos = jnp.concatenate([jnp.arange(S, dtype=jnp.int32),
                                jnp.full((pad,), -1, jnp.int32)])
    L = cfg.n_layers
    cache = attn.KVCache(padkv(ks), padkv(vs),
                         jnp.broadcast_to(slot_pos, (L, n_slots)).copy())
    return logits, cache


def decoder_init_cache(cfg: ModelConfig, batch: int, n_slots: int, dtype):
    c = attn.init_cache(cfg, batch, n_slots, dtype)
    L = cfg.n_layers
    return attn.KVCache(*(jnp.broadcast_to(a, (L,) + a.shape).copy()
                          if hasattr(a, "shape") else a for a in
                          (c.k, c.v, c.slot_pos)))


def decoder_decode(params: dict, cfg: ModelConfig, token: jax.Array,
                   cache: attn.KVCache, pos: jax.Array, window: int):
    """token: (B,) int32 -> (logits (B, vocab), new cache)."""
    x = embed(params["embed"], token[:, None]).astype(jnp.dtype(cfg.dtype))
    pos = pos.astype(jnp.int32)

    def body(x, layer):
        lp, lc = layer
        x, nc = block_decode(lp, cfg, x, attn.KVCache(*lc), pos, window)
        return x, nc

    x, ncache = jax.lax.scan(body, x, (params["blocks"],
                                       (cache.k, cache.v, cache.slot_pos)))
    x = norm_apply(params["ln_f"], x, cfg.norm)
    logits = unembed(params["embed"], x)[:, 0]
    return logits, attn.KVCache(*ncache)


# ---------------------------------------------------------------------------
# VLM: groups of (cross_attn_period - 1) self blocks + 1 cross block
# ---------------------------------------------------------------------------

def schema_cross_block(cfg: ModelConfig) -> dict:
    return {
        "ln1": schema_norm(cfg.d_model, cfg.norm),
        "xattn": attn.schema_attention(cfg, cross=True),
        "gate": ParamDef((1,), (None,), init="zeros", dtype="float32"),
        "ln2": schema_norm(cfg.d_model, cfg.norm),
        "mlp": ffn_mod.schema_ffn(cfg),
    }


def schema_vlm(cfg: ModelConfig) -> dict:
    g = cfg.cross_attn_period
    assert cfg.n_layers % g == 0, "vlm layers must tile into cross groups"
    G = cfg.n_layers // g
    group = {
        "selfs": stack(schema_block(cfg), g - 1),
        "cross": schema_cross_block(cfg),
    }
    return {
        "embed": schema_embed(cfg.vocab_size, cfg.d_model),
        "groups": stack(group, G),
        "ln_f": schema_norm(cfg.d_model, cfg.norm),
    }


def _cross_block_fwd(p, cfg, x, img):
    h = norm_apply(p["ln1"], x, cfg.norm)
    gate = jnp.tanh(p["gate"]).astype(x.dtype)
    x = x + gate * attn.cross_attention(p["xattn"], cfg, h, img)
    h = norm_apply(p["ln2"], x, cfg.norm)
    return x + ffn_mod.ffn(p["mlp"], cfg, h)


def vlm_hidden(params: dict, cfg: ModelConfig, inputs: dict):
    tokens, img = inputs["tokens"], inputs["image_embeds"]
    B, S = tokens.shape
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    img = img.astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    xblock = (jax.checkpoint(_cross_block_fwd, static_argnums=(1,))
              if cfg.remat else _cross_block_fwd)

    def group_body(x, gp):
        x, _ = _scan_blocks(gp["selfs"], cfg, x, positions, cfg.sliding_window)
        x = xblock(gp["cross"], cfg, x, img)
        return x, None

    x, _ = jax.lax.scan(group_body, x, params["groups"])
    x = norm_apply(params["ln_f"], x, cfg.norm)
    return x, jnp.zeros((), jnp.float32)


def vlm_logits(params: dict, cfg: ModelConfig, inputs: dict):
    x, aux = vlm_hidden(params, cfg, inputs)
    return unembed(params["embed"], x), aux


class VLMCache(NamedTuple):
    k: jax.Array         # (G, g-1, B, W, K, hd)
    v: jax.Array
    slot_pos: jax.Array  # (G, g-1, W)
    xk: jax.Array        # (G, B, T, K, hd)
    xv: jax.Array


def vlm_init_cache(params: dict, cfg: ModelConfig, image_embeds: jax.Array,
                   n_slots: int, dtype) -> VLMCache:
    g = cfg.cross_attn_period
    G = cfg.n_layers // g
    B = image_embeds.shape[0]
    c = attn.init_cache(cfg, B, n_slots, dtype)

    def per_group(gp, img):
        ckv = attn.cross_kv(gp["cross"]["xattn"], cfg, img.astype(dtype))
        return ckv.k, ckv.v

    xk, xv = jax.vmap(per_group, in_axes=(0, None))(params["groups"],
                                                    image_embeds)
    tile = lambda a: jnp.broadcast_to(a, (G, g - 1) + a.shape).copy()
    return VLMCache(tile(c.k), tile(c.v), tile(c.slot_pos), xk, xv)


def vlm_decode(params: dict, cfg: ModelConfig, token: jax.Array,
               cache: VLMCache, pos: jax.Array, window: int):
    x = embed(params["embed"], token[:, None]).astype(jnp.dtype(cfg.dtype))
    pos = pos.astype(jnp.int32)

    def group_body(x, layer):
        gp, (k, v, sp, xk, xv) = layer

        def self_body(x, sl):
            lp, lc = sl
            x, nc = block_decode(lp, cfg, x, attn.KVCache(*lc), pos, window)
            return x, nc

        x, nself = jax.lax.scan(self_body, x, (gp["selfs"], (k, v, sp)))
        h = norm_apply(gp["cross"]["ln1"], x, cfg.norm)
        gate = jnp.tanh(gp["cross"]["gate"]).astype(x.dtype)
        x = x + gate * attn.decode_cross_attention(
            gp["cross"]["xattn"], cfg, h, attn.CrossKV(xk, xv))
        h = norm_apply(gp["cross"]["ln2"], x, cfg.norm)
        x = x + ffn_mod.ffn(gp["cross"]["mlp"], cfg, h)
        return x, nself

    x, (nk, nv, nsp) = jax.lax.scan(
        group_body, x,
        (params["groups"], (cache.k, cache.v, cache.slot_pos, cache.xk, cache.xv)))
    x = norm_apply(params["ln_f"], x, cfg.norm)
    logits = unembed(params["embed"], x)[:, 0]
    return logits, VLMCache(nk, nv, nsp, cache.xk, cache.xv)
