"""Mamba2 (SSD) blocks — TPU-adapted chunked form.

Hardware adaptation note (see DESIGN.md): the reference CUDA Mamba2 kernel
is a fused warp-level scan; on TPU the idiomatic form is the *chunked SSD*
algorithm — intra-chunk contributions become dense (Lc x Lc) matmuls that
map onto the MXU, and only the O(S / Lc) inter-chunk state propagation is a
sequential ``lax.scan``.  Chunk length defaults to 256 (two 128-lanes tiles).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import norm_apply, schema_norm
from repro.sharding.policy import ParamDef


class MambaState(NamedTuple):
    conv: jax.Array   # (B, conv_width-1, conv_channels)
    ssm: jax.Array    # (B, H, N, P) fp32


def conv_channels(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def schema_mamba_block(cfg: ModelConfig) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    ch = conv_channels(cfg)
    return {
        "ln": schema_norm(d, cfg.norm),
        "in_proj": ParamDef((d, 2 * di + 2 * G * N + H), ("fsdp", "tp")),
        "conv_w": ParamDef((cfg.conv_width, ch), (None, "tp"), init="fan_in"),
        "conv_b": ParamDef((ch,), ("tp",), init="zeros"),
        "A_log": ParamDef((H,), (None,), init="mamba_A", dtype="float32"),
        "dt_bias": ParamDef((H,), (None,), init="dt_bias", dtype="float32"),
        "D": ParamDef((H,), (None,), init="ones", dtype="float32"),
        "ln_gate": schema_norm(di, cfg.norm),
        "out_proj": ParamDef((di, d), ("tp", "fsdp")),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z, x, Bm, Cm, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1)
    return z, x, Bm, Cm, dt


def _causal_conv(p: dict, x: jax.Array) -> jax.Array:
    """Depthwise causal conv via shifted adds. x: (B, S, ch)."""
    W = p["conv_w"].shape[0]
    w = p["conv_w"].astype(x.dtype)
    out = x * w[-1]
    for i in range(1, W):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i, :]
        out = out + shifted * w[W - 1 - i]
    return jax.nn.silu(out + p["conv_b"].astype(x.dtype))


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., L) log-decays -> (..., L, L) lower-tri cumulative segment sums."""
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    L = a.shape[-1]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(cfg: ModelConfig, x, dt, A, Bm, Cm, init_state=None):
    """Chunked selective-state-space scan.

    x: (B,S,H,P) fp32-scaled inputs; dt: (B,S,H) fp32; A: (H,) fp32 (negative);
    Bm/Cm: (B,S,G,N).  Returns (y (B,S,H,P), final_state (B,H,N,P))."""
    B_, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Lc = min(cfg.ssm_chunk, S)
    assert S % Lc == 0
    Nc = S // Lc
    rep = H // G

    def chunk(t):  # (B,S,...) -> (B,Nc,Lc,...)
        return t.reshape((B_, Nc, Lc) + t.shape[2:])

    # intra-chunk tensors optionally ride in bf16 (cfg.ssd_bf16): the dense
    # (Lc x Lc) matmuls are the HBM-traffic hot spot; the inter-chunk state
    # recurrence below stays fp32 for stability.
    cdt = jnp.bfloat16 if cfg.ssd_bf16 else jnp.float32
    xdt = chunk(x * dt[..., None]).astype(cdt)            # (B,Nc,Lc,H,P)
    a = chunk(dt * A)                                     # (B,Nc,Lc,H) fp32
    Bh = jnp.repeat(chunk(Bm), rep, axis=3).astype(cdt)   # (B,Nc,Lc,H,N)
    Ch = jnp.repeat(chunk(Cm), rep, axis=3).astype(cdt)

    cs = jnp.cumsum(a, axis=2)                            # (B,Nc,Lc,H)
    # intra-chunk: dense (Lc x Lc) decay-weighted attention-like matmuls
    Lmat = jnp.exp(_segsum(jnp.moveaxis(a, 3, 2))).astype(cdt)
    scores = jnp.einsum("bclhn,bcshn->bchls", Ch, Bh)
    y_intra = jnp.einsum("bchls,bcshp->bclhp", scores * Lmat,
                         xdt).astype(jnp.float32)

    # chunk-final states
    decay_end = jnp.exp(cs[:, :, -1:, :] - cs).astype(cdt)  # (B,Nc,Lc,H)
    states = jnp.einsum("bcshn,bcsh,bcshp->bchnp", Bh, decay_end,
                        xdt).astype(jnp.float32)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cs[:, :, -1, :])                # (B,Nc,H)
    h0 = (jnp.zeros((B_, H, N, P), jnp.float32) if init_state is None
          else init_state)

    def body(h, inp):
        st, dec = inp
        h_out = h
        h = h * dec[:, :, None, None] + st
        return h, h_out

    hT, h_prev = jax.lax.scan(
        body, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                   # (B,Nc,H,N,P)
    y_inter = jnp.einsum("bclhn,bchnp,bclh->bclhp", Ch, h_prev, jnp.exp(cs))

    y = (y_intra + y_inter).reshape(B_, S, H, P)
    return y, hT


def mamba_block(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Full-sequence forward. x: (B,S,d)."""
    B, S, d = x.shape
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    h = norm_apply(p["ln"], x, cfg.norm)
    z, xin, Bm, Cm, dt = _split_proj(cfg, h @ p["in_proj"])
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_out = _causal_conv(p, conv_in)
    xin, Bm, Cm = jnp.split(
        conv_out, [cfg.d_inner, cfg.d_inner + cfg.ssm_groups * cfg.ssm_state],
        axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xin.astype(jnp.float32).reshape(B, S, H, P)
    Bm = Bm.astype(jnp.float32).reshape(B, S, cfg.ssm_groups, cfg.ssm_state)
    Cm = Cm.astype(jnp.float32).reshape(B, S, cfg.ssm_groups, cfg.ssm_state)
    y, _ = ssd_chunked(cfg, xh, dt, A, Bm, Cm)
    y = y + xh * p["D"][:, None]
    y = y.reshape(B, S, cfg.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = norm_apply(p["ln_gate"], y, cfg.norm)
    return x + y @ p["out_proj"]


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> MambaState:
    H, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    return MambaState(
        conv=jnp.zeros((batch, cfg.conv_width - 1, conv_channels(cfg)), dtype),
        ssm=jnp.zeros((batch, H, N, P), jnp.float32),
    )


def mamba_decode(p: dict, cfg: ModelConfig, x: jax.Array, state: MambaState):
    """x: (B, 1, d) -> (y (B,1,d), new state)."""
    B = x.shape[0]
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    h = norm_apply(p["ln"], x, cfg.norm)
    z, xin, Bm, Cm, dt = _split_proj(cfg, h @ p["in_proj"])
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)[:, 0]      # (B, ch)
    W = cfg.conv_width
    w = p["conv_w"].astype(x.dtype)
    hist = jnp.concatenate([state.conv, conv_in[:, None]], axis=1)  # (B,W,ch)
    conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist, w)
                           + p["conv_b"].astype(x.dtype))
    new_conv = hist[:, 1:]
    xin, Bm, Cm = jnp.split(
        conv_out, [cfg.d_inner, cfg.d_inner + G * N], axis=-1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)                                       # (B,H)
    xh = xin.astype(jnp.float32).reshape(B, H, P)
    Bm = jnp.repeat(Bm.astype(jnp.float32).reshape(B, G, N), H // G, axis=1)
    Cm = jnp.repeat(Cm.astype(jnp.float32).reshape(B, G, N), H // G, axis=1)
    upd = jnp.einsum("bh,bhn,bhp->bhnp", dt, Bm, xh)
    ssm = state.ssm * decay[:, :, None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", Cm, ssm) + xh * p["D"][:, None]
    y = y.reshape(B, 1, cfg.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = norm_apply(p["ln_gate"], y, cfg.norm)
    return x + y @ p["out_proj"], MambaState(new_conv, ssm)
