"""Hybrid and xLSTM stack assembly.

zamba2 [arXiv:2411.15242]: Mamba2 backbone with ONE shared attention+MLP
block applied after every ``attn_period`` mamba layers (parameter sharing —
the shared block's gradient accumulates across its applications through the
scan).  Simplification vs the released model (documented in DESIGN.md): the
shared block consumes the hidden stream directly (no concat-with-embedding
projector, no per-application LoRA deltas).

xlstm [arXiv:2405.04517]: groups of (slstm_period-1) mLSTM blocks closed by
one sLSTM block.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba2, xlstm
from repro.models.common import embed, norm_apply, schema_embed, schema_norm, unembed
from repro.models.transformer import block_decode, block_fwd, schema_block
from repro.sharding.policy import stack


# ---------------------------------------------------------------------------
# zamba2
# ---------------------------------------------------------------------------

def schema_zamba(cfg: ModelConfig) -> dict:
    assert cfg.n_layers % cfg.attn_period == 0
    G = cfg.n_layers // cfg.attn_period
    return {
        "embed": schema_embed(cfg.vocab_size, cfg.d_model),
        "mamba": stack(stack(mamba2.schema_mamba_block(cfg), cfg.attn_period), G),
        "shared": schema_block(cfg),           # ONE block, applied G times
        "ln_f": schema_norm(cfg.d_model, cfg.norm),
    }


def zamba_hidden(params: dict, cfg: ModelConfig, inputs: dict):
    tokens = inputs["tokens"]
    B, S = tokens.shape
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    mblock = (jax.checkpoint(mamba2.mamba_block, static_argnums=(1,))
              if cfg.remat else mamba2.mamba_block)
    ablock = (jax.checkpoint(block_fwd, static_argnums=(1, 4))
              if cfg.remat else block_fwd)

    def group(x, gp):
        def inner(x, lp):
            return mblock(lp, cfg, x), None
        x, _ = jax.lax.scan(inner, x, gp)
        x, _ = ablock(params["shared"], cfg, x, positions, cfg.sliding_window)
        return x, None

    x, _ = jax.lax.scan(group, x, params["mamba"])
    x = norm_apply(params["ln_f"], x, cfg.norm)
    return x, jnp.zeros((), jnp.float32)


def zamba_logits(params: dict, cfg: ModelConfig, inputs: dict):
    x, aux = zamba_hidden(params, cfg, inputs)
    return unembed(params["embed"], x), aux


class ZambaCache(NamedTuple):
    conv: jax.Array      # (G, period, B, W-1, ch)
    ssm: jax.Array       # (G, period, B, H, N, P)
    k: jax.Array         # (G, B, W, K, hd)
    v: jax.Array
    slot_pos: jax.Array  # (G, W)


def zamba_init_cache(cfg: ModelConfig, batch: int, n_slots: int, dtype) -> ZambaCache:
    G = cfg.n_layers // cfg.attn_period
    ms = mamba2.init_state(cfg, batch, dtype)
    kv = attn.init_cache(cfg, batch, n_slots, dtype)
    tile = lambda a, pre: jnp.broadcast_to(a, pre + a.shape).copy()
    return ZambaCache(
        conv=tile(ms.conv, (G, cfg.attn_period)),
        ssm=tile(ms.ssm, (G, cfg.attn_period)),
        k=tile(kv.k, (G,)), v=tile(kv.v, (G,)),
        slot_pos=tile(kv.slot_pos, (G,)),
    )


def zamba_decode(params: dict, cfg: ModelConfig, token: jax.Array,
                 cache: ZambaCache, pos: jax.Array, window: int):
    x = embed(params["embed"], token[:, None]).astype(jnp.dtype(cfg.dtype))
    pos = pos.astype(jnp.int32)

    def group(x, layer):
        gp, (conv, ssm, k, v, sp) = layer

        def inner(x, sl):
            lp, (c, s) = sl
            x, ns = mamba2.mamba_decode(lp, cfg, x, mamba2.MambaState(c, s))
            return x, (ns.conv, ns.ssm)

        x, (nconv, nssm) = jax.lax.scan(inner, x, (gp, (conv, ssm)))
        x, nkv = block_decode(params["shared"], cfg, x, attn.KVCache(k, v, sp),
                              pos, window)
        return x, (nconv, nssm, nkv.k, nkv.v, nkv.slot_pos)

    x, new = jax.lax.scan(group, x, (params["mamba"],
                                     (cache.conv, cache.ssm, cache.k, cache.v,
                                      cache.slot_pos)))
    x = norm_apply(params["ln_f"], x, cfg.norm)
    logits = unembed(params["embed"], x)[:, 0]
    return logits, ZambaCache(*new)


# ---------------------------------------------------------------------------
# xLSTM
# ---------------------------------------------------------------------------

def schema_xlstm(cfg: ModelConfig) -> dict:
    assert cfg.n_layers % cfg.slstm_period == 0
    G = cfg.n_layers // cfg.slstm_period
    group = {
        "mlstms": stack(xlstm.schema_mlstm(cfg), cfg.slstm_period - 1),
        "slstm": xlstm.schema_slstm(cfg),
    }
    return {
        "embed": schema_embed(cfg.vocab_size, cfg.d_model),
        "groups": stack(group, G),
        "ln_f": schema_norm(cfg.d_model, cfg.norm),
    }


def xlstm_hidden(params: dict, cfg: ModelConfig, inputs: dict):
    tokens = inputs["tokens"]
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))

    mlblock = (jax.checkpoint(xlstm.mlstm_block, static_argnums=(1,))
               if cfg.remat else xlstm.mlstm_block)
    slblock = (jax.checkpoint(xlstm.slstm_block, static_argnums=(1,))
               if cfg.remat else xlstm.slstm_block)

    def group(x, gp):
        def inner(x, lp):
            return mlblock(lp, cfg, x), None
        x, _ = jax.lax.scan(inner, x, gp["mlstms"])
        x = slblock(gp["slstm"], cfg, x)
        return x, None

    x, _ = jax.lax.scan(group, x, params["groups"])
    x = norm_apply(params["ln_f"], x, cfg.norm)
    return x, jnp.zeros((), jnp.float32)


def xlstm_logits(params: dict, cfg: ModelConfig, inputs: dict):
    x, aux = xlstm_hidden(params, cfg, inputs)
    return unembed(params["embed"], x), aux


class XLSTMCache(NamedTuple):
    mC: jax.Array   # (G, period-1, B, H, P, P)
    mn: jax.Array   # (G, period-1, B, H, P)
    sc: jax.Array   # (G, B, H, Pd)
    sn: jax.Array
    sh: jax.Array
    sm: jax.Array


def xlstm_init_cache(cfg: ModelConfig, batch: int) -> XLSTMCache:
    G = cfg.n_layers // cfg.slstm_period
    m = xlstm.mlstm_init_state(cfg, batch)
    s = xlstm.slstm_init_state(cfg, batch)
    tile = lambda a, pre: jnp.broadcast_to(a, pre + a.shape).copy()
    return XLSTMCache(
        mC=tile(m.C, (G, cfg.slstm_period - 1)),
        mn=tile(m.n, (G, cfg.slstm_period - 1)),
        sc=tile(s.c, (G,)), sn=tile(s.n, (G,)),
        sh=tile(s.h, (G,)), sm=tile(s.m, (G,)),
    )


def xlstm_decode(params: dict, cfg: ModelConfig, token: jax.Array,
                 cache: XLSTMCache, pos: jax.Array, window: int = 0):
    del pos, window   # recurrent: position-free
    x = embed(params["embed"], token[:, None]).astype(jnp.dtype(cfg.dtype))

    def group(x, layer):
        gp, (mC, mn, sc, sn, sh, sm) = layer

        def inner(x, sl):
            lp, (C, n) = sl
            x, ns = xlstm.mlstm_decode(lp, cfg, x, xlstm.MLSTMState(C, n))
            return x, (ns.C, ns.n)

        x, (nmC, nmn) = jax.lax.scan(inner, x, (gp["mlstms"], (mC, mn)))
        x, ns = xlstm.slstm_decode(gp["slstm"], cfg, x,
                                   xlstm.SLSTMState(sc, sn, sh, sm))
        return x, (nmC, nmn, ns.c, ns.n, ns.h, ns.m)

    x, new = jax.lax.scan(group, x, (params["groups"], tuple(cache)))
    x = norm_apply(params["ln_f"], x, cfg.norm)
    logits = unembed(params["embed"], x)[:, 0]
    return logits, XLSTMCache(*new)
