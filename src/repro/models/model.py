"""Model dispatch: one entrypoint per family.

Public surface:
  schema(cfg)                      -> ParamDef pytree
  hidden(params, cfg, inputs)      -> (B,S,d) final hidden states, moe aux
  logits(params, cfg, inputs)      -> (B,S,V) logits, moe aux
  init_cache(params, cfg, shape)   -> decode cache pytree
  decode(params, cfg, token, cache, pos, window) -> (logits (B,V), cache)
  count_params_analytic / count_active_params
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import hybrid, transformer
from repro.sharding.policy import param_count


def schema(cfg: ModelConfig):
    if cfg.family == "vlm":
        return transformer.schema_vlm(cfg)
    if cfg.family == "hybrid":
        return hybrid.schema_zamba(cfg)
    if cfg.family == "ssm":
        return hybrid.schema_xlstm(cfg)
    return transformer.schema_decoder(cfg)   # dense | moe | audio


def hidden(params, cfg: ModelConfig, inputs: dict):
    if cfg.family == "vlm":
        return transformer.vlm_hidden(params, cfg, inputs)
    if cfg.family == "hybrid":
        return hybrid.zamba_hidden(params, cfg, inputs)
    if cfg.family == "ssm":
        return hybrid.xlstm_hidden(params, cfg, inputs)
    return transformer.decoder_hidden(params, cfg, inputs)


def logits(params, cfg: ModelConfig, inputs: dict):
    if cfg.family == "vlm":
        return transformer.vlm_logits(params, cfg, inputs)
    if cfg.family == "hybrid":
        return hybrid.zamba_logits(params, cfg, inputs)
    if cfg.family == "ssm":
        return hybrid.xlstm_logits(params, cfg, inputs)
    return transformer.decoder_logits(params, cfg, inputs)


def supports_decode(cfg: ModelConfig) -> bool:
    return cfg.family != "audio"


def init_cache(params, cfg: ModelConfig, batch: int, n_slots: int,
               image_embeds=None):
    dtype = jnp.dtype(cfg.dtype)
    if cfg.family == "vlm":
        assert image_embeds is not None
        return transformer.vlm_init_cache(params, cfg, image_embeds, n_slots, dtype)
    if cfg.family == "hybrid":
        return hybrid.zamba_init_cache(cfg, batch, n_slots, dtype)
    if cfg.family == "ssm":
        return hybrid.xlstm_init_cache(cfg, batch)
    return transformer.decoder_init_cache(cfg, batch, n_slots, dtype)


def decode(params, cfg: ModelConfig, token, cache, pos, window: int = 0):
    if cfg.family == "vlm":
        return transformer.vlm_decode(params, cfg, token, cache, pos, window)
    if cfg.family == "hybrid":
        return hybrid.zamba_decode(params, cfg, token, cache, pos, window)
    if cfg.family == "ssm":
        return hybrid.xlstm_decode(params, cfg, token, cache, pos, window)
    return transformer.decoder_decode(params, cfg, token, cache, pos, window)


def count_params_analytic(cfg: ModelConfig) -> int:
    return param_count(schema(cfg))


def count_active_params(cfg: ModelConfig) -> int:
    """Active params per token (MoE: only top-k experts count)."""
    total = count_params_analytic(cfg)
    if cfg.n_experts == 0:
        return total
    bank = 3 * cfg.n_experts * cfg.d_model * cfg.d_ff * cfg.n_layers
    active = bank * cfg.experts_per_token // cfg.n_experts
    return total - bank + active
