"""Shared model pieces: norms, RoPE, embeddings, attention masks."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.policy import ParamDef


# ---------------------------------------------------------------------------
# norms (fp32 compute, param dtype fp32 for stability)
# ---------------------------------------------------------------------------

def schema_norm(d_model: int, kind: str = "rmsnorm") -> dict:
    s = {"scale": ParamDef((d_model,), (None,), init="ones", dtype="float32")}
    if kind == "layernorm":
        s["bias"] = ParamDef((d_model,), (None,), init="zeros", dtype="float32")
    return s


def norm_apply(p: dict, x: jax.Array, kind: str = "rmsnorm", eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
        return (y * p["scale"]).astype(dt)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) * 2.0 / hd))
    ang = positions[..., None].astype(jnp.float32) * freqs        # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                               # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def schema_embed(vocab: int, d_model: int) -> dict:
    return {
        "tok": ParamDef((vocab, d_model), ("vocab", "fsdp"), init="embed"),
        "out": ParamDef((d_model, vocab), ("fsdp", "vocab"), init="fan_in"),
    }


def embed(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(p: dict, x: jax.Array) -> jax.Array:
    return x @ p["out"].astype(x.dtype)


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def seq_shard(x: jax.Array, cfg) -> jax.Array:
    """Sequence-parallel sharding constraint on the residual stream
    (B, S, d): seq dim -> tp axis.  Turns per-block TP all-reduces into
    reduce-scatter (+ later all-gather) = half the collective bytes, and
    runs norms/FFN pointwise work on S/tp tokens per device (Korthikanti
    et al.). No-op unless cfg.seq_parallel and the launcher set mesh_axes."""
    if not getattr(cfg, "seq_parallel", False) or not cfg.mesh_axes:
        return x
    from jax.sharding import PartitionSpec as P
    from repro.sharding.policy import batch_pspec
    dp = batch_pspec(cfg.mesh_axes)
    spec = P(dp, "model", *([None] * (x.ndim - 2)))
    return jax.lax.with_sharding_constraint(x, spec)


def causal_mask(S: int, window: int = 0) -> jax.Array:
    """(S, S) additive mask; ``window`` > 0 adds a sliding-window constraint."""
    i = jnp.arange(S, dtype=jnp.int32)[:, None]
    j = jnp.arange(S, dtype=jnp.int32)[None, :]
    ok = j <= i
    if window:
        ok &= (i - j) < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
