"""xLSTM blocks: chunked-parallel mLSTM + sequential sLSTM [arXiv:2405.04517].

TPU adaptation (DESIGN.md): mLSTM's matrix memory C_t = f_t C_{t-1} +
i_t v_t k_t^T admits the same chunked decay-matmul decomposition as SSD, so
the training path is MXU matmuls with an O(S/Lc) inter-chunk scan.  sLSTM has
state->gate feedback (recurrent R weights) and is *inherently* sequential —
it stays a ``lax.scan`` over time; the assigned config places one sLSTM per
``slstm_period`` blocks so the sequential fraction is small.

Simplifications (documented): the max-stabilizer m_t is replaced by the
bounded normalizer denom = max(|q . n|, 1) from the official inference code;
input/forget gates are computed from the current input only for mLSTM (as in
the paper) and with recurrent feedback for sLSTM (as in the paper).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import norm_apply, schema_norm
from repro.sharding.policy import ParamDef


class MLSTMState(NamedTuple):
    C: jax.Array   # (B, H, P, P) fp32 matrix memory
    n: jax.Array   # (B, H, P) fp32 normalizer


class SLSTMState(NamedTuple):
    c: jax.Array   # (B, H, P) fp32
    n: jax.Array
    h: jax.Array
    m: jax.Array   # log-stabilizer


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mlstm_dims(cfg: ModelConfig):
    di = cfg.d_inner
    H = cfg.n_heads
    return di, H, di // H


def schema_mlstm(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, H, P = _mlstm_dims(cfg)
    return {
        "ln": schema_norm(d, cfg.norm),
        "w_up": ParamDef((d, 2 * di), ("fsdp", "tp")),
        "wq": ParamDef((di, di), (None, "tp")),
        "wk": ParamDef((di, di), (None, "tp")),
        "wv": ParamDef((di, di), (None, "tp")),
        "w_if": ParamDef((di, 2 * H), (None, None), init="small", dtype="float32"),
        "b_i": ParamDef((H,), (None,), init="zeros", dtype="float32"),
        "b_f": ParamDef((H,), (None,), init="ones", dtype="float32"),
        "ln_out": schema_norm(di, cfg.norm),
        "w_down": ParamDef((di, d), ("tp", "fsdp")),
    }


def _heads(x, H, P):
    return x.reshape(x.shape[:-1] + (H, P))


def mlstm_block(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Chunked-parallel full-sequence mLSTM. x: (B,S,d)."""
    B, S, _ = x.shape
    di, H, P = _mlstm_dims(cfg)
    h = norm_apply(p["ln"], x, cfg.norm)
    up = h @ p["w_up"]
    xin, z = jnp.split(up, 2, axis=-1)
    q = _heads(xin @ p["wq"], H, P).astype(jnp.float32)
    k = _heads(xin @ p["wk"], H, P).astype(jnp.float32) / jnp.sqrt(P).astype(jnp.float32)
    v = _heads(xin @ p["wv"], H, P).astype(jnp.float32)
    gates = xin.astype(jnp.float32) @ p["w_if"]                   # (B,S,2H)
    ig, fg = jnp.split(gates, 2, axis=-1)
    logi = ig + p["b_i"]                                          # pre-exp input gate
    logf = jax.nn.log_sigmoid(fg + p["b_f"])                      # (B,S,H)

    Lc = min(cfg.ssm_chunk, S)
    assert S % Lc == 0
    Nc = S // Lc
    ch = lambda t: t.reshape((B, Nc, Lc) + t.shape[2:])
    qc, kc, vc = ch(q), ch(k), ch(v)
    a = ch(logf)                                                  # (B,Nc,Lc,H)
    li = ch(logi)
    cs = jnp.cumsum(a, axis=2)

    # intra-chunk: D[l,s] = exp(cs_l - cs_s + logi_s) for l >= s
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]            # (B,Nc,L,S,H)
    Dmat = jnp.where(
        jnp.tril(jnp.ones((Lc, Lc), bool))[None, None, :, :, None],
        jnp.exp(diff + li[:, :, None, :, :]), 0.0)
    scores = jnp.einsum("bclhp,bcshp->bclsh", qc, kc) * Dmat
    y_intra = jnp.einsum("bclsh,bcshp->bclhp", scores, vc)

    # chunk-final (C, n) contributions
    decay_end = jnp.exp(cs[:, :, -1:, :] - cs + li)               # (B,Nc,Lc,H)
    Cstate = jnp.einsum("bcsh,bcshp,bcshq->bchpq", decay_end, kc, vc)
    nstate = jnp.einsum("bcsh,bcshp->bchp", decay_end, kc)
    chunk_decay = jnp.exp(cs[:, :, -1, :])

    def body(carry, inp):
        C, n = carry
        Cc, nc_, dec = inp
        out = (C, n)
        C = C * dec[:, :, None, None] + Cc
        n = n * dec[:, :, None] + nc_
        return (C, n), out

    C0 = jnp.zeros((B, H, P, P), jnp.float32)
    n0 = jnp.zeros((B, H, P), jnp.float32)
    (_, _), (C_prev, n_prev) = jax.lax.scan(
        body, (C0, n0),
        (jnp.moveaxis(Cstate, 1, 0), jnp.moveaxis(nstate, 1, 0),
         jnp.moveaxis(chunk_decay, 1, 0)))
    C_prev = jnp.moveaxis(C_prev, 0, 1)                           # (B,Nc,H,P,P)
    n_prev = jnp.moveaxis(n_prev, 0, 1)                           # (B,Nc,H,P)

    qdec = qc * jnp.exp(cs)[..., None]
    y_inter = jnp.einsum("bclhp,bchpq->bclhq", qdec, C_prev)
    n_inter = jnp.einsum("bclhp,bchp->bclh", qdec, n_prev)

    n_tot = jnp.einsum("bclsh->bclh", scores) + n_inter           # q.n accumulated
    y = (y_intra + y_inter) / jnp.maximum(jnp.abs(n_tot), 1.0)[..., None]
    y = y.reshape(B, S, di).astype(x.dtype)
    y = norm_apply(p["ln_out"], y, cfg.norm) * jax.nn.silu(z)
    return x + y @ p["w_down"]


def mlstm_init_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    _, H, P = _mlstm_dims(cfg)
    return MLSTMState(jnp.zeros((batch, H, P, P), jnp.float32),
                      jnp.zeros((batch, H, P), jnp.float32))


def mlstm_decode(p: dict, cfg: ModelConfig, x: jax.Array, st: MLSTMState):
    """x: (B,1,d)."""
    B = x.shape[0]
    di, H, P = _mlstm_dims(cfg)
    h = norm_apply(p["ln"], x, cfg.norm)
    xin, z = jnp.split(h @ p["w_up"], 2, axis=-1)
    xin1 = xin[:, 0]
    q = _heads(xin1 @ p["wq"], H, P).astype(jnp.float32)
    k = _heads(xin1 @ p["wk"], H, P).astype(jnp.float32) / jnp.sqrt(P).astype(jnp.float32)
    v = _heads(xin1 @ p["wv"], H, P).astype(jnp.float32)
    gates = xin1.astype(jnp.float32) @ p["w_if"]
    ig, fg = jnp.split(gates, 2, axis=-1)
    i = jnp.exp(ig + p["b_i"])                                    # (B,H)
    f = jnp.exp(jax.nn.log_sigmoid(fg + p["b_f"]))
    C = st.C * f[:, :, None, None] + i[:, :, None, None] * \
        jnp.einsum("bhp,bhq->bhpq", k, v)
    n = st.n * f[:, :, None] + i[:, :, None] * k
    num = jnp.einsum("bhp,bhpq->bhq", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", q, n)), 1.0)
    y = (num / den[:, :, None]).reshape(B, 1, di).astype(x.dtype)
    y = norm_apply(p["ln_out"], y, cfg.norm) * jax.nn.silu(z)
    return x + y @ p["w_down"], MLSTMState(C, n)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def schema_slstm(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    P = d // H
    return {
        "ln": schema_norm(d, cfg.norm),
        "wx": ParamDef((d, 4 * d), ("fsdp", "tp")),
        "r": ParamDef((H, P, 4 * P), (None, None, None), init="fan_in",
                      dtype="float32"),
        "b": ParamDef((4 * d,), (None,), init="zeros", dtype="float32"),
        "ln_out": schema_norm(d, cfg.norm),
        "w_down": ParamDef((d, d), ("tp", "fsdp")),
    }


def slstm_init_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    H = cfg.n_heads
    P = cfg.d_model // H
    z = jnp.zeros((batch, H, P), jnp.float32)
    return SLSTMState(z, z, z, z - 1e30)


def _slstm_cell(p, cfg, xt, st: SLSTMState):
    """xt: (B, 4d) precomputed input projection (fp32)."""
    B = xt.shape[0]
    H = cfg.n_heads
    P = cfg.d_model // H
    rec = jnp.einsum("bhp,hpq->bhq", st.h, p["r"])                # (B,H,4P)
    g = xt.reshape(B, H, 4 * P) + rec + p["b"].reshape(H, 4 * P)
    zt, it, ft, ot = jnp.split(g, 4, axis=-1)                     # (B,H,P)
    zt = jnp.tanh(zt)
    ot = jax.nn.sigmoid(ot)
    m_new = jnp.maximum(ft + st.m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(ft + st.m - m_new)
    c = f_p * st.c + i_p * zt
    n = f_p * st.n + i_p
    h = ot * c / jnp.maximum(n, 1e-6)
    return SLSTMState(c, n, h, m_new)


def slstm_block(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    B, S, d = x.shape
    hin = norm_apply(p["ln"], x, cfg.norm)
    xt = (hin @ p["wx"]).astype(jnp.float32)                      # (B,S,4d)

    def body(st, x_t):
        st = _slstm_cell(p, cfg, x_t, st)
        return st, st.h

    st0 = slstm_init_state(cfg, B)
    _, hs = jax.lax.scan(body, st0, jnp.moveaxis(xt, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    y = norm_apply(p["ln_out"], y, cfg.norm)
    return x + y @ p["w_down"]


def slstm_decode(p: dict, cfg: ModelConfig, x: jax.Array, st: SLSTMState):
    B = x.shape[0]
    hin = norm_apply(p["ln"], x, cfg.norm)
    xt = (hin[:, 0] @ p["wx"]).astype(jnp.float32)
    st = _slstm_cell(p, cfg, xt, st)
    y = st.h.reshape(B, 1, cfg.d_model).astype(x.dtype)
    y = norm_apply(p["ln_out"], y, cfg.norm)
    return x + y @ p["w_down"], st
