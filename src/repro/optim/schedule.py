"""LR schedules (pure functions of the int32 step) + a schedule-aware AdamW
wrapper and microbatched gradient accumulation for the train step."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Callable:
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup_steps, warm, peak_lr * cos)
    return lr


def constant(lr_value: float) -> Callable:
    return lambda step: jnp.full((), lr_value, jnp.float32)


def accumulate_grads(loss_fn: Callable, n_micro: int) -> Callable:
    """Wrap loss_fn(params, batch) -> (loss, aux) with microbatch gradient
    accumulation over the leading batch dim (memory/compute trade — one of
    the §Perf levers). Batch size must divide n_micro."""
    if n_micro <= 1:
        return jax.value_and_grad(loss_fn, has_aux=True)

    def split(batch):
        def re(x):
            b = x.shape[0]
            assert b % n_micro == 0, (b, n_micro)
            return x.reshape((n_micro, b // n_micro) + x.shape[1:])
        return jax.tree.map(re, batch)

    def vag(params, batch):
        micro = split(batch)

        def body(carry, mb):
            (loss, aux, grads) = carry
            (l, a), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            grads = jax.tree.map(jnp.add, grads, g)
            aux = jax.tree.map(jnp.add, aux, a)
            return (loss + l, aux, grads), None

        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
        l0 = jnp.zeros((), jnp.float32)
        mb0 = jax.tree.map(lambda x: x[0], micro)
        aux0 = jax.eval_shape(lambda p, b: loss_fn(p, b)[1], params, mb0)
        zero_aux = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), aux0)
        (loss, aux, grads), _ = jax.lax.scan(
            body, (l0, zero_aux, zero_g), micro)
        scale = 1.0 / n_micro
        return (loss * scale,
                jax.tree.map(lambda a: a * scale, aux)), \
            jax.tree.map(lambda g: g * scale, grads)

    return vag
