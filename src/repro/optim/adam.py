"""AdamW on parameter pytrees. Optimizer state inherits param sharding
(m/v are fp32 mirrors of each param leaf).

This is the single Adam implementation in the repo: the LM training loop
uses the LLM-flavoured defaults below (b2=0.95, grad clip 1.0), while the
tabular APC-VFL stages use :func:`paper_adam` (Kingma & Ba defaults,
paper Appendix B) through ``repro.core.training``."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


class AdamW(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0

    def init(self, params) -> AdamState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(jnp.zeros((), jnp.int32),
                         jax.tree.map(zeros, params),
                         jax.tree.map(zeros, params))

    def update(self, grads, state: AdamState, params):
        step = state.step + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-12)) \
            if self.grad_clip else 1.0
        t = step.astype(jnp.float32)
        bc1 = 1.0 - self.b1 ** t
        bc2 = 1.0 - self.b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - self.lr * u
            return new_p.astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, AdamState(step, new_m, new_v), gnorm


def paper_adam(lr: float = 1e-3) -> AdamW:
    """Adam with the APC-VFL paper's settings (Kingma & Ba defaults,
    Appendix B): b2=0.999, no weight decay, no gradient clipping."""
    return AdamW(lr=lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                 grad_clip=0.0)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def sgd_momentum_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
