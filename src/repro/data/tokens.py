"""Synthetic LM token pipeline: a learnable k-order Markov stream (so CE
demonstrably falls below the unigram entropy during training) with
deterministic, shardable batching."""
from __future__ import annotations

import numpy as np


class MarkovTokens:
    """Order-1 Markov chain over ``vocab`` with ``n_states`` latent modes:
    cheap to sample, non-trivial to model, and a clear learnability signal."""

    def __init__(self, vocab: int, seed: int = 0, concentration: float = 0.3):
        rng = np.random.RandomState(seed)
        self.vocab = vocab
        k = min(vocab, 512)            # transition support per token
        self.support = rng.randint(0, vocab, size=(vocab, k))
        raw = rng.dirichlet(np.full(k, concentration), size=vocab)
        self.probs = raw.astype(np.float64)
        self.rng = rng

    def sample(self, batch: int, seq_len: int) -> np.ndarray:
        out = np.empty((batch, seq_len), np.int32)
        cur = self.rng.randint(0, self.vocab, size=batch)
        for t in range(seq_len):
            out[:, t] = cur
            rows = self.probs[cur]
            cum = rows.cumsum(axis=1)
            u = self.rng.rand(batch, 1)
            nxt_idx = (u < cum).argmax(axis=1)
            cur = self.support[cur, nxt_idx]
        return out


def batches(vocab: int, batch: int, seq_len: int, n_steps: int,
            seed: int = 0):
    gen = MarkovTokens(vocab, seed)
    for _ in range(n_steps):
        yield {"tokens": gen.sample(batch, seq_len)}
