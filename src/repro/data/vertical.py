"""Vertical partitioner: split a tabular dataset between an active and a
passive participant, with a controlled number of aligned samples
(paper Sec. 5 "Data partitions")."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.data.synthetic import TabularDataset


@dataclass
class ParticipantData:
    x: np.ndarray
    ids: np.ndarray
    y: np.ndarray | None = None      # only the active party holds labels


@dataclass
class VFLScenario:
    name: str
    active: ParticipantData
    passive: ParticipantData
    n_aligned: int
    n_classes: int
    active_feature_idx: np.ndarray
    passive_feature_idx: np.ndarray


def make_scenario(ds: TabularDataset, *, n_active_features: int,
                  n_aligned: int, seed: int = 0,
                  active_rows: int | None = None) -> VFLScenario:
    """Active gets ``n_active_features`` columns and all labels; passive the
    remaining columns. Exactly ``n_aligned`` row IDs are common to both;
    remaining rows are split disjointly (realistic partial overlap)."""
    rng = np.random.RandomState(seed + 1000)
    d = ds.x.shape[1]
    cols = rng.permutation(d)
    a_cols = np.sort(cols[:n_active_features])
    p_cols = np.sort(cols[n_active_features:])

    n = len(ds.x)
    perm = rng.permutation(n)
    aligned = perm[:n_aligned]
    rest = perm[n_aligned:]
    # split the rest between the two parties (unaligned rows each side)
    half = len(rest) // 2
    a_only, p_only = rest[:half], rest[half:]
    if active_rows is not None:        # e.g. BCW: active holds 500 rows
        extra = max(active_rows - n_aligned - len(a_only), 0)
        a_rows = np.concatenate([aligned, a_only])[:active_rows + extra]
    else:
        a_rows = np.concatenate([aligned, a_only])
    p_rows = np.concatenate([aligned, p_only])

    active = ParticipantData(x=ds.x[a_rows][:, a_cols], ids=ds.ids[a_rows],
                             y=ds.y[a_rows])
    passive = ParticipantData(x=ds.x[p_rows][:, p_cols], ids=ds.ids[p_rows])
    return VFLScenario(ds.name, active, passive, n_aligned, ds.n_classes,
                       a_cols, p_cols)
