"""Streaming synthetic vertical partitions at scale — the million-row
workload of the mesh-sharded lane engine.

``make_scale_lanes`` builds an n-row x K-party vertical partition where
every party holds a correlated nonlinear view of the SAME rows (the
latent-factor recipe of :mod:`repro.data.synthetic`, shared latent ``z``
per row, per-party ``tanh`` feature views), sized so the single-device
host path cannot touch it.  Two properties make it a *scale* generator
rather than a bigger ``make_dataset``:

* **device-resident streaming**: rows are generated block-by-block inside
  one jitted kernel driven by ``jax.random`` — a ``(n, d)`` host numpy
  buffer never exists; blocks are concatenated on device and (optionally)
  placed row-sharded across a mesh's ``data`` axis as they are built;
* **lane-shaped output**: the return value is a list of
  ``training.LaneSpec`` (one per party x seed replicate, each with fresh
  encoder params and its own PRNG stream), i.e. exactly what
  ``training.train_lanes(..., mesh=...)`` consumes — parties ARE lanes.

Labels are not generated: the scale benchmark measures the g1
representation-learning stage (``masked_recon_loss``), which is where the
paper's local-compute claim lives; the probe stage is O(z_dim) and
irrelevant at this scale.

Features are approximately standardized by construction (unit-variance
latents through ``tanh`` of an O(1) mix plus scaled noise, then a fixed
analytic rescale) — exact per-column standardization would need a second
full pass over data that deliberately never sits in one buffer.
"""
from __future__ import annotations

from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autoencoder as ae
from repro.core.training import LaneSpec


@partial(jax.jit, static_argnames=("n_rows", "n_latent", "n_features",
                                   "noise"))
def _party_block(kz, ke, mix, *, n_rows: int, n_latent: int,
                 n_features: int, noise: float):
    """One block of one party's rows, entirely on device: shared latents
    (``kz`` derived from the block index only, so every party's view of a
    block draws the SAME z) through the party's mixing matrix, saturating
    tanh, party-specific noise (``ke``), fixed analytic rescale to ~unit
    variance."""
    z = jax.random.normal(kz, (n_rows, n_latent))
    v = jnp.tanh(z @ mix)                      # var(tanh(N(0,~1))) ~ 0.4
    x = v + noise * jax.random.normal(ke, (n_rows, n_features))
    return (x / np.sqrt(0.4 + noise * noise)).astype(jnp.float32)


def _party_mix(n_latent: int, n_features: int, party: int = 0):
    """Party mixing matrix: each feature reads (mostly) one latent factor
    plus a weak second — the synthetic.make_dataset column recipe,
    vectorized; the party index rotates which latents a party observes, so
    parties hold genuinely different (but correlated) views."""
    mix = np.zeros((n_latent, n_features), np.float32)
    for j in range(n_features):
        mix[(j + party) % n_latent, j] = 1.3
        mix[(j * 5 + 1 + party) % n_latent, j] += 0.25
    return jnp.asarray(mix)


def make_scale_party(n_rows: int, *, n_features: int, n_latent: int = 8,
                     party: int = 0, seed: int = 0, noise: float = 0.5,
                     block_rows: int = 1 << 17, mesh=None) -> jax.Array:
    """One party's ``(n_rows, n_features)`` feature block, streamed on
    device in ``block_rows`` chunks.  Block b's latent key depends only on
    ``(seed, b)`` — NOT on the party — so all parties of one scenario see
    the same latent z per row: a genuine vertical partition.  With a
    ``mesh`` carrying a ``data`` axis that divides ``n_rows``, the
    finished array is placed row-sharded across it."""
    mix = _party_mix(n_latent, n_features, party)
    blocks = []
    done = 0
    b = 0
    while done < n_rows:
        rows = min(block_rows, n_rows - done)
        kz = jax.random.fold_in(jax.random.PRNGKey(seed), b)
        ke = jax.random.fold_in(kz, party + 1)   # party-specific noise
        blocks.append(_party_block(
            kz, ke, mix, n_rows=rows, n_latent=n_latent,
            n_features=n_features, noise=noise))
        done += rows
        b += 1
    x = blocks[0] if len(blocks) == 1 else jnp.concatenate(blocks, axis=0)
    if mesh is not None and "data" in mesh.axis_names:
        from jax.sharding import NamedSharding, PartitionSpec as P
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        if n_rows % sizes["data"] == 0:
            x = jax.device_put(x, NamedSharding(mesh, P("data")))
    return x


def make_scale_lanes(n_rows: int, n_parties: int, *, n_features: int = 16,
                     n_latent: int = 8, widths: Optional[list] = None,
                     seeds=(0,), noise: float = 0.5,
                     block_rows: int = 1 << 17,
                     mesh=None) -> List[LaneSpec]:
    """The benchmark workload: ``n_parties * len(seeds)`` equal-shape
    lanes, one per (party, seed replicate).  Each seed replicate re-draws
    the scenario (fresh latents, fresh encoder inits, its own train/val
    split and epoch perms via ``LaneSpec.seed``); within one seed, all
    parties share latents per row.  Feed the result straight to
    ``training.train_lanes(lanes, ae.masked_recon_loss, mesh=...)``."""
    widths = list(widths) if widths is not None else [n_features, 32, 64]
    if widths[0] != n_features:
        raise ValueError(f"widths[0] ({widths[0]}) must equal n_features "
                         f"({n_features})")
    lanes = []
    for si, s in enumerate(seeds):
        for party in range(n_parties):
            x = make_scale_party(n_rows, n_features=n_features,
                                 n_latent=n_latent, party=party, seed=int(s),
                                 noise=noise, block_rows=block_rows,
                                 mesh=mesh)
            params = ae.init_autoencoder(
                jax.random.fold_in(jax.random.PRNGKey(int(s) + 7001), party),
                widths)
            lanes.append(LaneSpec(params, {"x": x},
                                  seed=int(s) * 100 + party))
    return lanes
