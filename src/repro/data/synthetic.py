"""Synthetic stand-ins for the paper's three datasets (offline container;
MIMIC-III is access-gated — see DESIGN.md "Data gate").

A shared latent factor model generates features so that (a) both parties'
features carry label signal, (b) cross-party features are correlated (the
federation has something to transfer), (c) shapes/class counts match the
paper exactly:

  mimic3: 20000 rows x 15 features, 4 classes (paper reduces 58976 -> 20000)
  bcw:      569 rows x 30 features, 2 classes
  credit: 20000 rows x 23 features, 2 classes (paper reduces 30000 -> 20000)
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class TabularDataset:
    name: str
    x: np.ndarray          # (n, d) float32, standardized
    y: np.ndarray          # (n,) int64
    n_classes: int
    ids: np.ndarray        # (n,) int64 record IDs


SPECS = {
    "mimic3": dict(n=20000, d=15, n_classes=4, latent=6, noise=0.7),
    "bcw": dict(n=569, d=30, n_classes=2, latent=5, noise=0.4),
    "credit": dict(n=20000, d=23, n_classes=2, latent=6, noise=0.9),
}


def make_dataset(name: str, seed: int = 0) -> TabularDataset:
    spec = SPECS[name]
    rng = np.random.RandomState(seed)
    n, d, C, r = spec["n"], spec["d"], spec["n_classes"], spec["latent"]
    z = rng.randn(n, r)
    # class logits: linear + QUADRATIC latent terms.  The quadratic part is
    # invisible to a linear probe on (monotone) raw features but recoverable
    # by a nonlinear encoder — the regime where representation learning (and
    # the paper's distillation toward the joint representation) pays off.
    wy = rng.randn(r, C) * 1.0
    wy2 = rng.randn(r, C) * 1.2
    wyx = rng.randn(r, C) * 0.8
    zsq = z * z - 1.0
    zint = z * np.roll(z, 1, axis=1)
    logits = z @ wy + zsq @ wy2 + zint @ wyx + rng.randn(n, C) * 0.5
    y = np.argmax(logits, axis=1)
    # features: each column is a saturating NONLINEAR view of (mostly) ONE
    # latent factor + noise.  Few features => few observed latents => a
    # party with fewer columns genuinely has less label information (the
    # paper's "limited features" setting), and a linear probe on raw
    # features is suboptimal; an encoder distilled toward the feature-rich
    # joint representation can denoise/invert the nonlinearity (Sec. 4.3).
    x = np.empty((n, d))
    for j in range(d):
        lj = j % r
        lo = (j * 5 + 1) % r
        v = 1.3 * z[:, lj] + 0.25 * z[:, lo]
        x[:, j] = np.tanh(v + 0.3 * rng.randn())   # monotone nonlinear view
    x = x + rng.randn(n, d) * spec["noise"] * 0.6
    x = (x - x.mean(0)) / (x.std(0) + 1e-8)
    ids = rng.permutation(10 * n)[:n].astype(np.int64)
    return TabularDataset(name, x.astype(np.float32), y.astype(np.int64),
                          C, ids)


# paper metric per dataset (Fig. 5 / Table 2)
PAPER_METRIC = {"mimic3": "f1_micro", "bcw": "accuracy", "credit": "f1_binary"}

# paper alignment scenarios (Appendix A) incl. the reduced MIMIC set (Fig. 8)
ALIGNED_SCENARIOS = {
    "mimic3": [10000, 7500, 5000, 2500],
    "bcw": [250, 200, 150, 100],
    "credit": [10000, 7500, 5000, 2500],
}
REDUCED_SCENARIOS = [750, 500, 250, 100]

# active-party feature counts a in {2,3,4,5} (Appendix A/B)
ACTIVE_FEATURES = [5, 4, 3, 2]
