"""Scenario building and grid execution for declarative experiments.

``sweep(spec)`` expands the spec's aligned x K x seed grid, builds each
scenario ONCE and runs every method on it (so per-cell PSI inputs, data
partitions and label vectors are identical across methods), and returns a
flat list of uniform ``RunResult`` records.  ``tidy(results)`` flattens
them into JSON-ready rows for files and dataframes.

Replica-lane dispatch: grid cells that are identical up to seed (the grid
keeps seeds innermost, so they are consecutive) form a *seed group*.  For
a method whose registry entry carries a replicated runner
(``register_replicas``), the whole group runs as ONE call with a leading
replica axis — S seeds of every protocol stage training as stacked lanes
of one vmapped scan (``training.train_lanes``) instead of S sequential
protocol runs.  Methods without one, single-seed groups, and
``replicate=False`` specs take the sequential per-seed path.  Result
order and values are the same either way (parity within the lane-engine
tolerance, pinned by ``tests/test_replicas.py``).

Validation is eager: unknown method names and K>2 grids containing
2-party-only methods raise BEFORE any scenario is built or any model
compiled.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Callable, Iterator, List, Optional

from repro.data.synthetic import make_dataset
from repro.data.vertical import make_scenario
from repro.experiments.registry import get_method
from repro.experiments.results import RunResult
from repro.experiments.specs import ExperimentSpec, ScenarioSpec


def build_scenario(sspec: ScenarioSpec, *, _ds_cache: Optional[dict] = None):
    """Materialize one grid cell: a ``VFLScenario`` for 2 parties or a
    ``VFLScenarioK`` for K > 2.  ``_ds_cache`` (dict) reuses generated
    datasets across cells of the same sweep."""
    cache_key = (sspec.dataset, sspec.seed)
    if _ds_cache is not None and cache_key in _ds_cache:
        ds = _ds_cache[cache_key]
    else:
        ds = make_dataset(sspec.dataset, seed=sspec.seed)
        if _ds_cache is not None:
            _ds_cache[cache_key] = ds
    n_aligned = sspec.resolve_aligned(len(ds.x))
    if sspec.n_parties == 2:
        return make_scenario(ds, n_active_features=sspec.n_active_features,
                             n_aligned=n_aligned, seed=sspec.seed)
    from repro.core.multiparty import make_scenario_k
    return make_scenario_k(ds, n_parties=sspec.n_parties,
                           n_active_features=sspec.n_active_features,
                           n_aligned=n_aligned, seed=sspec.seed)


def _validate(spec: ExperimentSpec) -> None:
    if not spec.methods:
        raise ValueError(f"ExperimentSpec {spec.name!r} has no methods")
    if any(k < 2 for k in spec.n_parties):
        raise ValueError(f"n_parties must all be >= 2, got "
                         f"{list(spec.n_parties)}")
    unknown_axes = set(spec.devices) - {"lane", "data"}
    if unknown_axes:
        raise ValueError(f"devices: unknown mesh axes "
                         f"{sorted(unknown_axes)}; valid axes are "
                         f"['data', 'lane']")
    for ax, n in spec.devices.items():
        if not (isinstance(n, int) and n >= 1):
            raise ValueError(f"devices[{ax!r}] must be a positive int, "
                             f"got {n!r}")
    max_k = max(spec.n_parties, default=2)
    seen_labels = set()
    for m in spec.methods:
        entry = get_method(m.method)       # raises on unknown names
        if max_k > 2 and not entry.supports_multiparty:
            raise ValueError(
                f"method {m.method!r} supports only 2-party scenarios but "
                f"the grid includes n_parties={max_k}")
        if m.row_label in seen_labels:
            raise ValueError(
                f"duplicate method label {m.row_label!r}: give each "
                f"MethodSpec variant a distinct label= so result rows "
                f"stay distinguishable")
        seen_labels.add(m.row_label)
        if entry.accepts is not None:
            unknown = set(spec.overrides) | set(m.params)
            unknown -= entry.accepts
            if unknown:
                raise ValueError(
                    f"method {m.row_label!r} does not accept params "
                    f"{sorted(unknown)}; accepted: "
                    f"{sorted(entry.accepts)}")


def _seed_groups(spec: ExperimentSpec) -> Iterator[List[ScenarioSpec]]:
    """Yield runs of consecutive grid cells identical up to seed.  The
    grid expansion keeps seeds innermost, so each aligned x K cell's
    seeds arrive as one contiguous group."""
    group: List[ScenarioSpec] = []
    for sspec in spec.scenarios():
        if group and replace(sspec, seed=group[0].seed) != group[0]:
            yield group
            group = []
        group.append(sspec)
    if group:
        yield group


def sweep(spec: ExperimentSpec, *,
          progress: Optional[Callable[[str], None]] = None
          ) -> List[RunResult]:
    """Run the whole experiment; one ``RunResult`` per (cell, method).

    Every result's ``scenario`` dict carries the resolved grid coordinates
    and its ``method`` carries the spec's row label, so the output is
    self-describing without the spec in hand.  Seed groups dispatch
    through replica-lane runners where available (module docstring);
    results keep the historical order (cell-major, methods inside each
    cell) regardless of how they were computed.

    ``spec.devices`` builds a lane mesh up front (one mesh for the whole
    sweep — ``launch.mesh.make_lane_mesh`` raises early on a device
    shortfall) and threads it into every replicated dispatch as
    ``mesh=``; sequential dispatches ignore it."""
    _validate(spec)
    mesh = None
    if spec.devices:
        from repro.launch.mesh import make_lane_mesh
        mesh = make_lane_mesh(**spec.devices)
    ds_cache: dict = {}
    results: List[RunResult] = []
    for group in _seed_groups(spec):
        scenarios = [build_scenario(s, _ds_cache=ds_cache) for s in group]
        seeds = [s.seed for s in group]
        coords = [{
            "dataset": s.dataset,
            "n_aligned": sc.n_aligned,
            "n_parties": s.n_parties,
            "n_active_features": s.n_active_features,
        } for s, sc in zip(group, scenarios)]
        per_method: List[List[RunResult]] = []
        for m in spec.methods:
            entry = get_method(m.method)
            mspec = replace(m, params={**spec.overrides, **m.params})
            if (spec.replicate and entry.supports_replicas
                    and len(group) > 1):
                # mesh only when requested: registered runners that
                # predate sharding keep their (scenarios, spec, seeds)
                # signature working untouched
                extra = {} if mesh is None else {"mesh": mesh}
                rs = entry.replicated_fn(scenarios, mspec, seeds=seeds,
                                         **extra)
                if len(rs) != len(group):
                    raise RuntimeError(
                        f"replicated runner for {m.method!r} returned "
                        f"{len(rs)} results for {len(group)} seeds")
            else:
                rs = [entry.fn(sc, mspec, seed=s)
                      for sc, s in zip(scenarios, seeds)]
            per_method.append(rs)
        for j, sspec in enumerate(group):
            for m, rs in zip(spec.methods, per_method):
                r = rs[j]
                r.method = m.row_label
                r.seed = sspec.seed
                r.scenario = dict(coords[j])
                results.append(r)
                if progress is not None:
                    progress(
                        f"{spec.name}: {m.row_label} "
                        f"al={coords[j]['n_aligned']} "
                        f"K={coords[j]['n_parties']} "
                        f"seed={sspec.seed} -> "
                        + " ".join(f"{k}={v:.4f}"
                                   for k, v in r.metrics.items()))
    return results


def tidy(results: List[RunResult]) -> List[dict]:
    """Flatten results into tidy JSON-ready rows (one per run)."""
    return [r.to_record() for r in results]
