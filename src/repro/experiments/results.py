"""The unified experiment result type.

Every registered method — whatever its internal protocol — returns one
``RunResult``: metrics from the shared evaluation, the measured
communication summary (``comm.Channel.summary()`` shape), per-stage epoch
counts, and (optionally) trained params and the live per-link channels for
in-process inspection.  ``to_record()`` flattens a result into one tidy
row for sweeps, JSON files and dataframes.

This module is imported by the ``repro.core`` method modules, so it must
stay free of any ``repro.core`` model/training imports (``comm`` is the
one dependency-free exception).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core import comm


@dataclass
class RunResult:
    """Uniform outcome of one (method, scenario, seed) run.

    ``comm`` is a JSON-ready dict in the ``Channel.summary()`` shape
    (total/uplink/downlink bytes, transfer count, per-stage bytes);
    ``rounds`` is the protocol's round count (analytic where the protocol
    prescribes it, e.g. SplitNN's per-batch exchanges).  ``channels``,
    ``params`` and ``artifacts`` are live objects for in-process use and
    are excluded from ``to_record()``; ``artifacts`` carries the
    non-parameter state the active party holds after training and needs
    for online serving (aligned row ids, the received passive latents —
    consumed by ``repro.serve.vfl.export_bundle``).
    """
    method: str
    metrics: Dict[str, float]
    rounds: int
    epochs: Dict[str, int] = field(default_factory=dict)
    comm: Dict = field(default_factory=dict)
    seed: int = 0
    scenario: Dict = field(default_factory=dict)
    z_dim: Optional[int] = None
    params: Optional[dict] = field(default=None, repr=False)
    channels: Tuple[comm.Channel, ...] = field(default=(), repr=False)
    artifacts: Optional[dict] = field(default=None, repr=False)

    @property
    def channel(self) -> Optional[comm.Channel]:
        """The single link of a 2-party run (None for local baselines)."""
        return self.channels[0] if self.channels else None

    def to_record(self) -> dict:
        """One flat, JSON-ready row: scenario coordinates, metrics, and
        communication totals (per-stage detail stays in ``self.comm``)."""
        rec = {"method": self.method, "seed": self.seed}
        rec.update(self.scenario)
        rec.update(self.metrics)
        rec.update({
            "rounds": self.rounds,
            "comm_total_bytes": self.comm.get("total_bytes", 0),
            "comm_uplink_bytes": self.comm.get("uplink_bytes", 0),
            "comm_downlink_bytes": self.comm.get("downlink_bytes", 0),
            "comm_mb": self.comm.get("total_mb", 0.0),
            "epochs_total": int(sum(self.epochs.values())),
            "z_dim": self.z_dim,
        })
        return rec
