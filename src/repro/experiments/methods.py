"""Built-in method adapters: the ``repro.core`` entry points behind the
registry's one signature ``(scenario, spec, *, seed) -> RunResult``.

Each adapter forwards ``spec.params`` as keyword overrides to the
underlying ``run_*`` function, whose defaults are the paper's settings
(``configs.apcvfl_paper.TABULAR``) — an empty spec reproduces the paper.
Importing this module registers every adapter (the registry does so
lazily on first lookup).
"""
from __future__ import annotations

from repro.core import comm, multiparty, pipeline, privacy, splitnn, \
    vfedtrans
from repro.core.multiparty import VFLScenarioK
from repro.experiments.registry import register_method, register_replicas
from repro.experiments.results import RunResult
from repro.experiments.specs import MethodSpec
from repro.robustness import attacks as rb_attacks
from repro.robustness import defense as rb_defense


@register_method("local", supports_multiparty=True)
def _local(scenario, spec: MethodSpec, *, seed: int = 0) -> RunResult:
    """Raw-feature probe at the active party: no training hyperparameters,
    no communication — ``spec.params`` (e.g. sweep-wide overrides like
    ``max_epochs``) is intentionally ignored."""
    metrics = pipeline.run_local_baseline(scenario, seed=seed)
    return RunResult(method="local", metrics=metrics, rounds=0,
                     comm=comm.Channel().summary(), seed=seed)


@register_method("apcvfl", supports_multiparty=True,
                 params_from=pipeline.run_apcvfl)
def _apcvfl(scenario, spec: MethodSpec, *, seed: int = 0) -> RunResult:
    # run_apcvfl and run_apcvfl_k share one keyword surface (pinned by
    # test_apcvfl_k_signature_matches_2party), so params_from covers both
    if isinstance(scenario, VFLScenarioK):
        return multiparty.run_apcvfl_k(scenario, seed=seed, **spec.params)
    return pipeline.run_apcvfl(scenario, seed=seed, **spec.params)


@register_replicas("apcvfl")
def _apcvfl_replicated(scenarios, spec: MethodSpec, *, seeds, mesh=None):
    """Seed groups run through the replica-lane runners — every protocol
    stage is S stacked lanes of one vmapped scan: 2-party cells via
    ``run_apcvfl_replicated``, K-party cells via
    ``run_apcvfl_k_replicated`` (S*K g1 lanes per dispatch).  ``mesh``
    (from a spec's ``devices`` field) shards every stage's lane axis
    across devices."""
    if isinstance(scenarios[0], VFLScenarioK):
        return multiparty.run_apcvfl_k_replicated(scenarios, seeds=seeds,
                                                  mesh=mesh, **spec.params)
    return pipeline.run_apcvfl_replicated(scenarios, seeds=seeds,
                                          mesh=mesh, **spec.params)


@register_method("serve_smoke", supports_multiparty=True,
                 params_from=pipeline.run_apcvfl)
def _serve_smoke(scenario, spec: MethodSpec, *, seed: int = 0) -> RunResult:
    """Train-then-serve record: runs the full APC-VFL protocol, exports
    the ``ModelBundle`` (round-tripped through the checkpoint layer), and
    drives a small mixed request stream through the bucketed serving
    engine (``repro.serve.vfl``).  The record's metrics combine the
    training accuracy with the serving health numbers — active-path
    parity vs the training-time evaluator, cache hit-rate, throughput —
    so a spec grid can regression-track deployment alongside accuracy."""
    import os
    import tempfile

    import jax.numpy as jnp
    import numpy as np

    from repro.core import autoencoder as ae
    from repro.core import classifier as clf
    from repro.serve import vfl as sv

    if isinstance(scenario, VFLScenarioK):
        result = multiparty.run_apcvfl_k(scenario, seed=seed, **spec.params)
    else:
        result = pipeline.run_apcvfl(scenario, seed=seed, **spec.params)
    bundle = sv.export_bundle(result, scenario)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "bundle")
        bundle.save(path)
        bundle = sv.ModelBundle.load(path)    # serve the reloaded copy
    engine = sv.VFLServingEngine(bundle)
    engine.warmup()
    requests = sv.make_request_stream(
        scenario.active.x, scenario.active.ids, 200, seed=seed + 1,
        max_rows=48, p_known=0.5)
    stats = sv.serve_stream(engine, requests)

    # active-path parity vs the training-time evaluator on the same params
    probe = jnp.asarray(np.asarray(scenario.active.x[:64], np.float32))
    want = clf.logreg_logits(bundle.head_active,
                             ae.encode(bundle.g3, probe))
    got = engine.predict_active(probe)
    parity = float(np.max(np.abs(got - np.asarray(want))))

    metrics = dict(result.metrics)
    metrics.update({
        "serve_parity_max_abs": parity,
        "serve_rows_per_s": float(stats["rows_per_s"]),
        "serve_latency_ms_p50": float(stats["latency_ms_p50"]),
        "serve_cache_hit_rate": float(stats["cache_hit_rate"] or 0.0),
        "serve_batch_shapes": float(
            stats["compiled"]["distinct_batch_shapes"]),
    })
    return RunResult(method="serve_smoke", metrics=metrics,
                     rounds=result.rounds, epochs=result.epochs,
                     comm=result.comm, seed=seed, z_dim=result.z_dim,
                     params=result.params, channels=result.channels,
                     artifacts=result.artifacts)


@register_method("inversion", params_from=privacy.run_inversion)
def _inversion(scenario, spec: MethodSpec, *, seed: int = 0) -> RunResult:
    """Representation-inversion privacy probe (``core.privacy``): spec
    params sweep the attacker's auxiliary budget (``n_aux``); metrics are
    leakage numbers (r2_mean/attack_mse), not classification scores."""
    return privacy.run_inversion(scenario, seed=seed, **spec.params)


@register_method("apcvfl_aligned_only",
                 params_from=pipeline.run_apcvfl_aligned_only)
def _apcvfl_aligned_only(scenario, spec: MethodSpec, *,
                         seed: int = 0) -> RunResult:
    return pipeline.run_apcvfl_aligned_only(scenario, seed=seed,
                                            **spec.params)


@register_replicas("apcvfl_aligned_only")
def _apcvfl_aligned_only_replicated(scenarios, spec: MethodSpec, *, seeds,
                                    mesh=None):
    return pipeline.run_apcvfl_aligned_only_replicated(scenarios,
                                                       seeds=seeds,
                                                       mesh=mesh,
                                                       **spec.params)


@register_method("apcvfl_dp", supports_multiparty=True,
                 params_from=rb_defense.run_apcvfl_dp)
def _apcvfl_dp(scenario, spec: MethodSpec, *, seed: int = 0) -> RunResult:
    """The full protocol with a hardened exchange
    (``repro.robustness.defense``): spec params sweep the defense knobs
    (``sigma``, ``mechanism``, ``clip``, ``quantize``) alongside the
    usual training hyperparameters.  With every defense off this is
    bit-identical to ``apcvfl`` (pinned in tests/test_robustness.py)."""
    return rb_defense.run_apcvfl_dp(scenario, seed=seed, **spec.params)


@register_replicas("apcvfl_dp")
def _apcvfl_dp_replicated(scenarios, spec: MethodSpec, *, seeds, mesh=None):
    return rb_defense.run_apcvfl_dp_replicated(scenarios, seeds=seeds,
                                               mesh=mesh, **spec.params)


@register_method("attack_inversion",
                 params_from=rb_attacks.run_attack_inversion)
def _attack_inversion(scenario, spec: MethodSpec, *,
                      seed: int = 0) -> RunResult:
    """Registry attacks (``repro.robustness.attacks``): each runs the
    protocol's attack surface under a chosen defense (same ``sigma`` /
    ``clip`` / ``quantize`` knobs as ``apcvfl_dp``) and emits the shared
    leakage schema — ``leakage`` in [0, 1] plus the attack's raw
    statistic — so one spec sweeps defense strength against utility AND
    leakage in the same tidy records."""
    return rb_attacks.run_attack_inversion(scenario, seed=seed,
                                           **spec.params)


@register_method("attack_label_leak",
                 params_from=rb_attacks.run_attack_label_leak)
def _attack_label_leak(scenario, spec: MethodSpec, *,
                       seed: int = 0) -> RunResult:
    return rb_attacks.run_attack_label_leak(scenario, seed=seed,
                                            **spec.params)


@register_method("attack_membership",
                 params_from=rb_attacks.run_attack_membership)
def _attack_membership(scenario, spec: MethodSpec, *,
                       seed: int = 0) -> RunResult:
    return rb_attacks.run_attack_membership(scenario, seed=seed,
                                            **spec.params)


@register_method("splitnn", params_from=splitnn.run_splitnn)
def _splitnn(scenario, spec: MethodSpec, *, seed: int = 0) -> RunResult:
    return splitnn.run_splitnn(scenario, seed=seed, **spec.params)


@register_method("vfedtrans", params_from=vfedtrans.run_vfedtrans)
def _vfedtrans(scenario, spec: MethodSpec, *, seed: int = 0) -> RunResult:
    return vfedtrans.run_vfedtrans(scenario, seed=seed, **spec.params)
