"""Built-in method adapters: the ``repro.core`` entry points behind the
registry's one signature ``(scenario, spec, *, seed) -> RunResult``.

Each adapter forwards ``spec.params`` as keyword overrides to the
underlying ``run_*`` function, whose defaults are the paper's settings
(``configs.apcvfl_paper.TABULAR``) — an empty spec reproduces the paper.
Importing this module registers every adapter (the registry does so
lazily on first lookup).
"""
from __future__ import annotations

from repro.core import comm, multiparty, pipeline, privacy, splitnn, \
    vfedtrans
from repro.core.multiparty import VFLScenarioK
from repro.experiments.registry import register_method, register_replicas
from repro.experiments.results import RunResult
from repro.experiments.specs import MethodSpec


@register_method("local", supports_multiparty=True)
def _local(scenario, spec: MethodSpec, *, seed: int = 0) -> RunResult:
    """Raw-feature probe at the active party: no training hyperparameters,
    no communication — ``spec.params`` (e.g. sweep-wide overrides like
    ``max_epochs``) is intentionally ignored."""
    metrics = pipeline.run_local_baseline(scenario, seed=seed)
    return RunResult(method="local", metrics=metrics, rounds=0,
                     comm=comm.Channel().summary(), seed=seed)


@register_method("apcvfl", supports_multiparty=True,
                 params_from=pipeline.run_apcvfl)
def _apcvfl(scenario, spec: MethodSpec, *, seed: int = 0) -> RunResult:
    # run_apcvfl and run_apcvfl_k share one keyword surface (pinned by
    # test_apcvfl_k_signature_matches_2party), so params_from covers both
    if isinstance(scenario, VFLScenarioK):
        return multiparty.run_apcvfl_k(scenario, seed=seed, **spec.params)
    return pipeline.run_apcvfl(scenario, seed=seed, **spec.params)


@register_replicas("apcvfl")
def _apcvfl_replicated(scenarios, spec: MethodSpec, *, seeds):
    """Seed groups of 2-party cells run through ``run_apcvfl_replicated``
    — every protocol stage is S stacked lanes of one vmapped scan.
    K-party groups fall back to the sequential per-seed path (replicating
    ``run_apcvfl_k`` is an open item)."""
    if isinstance(scenarios[0], VFLScenarioK):
        return [multiparty.run_apcvfl_k(sc, seed=s, **spec.params)
                for sc, s in zip(scenarios, seeds)]
    return pipeline.run_apcvfl_replicated(scenarios, seeds=seeds,
                                          **spec.params)


@register_method("inversion", params_from=privacy.run_inversion)
def _inversion(scenario, spec: MethodSpec, *, seed: int = 0) -> RunResult:
    """Representation-inversion privacy probe (``core.privacy``): spec
    params sweep the attacker's auxiliary budget (``n_aux``); metrics are
    leakage numbers (r2_mean/attack_mse), not classification scores."""
    return privacy.run_inversion(scenario, seed=seed, **spec.params)


@register_method("apcvfl_aligned_only",
                 params_from=pipeline.run_apcvfl_aligned_only)
def _apcvfl_aligned_only(scenario, spec: MethodSpec, *,
                         seed: int = 0) -> RunResult:
    return pipeline.run_apcvfl_aligned_only(scenario, seed=seed,
                                            **spec.params)


@register_replicas("apcvfl_aligned_only")
def _apcvfl_aligned_only_replicated(scenarios, spec: MethodSpec, *, seeds):
    return pipeline.run_apcvfl_aligned_only_replicated(scenarios,
                                                       seeds=seeds,
                                                       **spec.params)


@register_method("splitnn", params_from=splitnn.run_splitnn)
def _splitnn(scenario, spec: MethodSpec, *, seed: int = 0) -> RunResult:
    return splitnn.run_splitnn(scenario, seed=seed, **spec.params)


@register_method("vfedtrans", params_from=vfedtrans.run_vfedtrans)
def _vfedtrans(scenario, spec: MethodSpec, *, seed: int = 0) -> RunResult:
    return vfedtrans.run_vfedtrans(scenario, seed=seed, **spec.params)
