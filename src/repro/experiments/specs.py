"""Declarative experiment specs: frozen, JSON-round-trippable dataclasses.

An ``ExperimentSpec`` names a dataset, a scenario grid (aligned rows x
party counts x seeds), and the methods to run on every grid cell.  It is
pure data — building scenarios and running methods lives in
``repro.experiments.sweep`` — so this module imports neither jax nor the
model code and a spec file can be validated without touching a device.

Example (the whole public API)::

    spec = ExperimentSpec(
        name="bcw-alignment-sweep",
        dataset="bcw",
        aligned=(250, 150, 100),
        seeds=(0, 1, 2),
        methods=(MethodSpec("local"),
                 MethodSpec("apcvfl"),
                 MethodSpec("apcvfl", label="ablation",
                            params={"ablation": True}),
                 MethodSpec("vfedtrans")),
        overrides={"max_epochs": 60},
    )
    results = sweep(spec)            # list of uniform RunResult records

``aligned`` entries > 1 are absolute row counts; entries <= 1.0 are
fractions of the dataset's rows (resolved per dataset at build time).
``overrides`` are hyperparameter kwargs applied to EVERY method (a
method's own ``params`` win on conflict); they must be accepted by each
non-local method in the spec.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field, fields
from typing import Dict, Iterator, Tuple, Union


@dataclass(frozen=True)
class MethodSpec:
    """One method to run: a registry name plus its hyperparameter
    overrides.  ``label`` names the result rows (defaults to ``method``),
    letting one method appear twice with different params."""
    method: str
    params: Dict = field(default_factory=dict)
    label: str = ""

    @property
    def row_label(self) -> str:
        return self.label or self.method

    @classmethod
    def from_dict(cls, d: Union[str, dict]) -> "MethodSpec":
        if isinstance(d, str):              # "local" sugar
            return cls(method=d)
        _check_keys(cls, d)
        return cls(**d)


@dataclass(frozen=True)
class ScenarioSpec:
    """One fully-resolved grid cell: the arguments to build a vertical
    scenario (2-party ``VFLScenario`` or K-party ``VFLScenarioK``)."""
    dataset: str
    n_aligned: float                     # >1 absolute rows, <=1.0 fraction
    n_parties: int = 2
    n_active_features: int = 5
    seed: int = 0

    def resolve_aligned(self, n_rows: int) -> int:
        if self.n_aligned <= 1.0:
            return max(int(round(self.n_aligned * n_rows)), 1)
        return int(self.n_aligned)

    @classmethod
    def from_dict(cls, d: dict) -> "ScenarioSpec":
        _check_keys(cls, d)
        return cls(**d)


@dataclass(frozen=True)
class ExperimentSpec:
    """The declarative experiment: scenario grid x methods.

    ``replicate=True`` (the default) lets ``sweep()`` batch grid cells
    that are identical up to seed through a method's replica-lane runner
    (one vmapped dispatch for all seeds); methods without one, and
    ``replicate=False`` specs, run the sequential per-seed path.  Either
    way results arrive in the same order with the same values up to
    replica-parity tolerance.

    ``devices`` requests mesh-sharded replicated dispatch: a dict of mesh
    axis sizes, e.g. ``{"lane": 4}`` or ``{"lane": 2, "data": 2}``
    (``lane`` shards the replica-lane axis across devices, ``data``
    reserves devices for row sharding).  ``sweep()`` builds the mesh via
    ``repro.launch.mesh.make_lane_mesh`` — raising early when the host
    has too few devices — and threads it through each method's replicated
    runner; sequential (non-replicated) dispatch ignores it.  Empty (the
    default) keeps every dispatch single-device."""
    name: str
    dataset: str = "bcw"
    methods: Tuple[MethodSpec, ...] = ()
    aligned: Tuple[float, ...] = (250,)
    n_parties: Tuple[int, ...] = (2,)
    n_active_features: int = 5
    seeds: Tuple[int, ...] = (0,)
    overrides: Dict = field(default_factory=dict)
    replicate: bool = True
    devices: Dict = field(default_factory=dict)

    def scenarios(self) -> Iterator[ScenarioSpec]:
        """Expand the aligned x K x seed grid (methods loop inside each
        cell so built scenarios are reused across methods)."""
        for k in self.n_parties:
            for al in self.aligned:
                for seed in self.seeds:
                    yield ScenarioSpec(
                        dataset=self.dataset, n_aligned=al, n_parties=k,
                        n_active_features=self.n_active_features, seed=seed)

    # --- JSON round-trip ---------------------------------------------------

    def to_dict(self) -> dict:
        return asdict(self)

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        _check_keys(cls, d)
        d = dict(d)
        for key in ("aligned", "n_parties", "seeds"):
            if key in d:
                d[key] = tuple(d[key])
        d["methods"] = tuple(MethodSpec.from_dict(m)
                             for m in d.get("methods", ()))
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))


def _check_keys(cls, d: dict) -> None:
    known = {f.name for f in fields(cls)}
    unknown = set(d) - known
    if unknown:
        raise ValueError(f"{cls.__name__}: unknown keys {sorted(unknown)}; "
                         f"valid keys are {sorted(known)}")
