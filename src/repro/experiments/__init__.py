"""Declarative experiment API: specs, method registry, sweeps.

Public surface::

    from repro.experiments import (ExperimentSpec, MethodSpec, ScenarioSpec,
                                   RunResult, register_method,
                                   register_replicas, get_method,
                                   available_methods, run_method,
                                   sweep, tidy, build_scenario)

Only the pure-data modules (``specs``, ``results``) load eagerly; the
registry, built-in method adapters, and sweep engine — which pull in jax
and the ``repro.core`` stack — resolve lazily on first attribute access,
keeping ``import repro.experiments`` cheap and cycle-free (the core
method modules themselves import ``repro.experiments.results``).
"""
from repro.experiments.results import RunResult                  # noqa: F401
from repro.experiments.specs import (ExperimentSpec, MethodSpec,  # noqa: F401
                                     ScenarioSpec)

_LAZY = {
    "register_method": "registry",
    "register_replicas": "registry",
    "get_method": "registry",
    "available_methods": "registry",
    "run_method": "registry",
    "sweep": "sweeps",
    "tidy": "sweeps",
    "build_scenario": "sweeps",
}

__all__ = ["ExperimentSpec", "MethodSpec", "ScenarioSpec", "RunResult",
           *_LAZY]


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    value = getattr(importlib.import_module(f"{__name__}.{mod}"), name)
    globals()[name] = value          # cache: next access skips __getattr__
    return value
