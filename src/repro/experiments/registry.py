"""Method registry: one uniform signature for every VFL method.

A registered runner has the signature::

    runner(scenario, spec: MethodSpec, *, seed: int) -> RunResult

where ``scenario`` is a built ``VFLScenario`` (2 parties) or
``VFLScenarioK`` (K > 2, only for runners registered with
``supports_multiparty=True``) and ``spec.params`` carries the method's
hyperparameter overrides.  The built-in adapters in
``repro.experiments.methods`` wrap the ``repro.core`` entry points; they
are loaded lazily on first lookup so importing this module stays cheap
and cycle-free.
"""
from __future__ import annotations

import dataclasses
import inspect
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple


@dataclass(frozen=True)
class MethodEntry:
    name: str
    fn: Callable
    supports_multiparty: bool = False
    accepts: Optional[frozenset] = None   # param names; None = accepts any
    # replica-lane runner: (scenarios, spec, *, seeds) -> List[RunResult],
    # one result per seed in order; attached via ``register_replicas``
    replicated_fn: Optional[Callable] = None

    @property
    def supports_replicas(self) -> bool:
        """True when the method can run a whole seed-replica group (grid
        cells identical up to seed) through one replica-lane dispatch."""
        return self.replicated_fn is not None


_REGISTRY: Dict[str, MethodEntry] = {}


def _kwarg_names(fn: Callable) -> frozenset:
    """Keyword parameter names of a ``run_*`` entry point, minus the
    scenario positional and the registry-supplied ``seed``."""
    params = list(inspect.signature(fn).parameters.values())
    return frozenset(p.name for p in params[1:] if p.name != "seed")


def register_method(name: str, *, supports_multiparty: bool = False,
                    params_from: Optional[Callable] = None):
    """Decorator: register ``fn`` as the runner for ``name``.

    ``params_from`` names the underlying ``run_*`` entry point whose
    keyword signature defines the spec params this method accepts —
    ``sweep`` validates specs against it eagerly, before any training
    runs.  Omit it for runners that ignore params (e.g. ``local``).
    Re-registering a name raises — methods are identities, not plugins to
    be silently shadowed."""
    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY:
            raise ValueError(f"method {name!r} is already registered")
        accepts = _kwarg_names(params_from) if params_from else None
        _REGISTRY[name] = MethodEntry(name, fn, supports_multiparty, accepts)
        return fn
    return deco


def register_replicas(name: str):
    """Decorator: attach a replica-lane runner to the already-registered
    method ``name``.  The runner signature is::

        fn(scenarios, spec: MethodSpec, *, seeds) -> List[RunResult]

    where ``scenarios`` is one built scenario per seed (a sweep group:
    grid cells identical up to seed) and the return order matches
    ``seeds``.  ``sweep()`` dispatches a whole group through it instead of
    looping ``entry.fn`` per seed; each per-seed result must match the
    sequential path within replica-parity tolerance
    (``tests/test_replicas.py``)."""
    def deco(fn: Callable) -> Callable:
        _ensure_builtins()     # a built-in name must resolve here too
        entry = _REGISTRY.get(name)
        if entry is None:
            raise KeyError(f"register_replicas: method {name!r} is not "
                           f"registered yet")
        if entry.replicated_fn is not None:
            raise ValueError(f"method {name!r} already has a replicated "
                             f"runner")
        _REGISTRY[name] = dataclasses.replace(entry, replicated_fn=fn)
        return fn
    return deco


def _ensure_builtins() -> None:
    # the import registers the built-in adapters as a side effect
    from repro.experiments import methods  # noqa: F401


def available_methods() -> Tuple[str, ...]:
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))


def get_method(name: str) -> MethodEntry:
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown method {name!r}; registered methods: "
            f"{', '.join(sorted(_REGISTRY))}") from None


def run_method(name: str, scenario, spec, *, seed: int = 0):
    """Dispatch one run through the registry (convenience wrapper)."""
    return get_method(name).fn(scenario, spec, seed=seed)
