"""Checkpointing: flat-path .npz save/restore for arbitrary param/opt pytrees
(with dataclass/NamedTuple-free trees — dicts, lists, tuples) plus sharding
metadata so a restore can be resharded onto a different mesh."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

SEP = "/"


def _flatten(tree: Any, prefix: str = "") -> dict:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}{SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}{SEP}"))
    else:
        out[prefix.rstrip(SEP)] = tree
    return out


def save(path: str, tree: Any, *, step: int = 0, meta: dict | None = None):
    flat = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **arrays)
    side = {"step": step, "meta": meta or {},
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()}}
    with open(path + ".json", "w") as fh:
        json.dump(side, fh)


def load_tree(path: str) -> tuple:
    """Rebuild a saved tree WITHOUT a ``like`` prototype, for dict-only
    trees (every container a dict — the shape trained params and serving
    bundles use; list/tuple indices would come back as string keys).
    Leaves are returned as host ``np.ndarray``s with their saved dtypes —
    the caller decides what to upload (``jnp.asarray`` downcasts int64
    under the default x64-disabled config, which would corrupt e.g.
    row-id arrays).  Returns ``(tree, side)`` where ``side`` is the
    sidecar dict written by ``save`` (step / meta / dtypes)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    side_path = path[:-len(".npz")] + ".json"
    if not os.path.exists(side_path):
        side_path = path + ".json"          # save("x.npz") wrote x.npz.json
    with open(side_path) as fh:
        side = json.load(fh)
    tree: dict = {}
    with np.load(path) as data:             # leaves copied out eagerly
        for k in data.files:
            parts = k.split(SEP)
            cur = tree
            for p in parts[:-1]:
                cur = cur.setdefault(p, {})
            cur[parts[-1]] = np.asarray(data[k])
    return tree, side


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    flat_like = _flatten(like)
    out_flat = {}
    for k, proto in flat_like.items():
        arr = data[k]
        dt = getattr(proto, "dtype", arr.dtype)
        out_flat[k] = jnp.asarray(arr, dtype=dt)
    return _unflatten_like(like, out_flat, "")


def _unflatten_like(like: Any, flat: dict, prefix: str) -> Any:
    if isinstance(like, dict):
        return {k: _unflatten_like(like[k], flat, f"{prefix}{k}{SEP}")
                for k in like}
    if isinstance(like, tuple) and hasattr(like, "_fields"):   # NamedTuple
        vals = [_unflatten_like(v, flat, f"{prefix}{i}{SEP}")
                for i, v in enumerate(like)]
        return type(like)(*vals)
    if isinstance(like, (list, tuple)):
        vals = [_unflatten_like(v, flat, f"{prefix}{i}{SEP}")
                for i, v in enumerate(like)]
        return type(like)(vals) if isinstance(like, list) else tuple(vals)
    return flat[prefix.rstrip(SEP)]
