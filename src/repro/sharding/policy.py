"""Parameter schema + logical-axis sharding policy.

Every model module describes its parameters as a pytree of :class:`ParamDef`
(shape + logical axes + init recipe).  The same schema drives three things:

* ``init_params``  — materialize a pytree of arrays,
* ``pspec_tree``   — the ``PartitionSpec`` tree for pjit in/out shardings,
* ``abstract_params`` — ``ShapeDtypeStruct`` stand-ins for dry-run lowering.

Logical axes (resolved per mesh):
  ``dp``    batch / data parallel          -> ("pod","data") or ("data",)
  ``fsdp``  fully-sharded param dim        -> ("data",)
  ``tp``    tensor parallel dim            -> ("model",)
  ``ep``    expert parallel dim            -> ("pod","model") or ("model",)
  ``vocab`` vocabulary dim                 -> ("model",)
  ``lane``  replica-lane dim (the VFL lane -> ("lane",)
            engine's stacked leading axis,
            meshes from make_lane_mesh)
  ``None``  replicated
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ParamDef:
    shape: tuple
    axes: tuple                  # one logical-axis name (or None) per dim
    init: str = "fan_in"         # fan_in|zeros|ones|embed|normal|mamba_A|dt_bias|small
    scale: float = 1.0
    dtype: Optional[str] = None  # override model dtype (e.g. fp32 for norms)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def stack(schema: Any, n: int) -> Any:
    """Add a leading (scanned) layer dimension to every ParamDef in a tree."""
    def add(d: ParamDef) -> ParamDef:
        return ParamDef((n,) + tuple(d.shape), (None,) + tuple(d.axes),
                        d.init, d.scale, d.dtype)
    return jax.tree.map(add, schema, is_leaf=is_def)


def _rules(mesh_axes: tuple) -> dict:
    multi_pod = "pod" in mesh_axes
    return {
        "dp": ("pod", "data") if multi_pod else ("data",),
        "fsdp": ("data",),
        "tp": ("model",),
        "ep": ("pod", "model") if multi_pod else ("model",),
        "vocab": ("model",),
        "lane": ("lane",),
        None: None,
    }


def resolve(axes: tuple, mesh_axes: tuple) -> P:
    r = _rules(tuple(mesh_axes))
    out = []
    for a in axes:
        v = r[a]
        if v is None:
            out.append(None)
        elif len(v) == 1:
            out.append(v[0])
        else:
            out.append(v)
    return P(*out)


def _divisible(shape, spec: P, mesh: Mesh) -> P:
    """Drop sharding on dims the mesh does not divide evenly (e.g. smoke
    configs on a 1-device mesh, or odd head counts)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        total = int(np.prod([sizes.get(n, 1) for n in names]))
        out.append(entry if total > 0 and dim % total == 0 else None)
    return P(*out)


def pspec_tree(schema: Any, mesh_axes: tuple) -> Any:
    return jax.tree.map(lambda d: resolve(d.axes, mesh_axes), schema, is_leaf=is_def)


def sharding_tree(schema: Any, mesh: Mesh) -> Any:
    def mk(d: ParamDef):
        spec = _divisible(d.shape, resolve(d.axes, mesh.axis_names), mesh)
        return NamedSharding(mesh, spec)
    return jax.tree.map(mk, schema, is_leaf=is_def)


def batch_pspec(mesh_axes: tuple) -> Any:
    """PartitionSpec entry for a global-batch dimension."""
    r = _rules(tuple(mesh_axes))["dp"]
    return r if len(r) > 1 else r[0]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_one(d: ParamDef, key, dtype) -> jax.Array:
    dt = jnp.dtype(d.dtype) if d.dtype else dtype
    shape = tuple(int(s) for s in d.shape)
    if d.init == "zeros":
        return jnp.zeros(shape, dt)
    if d.init == "ones":
        return jnp.ones(shape, dt)
    if d.init == "fan_in":
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        return (jax.random.normal(key, shape) * d.scale / np.sqrt(fan_in)).astype(dt)
    if d.init == "embed":
        return (jax.random.normal(key, shape) * d.scale * 0.02).astype(dt)
    if d.init == "normal":
        return (jax.random.normal(key, shape) * d.scale).astype(dt)
    if d.init == "mamba_A":   # A_log: log of Uniform(1, 16)
        u = jax.random.uniform(key, shape, minval=1.0, maxval=16.0)
        return jnp.log(u).astype(dt)
    if d.init == "dt_bias":   # softplus^-1 of Uniform(1e-3, 1e-1)
        u = jax.random.uniform(key, shape, minval=1e-3, maxval=1e-1)
        return (u + jnp.log(-jnp.expm1(-u))).astype(dt)
    if d.init == "small":
        return (jax.random.normal(key, shape) * d.scale * 1e-2).astype(dt)
    raise ValueError(f"unknown init {d.init}")


def init_params(schema: Any, key, dtype=jnp.float32) -> Any:
    leaves, treedef = jax.tree.flatten(schema, is_leaf=is_def)
    keys = jax.random.split(key, max(len(leaves), 1))
    arrs = [_init_one(d, k, jnp.dtype(dtype)) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def abstract_params(schema: Any, dtype=jnp.bfloat16) -> Any:
    def mk(d: ParamDef):
        dt = jnp.dtype(d.dtype) if d.dtype else jnp.dtype(dtype)
        return jax.ShapeDtypeStruct(tuple(int(s) for s in d.shape), dt)
    return jax.tree.map(mk, schema, is_leaf=is_def)


def param_count(schema: Any) -> int:
    leaves = jax.tree.leaves(schema, is_leaf=is_def)
    return int(sum(np.prod(d.shape) for d in leaves))
