import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything below is ordinary code.

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import INPUT_SHAPES, get_config           # noqa: E402
from repro.configs.base import ModelConfig, ShapeConfig      # noqa: E402
from repro.launch import hlo_analysis                        # noqa: E402
from repro.launch.mesh import make_production_mesh           # noqa: E402
from repro.models import model as M                          # noqa: E402
from repro.optim.adam import AdamState, AdamW                # noqa: E402
from repro.serve import decode as serve                      # noqa: E402
from repro.sharding.policy import (abstract_params, batch_pspec,  # noqa: E402
                                   sharding_tree)
from repro.train.loop import make_train_step                  # noqa: E402


def _named(mesh, spec):
    return NamedSharding(mesh, spec)


def _batch_sharding(mesh, shape, batch: int):
    """Shard the leading batch dim on dp when divisible, else replicate."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_axes = ("pod", "data") if "pod" in sizes else ("data",)
    dp_n = int(np.prod([sizes[a] for a in dp_axes]))
    dp = batch_pspec(mesh.axis_names)
    ent = [None] * len(shape)
    if shape and shape[0] % dp_n == 0:
        ent[0] = dp
    return _named(mesh, P(*ent))


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *, objective="lm"):
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    B, S = shape.global_batch, shape.seq_len
    f = jax.ShapeDtypeStruct
    if shape.mode in ("train", "prefill"):
        if cfg.family == "audio":
            batch = {"frames": f((B, S, cfg.d_model), jnp.bfloat16)}
            if shape.mode == "train":
                batch["labels"] = f((B, S), jnp.int32)
        else:
            batch = {"tokens": f((B, S), jnp.int32)}
            if cfg.family == "vlm":
                batch["image_embeds"] = f((B, cfg.n_image_tokens, cfg.d_model),
                                          jnp.bfloat16)
        if objective == "apcvfl_distill":
            batch["z_teacher"] = f((B, cfg.d_model), jnp.float32)
            batch["aligned"] = f((B,), jnp.int32)
        return batch
    # decode: one new token against a pre-filled cache
    return {"token": f((B,), jnp.int32), "pos": f((), jnp.int32)}


def _abstract_cache(params_abs, cfg, shape):
    slots = serve.n_cache_slots(cfg, shape)
    B = shape.global_batch
    if cfg.family == "vlm":
        img = jax.ShapeDtypeStruct((B, cfg.n_image_tokens, cfg.d_model),
                                   jnp.bfloat16)
        return jax.eval_shape(
            lambda p, i: M.init_cache(p, cfg, B, slots, i), params_abs, img)
    return jax.eval_shape(lambda p: M.init_cache(p, cfg, B, slots), params_abs)


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool,
                objective: str = "lm", cfg: ModelConfig | None = None):
    cfg = cfg or get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape.mode == "train" and not cfg.remat:
        # production default: activation checkpointing per block — without it
        # the scanned stack saves every intermediate for backward (TB/device)
        cfg = cfg.with_(remat=True)
    if shape.mode == "decode" and not M.supports_decode(cfg):
        raise SystemExit(f"{arch} is encoder-only: no decode step (skip)")

    mesh = make_production_mesh(multi_pod=multi_pod)
    sch = M.schema(cfg)
    params_abs = abstract_params(sch, jnp.dtype(cfg.dtype))
    pshard = sharding_tree(sch, mesh)

    t0 = time.time()
    with mesh:
        if shape.mode == "train":
            opt = AdamW()
            fns = make_train_step(cfg, opt, objective=objective)
            opt_abs = jax.eval_shape(opt.init, params_abs)
            # opt state m/v mirror the param sharding; step is replicated
            oshard = AdamState(_named(mesh, P()), pshard, pshard)
            batch = input_specs(cfg, shape, objective=objective)
            bshard = {k: _batch_sharding(mesh, v.shape, shape.global_batch)
                      for k, v in batch.items()}
            jitted = jax.jit(fns.step,
                             in_shardings=(pshard, oshard, bshard),
                             out_shardings=(pshard, oshard, None))
            lowered = jitted.lower(params_abs, opt_abs, batch)
        elif shape.mode == "prefill":
            batch = input_specs(cfg, shape)
            bshard = {k: _batch_sharding(mesh, v.shape, shape.global_batch)
                      for k, v in batch.items()}
            fn = lambda p, b: serve.prefill_step(p, cfg, b)
            jitted = jax.jit(fn, in_shardings=(pshard, bshard))
            lowered = jitted.lower(params_abs, batch)
        else:  # decode
            window = serve.decode_window(cfg, shape)
            cache_abs = _abstract_cache(params_abs, cfg, shape)
            cshard = jax.tree.map(lambda s: _named(mesh, s),
                                  serve.cache_pspecs(cache_abs, mesh,
                                                     shape.global_batch))
            step = serve.make_decode_step(cfg, window)
            tok = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(step, in_shardings=(
                pshard,
                _batch_sharding(mesh, tok.shape, shape.global_batch),
                cshard, _named(mesh, P())))
            lowered = jitted.lower(params_abs, tok, cache_abs, pos)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    return cfg, shape, mesh, compiled, t_lower, t_compile


def _cost_dict(compiled) -> dict:
    """compiled.cost_analysis() compat: jax<=0.4.x returns a one-dict list
    per program, newer versions return the dict itself."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def analyze(arch, shape_name, cfg, compiled, mesh, t_lower, t_compile,
            multi_pod, objective):
    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled)
    hlo = hlo_analysis.analyze_text(compiled.as_text())
    n_chips = int(np.prod(mesh.devices.shape))
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "objective": objective,
        "n_chips": n_chips,
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "params": M.count_params_analytic(cfg),
        "active_params": M.count_active_params(cfg),
        # per-device numbers from the SPMD-partitioned module
        "mem_argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "mem_output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "mem_temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "mem_generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        "xla_flops_per_device_raw": cost.get("flops", 0.0),
        "xla_bytes_per_device_raw": cost.get("bytes accessed", 0.0),
        # loop-corrected (trip-count aware) numbers from the HLO walker
        "hlo_flops_per_device": hlo["flops"],
        "hlo_bytes_per_device": hlo["bytes"],
        "collective_bytes_per_device": hlo["collective_bytes"],
        "collectives": hlo["collectives"],
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--objective", default="lm",
                    choices=["lm", "apcvfl_distill"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--opt", default="", help=(
        "comma list of perf knobs: chunked_attn[:N], seq_par, replicate_kv, "
        "ssd_chunk:N, window:N (see EXPERIMENTS.md section Perf)"))
    args = ap.parse_args()

    cfg = get_config(args.arch)
    for knob in [k for k in args.opt.split(",") if k]:
        name, _, val = knob.partition(":")
        if name == "chunked_attn":
            cfg = cfg.with_(attn_chunk=int(val or 512))
        elif name == "seq_par":
            axes = ("pod", "data", "model") if args.multi_pod else \
                ("data", "model")
            cfg = cfg.with_(seq_parallel=True, mesh_axes=axes)
        elif name == "replicate_kv":
            cfg = cfg.with_(replicate_kv=True)
        elif name == "ssd_chunk":
            cfg = cfg.with_(ssm_chunk=int(val))
        elif name == "ssd_bf16":
            cfg = cfg.with_(ssd_bf16=True)
        elif name == "softmax_bf16":
            cfg = cfg.with_(softmax_bf16=True)
        elif name == "window":
            cfg = cfg.with_(sliding_window=int(val))
        else:
            raise SystemExit(f"unknown opt {name}")

    cfg, shape, mesh, compiled, t_lower, t_compile = lower_combo(
        args.arch, args.shape, multi_pod=args.multi_pod,
        objective=args.objective, cfg=cfg)
    print(compiled.memory_analysis())
    print({k: v for k, v in _cost_dict(compiled).items()
           if k in ("flops", "bytes accessed")})
    rec = analyze(args.arch, args.shape, cfg, compiled, mesh, t_lower,
                  t_compile, args.multi_pod, args.objective)
    rec["opt"] = args.opt
    os.makedirs(args.out, exist_ok=True)
    tag = (args.tag + "_") if args.tag else ""
    name = f"{tag}{args.arch}_{args.shape}_{rec['mesh']}"
    if args.objective != "lm":
        name += "_" + args.objective
    path = os.path.join(args.out, name + ".json")
    with open(path, "w") as fh:
        json.dump(rec, fh, indent=1)
    print("wrote", path)


if __name__ == "__main__":
    main()
