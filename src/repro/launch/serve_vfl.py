"""Serve a trained APC-VFL model: train -> export -> round-trip through the
checkpoint layer -> drive a simulated request stream through the batched
serving engine (``repro.serve.vfl``).

Run:  PYTHONPATH=src python -m repro.launch.serve_vfl --smoke
      PYTHONPATH=src python -m repro.launch.serve_vfl --dataset bcw \
          --aligned 150 --epochs 30 --requests 5000 --bundle /tmp/apcvfl
      PYTHONPATH=src python -m repro.launch.serve_vfl --load /tmp/apcvfl \
          --requests 1000
      PYTHONPATH=src python -m repro.launch.serve_vfl --load /tmp/apcvfl \
          --arrival poisson --rate-rps 300 --slo-ms 100

With ``--bundle`` the exported ``ModelBundle`` is SAVED to that path and
reloaded before serving, so every run with it proves the save -> load ->
identical-predictions round trip; ``--load`` skips training entirely and
serves an existing bundle (the dataset/scenario is rebuilt only to source
request features).

``--arrival poisson|bursty`` switches from the backlog-drain
``serve_stream`` to the live serving runtime (``repro.serve.runtime``):
requests arrive on a seeded virtual clock, the SLO-aware scheduler
micro-batches them with admission control, and queueing latency is
reported separately from service latency plus SLO attainment and shed
rate.  The multi-tenant version of this loop is
``benchmarks/loadbench.py``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

import numpy as np

from repro.core import multiparty, pipeline
from repro.data.synthetic import make_dataset
from repro.data.vertical import make_scenario
from repro.serve import vfl as sv


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="online serving for a trained APC-VFL model")
    ap.add_argument("--dataset", default="bcw")
    ap.add_argument("--aligned", type=int, default=150)
    ap.add_argument("--n-parties", type=int, default=2)
    ap.add_argument("--active-features", type=int, default=5)
    ap.add_argument("--epochs", type=int, default=30)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--max-rows", type=int, default=64,
                    help="largest request size in the simulated stream")
    ap.add_argument("--p-known", type=float, default=0.5,
                    help="probability a request row keeps its real id "
                         "(cache candidate)")
    ap.add_argument("--buckets", default="16,32,64,128,256")
    ap.add_argument("--quantize", choices=["none", "int8"], default="none",
                    help="'int8' serves the active path from per-channel "
                         "symmetric int8 weights (serve.quant) and prints "
                         "the pinned fp32-parity report")
    ap.add_argument("--arrival", choices=["stream", "poisson", "bursty"],
                    default="stream",
                    help="'stream' = drain the request list as a backlog "
                         "(serve_stream); 'poisson'/'bursty' = live "
                         "arrival-clocked runtime with SLO micro-batching")
    ap.add_argument("--rate-rps", type=float, default=200.0,
                    help="arrival rate for --arrival poisson/bursty")
    ap.add_argument("--slo-ms", type=float, default=100.0,
                    help="end-to-end latency SLO for the live runtime")
    ap.add_argument("--queue-rows", type=int, default=4096,
                    help="admission bound: queued rows beyond this shed")
    ap.add_argument("--fault", default=None, metavar="PLAN.json",
                    help="inject a robustness.faults.FaultPlan into the "
                         "live runtime (requires --arrival poisson|"
                         "bursty); events target tenant 'default' — see "
                         "examples/faults/passive_dropout.json")
    ap.add_argument("--bundle", default=None,
                    help="save the exported bundle here and serve the "
                         "RELOADED copy (round-trip proof)")
    ap.add_argument("--load", default=None,
                    help="serve an existing bundle instead of training")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny settings: 2 epochs, 300 requests")
    ap.add_argument("--out", default=None,
                    help="also write the stream stats JSON here")
    args = ap.parse_args(argv)
    if args.smoke:
        args.epochs = min(args.epochs, 2)
        args.requests = min(args.requests, 300)
    plan = None
    if args.fault:
        if args.arrival == "stream":
            ap.error("--fault needs the live runtime: use --arrival "
                     "poisson or bursty (the backlog drain has no clock "
                     "to trigger events on)")
        from repro.robustness.faults import FaultPlan
        plan = FaultPlan.load(args.fault)
        print(f"fault plan {plan.name!r}: "
              f"{len(plan.serving_events())} serving events")

    ds = make_dataset(args.dataset, seed=args.seed)
    if args.n_parties == 2:
        sc = make_scenario(ds, n_active_features=args.active_features,
                           n_aligned=args.aligned, seed=args.seed)
    else:
        sc = multiparty.make_scenario_k(
            ds, n_parties=args.n_parties,
            n_active_features=args.active_features,
            n_aligned=args.aligned, seed=args.seed)

    if args.load:
        bundle = sv.ModelBundle.load(args.load)
        print(f"loaded bundle {args.load}: {bundle.meta}")
        # the scenario here only sources request features/ids — refuse a
        # bundle trained on a different feature split or dataset before
        # the mismatch surfaces as an XLA shape error (or, worse, silent
        # mis-keyed cache routing)
        d = sc.active.x.shape[1]
        want_d = bundle.meta.get("n_features_active")
        if want_d is not None and int(want_d) != d:
            ap.error(f"bundle expects {want_d} active features but the "
                     f"rebuilt scenario has {d}; rerun with the training "
                     f"flags (--dataset/--active-features/--seed)")
        want_ds = bundle.meta.get("dataset")
        if want_ds and want_ds != args.dataset:
            ap.error(f"bundle was trained on dataset {want_ds!r}, not "
                     f"{args.dataset!r}")
    else:
        print(f"training apcvfl on {args.dataset} "
              f"(K={args.n_parties}, aligned={args.aligned}, "
              f"epochs<={args.epochs}) ...")
        if args.n_parties == 2:
            result = pipeline.run_apcvfl(sc, seed=args.seed,
                                         max_epochs=args.epochs)
        else:
            result = multiparty.run_apcvfl_k(sc, seed=args.seed,
                                             max_epochs=args.epochs)
        print(f"trained: acc={result.metrics['accuracy']:.4f} "
              f"epochs={result.epochs}")
        bundle = sv.export_bundle(result, sc)
        with tempfile.TemporaryDirectory() as tmp:
            path = args.bundle or os.path.join(tmp, "bundle")
            bundle.save(path)
            reloaded = sv.ModelBundle.load(path)   # eager: outlives tmp
            probe = np.asarray(sc.active.x[:32], np.float32)
            a = sv.VFLServingEngine(bundle).predict_active(probe)
            b = sv.VFLServingEngine(reloaded).predict_active(probe)
            assert np.array_equal(a, b), \
                "bundle round-trip changed predictions"
        where = f"{args.bundle}.npz" if args.bundle else "(ephemeral)"
        print(f"bundle saved -> {where} (round-trip verified, "
              f"{bundle.meta['n_cached']} cached latents)")
        bundle = reloaded

    buckets = [int(b) for b in args.buckets.split(",") if b]
    quantize = None if args.quantize == "none" else args.quantize
    if quantize:
        from repro.serve import quant
        parity = quant.parity_report(bundle, sc.active.x, sc.active.y,
                                     n_classes=sc.n_classes)
        print(f"int8 parity vs fp32: max|dlogit|="
              f"{parity['max_abs_logit_delta']:.4f} "
              f"(rel {parity['rel_logit_delta']:.4f}), flip rate "
              f"{parity['pred_flip_rate']:.4f}, "
              f"{parity['compression']}x weight compression")
    if args.arrival != "stream":
        from repro.serve import runtime as rt
        registry = rt.TenantRegistry(buckets=buckets)
        engine = registry.register("default", bundle, quantize=quantize)
        engine.warmup()
        stream = rt.make_timed_stream(
            sc.active.x, sc.active.ids, args.requests,
            tenant="default", arrivals=args.arrival,
            rate_rps=args.rate_rps, seed=args.seed + 1,
            max_rows=args.max_rows, p_known=args.p_known)
        runtime = rt.ServingRuntime(
            registry, rt.RuntimeConfig(slo_ms=args.slo_ms,
                                       max_queue_rows=args.queue_rows))
        stats = runtime.run(stream, faults=plan)
        lat = stats["latency_ms"]
        print(f"\n=== {args.arrival} arrivals at {args.rate_rps} req/s: "
              f"served {stats['served']}/{stats['requests']} requests "
              f"({stats['rows']} rows) in "
              f"{stats['virtual_elapsed_ms']:.0f} virtual ms ===")
        print(f"throughput: {stats['rows_per_s']} rows/s over "
              f"{stats['dispatches']} micro-batches "
              f"(mean {stats['mean_batch_rows']} rows)")
        print(f"queueing  p50/p99: {lat['queue']['p50']} / "
              f"{lat['queue']['p99']} ms")
        print(f"service   p50/p99: {lat['service']['p50']} / "
              f"{lat['service']['p99']} ms")
        print(f"SLO {args.slo_ms} ms: attainment "
              f"{stats['slo']['attainment']}  shed rate "
              f"{stats['shed_rate']}")
        print(f"compiled batch shapes: {stats['compiled']['by_path']} "
              f"(distinct: {stats['compiled']['distinct_batch_shapes']})")
        if plan is not None:
            fb = stats["faults"]["tenants"].get("default", {})
            print(f"faults: applied {stats['faults']['events_applied']} "
                  f"events, faulted={fb.get('faulted')}, "
                  f"collab_while_faulted="
                  f"{fb.get('collab_dispatches_while_faulted')}, "
                  f"cache_stale={fb.get('cache_stale')}, "
                  f"cache_version={fb.get('cache_version')}")
    else:
        engine = sv.VFLServingEngine(bundle, buckets=buckets,
                                     quantize=quantize)
        requests = sv.make_request_stream(
            sc.active.x, sc.active.ids, args.requests, seed=args.seed + 1,
            max_rows=args.max_rows, p_known=args.p_known)
        stats = sv.serve_stream(engine, requests)

        print(f"\n=== served {stats['requests']} requests "
              f"({stats['rows']} rows) in {stats['wall_s']}s ===")
        print(f"throughput: {stats['rows_per_s']} rows/s "
              f"({stats['requests_per_s']} req/s)")
        print(f"latency p50/p99: {stats['latency_ms_p50']} / "
              f"{stats['latency_ms_p99']} ms (service; queueing separate "
              f"in latency_ms block)")
        print(f"cache hit-rate: {stats['cache_hit_rate']}  "
              f"dispatches: {stats['dispatches']}")
        print(f"compiled batch shapes: {stats['compiled']['by_path']} "
              f"(distinct: {stats['compiled']['distinct_batch_shapes']})")
    if quantize:
        stats["quant"] = parity
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(stats, fh, indent=1)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
