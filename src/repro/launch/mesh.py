"""Production mesh construction.

A function (not a module constant) so importing this module never touches
jax device state — required because the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* jax
initializes, while tests and benches must see one device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) local devices exist."""
    return jax.make_mesh((data, model), ("data", "model"))
