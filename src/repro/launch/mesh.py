"""Mesh construction — the single source of device meshes for both the LM
dry-run path and the VFL lane engine.

Functions (not module constants) so importing this module never touches
jax device state — required because the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* jax
initializes, while tests and benches must see one device.

Every constructor validates the requested shape against
``jax.device_count()`` up front: an oversized ``jax.make_mesh`` otherwise
fails deep inside jax with a reshape error that names neither the mesh nor
the fix.  The ``ValueError`` raised here names both.
"""
from __future__ import annotations

import math

import jax


def _checked_mesh(shape: tuple, axes: tuple):
    for ax, n in zip(axes, shape):
        if not (isinstance(n, int) and n >= 1):
            raise ValueError(f"mesh axis {ax!r} must be a positive int, "
                             f"got {n!r}")
    need = math.prod(shape)
    have = jax.device_count()
    if need > have:
        raise ValueError(
            f"mesh {dict(zip(axes, shape))} needs {need} devices but only "
            f"{have} are available — on CPU, fake host devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            "(set BEFORE jax initializes)")
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _checked_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) local devices exist."""
    return _checked_mesh((data, model), ("data", "model"))


def make_lane_mesh(lane: int = 1, data: int = 1):
    """Mesh for the replica-lane training engine
    (``core.training.train_lanes(..., mesh=...)``): the ``lane`` axis
    shards independent lanes across devices, the ``data`` axis optionally
    shards rows within a lane (``shard_rows=True``).  Axis names line up
    with the logical-axis policy (``sharding.policy``: ``"lane"`` ->
    ``("lane",)``, ``"dp"`` -> ``("data",)``)."""
    return _checked_mesh((lane, data), ("lane", "data"))
