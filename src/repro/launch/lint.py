"""jaxlint CLI — static analysis of the package against the committed
baseline.

    python -m repro.launch.lint                    # lint src/repro, table
    python -m repro.launch.lint --json             # machine-readable
    python -m repro.launch.lint --diff             # only files changed vs main
    python -m repro.launch.lint --baseline-update  # freeze current findings

Exit 0 when no non-baselined findings; 1 otherwise.  Pure stdlib — this
entry point never imports jax, so it runs backend-free in CI.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import List, Optional, Set

from repro.analysis.lint import (build_index, load_baseline, run_rules,
                                 apply_baseline, write_baseline)
from repro.analysis.rules import RULE_DOCS

DEFAULT_TARGET = "src/repro"
DEFAULT_BASELINE = "src/repro/analysis/baseline.json"


def find_repo_root(start: Optional[str] = None) -> str:
    d = os.path.abspath(start or os.getcwd())
    while True:
        if os.path.isdir(os.path.join(d, ".git")):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            return os.path.abspath(start or os.getcwd())
        d = parent


def changed_files(root: str, base: str = "main") -> Optional[Set[str]]:
    """Repo-relative .py files changed vs ``base`` (committed, staged and
    untracked).  None when git can't answer (no base ref): lint all."""
    try:
        diff = subprocess.run(
            ["git", "diff", "--name-only", base, "--", "*.py"],
            cwd=root, capture_output=True, text=True, check=True).stdout
        untracked = subprocess.run(
            ["git", "ls-files", "--others", "--exclude-standard", "*.py"],
            cwd=root, capture_output=True, text=True, check=True).stdout
    except (OSError, subprocess.CalledProcessError):
        return None
    return {line.strip() for line in (diff + untracked).splitlines()
            if line.strip()}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.lint",
        description="AST lint of JAX/Pallas contracts (rules R001-R007); "
                    "see src/repro/analysis/README.md")
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs to lint (default: {DEFAULT_TARGET})")
    ap.add_argument("--root", default=None,
                    help="repo root for relative paths (default: nearest "
                         "ancestor with .git)")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline file (default: {DEFAULT_BASELINE})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report everything, ignore the baseline")
    ap.add_argument("--baseline-update", action="store_true",
                    help="rewrite the baseline to the current findings "
                         "(keeps surviving justifications)")
    ap.add_argument("--diff", action="store_true",
                    help="report only findings in files changed vs "
                         "--diff-base (the whole tree is still indexed, "
                         "so cross-module tracedness stays sound)")
    ap.add_argument("--diff-base", default="main",
                    help="git ref --diff compares against (default: main)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as JSON")
    args = ap.parse_args(argv)

    root = args.root or find_repo_root()
    paths = args.paths or [DEFAULT_TARGET]
    baseline_path = os.path.join(
        root, args.baseline or DEFAULT_BASELINE)

    report_files: Optional[Set[str]] = None
    if args.diff:
        report_files = changed_files(root, args.diff_base)
        if report_files is not None and not report_files:
            print("lint --diff: no .py files changed vs "
                  f"{args.diff_base}; nothing to do")
            return 0

    project = build_index(paths, root)
    raw = run_rules(project, report_files)

    if args.baseline_update:
        write_baseline(baseline_path, raw)
        print(f"baseline updated: {len(raw)} findings frozen in "
              f"{os.path.relpath(baseline_path, root)} — fill in any "
              f"'TODO: justify or fix' entries")
        return 0

    findings = raw if args.no_baseline else \
        apply_baseline(raw, load_baseline(baseline_path))

    if args.as_json:
        print(json.dumps({
            "target": paths, "total": len(findings),
            "baselined": len(raw) - len(findings),
            "findings": [f.to_dict() for f in findings]}, indent=1))
        return 1 if findings else 0

    if not findings:
        suppressed = len(raw) - len(findings)
        note = f" ({suppressed} baselined)" if suppressed else ""
        print(f"jaxlint: clean{note} — "
              f"{len(project.modules)} modules indexed")
        return 0

    by_rule: dict = {}
    for f in findings:
        by_rule.setdefault(f.rule, []).append(f)
        where = f"{f.file}:{f.line}"
        sym = f" [{f.symbol}]" if f.symbol else ""
        print(f"{where}: {f.rule}{sym} {f.message}")
        if f.code:
            print(f"    > {f.code}")
        if f.hint:
            print(f"    hint: {f.hint}")
    print()
    for rule in sorted(by_rule):
        title, _ = RULE_DOCS.get(rule, ("?", ""))
        print(f"  {rule}  {title}: {len(by_rule[rule])}")
    print(f"jaxlint: {len(findings)} finding(s) not covered by the "
          f"baseline ({os.path.relpath(baseline_path, root)}); fix them "
          f"or justify with --baseline-update")
    return 1


if __name__ == "__main__":
    sys.exit(main())
