"""Run a declarative ``ExperimentSpec`` end-to-end from the command line.

Reads a spec JSON file (see ``repro.experiments.specs``), expands the
aligned x K x seed grid, runs every registered method on every cell, and
writes ``results.json`` with the spec echo plus one tidy record per run.

Run:  PYTHONPATH=src python -m repro.launch.experiment SPEC.json \
          [--out results.json]
      PYTHONPATH=src python -m repro.launch.experiment --smoke

``--smoke`` runs a tiny built-in spec (bcw, 120 aligned rows, 2 epochs,
all five methods) — the CI canary for the public entry point.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.experiments import ExperimentSpec, MethodSpec, sweep, tidy


def smoke_spec() -> ExperimentSpec:
    """Tiny spec proving every built-in method runs through one sweep()."""
    return ExperimentSpec(
        name="smoke",
        dataset="bcw",
        aligned=(120,),
        seeds=(0,),
        methods=(MethodSpec("local"),
                 MethodSpec("apcvfl"),
                 MethodSpec("apcvfl", label="ablation",
                            params={"ablation": True}),
                 MethodSpec("splitnn", params={"test_size": 40}),
                 MethodSpec("vfedtrans"),
                 MethodSpec("apcvfl_aligned_only",
                            params={"test_size": 40})),
        overrides={"max_epochs": 2},
    )


def _summary_table(records: list) -> str:
    cols = ["method", "dataset", "n_aligned", "n_parties", "seed",
            "accuracy", "f1_macro", "rounds", "comm_mb"]
    lines = [" ".join(f"{c:>12}" for c in cols)]
    for r in records:
        cells = []
        for c in cols:
            v = r.get(c)
            cells.append(f"{v:>12.4f}" if isinstance(v, float)
                         else f"{str(v):>12}")
        lines.append(" ".join(cells))
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="run a declarative ExperimentSpec end-to-end")
    ap.add_argument("spec", nargs="?", default=None,
                    help="path to an ExperimentSpec JSON file")
    ap.add_argument("--smoke", action="store_true",
                    help="run the tiny built-in smoke spec instead")
    ap.add_argument("--out", default="results.json",
                    help="output path (default: results.json)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-run progress lines")
    args = ap.parse_args(argv)

    if args.smoke == (args.spec is not None):
        ap.error("give exactly one of SPEC.json or --smoke")
    if args.smoke:
        spec = smoke_spec()
    else:
        with open(args.spec) as fh:
            spec = ExperimentSpec.from_json(fh.read())

    t0 = time.time()
    results = sweep(spec, progress=None if args.quiet else print)
    records = tidy(results)
    payload = {"spec": spec.to_dict(), "records": records,
               "elapsed_s": round(time.time() - t0, 1)}
    with open(args.out, "w") as fh:
        json.dump(payload, fh, indent=1)
    print(f"\n=== {spec.name}: {len(records)} runs in "
          f"{payload['elapsed_s']}s -> {args.out} ===")
    print(_summary_table(records))
    return 0


if __name__ == "__main__":
    sys.exit(main())
