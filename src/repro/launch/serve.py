"""Serving driver: batched requests through the continuous-batching engine
(reduced config on CPU; the same engine runs pjit'd on the production mesh).

Run:  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --requests 12
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.models import model as M
from repro.serve.engine import Engine, Request
from repro.sharding.policy import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--slots", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(M.schema(cfg), jax.random.PRNGKey(0), jnp.float32)
    eng = Engine(params, cfg, batch=args.batch, n_slots=args.slots)

    rng = np.random.RandomState(0)
    for rid in range(args.requests):
        plen = int(rng.randint(4, 24))
        eng.submit(Request(rid, rng.randint(0, cfg.vocab_size, plen)
                           .astype(np.int32), max_new=args.max_new))

    t0 = time.time()
    stats = eng.run()
    dt = time.time() - t0
    print(f"arch={cfg.name} (reduced) batch={args.batch}")
    print(f"completed {stats.completed}/{args.requests} requests, "
          f"{stats.tokens_out} tokens in {dt:.1f}s "
          f"({stats.tokens_out/max(dt,1e-9):.1f} tok/s, "
          f"{stats.decode_steps} decode steps, {stats.prefills} prefills)")


if __name__ == "__main__":
    main()
