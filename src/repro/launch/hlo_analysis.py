"""Trip-count-aware HLO text analysis.

``compiled.cost_analysis()`` counts a ``while`` (scan) body ONCE, ignoring
the trip count — a 48-layer scanned stack would be under-counted 48x.  This
walker parses the optimized per-device HLO text, recursively descends into
called computations (fusion/call/while/conditional), multiplies ``while``
bodies by their ``known_trip_count`` backend config, and accumulates:

  * matmul FLOPs (dot ops: 2 * prod(out_shape) * contraction),
  * convolution FLOPs,
  * bytes accessed (operands + outputs of dot/fusion/copy/collective ops —
    an HBM-traffic estimate; elementwise ops live inside fusions),
  * collective bytes by kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), operand sizes summed, loop-scaled.

Numbers are per-device (the module is the SPMD-partitioned program);
global = per-device * n_chips for balanced programs.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[\d,]*\})?")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_CALL_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w\.\-]+)")
_COND_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _parse_shapes(type_str):
    """'(f32[8,16], s32[4])' or 'f32[8,16]' -> [(dtype, [dims]), ...]"""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = [int(x) for x in dims.split(",") if x] if dims else []
        out.append((dt, shape))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _prod(xs):
    n = 1
    for x in xs:
        n *= x
    return n


class Op:
    __slots__ = ("name", "kind", "out_shapes", "body", "text", "operands")

    def __init__(self, name, kind, out_shapes, body, text, operands):
        self.name, self.kind = name, kind
        self.out_shapes, self.body = out_shapes, body
        self.text, self.operands = text, operands


def parse_module(text: str):
    """-> (computations dict name -> [Op], shapes dict op_name -> shapes)."""
    comps = {}
    shapes = {}
    cur = None
    for line in text.splitlines():
        stripped = line.strip()
        if stripped.startswith(("HloModule",)):
            continue
        # computation header: `%name (params...) -> type {` or `ENTRY %name ...`
        if stripped.endswith("{") and ("->" in stripped or stripped.startswith("ENTRY")):
            m = re.search(r"%([\w\.\-]+)\s*\(", stripped)
            if m:
                cur = m.group(1)
                comps[cur] = []
            continue
        if stripped == "}" or stripped.startswith("}"):
            continue
        m = _OP_RE.match(line)
        if not m or cur is None:
            continue
        name, rest = m.group(1), m.group(2)
        # rest: 'type op(operands), attrs'
        km = re.match(r"((?:\([^)]*\)|[\w\[\]\{\},\.]+))\s+([\w\-]+)", rest)
        if not km:
            continue
        type_str, kind = km.group(1), km.group(2)
        out_shapes = _parse_shapes(type_str)
        body = None
        if kind in ("fusion", "call", "while", "map", "reduce",
                    "reduce-window", "scatter", "sort", "custom-call",
                    "conditional", "async-start"):
            cm = _CALL_RE.search(rest)
            if cm:
                body = cm.group(1)
        # operand names appear inside the first (...) after the op kind
        par = rest[rest.find("(", len(type_str)) + 1:]
        depth = 1
        arglist = []
        for ch in par:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            arglist.append(ch)
        operands = _OPERAND_RE.findall("".join(arglist))
        op = Op(name, kind, out_shapes, body, rest, operands)
        comps[cur].append(op)
        shapes[name] = out_shapes
    return comps, shapes


def _dot_flops(op: Op, shapes) -> float:
    out_elems = sum(_prod(d) for _, d in op.out_shapes)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.text)
    if not m or not op.operands:
        return 2.0 * out_elems  # fallback
    lhs = shapes.get(op.operands[0])
    if not lhs:
        return 2.0 * out_elems
    dims = [int(x) for x in m.group(1).split(",") if x]
    k = _prod([lhs[0][1][i] for i in dims if i < len(lhs[0][1])])
    # batch dims are shared between out and lhs; out_elems * k * 2 covers it
    return 2.0 * out_elems * k


def _conv_flops(op: Op, shapes) -> float:
    out_elems = sum(_prod(d) for _, d in op.out_shapes)
    if len(op.operands) >= 2:
        rhs = shapes.get(op.operands[1])
        if rhs:
            kernel_elems = _prod(rhs[0][1])
            # rough: 2 * out * (kernel spatial*in_ch)
            return 2.0 * out_elems * max(kernel_elems // max(rhs[0][1][-1], 1), 1)
    return 2.0 * out_elems


def analyze_text(text: str) -> dict:
    comps, shapes = parse_module(text)

    entry = None
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", text)
    if m:
        entry = m.group(1)
    if entry not in comps:
        # fall back: the computation with most ops
        entry = max(comps, key=lambda c: len(comps[c])) if comps else None

    memo = {}

    def walk(cname: str) -> dict:
        if cname in memo:
            return memo[cname]
        acc = {"flops": 0.0, "bytes": 0.0,
               "coll": defaultdict(float)}
        memo[cname] = acc  # guard cycles
        for op in comps.get(cname, []):
            opbytes = _nbytes(op.out_shapes) + sum(
                _nbytes(shapes.get(o, [])) for o in op.operands)
            if op.kind == "dot":
                acc["flops"] += _dot_flops(op, shapes)
                acc["bytes"] += opbytes
            elif op.kind == "convolution":
                acc["flops"] += _conv_flops(op, shapes)
                acc["bytes"] += opbytes
            elif (op.kind in COLLECTIVES or
                  any(op.kind == c + "-start" for c in COLLECTIVES)):
                # exact or "-start" only: counting "-done" too would double
                kind = next(c for c in COLLECTIVES if op.kind.startswith(c))
                sz = sum(_nbytes(shapes.get(o, [])) for o in op.operands)
                acc["coll"][kind] += sz
                acc["bytes"] += opbytes
            elif op.kind == "while":
                trip = 1
                tm = _TRIP_RE.search(op.text)
                if tm:
                    trip = int(tm.group(1))
                sub = walk(op.body) if op.body else {"flops": 0, "bytes": 0,
                                                     "coll": {}}
                acc["flops"] += trip * sub["flops"]
                acc["bytes"] += trip * sub["bytes"]
                for k, v in sub["coll"].items():
                    acc["coll"][k] += trip * v
            elif op.kind == "conditional":
                bm = _COND_BRANCH_RE.search(op.text)
                branches = []
                if bm:
                    branches = [b.strip().lstrip("%") for b in
                                bm.group(1).split(",")]
                if branches:
                    subs = [walk(b) for b in branches if b in comps]
                    if subs:
                        mx = max(subs, key=lambda s: s["flops"])
                        acc["flops"] += mx["flops"]
                        acc["bytes"] += mx["bytes"]
                        for k, v in mx["coll"].items():
                            acc["coll"][k] += v
            elif op.body and op.kind in ("fusion", "call", "async-start"):
                sub = walk(op.body)
                acc["flops"] += sub["flops"]
                acc["bytes"] += sub["bytes"] if sub["bytes"] else 0
                for k, v in sub["coll"].items():
                    acc["coll"][k] += v
                if op.kind == "fusion":
                    acc["bytes"] += opbytes
        return acc

    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
                "collectives": {}}
    res = walk(entry)
    analyze_text.last_walk = (comps, shapes, memo, entry)  # for breakdown()
    coll = dict(res["coll"])
    return {
        "flops": res["flops"],
        "bytes": res["bytes"],
        "collective_bytes": float(sum(coll.values())),
        "collectives": {k: float(v) for k, v in coll.items()},
    }


def breakdown(text: str, top: int = 20) -> list:
    """Top contributors to loop-scaled bytes, grouped by the jax op_name
    metadata (module/op path) — the profiler substitute for the dry-run."""
    comps, shapes = parse_module(text)
    entry = None
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", text)
    if m:
        entry = m.group(1)
    # compute the trip multiplier of each computation by walking from entry
    mult = defaultdict(float)

    def walk(cname, scale):
        mult[cname] += scale
        for op in comps.get(cname, []):
            if op.kind == "while" and op.body:
                trip = 1
                tm = _TRIP_RE.search(op.text)
                if tm:
                    trip = int(tm.group(1))
                walk(op.body, scale * trip)
            elif op.body and op.kind in ("fusion", "call", "async-start"):
                walk(op.body, scale)

    walk(entry, 1.0)
    agg = defaultdict(lambda: [0.0, 0.0])   # opname -> [bytes, flops]
    for cname, ops in comps.items():
        scale = mult.get(cname, 0.0)
        if scale == 0:
            continue
        for op in ops:
            if op.kind not in ("dot", "convolution", "fusion") and not any(
                    op.kind.startswith(c) for c in COLLECTIVES):
                continue
            nm = re.search(r'op_name="([^"]*)"', op.text)
            label = nm.group(1) if nm else op.kind
            label = re.sub(r"\[.*?\]", "", label)[:110]
            b = (_nbytes(op.out_shapes) + sum(
                _nbytes(shapes.get(o, [])) for o in op.operands)) * scale
            f = _dot_flops(op, shapes) * scale if op.kind == "dot" else 0.0
            agg[label][0] += b
            agg[label][1] += f
    rows = sorted(agg.items(), key=lambda kv: -kv[1][0])[:top]
    return [{"op": k, "gbytes": v[0] / 1e9, "gflops": v[1] / 1e9}
            for k, v in rows]
