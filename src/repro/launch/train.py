"""LM training driver: train any ``--arch`` (reduced via --smoke for CPU)
on the synthetic Markov token stream, with checkpointing and metrics.

On real hardware the same driver runs the production mesh (pjit over
``make_production_mesh()``); on this CPU container use --smoke.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_config, get_smoke
from repro.data.tokens import batches
from repro.optim.adam import AdamW
from repro.train.loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "audio":
        raise SystemExit("use launch/train for LM families; hubert trains "
                         "via the masked-frame objective in tests/examples")
    fns = make_train_step(cfg, AdamW(lr=args.lr))
    params, opt = fns.init(jax.random.PRNGKey(0))
    step_fn = jax.jit(fns.step)

    def full_batch(tokens):
        b = {"tokens": jnp.asarray(tokens)}
        if cfg.family == "vlm":
            b["image_embeds"] = jnp.zeros(
                (args.batch, cfg.n_image_tokens, cfg.d_model), jnp.float32)
        return b

    from repro.train.loop import task_loss
    eval_loss = jax.jit(lambda p, b: task_loss(p, cfg, b)[0])
    # fixed held-out batch: "improved" compares the SAME data before/after,
    # immune to batch-to-batch sampling noise of the Markov stream
    eval_b = full_batch(next(batches(cfg.vocab_size, args.batch, args.seq,
                                     1, seed=1234))["tokens"])
    loss_before = float(eval_loss(params, eval_b))

    losses = []
    t0 = time.time()
    for i, batch in enumerate(batches(cfg.vocab_size, args.batch, args.seq,
                                      args.steps)):
        params, opt, metrics = step_fn(params, opt,
                                       full_batch(batch["tokens"]))
        losses.append(float(metrics["loss"]))
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {losses[-1]:.4f} "
                  f"ce {float(metrics['ce']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({time.time()-t0:.1f}s)", flush=True)

    loss_after = float(eval_loss(params, eval_b))
    if args.ckpt:
        ckpt.save(args.ckpt, {"params": params}, step=args.steps,
                  meta={"arch": args.arch, "loss": losses[-1]})
        print("saved", args.ckpt)
    print(json.dumps({"first_loss": losses[0], "last_loss": losses[-1],
                      "eval_before": loss_before, "eval_after": loss_after,
                      "improved": loss_after < loss_before}))


if __name__ == "__main__":
    main()
