"""Yi-6B [arXiv:2403.04652]: llama-architecture dense GQA decoder.
32L, d_model 4096, 32 heads (kv 4), d_ff 11008, vocab 64000."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b", family="dense", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=4, d_ff=11008, vocab_size=64000,
        head_dim=128, ffn_type="swiglu", rope_theta=5e6)


def smoke() -> ModelConfig:
    return config().with_(n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
                          head_dim=64, d_ff=512, vocab_size=512,
                          dtype="float32")
