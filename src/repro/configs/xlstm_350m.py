"""xLSTM-350M [arXiv:2405.04517]: 24 blocks, d_model 1024, 4 heads,
vocab 50304, d_ff 0 (no separate FFN; mLSTM blocks carry a 2x inner
up-projection). One sLSTM block closes each group of 8 (xLSTM[7:1])."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="ssm", n_layers=24, d_model=1024,
        n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=50304,
        ssm_expand=2, ssm_chunk=256, slstm_period=8)


def smoke() -> ModelConfig:
    return config().with_(n_layers=4, d_model=256, n_heads=2, n_kv_heads=2,
                          vocab_size=512, slstm_period=2, ssm_chunk=32,
                          dtype="float32")
