"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B]: MoE decoder. 48L, d_model 2048,
32 heads (kv 4, head_dim 128), 128 experts top-8, per-expert d_ff 768,
vocab 151936."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe", n_layers=48, d_model=2048,
        n_heads=32, n_kv_heads=4, d_ff=768, vocab_size=151936,
        head_dim=128, ffn_type="swiglu", rope_theta=1e6,
        n_experts=128, experts_per_token=8)


def smoke() -> ModelConfig:
    return config().with_(n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
                          head_dim=64, d_ff=128, vocab_size=512,
                          n_experts=4, experts_per_token=2, dtype="float32")
