"""Config dataclasses: model architectures and benchmark input shapes.

Every assigned architecture gets one module in ``repro.configs`` exporting
``config()`` (the exact assigned full-size config, source cited) and
``smoke()`` (a reduced same-family variant for CPU tests: <=2 layers,
d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    ffn_type: str = "swiglu"    # swiglu | squared_relu | gelu
    causal: bool = True
    rope_theta: float = 1e6
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- SSM (mamba2) / xLSTM ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    conv_width: int = 4
    ssm_chunk: int = 256
    slstm_period: int = 0       # xlstm: one sLSTM block closes each group of this size
    # --- hybrid (zamba2) ---
    attn_period: int = 0        # shared attention block after every N ssm layers
    # --- vlm ---
    cross_attn_period: int = 0  # one cross-attn block closes each group of this size
    n_image_tokens: int = 0
    # --- attention variants ---
    sliding_window: int = 0     # 0 = full attention (training/prefill)
    long_context_window: int = 8192   # window for long_500k decode mode
    # --- numerics / execution ---
    dtype: str = "bfloat16"
    remat: bool = False
    use_flash_kernel: bool = False   # Pallas path (TPU target; tests use interpret)
    # beyond-paper perf knobs (see EXPERIMENTS.md section "Perf")
    fsdp_params: bool = True    # shard params along the data axis too (2D sharding)
    replicate_kv: bool = False  # replicate GQA KV projections instead of TP-sharding
    attn_chunk: int = 0         # >0: chunked online-softmax attention (no S^2
                                # HBM materialization; flash-attention in XLA)
    seq_parallel: bool = False  # Megatron-style sequence parallelism: shard the
                                # residual stream's seq dim on the tp axis
    mesh_axes: tuple = ()       # set by the launcher when seq_parallel is on
    ssd_bf16: bool = False      # bf16 intra-chunk SSD matmuls (states stay fp32)
    softmax_bf16: bool = False  # bf16 attention scores/probs (halves S^2 HBM)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def n_params(self) -> int:
        """Analytic parameter count (matches the init schema exactly is not
        required; used for MODEL_FLOPS = 6*N*D roofline bookkeeping)."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# TPU v5e hardware model used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
