"""Kimi-K2 [arXiv:2501.kimi2]: trillion-parameter MoE (paper-table entry).
61L, d_model 7168, 64 heads (GQA kv 8 per assignment), 384 experts top-8,
per-expert d_ff 2048, vocab 163840."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
        n_heads=64, n_kv_heads=8, d_ff=2048, vocab_size=163840,
        head_dim=128, ffn_type="swiglu", rope_theta=5e6,
        n_experts=384, experts_per_token=8)


def smoke() -> ModelConfig:
    return config().with_(n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
                          head_dim=64, d_ff=128, vocab_size=512,
                          n_experts=4, experts_per_token=2, dtype="float32")
