"""HuBERT X-Large [arXiv:2106.07447]: encoder-only audio transformer
(wav2vec2 backbone). 48L, d_model 1280, 16 MHA heads, d_ff 5120, 504-unit
target vocabulary. The conv waveform frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (B, S, d_model)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="audio", n_layers=48, d_model=1280,
        n_heads=16, n_kv_heads=16, d_ff=5120, vocab_size=504,
        head_dim=80, ffn_type="gelu", norm="layernorm", causal=False,
        rope_theta=1e4)


def smoke() -> ModelConfig:
    return config().with_(n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
                          head_dim=64, d_ff=512, dtype="float32")
