"""Architecture config registry: ``get_config(arch_id)`` / ``get_smoke(arch_id)``."""
from __future__ import annotations

import importlib

from repro.configs.base import INPUT_SHAPES, ModelConfig, ShapeConfig  # noqa: F401

ARCH_IDS = [
    "internlm2-20b",
    "xlstm-350m",
    "zamba2-2.7b",
    "yi-6b",
    "nemotron-4-15b",
    "hubert-xlarge",
    "llama-3.2-vision-11b",
    "internlm2-1.8b",
    "qwen3-moe-30b-a3b",
    "kimi-k2-1t-a32b",
    "apcvfl-paper",      # the paper's own (tabular autoencoder) config
]


def _mod(arch: str):
    return importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_"))


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).config()


def get_smoke(arch: str) -> ModelConfig:
    return _mod(arch).smoke()
