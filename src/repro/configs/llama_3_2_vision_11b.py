"""Llama-3.2-11B-Vision [hf:meta-llama/Llama-3.2-11B-Vision]: dense GQA
decoder with gated cross-attention image layers. 40L, d_model 4096,
32 heads (kv 8), d_ff 14336, vocab 128256; one cross-attn block closes each
group of 5 layers (8 cross layers). The ViT vision encoder + projector is a
STUB: ``input_specs`` provides projected patch embeddings
(B, n_image_tokens, d_model)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b", family="vlm", n_layers=40, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=128256,
        head_dim=128, ffn_type="swiglu", rope_theta=5e5,
        cross_attn_period=5, n_image_tokens=1600)


def smoke() -> ModelConfig:
    return config().with_(n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
                          head_dim=64, d_ff=512, vocab_size=512,
                          cross_attn_period=2, n_image_tokens=16,
                          dtype="float32")
