"""Zamba2-2.7B [arXiv:2411.15242]: Mamba2 backbone + ONE shared
attention+MLP block applied every 6 mamba layers. 54L, d_model 2560,
shared block: 32 MHA heads (kv 32), d_ff 10240; vocab 32000; ssm_state 64."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
        n_heads=32, n_kv_heads=32, d_ff=10240, vocab_size=32000,
        head_dim=80, ffn_type="gelu", rope_theta=1e4,
        ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
        attn_period=6)


def smoke() -> ModelConfig:
    return config().with_(n_layers=4, d_model=256, n_heads=4, n_kv_heads=4,
                          head_dim=64, d_ff=512, vocab_size=512,
                          ssm_head_dim=32, ssm_chunk=32, attn_period=2,
                          dtype="float32")
