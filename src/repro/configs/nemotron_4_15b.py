"""Nemotron-4-15B [arXiv:2402.16819]: dense GQA decoder with squared-ReLU
MLP and 256k vocabulary. 32L, d_model 6144, 48 heads (kv 8), d_ff 24576."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b", family="dense", n_layers=32, d_model=6144,
        n_heads=48, n_kv_heads=8, d_ff=24576, vocab_size=256000,
        head_dim=128, ffn_type="squared_relu", norm="layernorm",
        rope_theta=1e4)


def smoke() -> ModelConfig:
    return config().with_(n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
                          head_dim=64, d_ff=512, vocab_size=512,
                          dtype="float32")
