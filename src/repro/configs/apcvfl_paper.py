"""The paper's own model family: symmetric MLP autoencoders (Table 3) +
logistic-regression probe. This config names the *scaled* variant used when
an assigned backbone acts as the student encoder g3; the faithful tabular
reproduction lives in repro.core (architectures straight from Table 3).

``TABULAR`` is the single source of the paper's tabular-protocol
hyperparameters (Appendix B): every ``run_*`` entry point in
``repro.core`` defaults its kwargs from here, and ``MethodSpec.params``
overrides flow through the same kwargs — so a spec with no params
reproduces the paper's settings exactly.
"""
from dataclasses import dataclass

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class TabularHparams:
    """Paper Appendix B defaults for the tabular APC-VFL stack."""
    batch_size: int = 128
    max_epochs: int = 200       # <=200 epochs ...
    patience: int = 10          # ... with early stopping, patience 10
    lr: float = 1e-3            # Adam, Kingma & Ba defaults
    lam: float = 0.01           # Eq. 5 distillation weight
    kind: str = "mse"           # distillation distance
    test_size: int = 500        # held-out rows in the SplitNN comparison


TABULAR = TabularHparams()


def config() -> ModelConfig:
    # Student-encoder backbone used by the apcvfl_distill objective at scale:
    # a small dense GQA encoder whose pooled hidden state is the
    # representation z = g3(x).
    return ModelConfig(
        name="apcvfl-paper", family="dense", n_layers=12, d_model=1024,
        n_heads=16, n_kv_heads=8, d_ff=4096, vocab_size=32768,
        head_dim=64, ffn_type="swiglu")


def smoke() -> ModelConfig:
    return config().with_(n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
                          head_dim=64, d_ff=512, vocab_size=512,
                          dtype="float32")
