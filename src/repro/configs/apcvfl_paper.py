"""The paper's own model family: symmetric MLP autoencoders (Table 3) +
logistic-regression probe. This config names the *scaled* variant used when
an assigned backbone acts as the student encoder g3; the faithful tabular
reproduction lives in repro.core (architectures straight from Table 3)."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    # Student-encoder backbone used by the apcvfl_distill objective at scale:
    # a small dense GQA encoder whose pooled hidden state is the
    # representation z = g3(x).
    return ModelConfig(
        name="apcvfl-paper", family="dense", n_layers=12, d_model=1024,
        n_heads=16, n_kv_heads=8, d_ff=4096, vocab_size=32768,
        head_dim=64, ffn_type="swiglu")


def smoke() -> ModelConfig:
    return config().with_(n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
                          head_dim=64, d_ff=512, vocab_size=512,
                          dtype="float32")
