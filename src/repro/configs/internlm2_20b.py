"""InternLM2-20B [arXiv:2403.17297]: dense GQA decoder.
48L, d_model 6144, 48 heads (kv 8), d_ff 16384, vocab 92544, swiglu."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b", family="dense", n_layers=48, d_model=6144,
        n_heads=48, n_kv_heads=8, d_ff=16384, vocab_size=92544,
        head_dim=128, ffn_type="swiglu", rope_theta=1e6)


def smoke() -> ModelConfig:
    return config().with_(n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
                          head_dim=64, d_ff=512, vocab_size=512,
                          dtype="float32")
