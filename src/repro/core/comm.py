"""Communication accounting: a simulated peer-to-peer channel that records
every transfer, plus the paper's analytic footprint formulas (Appendix E).

Every transfer carries a *direction* and a *stage* so a channel can report
per-direction (uplink/downlink) and per-stage byte totals.  The direction
convention follows federated-learning usage: ``uplink`` flows toward the
aggregating side (the active participant, or the trusted server in
FedSVD), ``downlink`` flows away from it.  ``Channel.summary()`` returns a
JSON-ready dict of the measured totals; ``summarize`` aggregates several
per-link channels (the K-party case) into one such dict.

All analytic formulas assume 4-byte floats, as in the paper.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from math import ceil
from typing import Iterable, List, NamedTuple

UPLINK = "uplink"        # toward the active participant / server
DOWNLINK = "downlink"    # away from the active participant / server


class Transfer(NamedTuple):
    what: str
    nbytes: int
    direction: str
    stage: str
    dtype: str = "float32"


@dataclass
class Channel:
    """Byte- and round-accounting for a logical link between two parties."""
    log: List[Transfer] = field(default_factory=list)

    def send(self, what: str, nbytes: int, *, direction: str = UPLINK,
             stage: str | None = None, dtype: str = "float32"):
        """Record one transfer.  ``stage`` defaults to the prefix of
        ``what`` before the first ``/`` (e.g. ``"step1/Z"`` -> ``step1``);
        ``dtype`` labels the wire element type (``"sign1"`` for 1-bit sign
        payloads) so quantized exchanges stay auditable per dtype."""
        if stage is None:
            stage = what.split("/", 1)[0]
        self.log.append(Transfer(what, int(nbytes), direction, stage, dtype))

    def send_array(self, what: str, arr, *, direction: str = UPLINK,
                   stage: str | None = None):
        # actual wire size AND dtype of the array: a quantized exchange
        # hands an int8 payload here and is charged 1 B/element, not the
        # fp32 4 B the paper's analytic formulas assume
        self.send(what, arr.size * arr.dtype.itemsize, direction=direction,
                  stage=stage, dtype=str(arr.dtype))

    @property
    def total_bytes(self) -> int:
        return sum(t.nbytes for t in self.log)

    @property
    def rounds(self) -> int:
        return len(self.log)

    def total_mb(self) -> float:
        return self.total_bytes / 1e6

    def bytes_by_direction(self) -> dict:
        out = {UPLINK: 0, DOWNLINK: 0}
        for t in self.log:
            out[t.direction] = out.get(t.direction, 0) + t.nbytes
        return out

    def bytes_by_stage(self) -> dict:
        out: dict = {}
        for t in self.log:
            out[t.stage] = out.get(t.stage, 0) + t.nbytes
        return out

    def bytes_by_dtype(self) -> dict:
        out: dict = {}
        for t in self.log:
            out[t.dtype] = out.get(t.dtype, 0) + t.nbytes
        return out

    def summary(self) -> dict:
        """JSON-ready measured totals for this link."""
        by_dir = self.bytes_by_direction()
        return {
            "total_bytes": self.total_bytes,
            "total_mb": self.total_mb(),
            "transfers": self.rounds,
            "uplink_bytes": by_dir.get(UPLINK, 0),
            "downlink_bytes": by_dir.get(DOWNLINK, 0),
            "by_stage": self.bytes_by_stage(),
            "by_dtype": self.bytes_by_dtype(),
        }


def exchange_array(channel: Channel, what: str, z, *, transform=None,
                   seed: int = 0, link: int = 0, direction: str = UPLINK):
    """THE one-shot latent exchange, with an optional hardening hook.

    ``transform=None`` is the paper's plain fp32 send: the array is
    byte-accounted as-is and the receiver gets exactly what the sender
    encoded.  A ``transform`` (an ``ExchangeTransform`` from
    ``repro.robustness.defense`` — anything with an ``exchange`` method)
    instead perturbs/quantizes the payload at the sender, accounts the
    TRANSFORMED wire bytes (per-dtype), and returns the fp32 array the
    receiver reconstructs — the active party must only ever consume this
    return value.  ``seed``/``link`` make the transform's randomness
    deterministic per run and per passive link."""
    if transform is None:
        channel.send_array(what, z, direction=direction)
        return z
    return transform.exchange(channel, what, z, seed=seed, link=link,
                              direction=direction)


def normalize_exchange(transform, n: int) -> list:
    """Replica contract for the ``*_replicated`` entry points: one
    transform shared by every replica, or exactly one per replica
    (entries may be ``None`` — a mixed-defense lane grid)."""
    if transform is None or hasattr(transform, "exchange"):
        return [transform] * n
    out = list(transform)
    if len(out) != n:
        raise ValueError(f"normalize_exchange: {len(out)} exchange "
                         f"transforms for {n} replicas")
    return out


def summarize(channels: Iterable[Channel]) -> dict:
    """Aggregate several per-link channels into one ``summary()``-shaped
    dict (bytes and transfer counts sum; stages merge)."""
    total = Channel()
    for ch in channels:
        total.log.extend(ch.log)
    return total.summary()


# --- Appendix E.1: APC-VFL -------------------------------------------------

def apcvfl_footprint_bytes(n_aligned: int, z_p: int = 256) -> int:
    """Eq. 6: one exchange of Z_A in R^{|D_A| x z_p}."""
    return n_aligned * z_p * 4


# --- Appendix E.2: SplitNN -------------------------------------------------

def splitnn_forward_bytes(epochs: int, n_aligned: int, z_p: int = 256) -> int:
    """Eq. 7."""
    return epochs * n_aligned * z_p * 4


def splitnn_backprop_bytes(epochs: int, n_aligned: int, batch_size: int,
                           p_params: int = 128 * 256 + 256) -> int:
    """Eq. 8: gradients w.r.t. the final passive-encoder layer, per batch."""
    return epochs * ceil(n_aligned / batch_size) * p_params * 4


def splitnn_footprint_bytes(epochs: int, n_aligned: int, batch_size: int,
                            z_p: int = 256,
                            p_params: int = 128 * 256 + 256) -> int:
    """Eq. 9."""
    return (splitnn_forward_bytes(epochs, n_aligned, z_p)
            + splitnn_backprop_bytes(epochs, n_aligned, batch_size, p_params))


def splitnn_rounds(epochs: int, n_aligned: int, batch_size: int) -> int:
    """Table 2: 2x the number of backprop events (one up, one down)."""
    return 2 * epochs * ceil(n_aligned / batch_size)


# --- Appendix E: VFedTrans (FedSVD) ----------------------------------------

def vfedtrans_footprint_bytes(n_aligned: int, x_t: int, x_d: int) -> int:
    """Eq. 10: 2|D_A|^2 + x_t*x_tot + x_d*x_tot + |D_A|x_t + |D_A|x_d +
    |D_A|x_tot elements, 5 exchanges, 4 bytes each."""
    x_tot = x_t + x_d
    elems = (2 * n_aligned ** 2 + x_t * x_tot + x_d * x_tot
             + n_aligned * x_t + n_aligned * x_d + n_aligned * x_tot)
    return elems * 4


VFEDTRANS_ROUNDS = 5   # trusted keygen (x2), uploads (x2), U download
APCVFL_ROUNDS = 1
