"""Communication accounting: a simulated peer-to-peer channel that records
every transfer, plus the paper's analytic footprint formulas (Appendix E).

All analytic formulas assume 4-byte floats, as in the paper.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from math import ceil


@dataclass
class Channel:
    """Byte- and round-accounting for a logical link between two parties."""
    log: list = field(default_factory=list)

    def send(self, what: str, nbytes: int):
        self.log.append((what, int(nbytes)))

    def send_array(self, what: str, arr):
        # actual wire size of the array; the protocol sends float32 (4 B)
        # everywhere, matching the paper's analytic formulas below
        self.send(what, arr.size * arr.dtype.itemsize)

    @property
    def total_bytes(self) -> int:
        return sum(b for _, b in self.log)

    @property
    def rounds(self) -> int:
        return len(self.log)

    def total_mb(self) -> float:
        return self.total_bytes / 1e6


# --- Appendix E.1: APC-VFL -------------------------------------------------

def apcvfl_footprint_bytes(n_aligned: int, z_p: int = 256) -> int:
    """Eq. 6: one exchange of Z_A in R^{|D_A| x z_p}."""
    return n_aligned * z_p * 4


# --- Appendix E.2: SplitNN -------------------------------------------------

def splitnn_forward_bytes(epochs: int, n_aligned: int, z_p: int = 256) -> int:
    """Eq. 7."""
    return epochs * n_aligned * z_p * 4


def splitnn_backprop_bytes(epochs: int, n_aligned: int, batch_size: int,
                           p_params: int = 128 * 256 + 256) -> int:
    """Eq. 8: gradients w.r.t. the final passive-encoder layer, per batch."""
    return epochs * ceil(n_aligned / batch_size) * p_params * 4


def splitnn_footprint_bytes(epochs: int, n_aligned: int, batch_size: int,
                            z_p: int = 256,
                            p_params: int = 128 * 256 + 256) -> int:
    """Eq. 9."""
    return (splitnn_forward_bytes(epochs, n_aligned, z_p)
            + splitnn_backprop_bytes(epochs, n_aligned, batch_size, p_params))


def splitnn_rounds(epochs: int, n_aligned: int, batch_size: int) -> int:
    """Table 2: 2x the number of backprop events (one up, one down)."""
    return 2 * epochs * ceil(n_aligned / batch_size)


# --- Appendix E: VFedTrans (FedSVD) ----------------------------------------

def vfedtrans_footprint_bytes(n_aligned: int, x_t: int, x_d: int) -> int:
    """Eq. 10: 2|D_A|^2 + x_t*x_tot + x_d*x_tot + |D_A|x_t + |D_A|x_d +
    |D_A|x_tot elements, 5 exchanges, 4 bytes each."""
    x_tot = x_t + x_d
    elems = (2 * n_aligned ** 2 + x_t * x_tot + x_d * x_tot
             + n_aligned * x_t + n_aligned * x_d + n_aligned * x_tot)
    return elems * 4


VFEDTRANS_ROUNDS = 5   # trusted keygen (x2), uploads (x2), U download
APCVFL_ROUNDS = 1
