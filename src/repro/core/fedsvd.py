"""FedSVD (Chai et al., KDD'22): lossless federated SVD over vertically
partitioned data via random orthogonal masking — the representation-learning
stage of VFedTrans.

Protocol (and the paper's Appendix-E comm accounting):
  keygen -> parties:  A (n x n), B_t / B_d slices of orthogonal B
  party k -> server:  S~_k = A X_k B_k
  server:             X' = sum_k S~_k ;  SVD(X') = U' S V'^T
  server -> active:   U~ = U' (masked left factors)
  active:             U = A^T U'   (lossless since A, B orthogonal)

Implementation note (DESIGN.md): generating a dense random-orthogonal
A (n x n) costs O(n^3); we use a signed permutation (exactly orthogonal,
O(n)) — the protocol and its *byte accounting* are unchanged (A ships as a
dense n x n matrix per Eq. 10), the algebra is identical.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import comm


@dataclass
class FedSVDResult:
    U: np.ndarray            # (n, r) federated left factors (active's copy)
    S: np.ndarray            # (r,) singular values
    channel: comm.Channel
    rounds: int


def _signed_perm(n: int, rng) -> tuple:
    perm = rng.permutation(n)
    sign = rng.choice([-1.0, 1.0], n).astype(np.float32)
    return perm, sign


def _apply_A(perm, sign, X):          # A @ X  with A = P diag(sign)
    return (X * sign[:, None])[perm]


def _apply_AT(perm, sign, X):
    # A[i,j] = sign[j]*[j == perm[i]]  =>  (A^T Y)[i] = sign[i]*Y[perm^-1(i)]
    return X[np.argsort(perm)] * sign[:, None]


def fedsvd(x_active: np.ndarray, x_passive: np.ndarray, *, seed: int = 0,
           channel: comm.Channel | None = None) -> FedSVDResult:
    """x_active (n, x_t), x_passive (n, x_d): the ALIGNED rows of each party."""
    n, x_t = x_active.shape
    x_d = x_passive.shape[1]
    x_tot = x_t + x_d
    rng = np.random.RandomState(seed)
    channel = channel or comm.Channel()

    # trusted key generator
    permA, signA = _signed_perm(n, rng)
    permB, signB = _signed_perm(x_tot, rng)
    channel.send("fedsvd/keygen->active: A,B_t", (n * n + x_t * x_tot) * 4,
                 direction="downlink")
    channel.send("fedsvd/keygen->passive: A,B_d", (n * n + x_d * x_tot) * 4,
                 direction="downlink")

    # masked uploads: S~_k = A X_k B_k   (B_k = rows of B for party k's cols)
    def mask_party(Xk, col_offset, ncols):
        AX = _apply_A(permA, signA, Xk.astype(np.float32))
        S = np.zeros((n, x_tot), np.float32)
        # B = P_B diag(signB): column j of global X lands in column permB[j]
        for j in range(ncols):
            gj = col_offset + j
            S[:, permB[gj]] = AX[:, j] * signB[permB[gj]]
        return S

    St = mask_party(x_active, 0, x_t)
    Sd = mask_party(x_passive, x_t, x_d)
    channel.send("fedsvd/active->server: S~_t", n * x_t * 4,
                 direction="uplink")
    channel.send("fedsvd/passive->server: S~_d", n * x_d * 4,
                 direction="uplink")

    Xp = St + Sd
    Up, S, _ = np.linalg.svd(Xp, full_matrices=False)
    channel.send("fedsvd/server->active: U~", n * x_tot * 4,
                 direction="downlink")

    U = _apply_AT(permA, signA, Up)
    return FedSVDResult(U.astype(np.float32), S.astype(np.float32),
                        channel, comm.VFEDTRANS_ROUNDS)
