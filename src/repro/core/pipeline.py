"""APC-VFL: the four-step protocol (paper Fig. 3) plus the aligned-only
adaptation used against SplitNN (paper Fig. 4) and the Appendix-F
encoder-quality probe (Algorithm 1).

Step 1  local representation learning   (every participant, autoencoder)
        -> passive sends Z_p[aligned] to active: THE single exchange.
Step 2  aligned representation learning (active, autoencoder g2 on
        concat(Z_a, Z_p) of aligned rows)
Step 3  knowledge distillation          (active, student AE g3 on the FULL
        active dataset, Eq. 5 masked loss)
Step 4  classifier on Z = g3(X_active), labels from the active party.

All stages train on the device-resident scan engine (``core.training``):
each stage uploads its arrays once and runs whole epochs as a single jitted
scan, and every ``distill.make_loss`` closure with equal hyperparameters
reuses the g3 engine via its semantic cache key.  The two step-1 (g1)
autoencoders train TOGETHER through ``training.train_many`` — params and
data zero-padded to common shapes, stacked on a leading party axis, every
epoch one vmapped scan — the same batched engine ``core.multiparty`` uses
for K parties (this is the K=2 special case).

Hyperparameter defaults come from ``configs.apcvfl_paper.TABULAR`` (the
paper's Appendix-B settings); every entry point returns the unified
``experiments.results.RunResult``, so declarative specs
(``repro.experiments``) and direct calls see identical behavior.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.apcvfl_paper import TABULAR as HP
from repro.core import autoencoder as ae
from repro.core import classifier as clf
from repro.core import comm
from repro.core import distill
from repro.core import training
from repro.core.psi import psi
from repro.data.vertical import VFLScenario
from repro.experiments.results import RunResult


def run_apcvfl(sc: VFLScenario, *, lam: float = HP.lam, kind: str = HP.kind,
               seed: int = 0, batch_size: int = HP.batch_size,
               max_epochs: int = HP.max_epochs, patience: int = HP.patience,
               lr: float = HP.lr, use_kernel: bool = False,
               ablation: bool = False) -> RunResult:
    """Full protocol. ``ablation=True`` trains g3 WITHOUT the distillation
    term (paper's 'Ablation' curves — isolates the nonlinear-encoder gain).
    """
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    channel = comm.Channel()
    epochs = {}
    train_kw = dict(batch_size=batch_size, max_epochs=max_epochs,
                    patience=patience, lr=lr)

    # --- PSI on IDs (assumed precondition in the paper; bytes logged) ------
    aligned_ids, idx_a, idx_p = psi(sc.active.ids, sc.passive.ids,
                                    channel=channel)

    xa, xp = sc.active.x, sc.passive.x

    # --- Step 1: local representation learning -----------------------------
    if not ablation:
        wa = ae.table3_encoder("g1_active", xa.shape[1])
        wp = ae.table3_encoder("g1_passive", xp.shape[1])
        ae_a = ae.init_autoencoder(k1, wa)
        ae_p = ae.init_autoencoder(k2, wp)
        ra, rp = training.train_many(
            [training.PartySpec(ae_a, {"x": xa}, seed),
             training.PartySpec(ae_p, {"x": xp}, seed + 1)],
            ae.masked_recon_loss, **train_kw)
        epochs["g1_active"], epochs["g1_passive"] = ra.epochs_run, rp.epochs_run

        za_al = np.asarray(ae.encode(ra.params, jnp.asarray(xa[idx_a])))
        zp_al = np.asarray(ae.encode(rp.params, jnp.asarray(xp[idx_p])))

        # THE single information exchange: passive -> active, aligned latents
        channel.send_array("step1/Z_passive_aligned", zp_al,
                           direction="uplink")

        # --- Step 2: aligned (joint) representation learning ---------------
        zj = np.concatenate([za_al, zp_al], axis=1).astype(np.float32)
        w2 = ae.table3_encoder("g2", zj.shape[1])
        ae_2 = ae.init_autoencoder(k3, w2)
        r2 = training.train(ae_2, {"x": zj}, ae.recon_loss, seed=seed + 2,
                            **train_kw)
        epochs["g2"] = r2.epochs_run
        z_teacher_al = np.asarray(ae.encode(r2.params, jnp.asarray(zj)))
        m2 = z_teacher_al.shape[1]
    else:
        m2 = ae.table3_encoder("g2", 1)[-1]
        z_teacher_al = None

    # --- Step 3: knowledge distillation into g3 -----------------------------
    n_a = len(xa)
    z_teacher = np.zeros((n_a, m2), np.float32)
    mask = np.zeros((n_a,), np.float32)
    if not ablation:
        z_teacher[idx_a] = z_teacher_al
        mask[idx_a] = 1.0
    w3 = ae.table3_encoder("g3", xa.shape[1])
    assert w3[-1] == m2, "M3 == M2: dimensional consistency (Sec. 4.3)"
    ae_3 = ae.init_autoencoder(k4, w3)
    loss3 = distill.make_loss(lam=lam, kind=kind, use_kernel=use_kernel)
    r3 = training.train(ae_3, {"x": xa, "z_teacher": z_teacher,
                               "aligned": mask}, loss3, seed=seed + 3,
                        **train_kw)
    epochs["g3"] = r3.epochs_run

    # --- Step 4: classifier on the enhanced dataset -------------------------
    z_all = np.asarray(ae.encode(r3.params, jnp.asarray(xa)))
    metrics = clf.kfold_cv(z_all, sc.active.y, sc.n_classes, seed=seed)

    data_rounds = 0 if ablation else comm.APCVFL_ROUNDS
    return RunResult(method="apcvfl", metrics=metrics, rounds=data_rounds,
                     epochs=epochs, comm=channel.summary(), seed=seed,
                     z_dim=m2, params={"g3": r3.params}, channels=(channel,))


def run_local_baseline(sc, seed: int = 0) -> dict:
    """Paper 'Local': probe on raw active features.  Works for 2-party and
    K-party scenarios (only ``sc.active`` is touched); returns the bare
    metrics dict — the ``experiments`` registry wraps it into a
    ``RunResult``."""
    return clf.kfold_cv(sc.active.x, sc.active.y, sc.n_classes, seed=seed)


# ---------------------------------------------------------------------------
# aligned-only adaptation (paper Fig. 4, for the SplitNN comparison)
# ---------------------------------------------------------------------------

def run_apcvfl_aligned_only(sc: VFLScenario, *, seed: int = 0,
                            batch_size: int = HP.batch_size,
                            max_epochs: int = HP.max_epochs,
                            patience: int = HP.patience, lr: float = HP.lr,
                            test_size: int = HP.test_size) -> RunResult:
    """Classical fully-aligned setting: train the classifier directly on the
    joint latents g2(concat(Z_a, Z_p)); distillation is skipped (no
    unaligned rows exist to distill into)."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    channel = comm.Channel()
    train_kw = dict(batch_size=batch_size, max_epochs=max_epochs,
                    patience=patience, lr=lr)
    _, idx_a, idx_p = psi(sc.active.ids, sc.passive.ids, channel=channel)
    xa, xp = sc.active.x[idx_a], sc.passive.x[idx_p]
    y = sc.active.y[idx_a]

    ae_a = ae.init_autoencoder(k1, ae.table3_encoder("g1_active", xa.shape[1]))
    ae_p = ae.init_autoencoder(k2, ae.table3_encoder("g1_passive", xp.shape[1]))
    ra, rp = training.train_many(
        [training.PartySpec(ae_a, {"x": xa}, seed),
         training.PartySpec(ae_p, {"x": xp}, seed + 1)],
        ae.masked_recon_loss, **train_kw)
    za = np.asarray(ae.encode(ra.params, jnp.asarray(xa)))
    zp = np.asarray(ae.encode(rp.params, jnp.asarray(xp)))
    channel.send_array("step1/Z_passive_aligned", zp, direction="uplink")

    zj = np.concatenate([za, zp], 1).astype(np.float32)
    ae_2 = ae.init_autoencoder(k3, ae.table3_encoder("g2", zj.shape[1]))
    r2 = training.train(ae_2, {"x": zj}, ae.recon_loss, seed=seed + 2,
                        **train_kw)
    z = np.asarray(ae.encode(r2.params, jnp.asarray(zj)))

    # train/test split as in the SplitNN comparison (test_size held out)
    rng = np.random.RandomState(seed)
    perm = rng.permutation(len(z))
    te, tr = perm[:test_size], perm[test_size:]
    params = clf.fit_logreg(jnp.asarray(z[tr]), jnp.asarray(y[tr]),
                            sc.n_classes)
    pred = clf.predict(params, z[te])
    metrics = clf.f1_scores(y[te], pred, sc.n_classes)
    return RunResult(method="apcvfl_aligned_only", metrics=metrics, rounds=1,
                     epochs={"g1_active": ra.epochs_run,
                             "g1_passive": rp.epochs_run,
                             "g2": r2.epochs_run},
                     comm=channel.summary(), seed=seed, z_dim=z.shape[1],
                     params={"g2": r2.params}, channels=(channel,))


# ---------------------------------------------------------------------------
# Appendix F, Algorithm 1: encoder training with representation-quality probe
# ---------------------------------------------------------------------------

def train_encoder_with_probe(x: np.ndarray, y: np.ndarray, n_classes: int,
                             widths: list, *, metric: str = "accuracy",
                             k: int = 5, max_epochs: int = 30,
                             seed: int = 0) -> dict:
    """Runs Algorithm 1: per-epoch, k-fold CV the probe on Z=g(X).  Returns
    the loss curve, per-epoch metric sets M~, the raw-X metric set M, and
    the equivalence gap (Eq. 12)."""
    key = jax.random.PRNGKey(seed)
    params = ae.init_autoencoder(key, widths)
    history = {"loss": [], "probe": []}

    def cb(epoch, p, tl, vl):
        # per-epoch probe; ``p`` is device-resident and donated into the
        # next epoch, so everything derived from it is computed here
        z = np.asarray(ae.encode(p, jnp.asarray(x)))
        m = clf.kfold_cv(z, y, n_classes, k=k, seed=seed)
        history["probe"].append(m[metric])
        history["loss"].append(tl)

    training.train(params, {"x": x}, ae.recon_loss, max_epochs=max_epochs,
                   patience=max_epochs, seed=seed, epoch_callback=cb)
    base = clf.kfold_cv(x, y, n_classes, k=k, seed=seed)[metric]
    gap = base - (history["probe"][-1] if history["probe"] else 0.0)
    return {"history": history, "metric_raw_x": base, "gap": gap}
