"""APC-VFL: the four-step protocol (paper Fig. 3) plus the aligned-only
adaptation used against SplitNN (paper Fig. 4) and the Appendix-F
encoder-quality probe (Algorithm 1).

Step 1  local representation learning   (every participant, autoencoder)
        -> passive sends Z_p[aligned] to active: THE single exchange.
Step 2  aligned representation learning (active, autoencoder g2 on
        concat(Z_a, Z_p) of aligned rows)
Step 3  knowledge distillation          (active, student AE g3 on the FULL
        active dataset, Eq. 5 masked loss)
Step 4  classifier on Z = g3(X_active), labels from the active party.

All stages train on the device-resident scan engine (``core.training``):
each stage uploads its arrays once and runs whole epochs as a single jitted
scan, and every ``distill.make_loss`` closure with equal hyperparameters
reuses the g3 engine via its semantic cache key.  The two step-1 (g1)
autoencoders train TOGETHER through ``training.train_lanes`` — params and
data zero-padded to common shapes, stacked on a leading lane axis, every
epoch one vmapped scan — the same lane engine ``core.multiparty`` uses
for K parties (this is the 2-lane special case).

Stage handoffs are device-resident: encoder outputs feed the next stage as
jax arrays (the lane engine gathers its train/val splits on device) and
the channel accounting reads only shapes/dtypes, so the handoffs
themselves add NO host round-trips — what remains is the engine's single
early-stop sync per FIT (the fused scan-of-scans engine keeps the whole
epoch loop on device) and the final metrics evaluation
(``clf.kfold_cv``, one sync for all folds).

``run_apcvfl_replicated`` runs S seed replicates of one grid cell through
every stage together: each stage becomes S (or 2S, for the two g1s) lanes
of one ``training.train_lanes`` call, so a whole multi-seed sweep cell
costs one compile and one host sync per stage instead of S of each.  Both
``*_replicated`` entry points take an optional ``mesh``
(``repro.launch.mesh.make_lane_mesh``) that shards every stage's lane
axis across devices — same computation, device-parallel lanes.

Hyperparameter defaults come from ``configs.apcvfl_paper.TABULAR`` (the
paper's Appendix-B settings); every entry point returns the unified
``experiments.results.RunResult``, so declarative specs
(``repro.experiments``) and direct calls see identical behavior.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.apcvfl_paper import TABULAR as HP
from repro.core import autoencoder as ae
from repro.core import classifier as clf
from repro.core import comm
from repro.core import distill
from repro.core import training
from repro.core.psi import psi
from repro.data.vertical import VFLScenario
from repro.experiments.results import RunResult


def run_apcvfl(sc: VFLScenario, *, lam: float = HP.lam, kind: str = HP.kind,
               seed: int = 0, batch_size: int = HP.batch_size,
               max_epochs: int = HP.max_epochs, patience: int = HP.patience,
               lr: float = HP.lr, use_kernel: bool = False,
               ablation: bool = False, exchange=None) -> RunResult:
    """Full protocol. ``ablation=True`` trains g3 WITHOUT the distillation
    term (paper's 'Ablation' curves — isolates the nonlinear-encoder gain).

    ``exchange`` hardens the single latent exchange: an
    ``ExchangeTransform`` (``repro.robustness.defense`` — DP noise,
    quantization) applied at the sender.  Everything downstream of the
    exchange (g2, g3, the serving artifacts) consumes the RECEIVED
    latents, and the channel accounts the transformed wire bytes.
    ``None`` (default) is the paper's plain fp32 exchange, bit-identical
    to the pre-hook behavior.
    """
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    channel = comm.Channel()
    epochs = {}
    train_kw = dict(batch_size=batch_size, max_epochs=max_epochs,
                    patience=patience, lr=lr)

    # --- PSI on IDs (assumed precondition in the paper; bytes logged) ------
    aligned_ids, idx_a, idx_p = psi(sc.active.ids, sc.passive.ids,
                                    channel=channel)

    xa, xp = sc.active.x, sc.passive.x

    # --- Step 1: local representation learning -----------------------------
    if not ablation:
        wa = ae.table3_encoder("g1_active", xa.shape[1])
        wp = ae.table3_encoder("g1_passive", xp.shape[1])
        ae_a = ae.init_autoencoder(k1, wa)
        ae_p = ae.init_autoencoder(k2, wp)
        ra, rp = training.train_lanes(
            [training.LaneSpec(ae_a, {"x": xa}, seed),
             training.LaneSpec(ae_p, {"x": xp}, seed + 1)],
            ae.make_masked_recon_loss(use_kernel), **train_kw)
        epochs["g1_active"], epochs["g1_passive"] = ra.epochs_run, rp.epochs_run

        # device-resident handoff: latents stay jax arrays end to end
        za_al = ae.encode(ra.params, jnp.asarray(xa[idx_a]))
        zp_al = ae.encode(rp.params, jnp.asarray(xp[idx_p]))

        # THE single information exchange: passive -> active, aligned
        # latents (byte accounting reads only shape/dtype — no host
        # sync).  With a transform, zp_al becomes what the active party
        # RECEIVED — the only form g2/g3/serving may ever see.
        zp_al = comm.exchange_array(channel, "step1/Z_passive_aligned",
                                    zp_al, transform=exchange, seed=seed)

        # --- Step 2: aligned (joint) representation learning ---------------
        zj = jnp.concatenate([za_al, zp_al], axis=1).astype(jnp.float32)
        w2 = ae.table3_encoder("g2", zj.shape[1])
        ae_2 = ae.init_autoencoder(k3, w2)
        # singleton lane (not training.train): the SAME engine + loss the
        # replicated path runs, so rep-vs-seq g2 params are bit-identical
        # (the probe is chaotic enough to amplify a 1e-8 loss-reduction
        # reordering into whole flipped CV predictions)
        (r2,) = training.train_lanes(
            [training.LaneSpec(ae_2, {"x": zj}, seed + 2)],
            ae.make_masked_recon_loss(use_kernel), **train_kw)
        epochs["g2"] = r2.epochs_run
        z_teacher_al = ae.encode(r2.params, zj)
        m2 = z_teacher_al.shape[1]
    else:
        m2 = ae.table3_encoder("g2", 1)[-1]
        z_teacher_al = None

    # --- Step 3: knowledge distillation into g3 -----------------------------
    n_a = len(xa)
    z_teacher = jnp.zeros((n_a, m2), jnp.float32)
    mask = jnp.zeros((n_a,), jnp.float32)
    if not ablation:
        z_teacher = z_teacher.at[idx_a].set(z_teacher_al)
        mask = mask.at[idx_a].set(1.0)
    w3 = ae.table3_encoder("g3", xa.shape[1])
    assert w3[-1] == m2, "M3 == M2: dimensional consistency (Sec. 4.3)"
    ae_3 = ae.init_autoencoder(k4, w3)
    loss3 = distill.make_loss(lam=lam, kind=kind, use_kernel=use_kernel)
    r3 = training.train(ae_3, {"x": xa, "z_teacher": z_teacher,
                               "aligned": mask}, loss3, seed=seed + 3,
                        **train_kw)
    epochs["g3"] = r3.epochs_run

    # --- Step 4: classifier on the enhanced dataset -------------------------
    # the protocol's single host sync: kfold_cv pulls predictions once
    z_all = ae.encode(r3.params, jnp.asarray(xa))
    metrics = clf.kfold_cv(z_all, sc.active.y, sc.n_classes, seed=seed)

    data_rounds = 0 if ablation else comm.APCVFL_ROUNDS
    params = {"g3": r3.params}
    artifacts = None
    if not ablation:
        # everything the active party holds after training, captured for
        # serving export (serve.vfl.export_bundle): its own encoders plus
        # the passive latents it RECEIVED — never the passive party's model
        params["g1_active"] = ra.params
        params["g2"] = r2.params
        artifacts = {"aligned_ids": np.asarray(aligned_ids),
                     "z_passive_aligned": zp_al}
    return RunResult(method="apcvfl", metrics=metrics, rounds=data_rounds,
                     epochs=epochs, comm=channel.summary(), seed=seed,
                     z_dim=m2, params=params, channels=(channel,),
                     artifacts=artifacts)


# ---------------------------------------------------------------------------
# replica-lane execution: all seeds of one grid cell per stage dispatch
# ---------------------------------------------------------------------------

def _normalize_replicas(fn_name: str, scenarios, seeds):
    """Shared contract of the ``*_replicated`` entry points: int seeds,
    one scenario broadcast to every seed or exactly one per seed."""
    seeds = [int(s) for s in seeds]
    S = len(seeds)
    scs = ([scenarios] * S if isinstance(scenarios, VFLScenario)
           else list(scenarios))
    if len(scs) != S:
        raise ValueError(f"{fn_name}: {len(scs)} scenarios for {S} seeds")
    return scs, seeds


def run_apcvfl_replicated(scenarios, *, seeds, lam: float = HP.lam,
                          kind: str = HP.kind,
                          batch_size: int = HP.batch_size,
                          max_epochs: int = HP.max_epochs,
                          patience: int = HP.patience, lr: float = HP.lr,
                          use_kernel: bool = False,
                          ablation: bool = False, exchange=None,
                          mesh=None) -> list:
    """Full protocol for S seed replicates of one grid cell, every stage
    one ``training.train_lanes`` dispatch: the two g1s of all seeds run as
    2S lanes, g2 as S lanes, g3 as S lanes — one compile and one host sync
    per epoch for the whole replica set instead of S of each.

    ``scenarios`` is a single ``VFLScenario`` shared by every seed, or a
    sequence of per-seed scenarios of EQUAL shapes (a sweep group: same
    dataset / n_aligned / feature split, different partition seeds).
    Returns one ``RunResult`` per seed, each matching what
    ``run_apcvfl(scenarios[i], seed=seeds[i], ...)`` produces to float
    tolerance (per-lane trajectories are lane-local; tests/test_replicas.py
    pins the parity).  ``use_kernel=True`` runs the g3 lanes through the
    fused Eq. 5 Pallas kernel (``distill.make_lanes_loss(use_kernel=True)``
    — trainable since the kernel grew its closed-form custom VJP).
    ``mesh`` shards every stage's lane axis across devices (see
    ``training.train_lanes``).

    ``exchange`` is one ``ExchangeTransform`` shared by every replica or
    a per-replica sequence (entries may be ``None``): a whole defense
    grid — e.g. one sigma per lane via ``robustness.defense.dp_frontier``
    — runs its g1/g2/g3 stages as lanes of the same vmapped scans, with
    only the cheap eager exchange differing per lane.  Per-lane noise
    keys derive from each lane's SEED (not its lane index), so a lane
    matches ``run_apcvfl(sc, seed=s, exchange=t)`` exactly."""
    scs, seeds = _normalize_replicas("run_apcvfl_replicated", scenarios,
                                     seeds)
    S = len(seeds)
    if S == 0:
        return []
    exchanges = comm.normalize_exchange(exchange, S)
    train_kw = dict(batch_size=batch_size, max_epochs=max_epochs,
                    patience=patience, lr=lr, mesh=mesh)

    channels = [comm.Channel() for _ in range(S)]
    psis = [psi(sc.active.ids, sc.passive.ids, channel=ch)
            for sc, ch in zip(scs, channels)]
    keys = [jax.random.split(jax.random.PRNGKey(s), 4) for s in seeds]
    epochs = [{} for _ in range(S)]

    if not ablation:
        # --- Step 1: 2S g1 lanes (active + passive per seed) ---------------
        lanes = []
        for sc, s, (k1, k2, _, _) in zip(scs, seeds, keys):
            lanes.append(training.LaneSpec(
                ae.init_autoencoder(k1, ae.table3_encoder(
                    "g1_active", sc.active.x.shape[1])),
                {"x": sc.active.x}, s))
            lanes.append(training.LaneSpec(
                ae.init_autoencoder(k2, ae.table3_encoder(
                    "g1_passive", sc.passive.x.shape[1])),
                {"x": sc.passive.x}, s + 1))
        g1 = training.train_lanes(lanes, ae.make_masked_recon_loss(use_kernel),
                                  **train_kw)

        # --- Step 2: S g2 lanes on device-resident joint latents -----------
        zjs, zps = [], []
        for i, (sc, ch, (_, idx_a, idx_p)) in enumerate(
                zip(scs, channels, psis)):
            ra, rp = g1[2 * i], g1[2 * i + 1]
            epochs[i]["g1_active"] = ra.epochs_run
            epochs[i]["g1_passive"] = rp.epochs_run
            za_al = ae.encode(ra.params, jnp.asarray(sc.active.x[idx_a]))
            zp_al = ae.encode(rp.params, jnp.asarray(sc.passive.x[idx_p]))
            zp_al = comm.exchange_array(ch, "step1/Z_passive_aligned",
                                        zp_al, transform=exchanges[i],
                                        seed=seeds[i])
            zps.append(zp_al)
            zjs.append(jnp.concatenate([za_al, zp_al],
                                       axis=1).astype(jnp.float32))
        g2 = training.train_lanes(
            [training.LaneSpec(
                ae.init_autoencoder(k3, ae.table3_encoder("g2",
                                                          zj.shape[1])),
                {"x": zj}, s + 2)
             for zj, s, (_, _, k3, _) in zip(zjs, seeds, keys)],
            ae.make_masked_recon_loss(use_kernel), **train_kw)
        zts = [ae.encode(r2.params, zj) for r2, zj in zip(g2, zjs)]
        m2 = zts[0].shape[1]
        for i, r2 in enumerate(g2):
            epochs[i]["g2"] = r2.epochs_run
    else:
        m2 = ae.table3_encoder("g2", 1)[-1]
        zts = [None] * S
        zps = [None] * S

    # --- Step 3: S g3 distillation lanes ------------------------------------
    g3_lanes = []
    for sc, s, (_, _, _, k4), zt, (_, idx_a, _) in zip(scs, seeds, keys,
                                                       zts, psis):
        xa = sc.active.x
        z_teacher = jnp.zeros((len(xa), m2), jnp.float32)
        mask = jnp.zeros((len(xa),), jnp.float32)
        if not ablation:
            z_teacher = z_teacher.at[idx_a].set(zt)
            mask = mask.at[idx_a].set(1.0)
        w3 = ae.table3_encoder("g3", xa.shape[1])
        assert w3[-1] == m2, "M3 == M2: dimensional consistency (Sec. 4.3)"
        g3_lanes.append(training.LaneSpec(
            ae.init_autoencoder(k4, w3),
            {"x": xa, "z_teacher": z_teacher, "aligned": mask}, s + 3))
    g3 = training.train_lanes(
        g3_lanes, distill.make_lanes_loss(lam, kind, use_kernel=use_kernel),
        **train_kw)

    # --- Step 4: classifier probes, all S seeds' folds as one doubly-
    # vmapped lane dispatch (S x k probe fits, one compile + one sync).
    # Per-seed metrics match kfold_cv(z, ..., seed=s) within lane-engine
    # tolerance (tests/test_replicas.py pins the band).
    z_alls = [ae.encode(r3.params, jnp.asarray(sc.active.x))
              for sc, r3 in zip(scs, g3)]
    metrics_list = clf.kfold_cv_many(
        z_alls, [sc.active.y for sc in scs], scs[0].n_classes, seeds=seeds)
    results = []
    data_rounds = 0 if ablation else comm.APCVFL_ROUNDS
    for i, (s, ch, r3, ep, metrics) in enumerate(zip(seeds, channels, g3,
                                                     epochs, metrics_list)):
        ep["g3"] = r3.epochs_run
        params = {"g3": r3.params}
        artifacts = None
        if not ablation:
            params["g1_active"] = g1[2 * i].params
            params["g2"] = g2[i].params
            artifacts = {"aligned_ids": np.asarray(psis[i][0]),
                         "z_passive_aligned": zps[i]}
        results.append(RunResult(
            method="apcvfl", metrics=metrics, rounds=data_rounds,
            epochs=ep, comm=ch.summary(), seed=s, z_dim=m2,
            params=params, channels=(ch,), artifacts=artifacts))
    return results


def run_local_baseline(sc, seed: int = 0) -> dict:
    """Paper 'Local': probe on raw active features.  Works for 2-party and
    K-party scenarios (only ``sc.active`` is touched); returns the bare
    metrics dict — the ``experiments`` registry wraps it into a
    ``RunResult``."""
    return clf.kfold_cv(sc.active.x, sc.active.y, sc.n_classes, seed=seed)


# ---------------------------------------------------------------------------
# aligned-only adaptation (paper Fig. 4, for the SplitNN comparison)
# ---------------------------------------------------------------------------

def run_apcvfl_aligned_only(sc: VFLScenario, *, seed: int = 0,
                            batch_size: int = HP.batch_size,
                            max_epochs: int = HP.max_epochs,
                            patience: int = HP.patience, lr: float = HP.lr,
                            test_size: int = HP.test_size) -> RunResult:
    """Classical fully-aligned setting: train the classifier directly on the
    joint latents g2(concat(Z_a, Z_p)); distillation is skipped (no
    unaligned rows exist to distill into)."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    channel = comm.Channel()
    train_kw = dict(batch_size=batch_size, max_epochs=max_epochs,
                    patience=patience, lr=lr)
    _, idx_a, idx_p = psi(sc.active.ids, sc.passive.ids, channel=channel)
    xa, xp = sc.active.x[idx_a], sc.passive.x[idx_p]
    y = sc.active.y[idx_a]

    ae_a = ae.init_autoencoder(k1, ae.table3_encoder("g1_active", xa.shape[1]))
    ae_p = ae.init_autoencoder(k2, ae.table3_encoder("g1_passive", xp.shape[1]))
    ra, rp = training.train_lanes(
        [training.LaneSpec(ae_a, {"x": xa}, seed),
         training.LaneSpec(ae_p, {"x": xp}, seed + 1)],
        ae.masked_recon_loss, **train_kw)
    za = ae.encode(ra.params, jnp.asarray(xa))
    zp = ae.encode(rp.params, jnp.asarray(xp))
    channel.send_array("step1/Z_passive_aligned", zp, direction="uplink")

    zj = jnp.concatenate([za, zp], 1).astype(jnp.float32)
    ae_2 = ae.init_autoencoder(k3, ae.table3_encoder("g2", zj.shape[1]))
    # singleton lane: bit-identical twin of the replicated g2 stage
    (r2,) = training.train_lanes(
        [training.LaneSpec(ae_2, {"x": zj}, seed + 2)],
        ae.masked_recon_loss, **train_kw)
    z = np.asarray(ae.encode(r2.params, zj))

    # train/test split as in the SplitNN comparison (test_size held out)
    rng = np.random.RandomState(seed)
    perm = rng.permutation(len(z))
    te, tr = perm[:test_size], perm[test_size:]
    params = clf.fit_logreg(jnp.asarray(z[tr]), jnp.asarray(y[tr]),
                            sc.n_classes)
    pred = clf.predict(params, z[te])
    metrics = clf.f1_scores(y[te], pred, sc.n_classes)
    return RunResult(method="apcvfl_aligned_only", metrics=metrics, rounds=1,
                     epochs={"g1_active": ra.epochs_run,
                             "g1_passive": rp.epochs_run,
                             "g2": r2.epochs_run},
                     comm=channel.summary(), seed=seed, z_dim=z.shape[1],
                     params={"g2": r2.params}, channels=(channel,))


def run_apcvfl_aligned_only_replicated(scenarios, *, seeds,
                                       batch_size: int = HP.batch_size,
                                       max_epochs: int = HP.max_epochs,
                                       patience: int = HP.patience,
                                       lr: float = HP.lr,
                                       test_size: int = HP.test_size,
                                       mesh=None) -> list:
    """S seed replicates of the aligned-only adaptation, every stage one
    ``train_lanes`` dispatch (2S g1 lanes, S g2 lanes).  Both of its
    stages are dispatch-bound at tabular shapes, so this is the replica
    grid where lane batching pays most on CPU (see
    ``benchmarks/trainbench.py --sweep``).  Same contract as
    ``run_apcvfl_replicated``: one scenario shared or one per seed, one
    ``RunResult`` per seed matching the sequential path within lane
    tolerance.  ``mesh`` shards every stage's lane axis across devices."""
    scs, seeds = _normalize_replicas("run_apcvfl_aligned_only_replicated",
                                     scenarios, seeds)
    S = len(seeds)
    if S == 0:
        return []
    train_kw = dict(batch_size=batch_size, max_epochs=max_epochs,
                    patience=patience, lr=lr, mesh=mesh)

    channels = [comm.Channel() for _ in range(S)]
    keys = [jax.random.split(jax.random.PRNGKey(s), 3) for s in seeds]
    cells = []                        # (xa, xp, y) aligned rows per seed
    for sc, ch in zip(scs, channels):
        _, idx_a, idx_p = psi(sc.active.ids, sc.passive.ids, channel=ch)
        cells.append((sc.active.x[idx_a], sc.passive.x[idx_p],
                      sc.active.y[idx_a]))

    lanes = []
    for (xa, xp, _), s, (k1, k2, _) in zip(cells, seeds, keys):
        lanes.append(training.LaneSpec(
            ae.init_autoencoder(k1, ae.table3_encoder("g1_active",
                                                      xa.shape[1])),
            {"x": xa}, s))
        lanes.append(training.LaneSpec(
            ae.init_autoencoder(k2, ae.table3_encoder("g1_passive",
                                                      xp.shape[1])),
            {"x": xp}, s + 1))
    g1 = training.train_lanes(lanes, ae.masked_recon_loss, **train_kw)

    zjs = []
    for i, ((xa, xp, _), ch) in enumerate(zip(cells, channels)):
        ra, rp = g1[2 * i], g1[2 * i + 1]
        za = ae.encode(ra.params, jnp.asarray(xa))
        zp = ae.encode(rp.params, jnp.asarray(xp))
        ch.send_array("step1/Z_passive_aligned", zp, direction="uplink")
        zjs.append(jnp.concatenate([za, zp], 1).astype(jnp.float32))
    g2 = training.train_lanes(
        [training.LaneSpec(
            ae.init_autoencoder(k3, ae.table3_encoder("g2", zj.shape[1])),
            {"x": zj}, s + 2)
         for zj, s, (_, _, k3) in zip(zjs, seeds, keys)],
        ae.masked_recon_loss, **train_kw)

    results = []
    for i, ((_, _, y), s, ch, zj, r2) in enumerate(zip(cells, seeds,
                                                       channels, zjs, g2)):
        z = np.asarray(ae.encode(r2.params, zj))
        rng = np.random.RandomState(s)
        perm = rng.permutation(len(z))
        te, tr = perm[:test_size], perm[test_size:]
        params = clf.fit_logreg(jnp.asarray(z[tr]), jnp.asarray(y[tr]),
                                scs[i].n_classes)
        pred = clf.predict(params, z[te])
        metrics = clf.f1_scores(y[te], pred, scs[i].n_classes)
        ra, rp = g1[2 * i], g1[2 * i + 1]
        results.append(RunResult(
            method="apcvfl_aligned_only", metrics=metrics, rounds=1,
            epochs={"g1_active": ra.epochs_run,
                    "g1_passive": rp.epochs_run, "g2": r2.epochs_run},
            comm=ch.summary(), seed=s, z_dim=z.shape[1],
            params={"g2": r2.params}, channels=(ch,)))
    return results


# ---------------------------------------------------------------------------
# Appendix F, Algorithm 1: encoder training with representation-quality probe
# ---------------------------------------------------------------------------

def train_encoder_with_probe(x: np.ndarray, y: np.ndarray, n_classes: int,
                             widths: list, *, metric: str = "accuracy",
                             k: int = 5, max_epochs: int = 30,
                             seed: int = 0) -> dict:
    """Runs Algorithm 1: per-epoch, k-fold CV the probe on Z=g(X).  Returns
    the loss curve, per-epoch metric sets M~, the raw-X metric set M, and
    the equivalence gap (Eq. 12)."""
    key = jax.random.PRNGKey(seed)
    params = ae.init_autoencoder(key, widths)
    history = {"loss": [], "probe": []}

    def cb(epoch, p, tl, vl):
        # per-epoch probe; ``p`` is device-resident and donated into the
        # next epoch, so everything derived from it is computed here
        z = np.asarray(ae.encode(p, jnp.asarray(x)))
        m = clf.kfold_cv(z, y, n_classes, k=k, seed=seed)
        history["probe"].append(m[metric])
        history["loss"].append(tl)

    training.train(params, {"x": x}, ae.recon_loss, max_epochs=max_epochs,
                   patience=max_epochs, seed=seed, epoch_callback=cb)
    base = clf.kfold_cv(x, y, n_classes, k=k, seed=seed)[metric]
    gap = base - (history["probe"][-1] if history["probe"] else 0.0)
    return {"history": history, "metric_raw_x": base, "gap": gap}
