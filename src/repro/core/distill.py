"""Paper Eq. 5: composite reconstruction + masked distillation loss.

    L_total(x_i) = L_enc-dec(x_i) + lambda * L_distill(x_i)   if x_i aligned
                 = L_enc-dec(x_i)                              otherwise

L_distill is MSE or MAE between the teacher joint latent z_A_i and the
student latent g3(x_i).  The batch carries z_A rows (zeros where unaligned)
and an ``aligned`` {0,1} mask; masking reproduces the per-sample case split.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import autoencoder as ae


def distill_loss(params: dict, batch: dict, *, lam: float = 0.01,
                 kind: str = "mse", use_kernel: bool = False) -> jax.Array:
    x, z_t, mask = batch["x"], batch["z_teacher"], batch["aligned"]
    if use_kernel:
        from repro.kernels import ops as kops
        z = ae.fused_encode(params, x)
        x_hat = ae.fused_mlp_apply(params["dec"], z)
        return kops.fused_distill_loss(x, x_hat, z, z_t, mask, lam=lam,
                                       kind=kind)
    z = ae.encode(params, x)
    x_hat = ae.mlp_apply(params["dec"], z)
    rec = jnp.mean(jnp.square(x - x_hat), axis=-1)               # (B,)
    diff = z - z_t
    if kind == "mae":
        dis = jnp.mean(jnp.abs(diff), axis=-1)
    else:
        dis = jnp.mean(jnp.square(diff), axis=-1)
    per_row = rec + lam * dis * mask.astype(rec.dtype)
    return jnp.mean(per_row)


def make_loss(lam: float = 0.01, kind: str = "mse", use_kernel: bool = False):
    def loss(params, batch):
        return distill_loss(params, batch, lam=lam, kind=kind,
                            use_kernel=use_kernel)
    # semantic identity: every closure with the same hyperparameters shares
    # one compiled training engine (training.get_engine) instead of
    # re-tracing per make_loss() call
    loss.cache_key = ("repro.core.distill.make_loss", float(lam), str(kind),
                      bool(use_kernel))
    return loss


def make_lanes_loss(lam: float = 0.01, kind: str = "mse",
                    use_kernel: bool = False):
    """Eq. 5 for replica-lane batches (``training.train_lanes``): consumes
    the engine's ``mask`` (real-feature columns) and ``row_w`` (real-row
    weights), so g3 lanes of different row/feature shapes can share one
    vmapped scan.  With 0/1 weights and no padding this equals
    ``make_loss(lam, kind)`` exactly (the weighted means reduce to plain
    means).  Lanes must share the latent width (true for every Table-3
    architecture: M3 = 256) — the latent axis is never padded.

    ``use_kernel=True`` computes the per-row Eq. 5 terms through the fused
    Pallas kernel (trainable since it grew its closed-form custom VJP).
    The kernel averages over all D feature columns, so the 0/1 feature
    mask is folded in by pre-masking x / x_hat and rescaling by
    sqrt(D / sum(mask)) — exact for 0/1 masks, a no-op for unpadded
    lanes."""
    def loss(params, batch):
        x, z_t, al = batch["x"], batch["z_teacher"], batch["aligned"]
        fm, rw = batch["mask"], batch["row_w"]
        if use_kernel:
            from repro.kernels import ops as kops
            z = ae.fused_encode(params, x)
            x_hat = ae.fused_mlp_apply(params["dec"], z)
            s = jnp.sqrt(x.shape[-1] / jnp.maximum(jnp.sum(fm), 1.0))
            per_row = kops.fused_distill_rows(x * fm * s, x_hat * fm * s,
                                              z, z_t, al, lam=lam, kind=kind)
        else:
            z = ae.encode(params, x)
            x_hat = ae.mlp_apply(params["dec"], z)
            se = jnp.square(x - x_hat) * fm
            rec = jnp.sum(se, axis=-1) / jnp.maximum(jnp.sum(fm), 1.0)  # (B,)
            diff = z - z_t
            if kind == "mae":
                dis = jnp.mean(jnp.abs(diff), axis=-1)
            else:
                dis = jnp.mean(jnp.square(diff), axis=-1)
            per_row = rec + lam * dis * al.astype(rec.dtype)
        return jnp.sum(per_row * rw) / jnp.maximum(jnp.sum(rw), 1.0)
    loss.cache_key = ("repro.core.distill.make_lanes_loss", float(lam),
                      str(kind), bool(use_kernel))
    return loss
