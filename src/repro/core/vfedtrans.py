"""VFedTrans baseline (Huang et al., WWW'23): FedSVD federated
representations of the aligned rows + representation distillation into a
local feature extractor, then classification on the enriched dataset.

Key structural contrast with APC-VFL (paper Sec. 6.1): the federated
representation dimension is FIXED at x_total by FedSVD (the "embedding
dimension constraint"); communication includes the dense n x n mask A
(footprint grows ~ |D_A|^2, Eq. 10) and a third-party server is required.

Hyperparameter defaults come from ``configs.apcvfl_paper.TABULAR``; the
entry point returns the unified ``experiments.results.RunResult`` (the
fixed FedSVD representation dimension is reported as ``z_dim``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.apcvfl_paper import TABULAR as HP
from repro.core import autoencoder as ae
from repro.core import classifier as clf
from repro.core import comm
from repro.core import fedsvd
from repro.core import training
from repro.core.psi import psi
from repro.data.vertical import VFLScenario
from repro.experiments.results import RunResult


def _distill_loss(params: dict, batch: dict) -> jax.Array:
    """Huang et al. representation distillation: recon + MAE to the
    federated representation on aligned rows. Module-level on purpose: its
    stable identity is the training engine's compilation-cache key."""
    x, z_t, mask = batch["x"], batch["z_teacher"], batch["aligned"]
    z = ae.encode(params, x)
    x_hat = ae.mlp_apply(params["dec"], z)
    rec = jnp.mean(jnp.square(x - x_hat), axis=-1)
    dis = jnp.mean(jnp.abs(z - z_t), axis=-1)
    return jnp.mean(rec + dis * mask)


def run_vfedtrans(sc: VFLScenario, *, seed: int = 0,
                  batch_size: int = HP.batch_size,
                  max_epochs: int = HP.max_epochs,
                  patience: int = HP.patience,
                  lr: float = HP.lr) -> RunResult:
    channel = comm.Channel()
    _, idx_a, idx_p = psi(sc.active.ids, sc.passive.ids, channel=channel)
    xa_al = sc.active.x[idx_a]
    xp_al = sc.passive.x[idx_p]

    # --- federated representation learning (FedSVD, 5 exchanges) ----------
    fs = fedsvd.fedsvd(xa_al, xp_al, seed=seed, channel=channel)
    rep = fs.U * fs.S[None, :]               # U Sigma: the federated data
    rep_dim = rep.shape[1]                   # = x_total (the constraint)

    # --- knowledge transfer: local extractor distilled to the fed reps ----
    n_a = len(sc.active.x)
    z_teacher = np.zeros((n_a, rep_dim), np.float32)
    mask = np.zeros((n_a,), np.float32)
    z_teacher[idx_a] = rep
    mask[idx_a] = 1.0
    widths = [sc.active.x.shape[1], 256, rep_dim]
    params = ae.init_autoencoder(jax.random.PRNGKey(seed), widths)
    res = training.train(params, {"x": sc.active.x, "z_teacher": z_teacher,
                                  "aligned": mask}, _distill_loss,
                         batch_size=batch_size, max_epochs=max_epochs,
                         patience=patience, lr=lr, seed=seed)

    # --- enriched dataset: [X_local, transferred reps] ---------------------
    z = np.asarray(ae.encode(res.params, jnp.asarray(sc.active.x)))
    enriched = np.concatenate([sc.active.x, z], axis=1)
    metrics = clf.kfold_cv(enriched, sc.active.y, sc.n_classes, seed=seed)
    return RunResult(method="vfedtrans", metrics=metrics, rounds=fs.rounds,
                     epochs={"distill": res.epochs_run},
                     comm=channel.summary(), seed=seed, z_dim=rep_dim,
                     params={"extractor": res.params}, channels=(channel,))
