"""Device-resident scan-of-scans training engine for the tabular APC-VFL
stack.

Optimization is the paper's Adam (Kingma & Ba defaults, Appendix B) via
:mod:`repro.optim.adam`, <=200 epochs, early stopping on a 10% validation
split with patience 10.

Data-layout contract (the fused fit engine)
-------------------------------------------
``train`` takes ``data`` as a dict of equal-length, row-aligned host arrays.
The engine:

1. splits rows into train/val ONCE on the host (``np.random.RandomState(seed)``,
   identical split to the legacy loop) and uploads both sides to device ONCE;
2. draws each epoch's row permutation on device with ``jax.random``
   (``fold_in(PRNGKey(seed), epoch)``);
3. runs the WHOLE FIT as one jitted scan-of-scans: an outer ``lax.scan``
   over epochs whose carry holds the early-stop state (best-val params,
   best val loss, epochs-since-best, a ``live`` flag, epochs run) as
   traced values, and an inner ``lax.scan`` over ``(n_batches,
   batch_size)`` index slices for the epoch itself;
4. wraps the epoch body in ``lax.cond(live, ...)`` so once early stopping
   fires, the remaining outer iterations are cheap passthroughs — and the
   host syncs exactly ONCE per fit (epoch count + loss histories), not
   once per epoch.

Batching semantics: ``batch_size`` is clamped to the train-split size and the
epoch DROPS the remainder rows of the permutation (``n_batches = n_tr // bs``)
so every scan step sees a static batch shape.  Correctness is pinned by a
stored-trace oracle (``tests/data/train_trace.json``): a committed loss
trajectory recorded from this engine, which any semantic change to the
split, permutation, loss, or optimizer math will break.

The pre-fusion per-epoch loop survives as ``train_epochwise`` /
``train_lanes_epochwise``: it is the live parity oracle for the fused
engine (``tests/test_training_engine.py`` pins exact epoch counts and
best-val params on the stored-trace workloads) and the only path that can
run ``epoch_callback(epoch, params, train_loss, val_loss)`` — callbacks
need params on the host every epoch, which is precisely the sync the fused
engine removes, so ``train`` transparently routes callback users there.

Compilation caching: one jitted fit function exists per
``(loss identity, lr)`` — closures built by ``distill.make_loss`` carry a
semantic ``cache_key`` attribute so repeated stages reuse the same compiled
engine instead of re-tracing (see ``get_engine`` / ``get_fit_engine``).

Replica-lane training (``train_lanes``)
---------------------------------------
A *lane* is any independent training instance — a federated party's g1
stage, a seed replicate of the same stage, a CV fold.  ``train_lanes``
runs L lanes as ONE vmapped scan-of-scans: one upload, one compile, one
host sync per fit for ALL lanes.  K-party batching (PR 2's ``train_many``)
is the K-lane special case; seed replication stacks S replicates of every
stage into S x K lanes through the very same engine (``core.pipeline``'s
``run_apcvfl_replicated`` does exactly this).  The padded-stack layout
(:mod:`repro.core.padding`):

* every param leaf is zero-padded per-axis to the max shape across lanes
  and stacked along a leading lane axis (zero rows/cols feed on zero
  inputs and receive zero gradients, so each lane's real sub-block evolves
  exactly as it would unpadded);
* every data array is zero-padded to the max row count / trailing width
  and stacked likewise, staying on device throughout (jax-array inputs —
  e.g. encoder outputs of an earlier protocol stage — are padded and
  stacked without a host round-trip); when padding is present the loss
  must consume the ``mask`` (real-feature columns) and ``row_w`` (real-row
  weights) entries the engine adds to each batch — see
  ``autoencoder.masked_recon_loss``.  Equal-shape lanes (the seed-replica
  case) need no masking: losses that ignore the extra keys see exactly
  the batches ``train`` would feed them;
* each lane keeps its own host-side train/val split, PRNG stream, Adam
  state and step budget (``n_batches_i = n_tr_i // bs``); the shared scan
  runs ``max_i n_batches_i`` steps and a per-lane step mask freezes params
  past a lane's own budget;
* early stopping is a per-lane ``live`` mask (mirroring the masked-loss
  trick in ``distill.make_loss``): converged lanes keep stepping on
  frozen params so the batch shape stays static, and the outer scan's
  ``lax.cond(any(live), ...)`` skips whole epochs once every lane has
  stopped.

The shared batch size is clamped to the SMALLEST lane's train split so
every lane runs at least one step per epoch.  For a lane whose row count
equals the padded maximum, the engine draws the IDENTICAL device
permutation as ``train`` (same fold_in key); when additionally
``batch_size <= min_i n_tr_i`` (no cross-lane clamping), that lane's
results match the sequential path to float tolerance — the parity tests in
``tests/test_train_many.py`` and ``tests/test_replicas.py`` pin this.

Mesh sharding (``train_lanes(..., mesh=...)``)
----------------------------------------------
Lanes are embarrassingly parallel, so the lane axis shards across devices
by *computation following data*: pass a mesh from
``repro.launch.mesh.make_lane_mesh`` (axes ``("lane", "data")``) and every
stacked input is ``device_put`` with a ``NamedSharding`` resolved through
the logical-axis policy (``repro.sharding.policy`` — lane axis ->
``"lane"``, rows -> ``"dp"`` when ``shard_rows=True``).  The SAME jitted
engine then runs device-parallel — jit specializes on the input shardings,
the computation is bitwise the computation the unsharded path runs, so
parity is exact.  The lane count is padded up to a multiple of the mesh's
lane-axis size with dead lanes (``live=False``, zero step budget) that are
stripped from the results; row sharding silently drops to replicated on
dims the mesh does not divide (``policy._divisible``), because padding
rows would change the device permutation and break parity.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import padding
from repro.optim.adam import paper_adam


@dataclass
class TrainResult:
    params: dict
    epochs_run: int
    steps_run: int
    train_loss: list
    val_loss: list


@dataclass
class LaneSpec:
    """One lane's training problem for ``train_lanes``: unpadded init
    params, unpadded row-aligned data dict, and the lane's PRNG seed
    (drives both the host train/val split and the device epoch perms,
    exactly as the same seed would in ``train``).  A lane is any
    independent instance — a party, a seed replicate, a fold."""
    params: dict
    data: dict
    seed: int = 0


PartySpec = LaneSpec     # the K-party special case, kept by its PR-2 name

# the pre-dedup names, kept so downstream code reads either way
_pad_to = padding.pad_to
_pad_stack = padding.pad_stack


# ---------------------------------------------------------------------------
# engine cache
# ---------------------------------------------------------------------------

_ENGINE_CACHE: dict = {}
_ENGINE_CACHE_MAX = 64   # FIFO-evict beyond this: entries strong-reference
                         # the loss fn and its compiled executables


def loss_cache_key(loss_fn):
    """Semantic identity of a loss: closures tagged with ``cache_key``
    (e.g. ``distill.make_loss``) share one compiled engine across instances;
    plain module-level functions key on their own identity.  Untagged
    per-call closures each get their own engine (a full re-trace per
    ``train`` call) — tag them if they are built in a loop."""
    return getattr(loss_fn, "cache_key", loss_fn)


def _cached_engine(tag: str, loss_fn: Callable, lr: float, builder):
    key = (tag, loss_cache_key(loss_fn), float(lr))
    engine = _ENGINE_CACHE.get(key)
    if engine is None:
        while len(_ENGINE_CACHE) >= _ENGINE_CACHE_MAX:
            _ENGINE_CACHE.pop(next(iter(_ENGINE_CACHE)))
        engine = builder(loss_fn, float(lr))
        _ENGINE_CACHE[key] = engine
    return engine


# ---------------------------------------------------------------------------
# per-epoch engines (the epochwise parity oracle + callback path)
# ---------------------------------------------------------------------------

def _build_engine(loss_fn: Callable, lr: float):
    opt = paper_adam(lr)

    @partial(jax.jit, static_argnames=("n_batches", "batch_size"),
             donate_argnums=(0, 1))
    def run_epoch(params, opt_state, key, tr, val, *, n_batches, batch_size):
        n_tr = jax.tree.leaves(tr)[0].shape[0]
        perm = jax.random.permutation(key, n_tr)
        idx = perm[: n_batches * batch_size].reshape(n_batches, batch_size)

        def step(carry, bidx):
            p, s = carry
            batch = {k: v[bidx] for k, v in tr.items()}
            loss, grads = jax.value_and_grad(loss_fn)(p, batch)
            p, s, _ = opt.update(grads, s, p)
            return (p, s), loss

        (params, opt_state), losses = jax.lax.scan(step, (params, opt_state),
                                                   idx)
        return params, opt_state, jnp.mean(losses), loss_fn(params, val)

    return run_epoch


def get_engine(loss_fn: Callable, *, lr: float = 1e-3):
    """Jitted epoch runner for ``loss_fn``, cached on (loss identity, lr)."""
    return _cached_engine("train", loss_fn, lr, _build_engine)


def get_lanes_engine(loss_fn: Callable, *, lr: float = 1e-3):
    """Jitted vmapped replica-lane epoch runner, cached like
    ``get_engine``."""
    return _cached_engine("train_many", loss_fn, lr, _build_many_engine)


get_many_engine = get_lanes_engine   # pre-lane-engine name


# ---------------------------------------------------------------------------
# fused whole-fit engines (outer epoch scan, one host sync per fit)
# ---------------------------------------------------------------------------

def _build_fit_engine(loss_fn: Callable, lr: float):
    opt = paper_adam(lr)

    @partial(jax.jit, static_argnames=("n_batches", "batch_size",
                                       "max_epochs", "patience"))
    def run_fit(params, opt_state, base_key, tr, val, *, n_batches,
                batch_size, max_epochs, patience):
        n_tr = jax.tree.leaves(tr)[0].shape[0]

        def epoch_body(carry, epoch):
            p, s, best_p, best_v, since, live, epochs = carry
            key = jax.random.fold_in(base_key, epoch)
            perm = jax.random.permutation(key, n_tr)
            idx = perm[: n_batches * batch_size].reshape(n_batches,
                                                         batch_size)

            def step(c, bidx):
                p_, s_ = c
                batch = {k: v[bidx] for k, v in tr.items()}
                loss, grads = jax.value_and_grad(loss_fn)(p_, batch)
                p_, s_, _ = opt.update(grads, s_, p_)
                return (p_, s_), loss

            (p, s), losses = jax.lax.scan(step, (p, s), idx)
            tl = jnp.mean(losses)
            vl = loss_fn(p, val)
            # the epochwise loop's host bookkeeping, as traced values
            improved = vl < best_v - 1e-6
            best_p = jax.tree.map(lambda b, q: jnp.where(improved, q, b),
                                  best_p, p)
            best_v = jnp.where(improved, vl, best_v)
            since = jnp.where(improved, 0, since + 1)
            live = improved | (since < patience)
            return (p, s, best_p, best_v, since, live, epochs + 1), (tl, vl)

        def epoch_step(carry, epoch):
            dead = lambda c: (c, (jnp.zeros((), jnp.float32),
                                  jnp.zeros((), jnp.float32)))
            return jax.lax.cond(carry[5],
                                lambda c: epoch_body(c, epoch), dead, carry)

        init = (params, opt_state, params,
                jnp.asarray(jnp.inf, jnp.float32),
                jnp.asarray(0, jnp.int32), jnp.asarray(True, jnp.bool_),
                jnp.asarray(0, jnp.int32))
        (_, _, best_p, _, _, _, epochs), (tls, vls) = jax.lax.scan(
            epoch_step, init, jnp.arange(max_epochs, dtype=jnp.int32))
        return best_p, epochs, tls, vls

    return run_fit


def get_fit_engine(loss_fn: Callable, *, lr: float = 1e-3):
    """Jitted whole-fit runner (scan-of-scans), cached like
    ``get_engine``."""
    return _cached_engine("fit", loss_fn, lr, _build_fit_engine)


def _build_lanes_fit_engine(loss_fn: Callable, lr: float):
    opt = paper_adam(lr)

    @partial(jax.jit, static_argnames=("n_batches", "batch_size",
                                       "max_epochs", "patience", "uniform"))
    def run_fit_k(params, opt_state, base_keys, tr, val, n_tr, nb, live0, *,
                  n_batches, batch_size, max_epochs, patience,
                  uniform=False):
        L = base_keys.shape[0]

        def lane_epoch(p, s, key, live_p, tr_p, val_p, n_tr_p, nb_p):
            n_max = tr_p["x"].shape[0]
            perm = jax.random.permutation(key, n_max)
            # stable-partition real rows (< n_tr_p) to the front: for an
            # unpadded lane this is exactly the solo engine's permutation,
            # so the two paths draw identical mini-batches
            order = perm[jnp.argsort(perm >= n_tr_p, stable=True)]
            idx = order[: n_batches * batch_size].reshape(n_batches,
                                                          batch_size)

            def step(carry, xs):
                p_, s_ = carry
                i, bidx = xs
                batch = {k: v[bidx] for k, v in tr_p.items() if k != "mask"}
                batch["mask"] = tr_p["mask"]
                batch["row_w"] = jnp.ones((batch_size,), jnp.float32)
                loss, grads = jax.value_and_grad(loss_fn)(p_, batch)
                p2, s2, _ = opt.update(grads, s_, p_)
                if uniform:
                    # every live lane runs every step (nb_p == n_batches for
                    # all lanes — caller-checked), so the freeze collapses
                    # to ONE live-select per epoch below instead of a
                    # params+opt tree select per step
                    return (p2, s2), loss
                # freeze past this lane's own step budget or after its
                # early stop — the masked-select twin of distill.make_loss
                on = live_p & (i < nb_p)
                sel = lambda a, b: jnp.where(on, a, b)
                return ((jax.tree.map(sel, p2, p_),
                         jax.tree.map(sel, s2, s_)),
                        jnp.where(on, loss, 0.0))

            (p2, s2), losses = jax.lax.scan(step, (p, s),
                                            (jnp.arange(n_batches, dtype=jnp.int32), idx))
            if uniform:
                sel = lambda a, b: jnp.where(live_p, a, b)
                p = jax.tree.map(sel, p2, p)
                s = jax.tree.map(sel, s2, s)
                tl = jnp.where(live_p,
                               jnp.sum(losses) / jnp.maximum(nb_p, 1), 0.0)
            else:
                p, s = p2, s2
                tl = jnp.sum(losses) / jnp.maximum(nb_p, 1)
            return p, s, tl, loss_fn(p, val_p)

        def live_epoch(carry, epoch):
            p, s, best_p, best_v, since, live, epochs = carry
            keys = jax.vmap(jax.random.fold_in, (0, None))(base_keys, epoch)
            p, s, tl, vl = jax.vmap(lane_epoch)(p, s, keys, live, tr, val,
                                                n_tr, nb)
            epochs = epochs + live.astype(jnp.int32)
            # the epochwise lanes loop's host bookkeeping, as traced values
            improved = live & (vl < best_v - 1e-6)
            best_p = jax.tree.map(
                lambda b, q: jnp.where(
                    improved.reshape((L,) + (1,) * (q.ndim - 1)), q, b),
                best_p, p)
            best_v = jnp.where(improved, vl, best_v)
            since = jnp.where(improved, 0, since + 1)
            live = live & (since < patience)
            return (p, s, best_p, best_v, since, live, epochs), (tl, vl)

        def epoch_step(carry, epoch):
            # the cond sits OUTSIDE the per-lane vmap: once every lane has
            # stopped, remaining epochs cost one predicate each
            dead = lambda c: (c, (jnp.zeros((L,), jnp.float32),
                                  jnp.zeros((L,), jnp.float32)))
            return jax.lax.cond(jnp.any(carry[5]),
                                lambda c: live_epoch(c, epoch), dead, carry)

        init = (params, opt_state, params,
                jnp.full((L,), jnp.inf, jnp.float32),
                jnp.zeros((L,), jnp.int32), live0,
                jnp.zeros((L,), jnp.int32))
        (_, _, best_p, _, _, _, epochs), (tls, vls) = jax.lax.scan(
            epoch_step, init, jnp.arange(max_epochs, dtype=jnp.int32))
        return best_p, epochs, tls, vls

    return run_fit_k


def get_lanes_fit_engine(loss_fn: Callable, *, lr: float = 1e-3):
    """Jitted vmapped whole-fit lane runner, cached like ``get_engine``."""
    return _cached_engine("lanes_fit", loss_fn, lr, _build_lanes_fit_engine)


# ---------------------------------------------------------------------------
# single-instance training
# ---------------------------------------------------------------------------

def _prep_single(data: dict, *, seed: int, val_frac: float, batch_size: int):
    """Host-side train/val split + device upload shared by the fused and
    epochwise paths (identical RandomState split either way)."""
    n = len(next(iter(data.values())))
    split = np.random.RandomState(seed).permutation(n)
    n_val = max(int(n * val_frac), 1)
    val_idx, tr_idx = split[:n_val], split[n_val:]
    # jnp.asarray is a no-op for arrays already on device (an earlier
    # stage's encoder output), one upload for host arrays; the split
    # itself is a device gather either way
    dev = {k: jnp.asarray(v) for k, v in data.items()}
    val = {k: v[val_idx] for k, v in dev.items()}
    tr = {k: v[tr_idx] for k, v in dev.items()}
    n_tr = len(tr_idx)
    bs = max(min(batch_size, n_tr), 1)
    return tr, val, bs, n_tr // bs


def train(params, data: dict, loss_fn: Callable, *, batch_size: int = 128,
          max_epochs: int = 200, patience: int = 10, lr: float = 1e-3,
          val_frac: float = 0.1, seed: int = 0,
          epoch_callback: Optional[Callable] = None) -> TrainResult:
    """data: dict of equal-length arrays (row-aligned). loss_fn(params, batch).

    Runs the whole fit as one jitted scan-of-scans (module docstring) with
    a single host sync.  ``epoch_callback`` callers are routed to
    ``train_epochwise`` — per-epoch host params are exactly the sync the
    fused engine removes."""
    if epoch_callback is not None:
        return train_epochwise(params, data, loss_fn, batch_size=batch_size,
                               max_epochs=max_epochs, patience=patience,
                               lr=lr, val_frac=val_frac, seed=seed,
                               epoch_callback=epoch_callback)
    tr, val, bs, n_batches = _prep_single(data, seed=seed, val_frac=val_frac,
                                          batch_size=batch_size)
    engine = get_fit_engine(loss_fn, lr=lr)
    best_p, epochs, tls, vls = engine(
        params, paper_adam(lr).init(params), jax.random.PRNGKey(seed), tr,
        val, n_batches=n_batches, batch_size=bs, max_epochs=max_epochs,
        patience=patience)
    # the single host sync of the fit
    epochs, tls, vls = jax.device_get((epochs, tls, vls))
    epochs = int(epochs)
    return TrainResult(best_p, epochs, epochs * n_batches,
                       [float(t) for t in tls[:epochs]],
                       [float(v) for v in vls[:epochs]])


def train_epochwise(params, data: dict, loss_fn: Callable, *,
                    batch_size: int = 128, max_epochs: int = 200,
                    patience: int = 10, lr: float = 1e-3,
                    val_frac: float = 0.1, seed: int = 0,
                    epoch_callback: Optional[Callable] = None) -> TrainResult:
    """The pre-fusion per-epoch loop: one jitted epoch per dispatch, one
    host sync per epoch.  Kept as the fused engine's parity oracle and as
    the ``epoch_callback`` path (callbacks get a defensive copy of the
    params each epoch — the engine donates its own buffers onward)."""
    tr, val, bs, n_batches = _prep_single(data, seed=seed, val_frac=val_frac,
                                          batch_size=batch_size)
    # fresh buffers: the engine donates its params/opt args, so the loop must
    # own them (never the caller's arrays, never the best-so-far snapshot)
    params = jax.tree.map(jnp.array, params)
    best_params = jax.tree.map(jnp.copy, params)
    engine = get_engine(loss_fn, lr=lr)
    opt_state = paper_adam(lr).init(params)
    base_key = jax.random.PRNGKey(seed)

    best_val, since_best = np.inf, 0
    tl_hist, vl_hist, steps, epochs = [], [], 0, 0
    for epoch in range(max_epochs):
        epochs = epoch + 1
        params, opt_state, tl, vl = engine(
            params, opt_state, jax.random.fold_in(base_key, epoch), tr, val,
            n_batches=n_batches, batch_size=bs)
        tl, vl = float(tl), float(vl)   # the single host sync of the epoch
        steps += n_batches
        tl_hist.append(tl)
        vl_hist.append(vl)
        if epoch_callback is not None:
            # defensive copy: the engine donates ``params`` into the next
            # epoch, so a stashed reference would be use-after-donate
            epoch_callback(epoch, jax.tree.map(jnp.copy, params), tl, vl)
        if vl < best_val - 1e-6:
            best_val, since_best = vl, 0
            best_params = jax.tree.map(jnp.copy, params)
        else:
            since_best += 1
            if since_best >= patience:
                break
    return TrainResult(best_params, epochs, steps, tl_hist, vl_hist)


# ---------------------------------------------------------------------------
# replica-lane training: all lanes' fits as ONE vmapped scan-of-scans
# ---------------------------------------------------------------------------

# all lanes' epoch keys in one dispatch; module-scoped so the trivial
# trace compiles once per process, not once per train_lanes call
_FOLD_KEYS = jax.jit(jax.vmap(jax.random.fold_in, (0, None)))


def _build_many_engine(loss_fn: Callable, lr: float):
    opt = paper_adam(lr)

    @partial(jax.jit, static_argnames=("n_batches", "batch_size"),
             donate_argnums=(0, 1))
    def run_epoch_k(params, opt_state, keys, tr, val, n_tr, nb, live, *,
                    n_batches, batch_size):
        def one(p, s, key, tr_p, val_p, n_tr_p, nb_p, live_p):
            n_max = tr_p["x"].shape[0]
            perm = jax.random.permutation(key, n_max)
            # stable-partition real rows (< n_tr_p) to the front: for an
            # unpadded lane this is exactly the solo engine's permutation,
            # so the two paths draw identical mini-batches
            order = perm[jnp.argsort(perm >= n_tr_p, stable=True)]
            idx = order[: n_batches * batch_size].reshape(n_batches,
                                                          batch_size)

            def step(carry, xs):
                p, s = carry
                i, bidx = xs
                batch = {k: v[bidx] for k, v in tr_p.items() if k != "mask"}
                batch["mask"] = tr_p["mask"]
                batch["row_w"] = jnp.ones((batch_size,), jnp.float32)
                loss, grads = jax.value_and_grad(loss_fn)(p, batch)
                p2, s2, _ = opt.update(grads, s, p)
                # freeze past this lane's own step budget or after its
                # early stop — the masked-select twin of distill.make_loss
                on = live_p & (i < nb_p)
                sel = lambda a, b: jnp.where(on, a, b)
                return ((jax.tree.map(sel, p2, p), jax.tree.map(sel, s2, s)),
                        jnp.where(on, loss, 0.0))

            (p, s), losses = jax.lax.scan(step, (p, s),
                                          (jnp.arange(n_batches, dtype=jnp.int32), idx))
            tl = jnp.sum(losses) / jnp.maximum(nb_p, 1)
            return p, s, tl, loss_fn(p, val_p)

        return jax.vmap(one)(params, opt_state, keys, tr, val, n_tr, nb,
                             live)

    return run_epoch_k


def _prep_lanes(specs: Sequence[LaneSpec], *, batch_size: int,
                val_frac: float, lr: float):
    """Per-lane host split + padded-stack upload shared by the fused and
    epochwise lane paths."""
    K = len(specs)
    assert K >= 1
    for sp in specs:
        if "x" not in sp.data:
            raise ValueError("train_lanes: every LaneSpec.data needs an "
                             "'x' feature array (sizes the rows and the "
                             "real-feature mask)")

    # --- per-lane split: host-side indices, device-side gather ------------
    tr_list, val_list, n_tr_l = [], [], []
    for sp in specs:
        n = len(next(iter(sp.data.values())))
        split = np.random.RandomState(sp.seed).permutation(n)
        n_val = max(int(n * val_frac), 1)
        vi, ti = split[:n_val], split[n_val:]
        dev = {k: jnp.asarray(v) for k, v in sp.data.items()}
        val_list.append({k: v[vi] for k, v in dev.items()})
        tr_list.append({k: v[ti] for k, v in dev.items()})
        n_tr_l.append(len(ti))
    n_tr = np.asarray(n_tr_l)
    bs = max(min(batch_size, int(n_tr.min())), 1)
    nb = n_tr // bs                       # per-lane step budget per epoch

    for t, v in zip(tr_list, val_list):
        t["mask"] = jnp.ones((t["x"].shape[1],), jnp.float32)
        v["mask"] = t["mask"]
        v["row_w"] = jnp.ones((v["x"].shape[0],), jnp.float32)

    # --- padded-stack, built on device (no host round-trip) ---------------
    tr = padding.pad_stack(tr_list)
    val = padding.pad_stack(val_list)
    shapes = [[np.shape(l) for l in jax.tree.leaves(sp.params)]
              for sp in specs]
    params = padding.pad_stack([sp.params for sp in specs])
    opt_state = paper_adam(lr).init(params)
    opt_state = opt_state._replace(step=jnp.zeros((K,), jnp.int32))
    base_keys = jnp.stack([jax.random.PRNGKey(sp.seed) for sp in specs])
    return params, opt_state, base_keys, tr, val, n_tr, nb, bs, shapes


def _shard_lanes(mesh, params, opt_state, base_keys, tr, val, n_tr, nb,
                 live0, *, shard_rows: bool):
    """Pad the lane axis to a mesh multiple with dead lanes and
    ``device_put`` every stacked input with policy-resolved shardings.
    Returns the inputs device-parallel; the engine itself is unchanged
    (computation follows data)."""
    from jax.sharding import NamedSharding

    from repro.sharding import policy

    if "lane" not in mesh.axis_names:
        raise ValueError(
            f"train_lanes: mesh axes {tuple(mesh.axis_names)} lack the "
            "'lane' axis — build the mesh with "
            "repro.launch.mesh.make_lane_mesh")
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    K = int(base_keys.shape[0])
    Lp = -(-K // sizes["lane"]) * sizes["lane"]

    def grow(a):
        # dead lanes: zero params/data, live=False, zero step budget
        return padding.pad_to(a, (Lp,) + a.shape[1:])

    (params, opt_state, base_keys, tr, val, n_tr, nb, live0) = jax.tree.map(
        grow, (params, opt_state, base_keys, tr, val, n_tr, nb, live0))

    def put(a, *, rows=False):
        axes = ("lane",)
        if rows and a.ndim > 1 and "data" in mesh.axis_names:
            axes = ("lane", "dp")
        axes = axes + (None,) * (a.ndim - len(axes))
        spec = policy._divisible(a.shape,
                                 policy.resolve(axes, mesh.axis_names), mesh)
        return jax.device_put(a, NamedSharding(mesh, spec))

    params = jax.tree.map(put, params)
    opt_state = jax.tree.map(put, opt_state)
    base_keys, n_tr, nb, live0 = (put(a) for a in (base_keys, n_tr, nb,
                                                   live0))
    # "mask" is per-feature, not per-row; everything else shards rows
    tr = {k: put(v, rows=shard_rows and k != "mask") for k, v in tr.items()}
    val = {k: put(v, rows=shard_rows and k != "mask") for k, v in val.items()}
    return params, opt_state, base_keys, tr, val, n_tr, nb, live0


def _strip_lane_params(specs, best_params, shapes):
    """Unstack the best-val params and strip each lane's zero padding."""
    treedef = jax.tree.structure(specs[0].params)
    leaves = jax.tree.leaves(best_params)
    out = []
    for i in range(len(specs)):
        pl = [l[i][tuple(slice(0, s) for s in shp)]
              for l, shp in zip(leaves, shapes[i])]
        out.append(jax.tree.unflatten(treedef, pl))
    return out


def _lane_groups(specs: Sequence[LaneSpec]):
    """Partition lane indices by (data shapes, param shapes) signature.
    Lanes in one group pad-stack with ZERO padding waste — mixed-shape
    fleets (e.g. one active + K passive parties) otherwise pay the max
    shape for every lane (the Table-3 active g1 is ~7x smaller than the
    passive g1 it was padded to)."""
    groups: dict = {}
    order = []
    for i, sp in enumerate(specs):
        dsig = tuple(sorted((k, tuple(np.shape(v)))
                            for k, v in sp.data.items()))
        psig = (jax.tree.structure(sp.params),
                tuple(tuple(np.shape(l))
                      for l in jax.tree.leaves(sp.params)))
        key = (dsig, psig)
        if key not in groups:
            groups[key] = []
            order.append(key)
        groups[key].append(i)
    return [groups[k] for k in order]


def train_lanes(specs: Sequence[LaneSpec], loss_fn: Callable, *,
                batch_size: int = 128, max_epochs: int = 200,
                patience: int = 10, lr: float = 1e-3,
                val_frac: float = 0.1, mesh=None,
                shard_rows: bool = False) -> List[TrainResult]:
    """Train L independent lanes as one vmapped scan-of-scans — one upload,
    one compile per shape group, ONE host sync per fit for all lanes
    (module docstring: padded-stack layout, per-lane early-stop mask, mesh
    sharding).

    Lanes are partitioned into shape groups (``_lane_groups``) so
    mixed-shape fleets never pad small lanes up to the largest party;
    the global batch-size clamp (min over ALL lanes' train rows) is
    computed before grouping, so every lane draws the same mini-batches
    as the ungrouped engine — parity is exact, only padding FLOPs are
    removed.  Groups whose lanes all share one step budget additionally
    run the ``uniform`` engine fast path (epoch-level live select instead
    of a per-step params+opt tree select).

    Every lane's ``data`` must carry its feature array under the ``"x"``
    key — the engine sizes rows and the real-feature ``mask`` from it; any
    other row-aligned keys are padded too but only ``"x"`` is masked.
    When lane shapes differ within a group (padding present) ``loss_fn``
    must consume the ``mask`` (real-feature columns) and ``row_w``
    (real-row weights) entries the engine adds to every batch — use
    ``autoencoder.masked_recon_loss`` for reconstruction workloads; lanes
    of identical shape (seed replicas) may use any plain loss, the extra
    keys are inert.

    ``mesh`` (from ``repro.launch.mesh.make_lane_mesh``, axes
    ``("lane", "data")``) shards the lane axis across devices;
    ``shard_rows=True`` additionally shards each lane's rows across the
    ``data`` axis (the large-row regime).  Sharded or not, the same jitted
    engine runs the same computation — parity is exact.

    Returns one ``TrainResult`` per lane with padding stripped from the
    best-val params and histories truncated at that lane's stop epoch."""
    K = len(specs)
    # global batch-size clamp (the ungrouped engine's bs): computed over
    # ALL lanes so per-group _prep_lanes clamps to exactly this value
    # (global min <= every group min)
    n_tr_all = []
    for sp in specs:
        n = len(next(iter(sp.data.values())))
        n_tr_all.append(n - max(int(n * val_frac), 1))
    global_bs = max(min(batch_size, min(n_tr_all)), 1)

    engine = get_lanes_fit_engine(loss_fn, lr=lr)
    launched = []                 # (idxs, gspecs, best_params, shapes, nb)
    host_parts = []               # (epochs, tls, vls) per group, in-flight
    for idxs in _lane_groups(specs):
        gspecs = [specs[i] for i in idxs]
        (params, opt_state, base_keys, tr, val, n_tr, nb, bs,
         shapes) = _prep_lanes(gspecs, batch_size=global_bs,
                               val_frac=val_frac, lr=lr)
        n_batches = int(nb.max())
        uniform = bool((nb == nb[0]).all())
        nb_dev = jnp.asarray(nb, jnp.int32)
        n_tr_dev = jnp.asarray(n_tr, jnp.int32)
        live0 = jnp.ones((len(idxs),), bool)
        if mesh is not None:
            (params, opt_state, base_keys, tr, val, n_tr_dev, nb_dev,
             live0) = _shard_lanes(mesh, params, opt_state, base_keys, tr,
                                   val, n_tr_dev, nb_dev, live0,
                                   shard_rows=shard_rows)
        best_params, epochs, tls, vls = engine(
            params, opt_state, base_keys, tr, val, n_tr_dev, nb_dev, live0,
            n_batches=n_batches, batch_size=bs, max_epochs=max_epochs,
            patience=patience, uniform=uniform)
        launched.append((idxs, gspecs, best_params, shapes, nb))
        host_parts.append((epochs, tls, vls))
    # the single host sync of the fit, coalesced over every shape group
    # (dead padding lanes sliced away)
    host_parts = jax.device_get(host_parts)

    results: List[TrainResult] = [None] * K  # type: ignore[list-item]
    for (idxs, gspecs, best_params, shapes, nb), (epochs, tls, vls) in zip(
            launched, host_parts):
        stripped = _strip_lane_params(gspecs, best_params, shapes)
        for j, i in enumerate(idxs):
            e = int(epochs[j])
            results[i] = TrainResult(stripped[j], e, e * int(nb[j]),
                                     [float(t) for t in tls[:e, j]],
                                     [float(v) for v in vls[:e, j]])
    return results


def train_lanes_epochwise(specs: Sequence[LaneSpec], loss_fn: Callable, *,
                          batch_size: int = 128, max_epochs: int = 200,
                          patience: int = 10, lr: float = 1e-3,
                          val_frac: float = 0.1) -> List[TrainResult]:
    """The pre-fusion lane loop: one vmapped epoch per dispatch, one host
    sync per epoch for the early-stop bookkeeping.  Kept as the fused lane
    engine's live parity oracle (``tests/test_training_engine.py``) —
    it shape-groups lanes exactly like ``train_lanes`` (same global
    batch-size clamp, same per-group padding) so the two paths draw
    identical device permutations."""
    n_tr_all = []
    for sp in specs:
        n = len(next(iter(sp.data.values())))
        n_tr_all.append(n - max(int(n * val_frac), 1))
    global_bs = max(min(batch_size, min(n_tr_all)), 1)

    results: List[TrainResult] = [None] * len(specs)  # type: ignore
    for idxs in _lane_groups(specs):
        gspecs = [specs[i] for i in idxs]
        for i, r in zip(idxs, _train_lanes_epochwise_group(
                gspecs, loss_fn, batch_size=global_bs,
                max_epochs=max_epochs, patience=patience, lr=lr,
                val_frac=val_frac)):
            results[i] = r
    return results


def _train_lanes_epochwise_group(specs, loss_fn, *, batch_size, max_epochs,
                                 patience, lr, val_frac):
    K = len(specs)
    (params, opt_state, base_keys, tr, val, n_tr, nb, bs,
     shapes) = _prep_lanes(specs, batch_size=batch_size, val_frac=val_frac,
                           lr=lr)
    n_batches = int(nb.max())
    best_params = jax.tree.map(jnp.copy, params)
    engine = get_lanes_engine(loss_fn, lr=lr)
    nb_dev = jnp.asarray(nb, jnp.int32)
    n_tr_dev = jnp.asarray(n_tr, jnp.int32)

    best_val = np.full((K,), np.inf)
    since = np.zeros((K,), np.int64)
    live = np.ones((K,), bool)
    epochs_run = np.zeros((K,), np.int64)
    tl_hist = [[] for _ in range(K)]
    vl_hist = [[] for _ in range(K)]

    for epoch in range(max_epochs):
        keys = _FOLD_KEYS(base_keys, epoch)  # all lanes' keys, one dispatch
        params, opt_state, tl, vl = engine(
            params, opt_state, keys, tr, val, n_tr_dev, nb_dev,
            jnp.asarray(live), n_batches=n_batches, batch_size=bs)
        tl = np.asarray(tl)
        vl = np.asarray(vl)               # the single host sync of the epoch
        epochs_run[live] += 1
        for i in range(K):
            if live[i]:
                tl_hist[i].append(float(tl[i]))
                vl_hist[i].append(float(vl[i]))
        improved = live & (vl < best_val - 1e-6)
        if improved.any():
            sel = jnp.asarray(improved)
            best_params = jax.tree.map(
                lambda b, p: jnp.where(
                    sel.reshape((K,) + (1,) * (p.ndim - 1)), p, b),
                best_params, params)
            best_val = np.where(improved, vl, best_val)
        since = np.where(improved, 0, since + 1)
        live = live & (since < patience)
        if not live.any():
            break

    stripped = _strip_lane_params(specs, best_params, shapes)
    return [TrainResult(stripped[i], int(epochs_run[i]),
                        int(epochs_run[i] * nb[i]), tl_hist[i], vl_hist[i])
            for i in range(K)]


train_many = train_lanes     # the K-party special case, by its PR-2 name
