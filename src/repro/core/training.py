"""Device-resident mini-batch training engine for the tabular APC-VFL stack.

Optimization is the paper's Adam (Kingma & Ba defaults, Appendix B) via
:mod:`repro.optim.adam`, <=200 epochs, early stopping on a 10% validation
split with patience 10.

Data-layout contract (the scan engine)
--------------------------------------
``train`` takes ``data`` as a dict of equal-length, row-aligned host arrays.
The engine:

1. splits rows into train/val ONCE on the host (``np.random.RandomState(seed)``,
   identical split to the legacy loop) and uploads both sides to device ONCE;
2. draws each epoch's row permutation on device with ``jax.random``
   (``fold_in(PRNGKey(seed), epoch)``);
3. runs the WHOLE epoch as a single ``jax.lax.scan`` over
   ``(n_batches, batch_size)`` index slices inside one jitted call, with the
   params and optimizer buffers donated epoch-to-epoch;
4. computes the validation loss inside the same jitted call, so exactly ONE
   host sync per epoch (the two scalar losses) remains for early-stopping
   bookkeeping.

Batching semantics: ``batch_size`` is clamped to the train-split size and the
epoch DROPS the remainder rows of the permutation (``n_batches = n_tr // bs``)
so every scan step sees a static batch shape.  Correctness is pinned by a
stored-trace oracle (``tests/data/train_trace.json``): a committed loss
trajectory recorded from this engine, which any semantic change to the
split, permutation, loss, or optimizer math will break.

``epoch_callback(epoch, params, train_loss, val_loss)`` receives a defensive
copy of the params (the engine's own buffers are donated into the next
epoch), so callbacks may stash them across epochs; the copy is only made
when a callback is registered.

Compilation caching: one jitted epoch function exists per
``(loss identity, lr)`` — closures built by ``distill.make_loss`` carry a
semantic ``cache_key`` attribute so repeated stages reuse the same compiled
engine instead of re-tracing (see ``get_engine``).

Replica-lane training (``train_lanes``)
---------------------------------------
A *lane* is any independent training instance — a federated party's g1
stage, a seed replicate of the same stage, a CV fold.  ``train_lanes``
runs L lanes as ONE vmapped scan: one upload, one compile, one host sync
per epoch for ALL lanes.  K-party batching (PR 2's ``train_many``) is the
K-lane special case; seed replication stacks S replicates of every stage
into S x K lanes through the very same engine (``core.pipeline``'s
``run_apcvfl_replicated`` does exactly this).  The padded-stack layout:

* every param leaf is zero-padded per-axis to the max shape across lanes
  and stacked along a leading lane axis (zero rows/cols feed on zero
  inputs and receive zero gradients, so each lane's real sub-block evolves
  exactly as it would unpadded);
* every data array is zero-padded to the max row count / trailing width
  and stacked likewise, staying on device throughout (jax-array inputs —
  e.g. encoder outputs of an earlier protocol stage — are padded and
  stacked without a host round-trip); when padding is present the loss
  must consume the ``mask`` (real-feature columns) and ``row_w`` (real-row
  weights) entries the engine adds to each batch — see
  ``autoencoder.masked_recon_loss``.  Equal-shape lanes (the seed-replica
  case) need no masking: losses that ignore the extra keys see exactly
  the batches ``train`` would feed them;
* each lane keeps its own host-side train/val split, PRNG stream, Adam
  state and step budget (``n_batches_i = n_tr_i // bs``); the shared scan
  runs ``max_i n_batches_i`` steps and a per-lane step mask freezes params
  past a lane's own budget;
* early stopping is a per-lane ``live`` mask (mirroring the masked-loss
  trick in ``distill.make_loss``): converged lanes keep stepping on
  frozen params so the batch shape stays static, and the epoch loop ends
  when every lane has stopped.

The shared batch size is clamped to the SMALLEST lane's train split so
every lane runs at least one step per epoch.  For a lane whose row count
equals the padded maximum, the engine draws the IDENTICAL device
permutation as ``train`` (same fold_in key); when additionally
``batch_size <= min_i n_tr_i`` (no cross-lane clamping), that lane's
results match the sequential path to float tolerance — the parity tests in
``tests/test_train_many.py`` and ``tests/test_replicas.py`` pin this.

``train_many`` and ``PartySpec`` remain as aliases of ``train_lanes`` and
``LaneSpec`` (the K-party call sites read naturally with either name).
The original per-batch host loop (``train_legacy``) soaked as a live
parity oracle through PRs 1-2 and is now retired; its role is covered by
the stored-trace oracle above.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adam import paper_adam


@dataclass
class TrainResult:
    params: dict
    epochs_run: int
    steps_run: int
    train_loss: list
    val_loss: list


@dataclass
class LaneSpec:
    """One lane's training problem for ``train_lanes``: unpadded init
    params, unpadded row-aligned data dict, and the lane's PRNG seed
    (drives both the host train/val split and the device epoch perms,
    exactly as the same seed would in ``train``).  A lane is any
    independent instance — a party, a seed replicate, a fold."""
    params: dict
    data: dict
    seed: int = 0


PartySpec = LaneSpec     # the K-party special case, kept by its PR-2 name


# ---------------------------------------------------------------------------
# the scan engine
# ---------------------------------------------------------------------------

_ENGINE_CACHE: dict = {}
_ENGINE_CACHE_MAX = 64   # FIFO-evict beyond this: entries strong-reference
                         # the loss fn and its compiled executables


def loss_cache_key(loss_fn):
    """Semantic identity of a loss: closures tagged with ``cache_key``
    (e.g. ``distill.make_loss``) share one compiled engine across instances;
    plain module-level functions key on their own identity.  Untagged
    per-call closures each get their own engine (a full re-trace per
    ``train`` call) — tag them if they are built in a loop."""
    return getattr(loss_fn, "cache_key", loss_fn)


def _build_engine(loss_fn: Callable, lr: float):
    opt = paper_adam(lr)

    @partial(jax.jit, static_argnames=("n_batches", "batch_size"),
             donate_argnums=(0, 1))
    def run_epoch(params, opt_state, key, tr, val, *, n_batches, batch_size):
        n_tr = jax.tree.leaves(tr)[0].shape[0]
        perm = jax.random.permutation(key, n_tr)
        idx = perm[: n_batches * batch_size].reshape(n_batches, batch_size)

        def step(carry, bidx):
            p, s = carry
            batch = {k: v[bidx] for k, v in tr.items()}
            loss, grads = jax.value_and_grad(loss_fn)(p, batch)
            p, s, _ = opt.update(grads, s, p)
            return (p, s), loss

        (params, opt_state), losses = jax.lax.scan(step, (params, opt_state),
                                                   idx)
        return params, opt_state, jnp.mean(losses), loss_fn(params, val)

    return run_epoch


def _cached_engine(tag: str, loss_fn: Callable, lr: float, builder):
    key = (tag, loss_cache_key(loss_fn), float(lr))
    engine = _ENGINE_CACHE.get(key)
    if engine is None:
        while len(_ENGINE_CACHE) >= _ENGINE_CACHE_MAX:
            _ENGINE_CACHE.pop(next(iter(_ENGINE_CACHE)))
        engine = builder(loss_fn, float(lr))
        _ENGINE_CACHE[key] = engine
    return engine


def get_engine(loss_fn: Callable, *, lr: float = 1e-3):
    """Jitted epoch runner for ``loss_fn``, cached on (loss identity, lr)."""
    return _cached_engine("train", loss_fn, lr, _build_engine)


def get_lanes_engine(loss_fn: Callable, *, lr: float = 1e-3):
    """Jitted vmapped replica-lane epoch runner, cached like
    ``get_engine``."""
    return _cached_engine("train_many", loss_fn, lr, _build_many_engine)


get_many_engine = get_lanes_engine   # pre-lane-engine name


def train(params, data: dict, loss_fn: Callable, *, batch_size: int = 128,
          max_epochs: int = 200, patience: int = 10, lr: float = 1e-3,
          val_frac: float = 0.1, seed: int = 0,
          epoch_callback: Optional[Callable] = None) -> TrainResult:
    """data: dict of equal-length arrays (row-aligned). loss_fn(params, batch).

    See the module docstring for the device-residency / batching contract."""
    n = len(next(iter(data.values())))
    split = np.random.RandomState(seed).permutation(n)
    n_val = max(int(n * val_frac), 1)
    val_idx, tr_idx = split[:n_val], split[n_val:]
    # jnp.asarray is a no-op for arrays already on device (an earlier
    # stage's encoder output), one upload for host arrays; the split
    # itself is a device gather either way
    dev = {k: jnp.asarray(v) for k, v in data.items()}
    val = {k: v[val_idx] for k, v in dev.items()}
    tr = {k: v[tr_idx] for k, v in dev.items()}
    n_tr = len(tr_idx)
    bs = max(min(batch_size, n_tr), 1)
    n_batches = n_tr // bs

    # fresh buffers: the engine donates its params/opt args, so the loop must
    # own them (never the caller's arrays, never the best-so-far snapshot)
    params = jax.tree.map(jnp.array, params)
    best_params = jax.tree.map(jnp.copy, params)
    engine = get_engine(loss_fn, lr=lr)
    opt_state = paper_adam(lr).init(params)
    base_key = jax.random.PRNGKey(seed)

    best_val, since_best = np.inf, 0
    tl_hist, vl_hist, steps, epochs = [], [], 0, 0
    for epoch in range(max_epochs):
        epochs = epoch + 1
        params, opt_state, tl, vl = engine(
            params, opt_state, jax.random.fold_in(base_key, epoch), tr, val,
            n_batches=n_batches, batch_size=bs)
        tl, vl = float(tl), float(vl)   # the single host sync of the epoch
        steps += n_batches
        tl_hist.append(tl)
        vl_hist.append(vl)
        if epoch_callback is not None:
            # defensive copy: the engine donates ``params`` into the next
            # epoch, so a stashed reference would be use-after-donate
            epoch_callback(epoch, jax.tree.map(jnp.copy, params), tl, vl)
        if vl < best_val - 1e-6:
            best_val, since_best = vl, 0
            best_params = jax.tree.map(jnp.copy, params)
        else:
            since_best += 1
            if since_best >= patience:
                break
    return TrainResult(best_params, epochs, steps, tl_hist, vl_hist)


# ---------------------------------------------------------------------------
# replica-lane engine: all lanes' epochs as ONE vmapped scan
# ---------------------------------------------------------------------------

# all lanes' epoch keys in one dispatch; module-scoped so the trivial
# trace compiles once per process, not once per train_lanes call
_FOLD_KEYS = jax.jit(jax.vmap(jax.random.fold_in, (0, None)))


def _pad_to(arr: jax.Array, shape) -> jax.Array:
    pads = [(0, t - s) for s, t in zip(arr.shape, shape)]
    return jnp.pad(arr, pads) if any(p for _, p in pads) else arr


def _pad_stack(trees):
    """Zero-pad every leaf per-axis to the max shape across trees and stack
    along a new leading lane axis, entirely on device (host leaves are
    uploaded once here; device leaves — an earlier stage's encoder outputs
    — never round-trip).  All trees must share one structure."""
    treedef = jax.tree.structure(trees[0])
    for t in trees[1:]:
        if jax.tree.structure(t) != treedef:
            raise ValueError("train_lanes: all lanes must share one "
                             "param/data tree structure")
    leaves = [[jnp.asarray(l) for l in jax.tree.leaves(t)] for t in trees]
    stacked = []
    for pos in zip(*leaves):
        target = tuple(max(l.shape[d] for l in pos)
                       for d in range(pos[0].ndim))
        stacked.append(jnp.stack([_pad_to(l, target) for l in pos]))
    return jax.tree.unflatten(treedef, stacked)


def _build_many_engine(loss_fn: Callable, lr: float):
    opt = paper_adam(lr)

    @partial(jax.jit, static_argnames=("n_batches", "batch_size"),
             donate_argnums=(0, 1))
    def run_epoch_k(params, opt_state, keys, tr, val, n_tr, nb, live, *,
                    n_batches, batch_size):
        def one(p, s, key, tr_p, val_p, n_tr_p, nb_p, live_p):
            n_max = tr_p["x"].shape[0]
            perm = jax.random.permutation(key, n_max)
            # stable-partition real rows (< n_tr_p) to the front: for an
            # unpadded lane this is exactly the solo engine's permutation,
            # so the two paths draw identical mini-batches
            order = perm[jnp.argsort(perm >= n_tr_p, stable=True)]
            idx = order[: n_batches * batch_size].reshape(n_batches,
                                                          batch_size)

            def step(carry, xs):
                p, s = carry
                i, bidx = xs
                batch = {k: v[bidx] for k, v in tr_p.items() if k != "mask"}
                batch["mask"] = tr_p["mask"]
                batch["row_w"] = jnp.ones((batch_size,), jnp.float32)
                loss, grads = jax.value_and_grad(loss_fn)(p, batch)
                p2, s2, _ = opt.update(grads, s, p)
                # freeze past this lane's own step budget or after its
                # early stop — the masked-select twin of distill.make_loss
                on = live_p & (i < nb_p)
                sel = lambda a, b: jnp.where(on, a, b)
                return ((jax.tree.map(sel, p2, p), jax.tree.map(sel, s2, s)),
                        jnp.where(on, loss, 0.0))

            (p, s), losses = jax.lax.scan(step, (p, s),
                                          (jnp.arange(n_batches), idx))
            tl = jnp.sum(losses) / jnp.maximum(nb_p, 1)
            return p, s, tl, loss_fn(p, val_p)

        return jax.vmap(one)(params, opt_state, keys, tr, val, n_tr, nb,
                             live)

    return run_epoch_k


def train_lanes(specs: Sequence[LaneSpec], loss_fn: Callable, *,
                batch_size: int = 128, max_epochs: int = 200,
                patience: int = 10, lr: float = 1e-3,
                val_frac: float = 0.1) -> List[TrainResult]:
    """Train L independent lanes as one vmapped scan — one upload, one
    compile, one host sync per epoch for all lanes (module docstring:
    padded-stack layout, per-lane early-stop mask).

    Every lane's ``data`` must carry its feature array under the ``"x"``
    key — the engine sizes rows and the real-feature ``mask`` from it; any
    other row-aligned keys are padded too but only ``"x"`` is masked.
    When lane shapes differ (padding present) ``loss_fn`` must consume the
    ``mask`` (real-feature columns) and ``row_w`` (real-row weights)
    entries the engine adds to every batch — use
    ``autoencoder.masked_recon_loss`` for reconstruction workloads; lanes
    of identical shape (seed replicas) may use any plain loss, the extra
    keys are inert.  Returns one ``TrainResult`` per lane with padding
    stripped from the best-val params and histories truncated at that
    lane's stop epoch."""
    K = len(specs)
    assert K >= 1
    for sp in specs:
        if "x" not in sp.data:
            raise ValueError("train_lanes: every LaneSpec.data needs an "
                             "'x' feature array (sizes the rows and the "
                             "real-feature mask)")

    # --- per-lane split: host-side indices, device-side gather ------------
    tr_list, val_list, n_tr_l = [], [], []
    for sp in specs:
        n = len(next(iter(sp.data.values())))
        split = np.random.RandomState(sp.seed).permutation(n)
        n_val = max(int(n * val_frac), 1)
        vi, ti = split[:n_val], split[n_val:]
        dev = {k: jnp.asarray(v) for k, v in sp.data.items()}
        val_list.append({k: v[vi] for k, v in dev.items()})
        tr_list.append({k: v[ti] for k, v in dev.items()})
        n_tr_l.append(len(ti))
    n_tr = np.asarray(n_tr_l)
    bs = max(min(batch_size, int(n_tr.min())), 1)
    nb = n_tr // bs                       # per-lane step budget per epoch
    n_batches = int(nb.max())

    for t, v in zip(tr_list, val_list):
        t["mask"] = jnp.ones((t["x"].shape[1],), jnp.float32)
        v["mask"] = t["mask"]
        v["row_w"] = jnp.ones((v["x"].shape[0],), jnp.float32)

    # --- padded-stack, built on device (no host round-trip) ---------------
    tr = _pad_stack(tr_list)
    val = _pad_stack(val_list)
    shapes = [[np.shape(l) for l in jax.tree.leaves(sp.params)]
              for sp in specs]
    params = _pad_stack([sp.params for sp in specs])
    best_params = jax.tree.map(jnp.copy, params)
    opt_state = paper_adam(lr).init(params)
    opt_state = opt_state._replace(step=jnp.zeros((K,), jnp.int32))
    engine = get_lanes_engine(loss_fn, lr=lr)
    base_keys = jnp.stack([jax.random.PRNGKey(sp.seed) for sp in specs])
    nb_dev = jnp.asarray(nb, jnp.int32)
    n_tr_dev = jnp.asarray(n_tr, jnp.int32)

    best_val = np.full((K,), np.inf)
    since = np.zeros((K,), np.int64)
    live = np.ones((K,), bool)
    epochs_run = np.zeros((K,), np.int64)
    tl_hist = [[] for _ in range(K)]
    vl_hist = [[] for _ in range(K)]

    for epoch in range(max_epochs):
        keys = _FOLD_KEYS(base_keys, epoch)  # all lanes' keys, one dispatch
        params, opt_state, tl, vl = engine(
            params, opt_state, keys, tr, val, n_tr_dev, nb_dev,
            jnp.asarray(live), n_batches=n_batches, batch_size=bs)
        tl = np.asarray(tl)
        vl = np.asarray(vl)               # the single host sync of the epoch
        epochs_run[live] += 1
        for i in range(K):
            if live[i]:
                tl_hist[i].append(float(tl[i]))
                vl_hist[i].append(float(vl[i]))
        improved = live & (vl < best_val - 1e-6)
        if improved.any():
            sel = jnp.asarray(improved)
            best_params = jax.tree.map(
                lambda b, p: jnp.where(
                    sel.reshape((K,) + (1,) * (p.ndim - 1)), p, b),
                best_params, params)
            best_val = np.where(improved, vl, best_val)
        since = np.where(improved, 0, since + 1)
        live = live & (since < patience)
        if not live.any():
            break

    treedef = jax.tree.structure(specs[0].params)
    leaves = jax.tree.leaves(best_params)
    results = []
    for i in range(K):
        pl = [l[i][tuple(slice(0, s) for s in shp)]
              for l, shp in zip(leaves, shapes[i])]
        results.append(TrainResult(jax.tree.unflatten(treedef, pl),
                                   int(epochs_run[i]),
                                   int(epochs_run[i] * nb[i]),
                                   tl_hist[i], vl_hist[i]))
    return results


train_many = train_lanes     # the K-party special case, by its PR-2 name
