"""Shared mini-batch trainer for the tabular APC-VFL stack: Adam with the
paper's settings (Kingma & Ba defaults), <=200 epochs, early stopping on a
10% validation split with patience 10 (Appendix B)."""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class TrainResult:
    params: dict
    epochs_run: int
    steps_run: int
    train_loss: list
    val_loss: list


def _adam_init(params):
    z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
            "t": jnp.zeros((), jnp.int32)}


@partial(jax.jit, static_argnames=("loss_fn", "lr"))
def _adam_step(params, opt, batch, loss_fn, lr=1e-3):
    loss, grads = jax.value_and_grad(loss_fn)(params, batch)
    t = opt["t"] + 1
    b1, b2, eps = 0.9, 0.999, 1e-8

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** t.astype(jnp.float32))
        vh = v / (1 - b2 ** t.astype(jnp.float32))
        return (p - lr * mh / (jnp.sqrt(vh) + eps)).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, opt["m"], opt["v"])
    istuple = lambda x: isinstance(x, tuple)
    params = jax.tree.map(lambda o: o[0], out, is_leaf=istuple)
    m = jax.tree.map(lambda o: o[1], out, is_leaf=istuple)
    v = jax.tree.map(lambda o: o[2], out, is_leaf=istuple)
    return params, {"m": m, "v": v, "t": t}, loss


def train(params, data: dict, loss_fn: Callable, *, batch_size: int = 128,
          max_epochs: int = 200, patience: int = 10, lr: float = 1e-3,
          val_frac: float = 0.1, seed: int = 0,
          epoch_callback: Optional[Callable] = None) -> TrainResult:
    """data: dict of equal-length arrays (row-aligned). loss_fn(params, batch)."""
    n = len(next(iter(data.values())))
    rng = np.random.RandomState(seed)
    perm = rng.permutation(n)
    n_val = max(int(n * val_frac), 1)
    val_idx, tr_idx = perm[:n_val], perm[n_val:]
    val_batch = {k: jnp.asarray(v[val_idx]) for k, v in data.items()}
    tr = {k: v[tr_idx] for k, v in data.items()}
    n_tr = len(tr_idx)

    opt = _adam_init(params)
    best_val, best_params, since_best = np.inf, params, 0
    tl_hist, vl_hist, steps = [], [], 0
    vloss_fn = jax.jit(loss_fn)

    epochs = 0
    for epoch in range(max_epochs):
        epochs = epoch + 1
        order = rng.permutation(n_tr)
        ep_loss, nb = 0.0, 0
        for s in range(0, n_tr, batch_size):
            idx = order[s:s + batch_size]
            if len(idx) < 2:
                continue
            batch = {k: jnp.asarray(v[idx]) for k, v in tr.items()}
            params, opt, loss = _adam_step(params, opt, batch, loss_fn, lr)
            ep_loss += float(loss)
            nb += 1
            steps += 1
        vl = float(vloss_fn(params, val_batch))
        tl_hist.append(ep_loss / max(nb, 1))
        vl_hist.append(vl)
        if epoch_callback is not None:
            epoch_callback(epoch, params, tl_hist[-1], vl)
        if vl < best_val - 1e-6:
            best_val, best_params, since_best = vl, params, 0
        else:
            since_best += 1
            if since_best >= patience:
                break
    return TrainResult(best_params, epochs, steps, tl_hist, vl_hist)
