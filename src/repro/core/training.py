"""Device-resident mini-batch training engine for the tabular APC-VFL stack.

Optimization is the paper's Adam (Kingma & Ba defaults, Appendix B) via
:mod:`repro.optim.adam`, <=200 epochs, early stopping on a 10% validation
split with patience 10.

Data-layout contract (the scan engine)
--------------------------------------
``train`` takes ``data`` as a dict of equal-length, row-aligned host arrays.
The engine:

1. splits rows into train/val ONCE on the host (``np.random.RandomState(seed)``,
   identical split to the legacy loop) and uploads both sides to device ONCE;
2. draws each epoch's row permutation on device with ``jax.random``
   (``fold_in(PRNGKey(seed), epoch)``);
3. runs the WHOLE epoch as a single ``jax.lax.scan`` over
   ``(n_batches, batch_size)`` index slices inside one jitted call, with the
   params and optimizer buffers donated epoch-to-epoch;
4. computes the validation loss inside the same jitted call, so exactly ONE
   host sync per epoch (the two scalar losses) remains for early-stopping
   bookkeeping.

Batching semantics: ``batch_size`` is clamped to the train-split size and the
epoch DROPS the remainder rows of the permutation (``n_batches = n_tr // bs``)
so every scan step sees a static batch shape. The legacy loop instead ran a
trailing partial batch when it had >= 2 rows; with divisible sizes the two
engines take identical step counts (the parity test pins this).

Caveats: ``epoch_callback(epoch, params, train_loss, val_loss)`` receives
device params that are DONATED into the next epoch — use them synchronously
or ``jax.tree.map(jnp.copy, ...)`` them; never stash the reference.

Compilation caching: one jitted epoch function exists per
``(loss identity, lr)`` — closures built by ``distill.make_loss`` carry a
semantic ``cache_key`` attribute so repeated stages reuse the same compiled
engine instead of re-tracing (see ``get_engine``).

``train_legacy`` keeps the original per-batch host loop as a reference
oracle for the parity test and ``benchmarks/trainbench.py``; it will be
removed once the scan engine has soaked.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adam import paper_adam


@dataclass
class TrainResult:
    params: dict
    epochs_run: int
    steps_run: int
    train_loss: list
    val_loss: list


# ---------------------------------------------------------------------------
# the scan engine
# ---------------------------------------------------------------------------

_ENGINE_CACHE: dict = {}
_ENGINE_CACHE_MAX = 64   # FIFO-evict beyond this: entries strong-reference
                         # the loss fn and its compiled executables


def loss_cache_key(loss_fn):
    """Semantic identity of a loss: closures tagged with ``cache_key``
    (e.g. ``distill.make_loss``) share one compiled engine across instances;
    plain module-level functions key on their own identity.  Untagged
    per-call closures each get their own engine (a full re-trace per
    ``train`` call) — tag them if they are built in a loop."""
    return getattr(loss_fn, "cache_key", loss_fn)


def _build_engine(loss_fn: Callable, lr: float):
    opt = paper_adam(lr)

    @partial(jax.jit, static_argnames=("n_batches", "batch_size"),
             donate_argnums=(0, 1))
    def run_epoch(params, opt_state, key, tr, val, *, n_batches, batch_size):
        n_tr = jax.tree.leaves(tr)[0].shape[0]
        perm = jax.random.permutation(key, n_tr)
        idx = perm[: n_batches * batch_size].reshape(n_batches, batch_size)

        def step(carry, bidx):
            p, s = carry
            batch = {k: v[bidx] for k, v in tr.items()}
            loss, grads = jax.value_and_grad(loss_fn)(p, batch)
            p, s, _ = opt.update(grads, s, p)
            return (p, s), loss

        (params, opt_state), losses = jax.lax.scan(step, (params, opt_state),
                                                   idx)
        return params, opt_state, jnp.mean(losses), loss_fn(params, val)

    return run_epoch


def get_engine(loss_fn: Callable, *, lr: float = 1e-3):
    """Jitted epoch runner for ``loss_fn``, cached on (loss identity, lr)."""
    key = (loss_cache_key(loss_fn), float(lr))
    engine = _ENGINE_CACHE.get(key)
    if engine is None:
        while len(_ENGINE_CACHE) >= _ENGINE_CACHE_MAX:
            _ENGINE_CACHE.pop(next(iter(_ENGINE_CACHE)))
        engine = _build_engine(loss_fn, float(lr))
        _ENGINE_CACHE[key] = engine
    return engine


def train(params, data: dict, loss_fn: Callable, *, batch_size: int = 128,
          max_epochs: int = 200, patience: int = 10, lr: float = 1e-3,
          val_frac: float = 0.1, seed: int = 0,
          epoch_callback: Optional[Callable] = None) -> TrainResult:
    """data: dict of equal-length arrays (row-aligned). loss_fn(params, batch).

    See the module docstring for the device-residency / batching contract."""
    n = len(next(iter(data.values())))
    split = np.random.RandomState(seed).permutation(n)
    n_val = max(int(n * val_frac), 1)
    val_idx, tr_idx = split[:n_val], split[n_val:]
    val = {k: jnp.asarray(np.asarray(v)[val_idx]) for k, v in data.items()}
    tr = {k: jnp.asarray(np.asarray(v)[tr_idx]) for k, v in data.items()}
    n_tr = len(tr_idx)
    bs = max(min(batch_size, n_tr), 1)
    n_batches = n_tr // bs

    # fresh buffers: the engine donates its params/opt args, so the loop must
    # own them (never the caller's arrays, never the best-so-far snapshot)
    params = jax.tree.map(jnp.array, params)
    best_params = jax.tree.map(jnp.copy, params)
    engine = get_engine(loss_fn, lr=lr)
    opt_state = paper_adam(lr).init(params)
    base_key = jax.random.PRNGKey(seed)

    best_val, since_best = np.inf, 0
    tl_hist, vl_hist, steps, epochs = [], [], 0, 0
    for epoch in range(max_epochs):
        epochs = epoch + 1
        params, opt_state, tl, vl = engine(
            params, opt_state, jax.random.fold_in(base_key, epoch), tr, val,
            n_batches=n_batches, batch_size=bs)
        tl, vl = float(tl), float(vl)   # the single host sync of the epoch
        steps += n_batches
        tl_hist.append(tl)
        vl_hist.append(vl)
        if epoch_callback is not None:
            epoch_callback(epoch, params, tl, vl)
        if vl < best_val - 1e-6:
            best_val, since_best = vl, 0
            best_params = jax.tree.map(jnp.copy, params)
        else:
            since_best += 1
            if since_best >= patience:
                break
    return TrainResult(best_params, epochs, steps, tl_hist, vl_hist)


# ---------------------------------------------------------------------------
# legacy per-batch host loop — reference oracle for the parity test and
# benchmarks/trainbench.py only; new code should call ``train``
# ---------------------------------------------------------------------------

def _adam_init(params):
    z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
            "t": jnp.zeros((), jnp.int32)}


@partial(jax.jit, static_argnames=("loss_fn", "lr"))
def _adam_step(params, opt, batch, loss_fn, lr=1e-3):
    loss, grads = jax.value_and_grad(loss_fn)(params, batch)
    t = opt["t"] + 1
    b1, b2, eps = 0.9, 0.999, 1e-8

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** t.astype(jnp.float32))
        vh = v / (1 - b2 ** t.astype(jnp.float32))
        return (p - lr * mh / (jnp.sqrt(vh) + eps)).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, opt["m"], opt["v"])
    istuple = lambda x: isinstance(x, tuple)
    params = jax.tree.map(lambda o: o[0], out, is_leaf=istuple)
    m = jax.tree.map(lambda o: o[1], out, is_leaf=istuple)
    v = jax.tree.map(lambda o: o[2], out, is_leaf=istuple)
    return params, {"m": m, "v": v, "t": t}, loss


def train_legacy(params, data: dict, loss_fn: Callable, *,
                 batch_size: int = 128, max_epochs: int = 200,
                 patience: int = 10, lr: float = 1e-3, val_frac: float = 0.1,
                 seed: int = 0,
                 epoch_callback: Optional[Callable] = None) -> TrainResult:
    """Original host-side per-batch loop (re-uploads every mini-batch and
    syncs ``float(loss)`` every step). Reference oracle — see module docs."""
    n = len(next(iter(data.values())))
    rng = np.random.RandomState(seed)
    perm = rng.permutation(n)
    n_val = max(int(n * val_frac), 1)
    val_idx, tr_idx = perm[:n_val], perm[n_val:]
    val_batch = {k: jnp.asarray(v[val_idx]) for k, v in data.items()}
    tr = {k: v[tr_idx] for k, v in data.items()}
    n_tr = len(tr_idx)

    opt = _adam_init(params)
    best_val, best_params, since_best = np.inf, params, 0
    tl_hist, vl_hist, steps = [], [], 0
    vloss_fn = jax.jit(loss_fn)

    epochs = 0
    for epoch in range(max_epochs):
        epochs = epoch + 1
        order = rng.permutation(n_tr)
        ep_loss, nb = 0.0, 0
        for s in range(0, n_tr, batch_size):
            idx = order[s:s + batch_size]
            if len(idx) < 2:
                continue
            batch = {k: jnp.asarray(v[idx]) for k, v in tr.items()}
            params, opt, loss = _adam_step(params, opt, batch, loss_fn, lr)
            ep_loss += float(loss)
            nb += 1
            steps += 1
        vl = float(vloss_fn(params, val_batch))
        tl_hist.append(ep_loss / max(nb, 1))
        vl_hist.append(vl)
        if epoch_callback is not None:
            epoch_callback(epoch, params, tl_hist[-1], vl)
        if vl < best_val - 1e-6:
            best_val, best_params, since_best = vl, params, 0
        else:
            since_best += 1
            if since_best >= patience:
                break
    return TrainResult(best_params, epochs, steps, tl_hist, vl_hist)
