"""Shared zero-padding utilities for lane stacking and fold batching.

Two families of callers pad to common shapes so independent work items can
share one vmapped computation:

* the replica-lane training engine (``core.training``) zero-pads every
  param/data leaf per-axis to the max shape across lanes and stacks along
  a new leading lane axis (zero rows/cols feed zero inputs and receive
  zero gradients, so each lane's real sub-block evolves exactly as it
  would unpadded);
* the k-fold probe (``core.classifier``) pads each fold's row-index lists
  to a common length with index 0 at weight 0 (the padded gather is inert
  under the weighted loss).

Both used to carry private copies of this logic; this module is the one
tested implementation.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def pad_to(arr: jax.Array, shape) -> jax.Array:
    """Zero-pad ``arr`` at the end of every axis up to ``shape`` (a no-op
    when the shapes already match).  Shrinking is not supported."""
    pads = [(0, t - s) for s, t in zip(arr.shape, shape)]
    if any(p < 0 for _, p in pads):
        raise ValueError(f"pad_to: cannot shrink {arr.shape} to {shape}")
    return jnp.pad(arr, pads) if any(p for _, p in pads) else arr


def pad_stack(trees: Sequence):
    """Zero-pad every leaf per-axis to the max shape across trees and stack
    along a new leading lane axis, entirely on device (host leaves are
    uploaded once here; device leaves — an earlier stage's encoder outputs
    — never round-trip).  All trees must share one structure."""
    treedef = jax.tree.structure(trees[0])
    for t in trees[1:]:
        if jax.tree.structure(t) != treedef:
            raise ValueError("pad_stack: all trees must share one "
                             "param/data tree structure")
    leaves = [[jnp.asarray(l) for l in jax.tree.leaves(t)] for t in trees]
    stacked = []
    for pos in zip(*leaves):
        target = tuple(max(l.shape[d] for l in pos)
                       for d in range(pos[0].ndim))
        stacked.append(jnp.stack([pad_to(l, target) for l in pos]))
    return jax.tree.unflatten(treedef, stacked)


def pad_index_rows(index_lists: Sequence[np.ndarray], *,
                   min_len: int = 0) -> tuple:
    """Pad variable-length host index arrays to one (k, max_len) int32
    matrix plus matching float32 0/1 weights.  Padded slots point at row 0
    with weight 0.0, so a gather through them is inert under any
    row-weighted reduction (the k-fold probe's zero-weight-row trick)."""
    k = len(index_lists)
    lens = [len(ix) for ix in index_lists]
    max_len = max([min_len] + lens)
    idx = np.zeros((k, max_len), np.int32)
    w = np.zeros((k, max_len), np.float32)
    for i, ix in enumerate(index_lists):
        idx[i, :len(ix)] = ix
        w[i, :len(ix)] = 1.0
    return idx, w
