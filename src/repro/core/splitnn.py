"""Vertical SplitNN baseline (paper Sec. 2.1, Fig. 1).

Local feature extractors use the paper's g1 architectures; the active head
is the g2 architecture + class layer (Appendix B "fair comparison").  Joint
end-to-end training on the ALIGNED rows only; per-batch communication is
one embedding upload (forward) + one gradient download (backward), with
byte accounting exactly as Appendix E.2.

Training runs on the device-resident scan engine (``core.training``); the
per-batch communication pattern above is ACCOUNTED analytically (it is the
protocol being simulated), not re-enacted step-by-step on the host.  The
analytic totals are recorded into the result's ``comm.Channel`` — forward
embeddings as uplink, gradient returns as downlink — so SplitNN reports
the same per-direction/per-stage summary as every other method.

Hyperparameter defaults come from ``configs.apcvfl_paper.TABULAR``; the
entry point returns the unified ``experiments.results.RunResult``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.apcvfl_paper import TABULAR as HP
from repro.core import autoencoder as ae
from repro.core import classifier as clf
from repro.core import comm
from repro.core import training
from repro.core.psi import psi
from repro.data.vertical import VFLScenario
from repro.experiments.results import RunResult


def _head_widths(n_classes: int) -> list:
    return [384, 256, 256, n_classes]   # g2 + class layer (Appendix B)


def init_splitnn(key, n_feat_a: int, n_feat_p: int, n_classes: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "la": ae.init_mlp(k1, ae.table3_encoder("g1_active", n_feat_a)),
        "lp": ae.init_mlp(k2, ae.table3_encoder("g1_passive", n_feat_p)),
        "head": ae.init_mlp(k3, _head_widths(n_classes)),
    }


def splitnn_logits(params: dict, xa: jax.Array, xp: jax.Array) -> jax.Array:
    za = ae.mlp_apply(params["la"], xa, final_act=True)
    zp = ae.mlp_apply(params["lp"], xp, final_act=True)
    return ae.mlp_apply(params["head"], jnp.concatenate([za, zp], axis=-1))


def splitnn_loss(params: dict, batch: dict) -> jax.Array:
    logits = splitnn_logits(params, batch["xa"], batch["xp"])
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


def run_splitnn(sc: VFLScenario, *, seed: int = 0,
                batch_size: int = HP.batch_size,
                max_epochs: int = HP.max_epochs, patience: int = HP.patience,
                lr: float = HP.lr,
                test_size: int = HP.test_size) -> RunResult:
    channel = comm.Channel()
    _, idx_a, idx_p = psi(sc.active.ids, sc.passive.ids, channel=channel)
    xa, xp = sc.active.x[idx_a], sc.passive.x[idx_p]
    y = sc.active.y[idx_a]

    rng = np.random.RandomState(seed)
    perm = rng.permutation(len(xa))
    te, tr = perm[:test_size], perm[test_size:]

    key = jax.random.PRNGKey(seed)
    params = init_splitnn(key, xa.shape[1], xp.shape[1], sc.n_classes)
    res = training.train(params,
                         {"xa": xa[tr], "xp": xp[tr], "y": y[tr]},
                         splitnn_loss, batch_size=batch_size,
                         max_epochs=max_epochs, patience=patience, lr=lr,
                         seed=seed)

    pred = np.asarray(jnp.argmax(
        splitnn_logits(res.params, jnp.asarray(xa[te]), jnp.asarray(xp[te])),
        axis=-1))
    metrics = clf.f1_scores(y[te], pred, sc.n_classes)

    # analytic Appendix-E.2 accounting, recorded on the channel so the
    # summary carries the same direction/stage structure as measured links
    n_al = len(tr)
    epochs = res.epochs_run
    channel.send("train/forward_embeddings",
                 comm.splitnn_forward_bytes(epochs, n_al),
                 direction="uplink")
    channel.send("train/backward_gradients",
                 comm.splitnn_backprop_bytes(epochs, n_al, batch_size),
                 direction="downlink")
    rounds = comm.splitnn_rounds(epochs, n_al, batch_size)
    return RunResult(method="splitnn", metrics=metrics, rounds=rounds,
                     epochs={"splitnn": epochs}, comm=channel.summary(),
                     seed=seed, channels=(channel,))
