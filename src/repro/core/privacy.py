"""Representation-inversion attack: an empirical check of the paper's
privacy argument (Sec. 4.5).

The paper argues sharing Z = g(X) is safe because g stays local ("there are
infinitely many g"). That holds against a *blind* attacker, but an
honest-but-curious active party with AUXILIARY (x, z) pairs (e.g. leaked or
public rows of the passive party's feature space) can train an inversion
network z -> x_hat. This module quantifies that leakage: inversion R^2 on
held-out aligned rows as a function of the auxiliary-pair budget — a
beyond-paper experiment that sharpens the privacy statement from
"safe" to "safe unless the attacker holds >= N paired rows".

``run_inversion`` wraps the attack as a registered experiment method
(``@register_method("inversion")`` in ``repro.experiments.methods``), so
privacy curves run from the same declarative spec JSONs as the accuracy
grids: sweep ``n_aux`` via per-method params and read ``r2_mean`` off the
tidy records.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.apcvfl_paper import TABULAR as HP
from repro.core import autoencoder as ae
from repro.core import comm
from repro.core import training
from repro.core.psi import psi
from repro.experiments.results import RunResult


@dataclass
class InversionReport:
    n_aux: int
    r2_per_feature: np.ndarray
    r2_mean: float
    baseline_mse: float       # variance of the target (predict-the-mean)
    attack_mse: float


def _inv_loss(params, batch):
    # module-level: stable identity keys the training engine's jit cache,
    # so every leakage_curve budget reuses one compiled step per shape
    x_hat = ae.mlp_apply(params, batch["z"], final_act=False)
    return jnp.mean(jnp.square(batch["x"] - x_hat))


def inversion_attack(z: np.ndarray, x: np.ndarray, *, n_aux: int,
                     hidden: int = 128, max_epochs: int = 120,
                     seed: int = 0) -> InversionReport:
    """z: (n, M) shared representations; x: (n, D) private features the
    attacker wants back. ``n_aux`` rows are the attacker's paired auxiliary
    data; the rest measure leakage."""
    rng = np.random.RandomState(seed)
    perm = rng.permutation(len(z))
    aux, test = perm[:n_aux], perm[n_aux:]
    inv = ae.init_mlp(jax.random.PRNGKey(seed),
                      [z.shape[1], hidden, x.shape[1]])
    res = training.train(inv, {"z": z[aux], "x": x[aux]}, _inv_loss,
                         batch_size=min(64, max(n_aux // 4, 2)),
                         max_epochs=max_epochs, seed=seed)
    x_hat = np.asarray(ae.mlp_apply(res.params, jnp.asarray(z[test]),
                                    final_act=False))
    err = x[test] - x_hat
    var = x[test].var(axis=0) + 1e-12
    r2 = 1.0 - err.var(axis=0) / var
    return InversionReport(
        n_aux=n_aux, r2_per_feature=r2, r2_mean=float(r2.mean()),
        baseline_mse=float(var.mean()), attack_mse=float((err ** 2).mean()))


def effective_n_aux(n_aux: int, n_rows: int) -> int:
    """The auxiliary budget an attack can actually use on ``n_rows``
    shared latents: at least 2 training pairs must exist and at least 20
    held-out rows must remain to measure leakage on.  A clamp is a LOUD
    event — a sweep that silently measured a smaller budget than its grid
    said would mislabel the leakage curve's x-axis — so it warns, and
    callers record both requested and effective values."""
    eff = max(min(int(n_aux), n_rows - 20), 2)
    if eff != n_aux:
        warnings.warn(
            f"inversion n_aux={n_aux} clamped to {eff}: only {n_rows} "
            f"aligned latents are shared and 20 held-out rows are "
            f"reserved for measurement (records carry n_aux_requested "
            f"alongside the effective n_aux)", RuntimeWarning,
            stacklevel=3)
    return eff


def leakage_curve(z: np.ndarray, x: np.ndarray, budgets=(10, 50, 200, 1000),
                  seed: int = 0) -> list:
    out = []
    for n_aux in budgets:
        if n_aux >= len(z) - 20:
            continue
        out.append(inversion_attack(z, x, n_aux=n_aux, seed=seed))
    return out


def run_inversion(sc, *, n_aux: int = 64, hidden: int = 128,
                  batch_size: int = HP.batch_size,
                  max_epochs: int = HP.max_epochs,
                  patience: int = HP.patience, lr: float = HP.lr,
                  seed: int = 0) -> RunResult:
    """The attack as a spec-runnable method, on exactly the protocol's
    attack surface: the passive party trains g1 on its FULL dataset (as
    step 1 prescribes) but shares only the ALIGNED rows' latents with the
    active party — the same PSI + ``Z_passive_aligned`` exchange
    ``run_apcvfl`` byte-accounts, so comm records line up across methods
    in one spec.  The honest-but-curious active party then inverts those
    latents with an ``n_aux``-pair auxiliary budget.  ``metrics`` carries
    the leakage numbers (``r2_mean`` is the headline: 0 = paper's safe
    regime, 1 = full reconstruction); an infeasible ``n_aux`` is clamped
    via ``effective_n_aux`` — which WARNS — and the record carries both
    ``n_aux`` (effective) and ``n_aux_requested``."""
    xp = np.asarray(sc.passive.x)
    channel = comm.Channel()
    _, _, idx_p = psi(sc.active.ids, sc.passive.ids, channel=channel)
    key = jax.random.split(jax.random.PRNGKey(seed), 4)[1]   # g1_passive's
    params = ae.init_autoencoder(key, ae.table3_encoder("g1_passive",
                                                        xp.shape[1]))
    r1 = training.train(params, {"x": xp}, ae.recon_loss,
                        batch_size=batch_size, max_epochs=max_epochs,
                        patience=patience, lr=lr, seed=seed + 1)
    x_al = xp[idx_p]
    if len(x_al) < 22:        # >= 2 aux pairs AND >= 20 held-out rows
        raise ValueError(
            f"run_inversion: {len(x_al)} aligned rows is too few to "
            f"measure leakage (need >= 22: 2 auxiliary pairs + 20 "
            f"held-out rows)")
    z = np.asarray(ae.encode(r1.params, jnp.asarray(x_al)))
    channel.send_array("step1/Z_passive_aligned", z, direction="uplink")
    eff_n_aux = effective_n_aux(n_aux, len(z))
    rep = inversion_attack(z, x_al, n_aux=eff_n_aux, hidden=hidden,
                           max_epochs=max_epochs, seed=seed)
    metrics = {"r2_mean": rep.r2_mean, "attack_mse": rep.attack_mse,
               "baseline_mse": rep.baseline_mse,
               "n_aux": float(rep.n_aux),
               "n_aux_requested": float(n_aux),
               "n_aux_clamped": float(rep.n_aux != n_aux)}
    return RunResult(method="inversion", metrics=metrics, rounds=1,
                     epochs={"g1_passive": r1.epochs_run},
                     comm=channel.summary(), seed=seed, z_dim=z.shape[1],
                     channels=(channel,))
