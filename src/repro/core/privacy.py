"""Representation-inversion attack: an empirical check of the paper's
privacy argument (Sec. 4.5).

The paper argues sharing Z = g(X) is safe because g stays local ("there are
infinitely many g"). That holds against a *blind* attacker, but an
honest-but-curious active party with AUXILIARY (x, z) pairs (e.g. leaked or
public rows of the passive party's feature space) can train an inversion
network z -> x_hat. This module quantifies that leakage: inversion R^2 on
held-out aligned rows as a function of the auxiliary-pair budget — a
beyond-paper experiment that sharpens the privacy statement from
"safe" to "safe unless the attacker holds >= N paired rows".
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autoencoder as ae
from repro.core import training


@dataclass
class InversionReport:
    n_aux: int
    r2_per_feature: np.ndarray
    r2_mean: float
    baseline_mse: float       # variance of the target (predict-the-mean)
    attack_mse: float


def _inv_loss(params, batch):
    # module-level: stable identity keys the training engine's jit cache,
    # so every leakage_curve budget reuses one compiled step per shape
    x_hat = ae.mlp_apply(params, batch["z"], final_act=False)
    return jnp.mean(jnp.square(batch["x"] - x_hat))


def inversion_attack(z: np.ndarray, x: np.ndarray, *, n_aux: int,
                     hidden: int = 128, max_epochs: int = 120,
                     seed: int = 0) -> InversionReport:
    """z: (n, M) shared representations; x: (n, D) private features the
    attacker wants back. ``n_aux`` rows are the attacker's paired auxiliary
    data; the rest measure leakage."""
    rng = np.random.RandomState(seed)
    perm = rng.permutation(len(z))
    aux, test = perm[:n_aux], perm[n_aux:]
    inv = ae.init_mlp(jax.random.PRNGKey(seed),
                      [z.shape[1], hidden, x.shape[1]])
    res = training.train(inv, {"z": z[aux], "x": x[aux]}, _inv_loss,
                         batch_size=min(64, max(n_aux // 4, 2)),
                         max_epochs=max_epochs, seed=seed)
    x_hat = np.asarray(ae.mlp_apply(res.params, jnp.asarray(z[test]),
                                    final_act=False))
    err = x[test] - x_hat
    var = x[test].var(axis=0) + 1e-12
    r2 = 1.0 - err.var(axis=0) / var
    return InversionReport(
        n_aux=n_aux, r2_per_feature=r2, r2_mean=float(r2.mean()),
        baseline_mse=float(var.mean()), attack_mse=float((err ** 2).mean()))


def leakage_curve(z: np.ndarray, x: np.ndarray, budgets=(10, 50, 200, 1000),
                  seed: int = 0) -> list:
    out = []
    for n_aux in budgets:
        if n_aux >= len(z) - 20:
            continue
        out.append(inversion_attack(z, x, n_aux=n_aux, seed=seed))
    return out
