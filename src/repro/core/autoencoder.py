"""Symmetric MLP autoencoders (paper Table 3, SELU activations).

Encoder layer widths are given per Table 3; the decoder mirrors them in
reverse ("all autoencoders considered in APC-VFL are symmetric").  The
linear latent layer (no activation on the last encoder layer) follows the
overcomplete-autoencoder usage in the paper.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def table3_encoder(role: str, n_features: int) -> list:
    """Paper Table 3 widths. role: g1_active|g1_passive|g2|g3."""
    return {
        "g1_active": [n_features, 64, 128],
        "g1_passive": [n_features, 128, 256],
        "g2": [n_features, 256, 256],
        "g3": [n_features, 256, 256],
    }[role]


def init_mlp(key, widths: Sequence[int]) -> dict:
    params = {}
    keys = jax.random.split(key, len(widths) - 1)
    for i, (a, b) in enumerate(zip(widths[:-1], widths[1:])):
        # LeCun normal — the recommended init for SELU networks
        params[f"w{i}"] = jax.random.normal(keys[i], (a, b)) / np.sqrt(a)
        params[f"b{i}"] = jnp.zeros((b,))
    return params


def mlp_apply(params: dict, x: jax.Array, *, final_act: bool = False) -> jax.Array:
    n = len([k for k in params if k.startswith("w")])
    for i in range(n):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1 or final_act:
            x = jax.nn.selu(x)
    return x


def init_autoencoder(key, enc_widths: Sequence[int]) -> dict:
    k1, k2 = jax.random.split(key)
    return {"enc": init_mlp(k1, list(enc_widths)),
            "dec": init_mlp(k2, list(enc_widths)[::-1])}


def encode(params: dict, x: jax.Array) -> jax.Array:
    return mlp_apply(params["enc"], x)


def reconstruct(params: dict, x: jax.Array) -> jax.Array:
    return mlp_apply(params["dec"], encode(params, x))


def fused_mlp_apply(params: dict, x: jax.Array, *,
                    final_act: bool = False) -> jax.Array:
    """``mlp_apply`` through the fused Pallas kernel when the MLP is the
    2-layer Table-3 shape (one fused fwd pass + closed-form VJP);
    arbitrary-depth MLPs fall back to the jnp layer loop.  The branch is
    on pytree STRUCTURE (layer count), a trace-time constant."""
    if len([k for k in params if k.startswith("w")]) != 2:
        return mlp_apply(params, x, final_act=final_act)
    from repro.kernels import ops as kops
    return kops.fused_mlp2(x, params["w0"], params["b0"], params["w1"],
                           params["b1"], final_act=final_act)


def fused_encode(params: dict, x: jax.Array) -> jax.Array:
    return fused_mlp_apply(params["enc"], x)


def fused_reconstruct(params: dict, x: jax.Array) -> jax.Array:
    return fused_mlp_apply(params["dec"], fused_encode(params, x))


def recon_loss(params: dict, batch: dict) -> jax.Array:
    x = batch["x"]
    return jnp.mean(jnp.square(x - reconstruct(params, x)))


def masked_recon_loss(params: dict, batch: dict) -> jax.Array:
    """``recon_loss`` over the padded-stack batches of
    ``training.train_many``: ``mask`` (D,) selects the party's real feature
    columns, ``row_w`` (B,) its real rows.  With no padding this equals
    ``recon_loss`` exactly (mean over real entries)."""
    x, fm, rw = batch["x"], batch["mask"], batch["row_w"]
    se = jnp.square(x - reconstruct(params, x)) * fm
    per_row = jnp.sum(se, axis=-1) / jnp.maximum(jnp.sum(fm), 1.0)
    return jnp.sum(per_row * rw) / jnp.maximum(jnp.sum(rw), 1.0)


def make_recon_loss(use_kernel: bool = False):
    """``recon_loss`` with the reconstruction routed through the fused
    lane-MLP kernel (``kernels.lane_mlp``) when ``use_kernel=True`` —
    identical math (kernel grads are exact vs the jnp path), one fused
    pass per MLP instead of a per-layer HBM round-trip."""
    if not use_kernel:
        return recon_loss

    def loss(params: dict, batch: dict) -> jax.Array:
        x = batch["x"]
        return jnp.mean(jnp.square(x - fused_reconstruct(params, x)))

    loss.cache_key = ("repro.core.autoencoder.make_recon_loss", True)
    return loss


def make_masked_recon_loss(use_kernel: bool = False):
    """``masked_recon_loss`` with a fused-kernel reconstruction path —
    the lane-engine (``train_lanes``) variant of ``make_recon_loss``."""
    if not use_kernel:
        return masked_recon_loss

    def loss(params: dict, batch: dict) -> jax.Array:
        x, fm, rw = batch["x"], batch["mask"], batch["row_w"]
        se = jnp.square(x - fused_reconstruct(params, x)) * fm
        per_row = jnp.sum(se, axis=-1) / jnp.maximum(jnp.sum(fm), 1.0)
        return jnp.sum(per_row * rw) / jnp.maximum(jnp.sum(rw), 1.0)

    loss.cache_key = ("repro.core.autoencoder.make_masked_recon_loss", True)
    return loss
