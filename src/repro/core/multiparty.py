"""K-participant APC-VFL (paper Sec. 3 formalizes K parties; the
experiments use K=2 — this module implements the general protocol).

One active participant (holds labels), K-1 passive participants. Step ①
runs at every party; each passive sends its aligned-row latents to the
active party (K-1 single exchanges — still ONE round per link, the paper's
claim is per-pair); steps ②-④ run at the active party on the concat of all
K latent blocks.

Alignment is the row-intersection across ALL parties, computed as K-1
genuine pairwise PSIs (active vs each passive) whose results are
intersected locally at the active party.  Each link is charged for the
active party's FULL hashed-ID upload — a real pairwise PSI cannot send the
already-shrunk running intersection, which would both leak information
about the other links and under-count bytes — so total PSI traffic is
monotone in K.

All K g1 stages (active + passives) train together through the replica-
lane engine (``training.train_lanes``, one lane per party): per-party
params and datasets are zero-padded to common shapes, stacked along a
leading lane axis, and every epoch runs as ONE vmapped ``lax.scan`` inside
a single jitted call — one upload, one compile, one host sync per epoch
for all parties.  Parties that early-stop keep stepping on frozen params
behind a per-lane mask (the masked-select twin of ``distill.make_loss``),
so the batch shape stays static; see the ``core.training`` module
docstring for the layout.  Stage handoffs stay device-resident (latents
feed g2/g3 as jax arrays; channel accounting reads only shapes), matching
``core.pipeline``.

Hyperparameter defaults come from ``configs.apcvfl_paper.TABULAR``;
``run_apcvfl_k`` returns the unified ``experiments.results.RunResult``
whose ``channels`` tuple holds one measured ``comm.Channel`` per passive
link (``rounds`` is the paper's per-link claim: ONE data exchange)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.apcvfl_paper import TABULAR as HP
from repro.core import autoencoder as ae
from repro.core import classifier as clf
from repro.core import comm
from repro.core import distill
from repro.core import training
from repro.core.psi import id_positions, psi
from repro.data.synthetic import TabularDataset
from repro.data.vertical import ParticipantData
from repro.experiments.results import RunResult


@dataclass
class VFLScenarioK:
    name: str
    active: ParticipantData
    passives: List[ParticipantData]
    n_aligned: int
    n_classes: int


def make_scenario_k(ds: TabularDataset, *, n_parties: int,
                    n_active_features: int, n_aligned: int,
                    seed: int = 0) -> VFLScenarioK:
    """Split columns among K parties (active gets ``n_active_features``,
    passives share the rest round-robin); rows: ``n_aligned`` common to all,
    remainder split disjointly."""
    assert n_parties >= 2
    rng = np.random.RandomState(seed + 2000)
    d = ds.x.shape[1]
    cols = rng.permutation(d)
    a_cols = np.sort(cols[:n_active_features])
    rest = cols[n_active_features:]
    p_cols = [np.sort(rest[i::n_parties - 1]) for i in range(n_parties - 1)]
    assert all(len(c) for c in p_cols), "not enough features for K parties"

    n = len(ds.x)
    perm = rng.permutation(n)
    aligned = perm[:n_aligned]
    rest_rows = np.array_split(perm[n_aligned:], n_parties)
    rows = [np.concatenate([aligned, rr]) for rr in rest_rows]

    active = ParticipantData(x=ds.x[rows[0]][:, a_cols], ids=ds.ids[rows[0]],
                             y=ds.y[rows[0]])
    passives = [ParticipantData(x=ds.x[rows[i + 1]][:, p_cols[i]],
                                ids=ds.ids[rows[i + 1]])
                for i in range(n_parties - 1)]
    return VFLScenarioK(ds.name, active, passives, n_aligned, ds.n_classes)


def align_k(active_ids: np.ndarray, passive_ids: List[np.ndarray]):
    """Multi-party alignment as K-1 genuine pairwise PSIs (active vs each
    passive), intersected locally at the active party.  Each link is
    charged for the active party's FULL hashed-ID upload — sending the
    already-shrunk running intersection instead would both leak the other
    links' results and under-count bytes.  Returns (common_ids sorted,
    per-link channels)."""
    if not passive_ids:          # degenerate: nothing to align against
        common = np.unique(np.asarray(active_ids))   # sorted, per contract
        if len(common) != len(active_ids):           # same policy as psi()
            raise ValueError("PSI requires unique IDs: got "
                             f"{len(active_ids)} ids, {len(common)} distinct")
        return common, []
    channels = [comm.Channel() for _ in passive_ids]
    pair_commons = []
    for ids, ch in zip(passive_ids, channels):
        c, _, _ = psi(active_ids, ids, channel=ch)
        pair_commons.append(c)
    common = pair_commons[0]
    for c in pair_commons[1:]:
        common = np.intersect1d(common, c)
    return common, channels


def run_apcvfl_k(sc: VFLScenarioK, *, lam: float = HP.lam,
                 kind: str = HP.kind, seed: int = 0,
                 batch_size: int = HP.batch_size,
                 max_epochs: int = HP.max_epochs,
                 patience: int = HP.patience, lr: float = HP.lr,
                 use_kernel: bool = False,
                 ablation: bool = False, exchange=None) -> RunResult:
    """K-party protocol; same feature set as the 2-party ``run_apcvfl``
    (``ablation=True`` trains g3 without the distillation term;
    ``exchange`` hardens every passive link's one-shot latent send — each
    link derives its own transform randomness via its link index)."""
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, len(sc.passives) + 3)
    epochs = {}
    train_kw = dict(batch_size=batch_size, max_epochs=max_epochs,
                    patience=patience, lr=lr)

    common, channels = align_k(sc.active.ids, [p.ids for p in sc.passives])
    idx_a = _index_of(sc.active.ids, common)
    idx_ps = [_index_of(p.ids, common) for p in sc.passives]
    xa = sc.active.x

    if not ablation:
        # --- step 1 at every party: ONE vmapped run, one lane per g1 -------
        specs = [training.LaneSpec(
            ae.init_autoencoder(keys[0],
                                ae.table3_encoder("g1_active", xa.shape[1])),
            {"x": xa}, seed)]
        for i, p in enumerate(sc.passives):
            specs.append(training.LaneSpec(
                ae.init_autoencoder(keys[i + 1],
                                    ae.table3_encoder("g1_passive",
                                                      p.x.shape[1])),
                {"x": p.x}, seed + i + 1))
        results = training.train_lanes(specs, ae.masked_recon_loss,
                                       **train_kw)
        ra, r_ps = results[0], results[1:]
        epochs["g1_active"] = ra.epochs_run
        za = ae.encode(ra.params, jnp.asarray(xa[idx_a]))

        blocks = [za]
        for i, (p, idx_p, ch, rp) in enumerate(zip(sc.passives, idx_ps,
                                                   channels, r_ps)):
            epochs[f"g1_passive{i}"] = rp.epochs_run
            zp = ae.encode(rp.params, jnp.asarray(p.x[idx_p]))
            zp = comm.exchange_array(                          # THE exchange
                ch, f"step1/Z_passive{i}_aligned", zp,
                transform=exchange, seed=seed, link=i)
            blocks.append(zp)

        # --- step 2 at the active party -------------------------------------
        zj = jnp.concatenate(blocks, axis=1).astype(jnp.float32)
        # singleton lane: bit-identical twin of the replicated g2 stage
        (r2,) = training.train_lanes(
            [training.LaneSpec(
                ae.init_autoencoder(keys[-2],
                                    ae.table3_encoder("g2", zj.shape[1])),
                {"x": zj}, seed + 100)],
            ae.masked_recon_loss, **train_kw)
        epochs["g2"] = r2.epochs_run
        zt_al = ae.encode(r2.params, zj)
        m2 = zt_al.shape[1]
    else:
        m2 = ae.table3_encoder("g2", 1)[-1]
        zt_al = None

    # --- steps 3-4 at the active party --------------------------------------
    n_a = len(xa)
    z_teacher = jnp.zeros((n_a, m2), jnp.float32)
    mask = jnp.zeros((n_a,), jnp.float32)
    if not ablation:
        z_teacher = z_teacher.at[idx_a].set(zt_al)
        mask = mask.at[idx_a].set(1.0)
    r3 = training.train(
        ae.init_autoencoder(keys[-1], ae.table3_encoder("g3", xa.shape[1])),
        {"x": xa, "z_teacher": z_teacher, "aligned": mask},
        distill.make_loss(lam=lam, kind=kind, use_kernel=use_kernel),
        seed=seed + 200, **train_kw)
    epochs["g3"] = r3.epochs_run

    z_all = ae.encode(r3.params, jnp.asarray(xa))
    metrics = clf.kfold_cv(z_all, sc.active.y, sc.n_classes, seed=seed)
    data_rounds = 0 if ablation else comm.APCVFL_ROUNDS
    params = {"g3": r3.params}
    artifacts = None
    if not ablation:
        # serving export capture (serve.vfl.export_bundle): the active
        # party's own encoders + the concat of the K-1 received latent
        # blocks for the aligned rows, keyed by their ids
        params["g1_active"] = ra.params
        params["g2"] = r2.params
        artifacts = {"aligned_ids": np.asarray(common),
                     "z_passive_aligned": jnp.concatenate(blocks[1:],
                                                          axis=1)}
    return RunResult(method="apcvfl", metrics=metrics, rounds=data_rounds,
                     epochs=epochs, comm=comm.summarize(channels), seed=seed,
                     z_dim=m2, params=params, channels=tuple(channels),
                     artifacts=artifacts)


def _index_of(ids: np.ndarray, subset: np.ndarray) -> np.ndarray:
    pos = id_positions(ids)
    return np.asarray([pos[int(s)] for s in subset], dtype=np.int64)


# ---------------------------------------------------------------------------
# replica-lane execution: all seeds of one K-party grid cell per dispatch
# ---------------------------------------------------------------------------

def run_apcvfl_k_replicated(scenarios, *, seeds, lam: float = HP.lam,
                            kind: str = HP.kind,
                            batch_size: int = HP.batch_size,
                            max_epochs: int = HP.max_epochs,
                            patience: int = HP.patience, lr: float = HP.lr,
                            use_kernel: bool = False,
                            ablation: bool = False, exchange=None,
                            mesh=None) -> List[RunResult]:
    """K-party protocol for S seed replicates of one grid cell, every
    stage one ``training.train_lanes`` dispatch: ALL parties of ALL seeds
    train their g1 stage as S*K lanes of one vmapped scan, then S g2
    lanes, then S g3 lanes — the K-party twin of
    ``pipeline.run_apcvfl_replicated`` (same contract: one scenario
    shared by every seed or one equal-shape scenario per seed; one
    ``RunResult`` per seed matching ``run_apcvfl_k(scenarios[i],
    seed=seeds[i], ...)`` within lane tolerance).  ``mesh`` shards every
    stage's lane axis across devices (see ``training.train_lanes``)."""
    seeds = [int(s) for s in seeds]
    S = len(seeds)
    scs = ([scenarios] * S if isinstance(scenarios, VFLScenarioK)
           else list(scenarios))
    if len(scs) != S:
        raise ValueError(f"run_apcvfl_k_replicated: {len(scs)} scenarios "
                         f"for {S} seeds")
    if S == 0:
        return []
    exchanges = comm.normalize_exchange(exchange, S)
    train_kw = dict(batch_size=batch_size, max_epochs=max_epochs,
                    patience=patience, lr=lr, mesh=mesh)
    K = len(scs[0].passives) + 1

    aligns = [align_k(sc.active.ids, [p.ids for p in sc.passives])
              for sc in scs]
    idx_as = [_index_of(sc.active.ids, common)
              for sc, (common, _) in zip(scs, aligns)]
    idx_pss = [[_index_of(p.ids, common) for p in sc.passives]
               for sc, (common, _) in zip(scs, aligns)]
    keys = [jax.random.split(jax.random.PRNGKey(s), K + 2) for s in seeds]
    epochs = [{} for _ in range(S)]

    if not ablation:
        # --- step 1: S * K g1 lanes (every party of every seed) ------------
        lanes = []
        for sc, s, ks in zip(scs, seeds, keys):
            lanes.append(training.LaneSpec(
                ae.init_autoencoder(ks[0], ae.table3_encoder(
                    "g1_active", sc.active.x.shape[1])),
                {"x": sc.active.x}, s))
            for i, p in enumerate(sc.passives):
                lanes.append(training.LaneSpec(
                    ae.init_autoencoder(ks[i + 1], ae.table3_encoder(
                        "g1_passive", p.x.shape[1])),
                    {"x": p.x}, s + i + 1))
        g1 = training.train_lanes(lanes, ae.masked_recon_loss, **train_kw)

        # --- step 2: S g2 lanes on device-resident joint latents -----------
        zjs, zps = [], []
        for i, (sc, (_, channels)) in enumerate(zip(scs, aligns)):
            ra = g1[K * i]
            epochs[i]["g1_active"] = ra.epochs_run
            za = ae.encode(ra.params,
                           jnp.asarray(sc.active.x[idx_as[i]]))
            blocks = [za]
            for j, (p, idx_p, ch) in enumerate(zip(sc.passives,
                                                   idx_pss[i], channels)):
                rp = g1[K * i + j + 1]
                epochs[i][f"g1_passive{j}"] = rp.epochs_run
                zp = ae.encode(rp.params, jnp.asarray(p.x[idx_p]))
                zp = comm.exchange_array(
                    ch, f"step1/Z_passive{j}_aligned", zp,
                    transform=exchanges[i], seed=seeds[i], link=j)
                blocks.append(zp)
            zps.append(jnp.concatenate(blocks[1:], axis=1))
            zjs.append(jnp.concatenate(blocks, axis=1).astype(jnp.float32))
        g2 = training.train_lanes(
            [training.LaneSpec(
                ae.init_autoencoder(ks[-2],
                                    ae.table3_encoder("g2", zj.shape[1])),
                {"x": zj}, s + 100)
             for zj, s, ks in zip(zjs, seeds, keys)],
            ae.masked_recon_loss, **train_kw)
        zts = [ae.encode(r2.params, zj) for r2, zj in zip(g2, zjs)]
        m2 = zts[0].shape[1]
        for i, r2 in enumerate(g2):
            epochs[i]["g2"] = r2.epochs_run
    else:
        m2 = ae.table3_encoder("g2", 1)[-1]
        zts = [None] * S
        zps = [None] * S

    # --- step 3: S g3 distillation lanes ------------------------------------
    g3_lanes = []
    for sc, s, ks, zt, idx_a in zip(scs, seeds, keys, zts, idx_as):
        xa = sc.active.x
        z_teacher = jnp.zeros((len(xa), m2), jnp.float32)
        mask = jnp.zeros((len(xa),), jnp.float32)
        if not ablation:
            z_teacher = z_teacher.at[idx_a].set(zt)
            mask = mask.at[idx_a].set(1.0)
        g3_lanes.append(training.LaneSpec(
            ae.init_autoencoder(ks[-1], ae.table3_encoder("g3",
                                                          xa.shape[1])),
            {"x": xa, "z_teacher": z_teacher, "aligned": mask}, s + 200))
    g3 = training.train_lanes(
        g3_lanes, distill.make_lanes_loss(lam, kind, use_kernel=use_kernel),
        **train_kw)

    # --- step 4: classifier per seed (see pipeline.run_apcvfl_replicated) --
    results = []
    data_rounds = 0 if ablation else comm.APCVFL_ROUNDS
    for i, (sc, s, r3, (common, channels)) in enumerate(zip(scs, seeds, g3,
                                                            aligns)):
        epochs[i]["g3"] = r3.epochs_run
        z_all = ae.encode(r3.params, jnp.asarray(sc.active.x))
        metrics = clf.kfold_cv(z_all, sc.active.y, sc.n_classes, seed=s)
        params = {"g3": r3.params}
        artifacts = None
        if not ablation:
            params["g1_active"] = g1[K * i].params
            params["g2"] = g2[i].params
            artifacts = {"aligned_ids": np.asarray(common),
                         "z_passive_aligned": zps[i]}
        results.append(RunResult(
            method="apcvfl", metrics=metrics, rounds=data_rounds,
            epochs=epochs[i], comm=comm.summarize(channels), seed=s,
            z_dim=m2, params=params, channels=tuple(channels),
            artifacts=artifacts))
    return results
