"""Logistic-regression probe (paper Sec. 5 "Models": linear probing) +
k-fold cross-validation and F1/accuracy metrics (micro/macro/weighted).

``kfold_cv`` treats the k folds as replica lanes: every fold's train split
is padded to a common row count with zero-weight rows, and all k fits plus
their test-fold predictions run as ONE jitted ``lax.scan``
(``_fit_predict_folds``).  Uneven ``array_split`` shapes used to force one
recompile per distinct fold size; now there is exactly one compile per
(n, d, k, n_classes) and one host sync for all predictions.  Zero-weight
padding is exact, not approximate: the weighted mean over real rows equals
the unweighted mean the per-fold path took, so gradients (and hence the
fitted probes) match to float tolerance — ``tests/test_replicas.py`` pins
parity against a per-fold reference.

The fit itself is FOLD-BLOCKED (``_probe_grads_blocked``): instead of
gathering k private per-fold copies of ``x`` and vmapping k independent
scans, every fold carries a full-row 0/1 weight vector (zero on its own
test rows) and all k probes advance through one closed-form gradient whose
fold axis is a column block of a single GEMM pair.  The probe step is
memory-bound on re-reading ``x``; reading it once for all folds instead of
once per fold is the dominant CV speedup on CPU.  ``use_kernel=True``
routes the same full-row-weight step through the fused Pallas probe kernel
(``kernels.probe``) with every fold a lane of the kernel grid.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import padding
from repro.optim.adam import paper_adam


def init_logreg(key, n_features: int, n_classes: int) -> dict:
    return {"w": jnp.zeros((n_features, n_classes)),
            "b": jnp.zeros((n_classes,))}


def logreg_logits(params: dict, x: jax.Array) -> jax.Array:
    return x @ params["w"] + params["b"]


def logreg_loss(params: dict, batch: dict) -> jax.Array:
    logits = logreg_logits(params, batch["x"])
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
    l2 = 1e-4 * jnp.sum(jnp.square(params["w"]))
    return jnp.mean(lse - gold) + l2


def _weighted_logreg_loss(params, x, y, w) -> jax.Array:
    """``logreg_loss`` with per-row weights: with 0/1 weights the weighted
    mean over real rows equals the plain mean over those rows exactly, so
    zero-weight padding rows are invisible to the gradients."""
    logits = logreg_logits(params, x)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    l2 = 1e-4 * jnp.sum(jnp.square(params["w"]))
    return jnp.sum((lse - gold) * w) / jnp.maximum(jnp.sum(w), 1.0) + l2


@partial(jax.jit, static_argnames=("n_classes", "steps", "lr",
                                   "use_kernel"))
def fit_logreg(x, y, n_classes: int, steps: int = 300, lr: float = 0.1,
               use_kernel: bool = False):
    """Full-batch Adam logistic regression (fast jit'd probe), on the same
    optimizer the training engine uses (repro.optim.adam).
    ``use_kernel=True`` computes each step's gradient through the fused
    Pallas probe kernel (``kernels.probe``, exact same math: all-ones row
    weights make the weighted CE the plain mean)."""
    params = {"w": jnp.zeros((x.shape[1], n_classes), jnp.float32),
              "b": jnp.zeros((n_classes,), jnp.float32)}
    opt = paper_adam(lr)
    if use_kernel:
        from repro.kernels import ops as kops
        ones = jnp.ones((x.shape[0],), jnp.float32)

        def grads(p):
            _, dw, db = kops.probe_grad_step(p["w"], p["b"], x, y, ones)
            return {"w": dw, "b": db}
    else:
        def grads(p):
            return jax.grad(logreg_loss)(p, {"x": x, "y": y})

    def step(carry, _):
        params, state = carry
        params, state, _ = opt.update(grads(params), state, params)
        return (params, state), None

    (params, _), _ = jax.lax.scan(step, (params, opt.init(params)), None,
                                  length=steps)
    return params


def _probe_grads_blocked(w, b, x, onehot, rw, *, l2: float = 1e-4):
    """Closed-form weighted softmax-CE gradient for ALL k fold probes in
    one pass over the SHARED ``x`` — the fold axis becomes a column block
    of a single GEMM pair instead of k gathered per-fold copies.

    ``w``: (k, d, C) stacked probes, ``b``: (k, C), ``x``: (n, d),
    ``onehot``: (n, C), ``rw``: (n, k) per-fold normalized row weights
    (0 for the fold's own test rows and padding).  The probe step is
    memory-bound on re-reading ``x``; this reads it exactly twice per
    step (logits + grad) for every fold at once, where the gathered
    per-fold layout read k private copies.  Matches the autodiff gradient
    of ``_weighted_logreg_loss`` exactly (``kernels.ref.probe_grad_ref``
    pins the algebra)."""
    k, d, c = w.shape
    w2 = w.transpose(1, 0, 2).reshape(d, k * c)
    logits = (x @ w2).reshape(-1, k, c) + b[None]
    g = (jax.nn.softmax(logits, axis=-1) - onehot[:, None, :]) * rw[:, :, None]
    dw = (x.T @ g.reshape(-1, k * c)).reshape(d, k, c).transpose(1, 0, 2)
    return dw + 2.0 * l2 * w, jnp.sum(g, axis=0)


def _fit_predict_folds_blocked(x, y, tr_idx, tr_w, te_idx, *, n_classes,
                               steps, lr, use_kernel=False):
    """Fold-blocked probe fits + test-fold predictions for one seed: all
    k probes advance together through ``steps`` Adam steps of
    ``_probe_grads_blocked``.  Zero-weight rows make the padding exact
    (module docstring).  ``use_kernel=True`` takes the same full-row-
    weight step through the fused Pallas probe kernel instead — every
    fold a lane of the kernel grid (``jax.vmap`` over stacked probes,
    shared ``x``/``y``)."""
    n = x.shape[0]
    k = tr_idx.shape[0]
    rw_full = jax.vmap(
        lambda tri, trw: jnp.zeros((n,), jnp.float32).at[tri].add(trw)
    )(tr_idx, tr_w)                                         # (k, n)
    denom = jnp.maximum(jnp.sum(tr_w, axis=1), 1.0)         # (k,)
    rw = (rw_full / denom[:, None]).T                       # (n, k)
    onehot = jax.nn.one_hot(y, n_classes, dtype=jnp.float32)
    params = {"w": jnp.zeros((k, x.shape[1], n_classes), jnp.float32),
              "b": jnp.zeros((k, n_classes), jnp.float32)}
    opt = paper_adam(lr)
    if use_kernel:
        from repro.kernels import ops as kops

        def fold_grads(w, b):
            # kernel normalizes by sum(rw) internally == denom for 0/1 w
            return jax.vmap(
                lambda wk, bk, rwk: kops.probe_grad_step(wk, bk, x, y, rwk),
                in_axes=(0, 0, 0))(w, b, rw_full)[1:]
    else:
        def fold_grads(w, b):
            return _probe_grads_blocked(w, b, x, onehot, rw)

    def step(carry, _):
        p, s = carry
        dw, db = fold_grads(p["w"], p["b"])
        p, s, _ = opt.update({"w": dw, "b": db}, s, p)
        return (p, s), None

    (params, _), _ = jax.lax.scan(step, (params, opt.init(params)), None,
                                  length=steps)
    logits = jnp.einsum("ked,kdc->kec", x[te_idx], params["w"]) \
        + params["b"][:, None, :]
    return jnp.argmax(logits, axis=-1)


@partial(jax.jit, static_argnames=("n_classes", "steps", "lr",
                                   "use_kernel"))
def _fit_predict_folds(x, y, tr_idx, tr_w, te_idx, *, n_classes: int,
                       steps: int = 300, lr: float = 0.1,
                       use_kernel: bool = False):
    """All k probe fits + test-fold predictions as one fold-blocked jitted
    call.

    ``tr_idx``/``te_idx`` are (k, max_tr)/(k, max_te) row indices into
    ``x`` (padded entries point at row 0), ``tr_w`` the matching 0/1 row
    weights.  Returns (k, max_te) predicted labels; padded test slots are
    sliced off by the host caller."""
    return _fit_predict_folds_blocked(x, y, tr_idx, tr_w, te_idx,
                                      n_classes=n_classes, steps=steps,
                                      lr=lr, use_kernel=use_kernel)


@partial(jax.jit, static_argnames=("n_classes", "steps", "lr",
                                   "use_kernel"))
def _fit_predict_folds_many(x, y, tr_idx, tr_w, te_idx, *, n_classes: int,
                            steps: int = 300, lr: float = 0.1,
                            use_kernel: bool = False):
    """S seeds x k folds of probe fits as one vmapped fold-blocked call:
    ``x``/``y`` carry a leading seed axis, the index arrays a leading
    (S, k) pair.  Returns (S, k, max_te) predicted labels."""
    per_seed = partial(_fit_predict_folds_blocked, n_classes=n_classes,
                       steps=steps, lr=lr, use_kernel=use_kernel)
    return jax.vmap(per_seed)(x, y, tr_idx, tr_w, te_idx)


def predict(params: dict, x) -> np.ndarray:
    return np.asarray(jnp.argmax(logreg_logits(params, jnp.asarray(x)),
                                 axis=-1))


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def f1_scores(y_true: np.ndarray, y_pred: np.ndarray, n_classes: int) -> dict:
    """Returns micro/macro/weighted F1 and accuracy.

    One ``np.bincount`` confusion matrix instead of four full-array passes
    per class; ``tests/test_replicas.py`` pins parity against the loop."""
    y_true = np.asarray(y_true)
    y_pred = np.asarray(y_pred)
    cm = np.bincount(y_true * n_classes + y_pred,
                     minlength=n_classes * n_classes)
    cm = cm.reshape(n_classes, n_classes)        # rows: true, cols: pred
    tp = np.diag(cm).astype(np.float64)
    fp = cm.sum(axis=0) - tp
    fn = cm.sum(axis=1) - tp
    support = cm.sum(axis=1).astype(np.float64)
    denom = 2 * tp + fp + fn
    f1c = np.where(denom > 0, 2 * tp / np.maximum(denom, 1), 0.0)
    micro_d = 2 * tp.sum() + fp.sum() + fn.sum()
    return {
        "accuracy": float(tp.sum() / max(len(y_true), 1)),
        "f1_micro": float(2 * tp.sum() / micro_d) if micro_d else 0.0,
        "f1_macro": float(np.mean(f1c)),
        "f1_weighted": float(np.sum(f1c * support) / max(support.sum(), 1)),
        # binary convention (positive class = 1), used for UCI credit card
        "f1_binary": float(f1c[1]) if n_classes == 2 else float(np.mean(f1c)),
    }


def _fold_arrays(n: int, k: int, seed: int):
    """The paper's fold assignment (seeded permutation + ``array_split``)
    as padded index arrays (``core.padding.pad_index_rows`` — the same
    zero-weight-row trick the lane engine uses): (k, max_tr) train indices
    + 0/1 weights (padded slots gather row 0 at zero weight — inert) and
    (k, max_te) test indices, plus the raw folds for host-side metric
    slicing."""
    perm = np.random.RandomState(seed).permutation(n)
    folds = np.array_split(perm, k)
    te_lens = [len(f) for f in folds]
    trs = [np.concatenate([folds[j] for j in range(k) if j != i])
           for i in range(k)]
    tr_idx, tr_w = padding.pad_index_rows(trs)
    te_idx, _ = padding.pad_index_rows(folds)
    return tr_idx, tr_w, te_idx, folds, te_lens


def kfold_cv(x: np.ndarray, y: np.ndarray, n_classes: int, *, k: int = 10,
             seed: int = 0, use_kernel: bool = False) -> dict:
    """Paper evaluation: 10-fold CV of the logistic probe; mean metrics.

    Fold assignment is the same ``array_split`` as always; the k fits run
    as one fold-blocked jitted call over zero-weight-padded folds (module
    docstring), with a single host sync for all predictions.
    ``use_kernel=True`` routes every fold's gradient step through the
    fused Pallas probe kernel (``kernels.probe``)."""
    x = np.asarray(x)
    y = np.asarray(y)
    tr_idx, tr_w, te_idx, folds, te_lens = _fold_arrays(len(x), k, seed)
    preds = np.asarray(_fit_predict_folds(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(tr_idx),
        jnp.asarray(tr_w), jnp.asarray(te_idx), n_classes=n_classes,
        use_kernel=use_kernel))
    accs = [f1_scores(y[folds[i]], preds[i, :te_lens[i]], n_classes)
            for i in range(k)]
    return {k_: float(np.mean([a[k_] for a in accs])) for k_ in accs[0]}


def kfold_cv_many(xs, ys, n_classes: int, *, k: int = 10, seeds,
                  use_kernel: bool = False) -> list:
    """S independent k-fold CVs (one per seed, equal shapes) as ONE jitted
    call: every (seed, fold) pair is a lane of the vmapped fold-blocked
    fit — the replica-lane treatment of the evaluation stage, and the
    step-4 dispatch ``pipeline.run_apcvfl_replicated`` runs (one compile
    + one host sync for all S x k probes).  Returns one metrics dict per
    seed, each matching ``kfold_cv(xs[i], ys[i], ..., seed=seeds[i])``
    within lane-engine tolerance."""
    seeds = list(seeds)
    ys = [np.asarray(y) for y in ys]
    x_s = jnp.stack([jnp.asarray(x) for x in xs])      # (S, n, d)
    y_s = jnp.stack([jnp.asarray(y) for y in ys])
    per_seed = [_fold_arrays(x_s.shape[1], k, s) for s in seeds]
    preds = np.asarray(_fit_predict_folds_many(
        x_s, y_s,
        jnp.asarray(np.stack([p[0] for p in per_seed])),
        jnp.asarray(np.stack([p[1] for p in per_seed])),
        jnp.asarray(np.stack([p[2] for p in per_seed])),
        n_classes=n_classes, use_kernel=use_kernel))   # (S, k, max_te)
    out = []
    for si, (y, (_, _, _, folds, te_lens)) in enumerate(zip(ys, per_seed)):
        accs = [f1_scores(y[folds[i]], preds[si, i, :te_lens[i]], n_classes)
                for i in range(k)]
        out.append({k_: float(np.mean([a[k_] for a in accs]))
                    for k_ in accs[0]})
    return out
