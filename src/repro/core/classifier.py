"""Logistic-regression probe (paper Sec. 5 "Models": linear probing) +
k-fold cross-validation and F1/accuracy metrics (micro/macro/weighted).

``kfold_cv`` treats the k folds as replica lanes: every fold's train split
is padded to a common row count with zero-weight rows, and all k fits plus
their test-fold predictions run as ONE vmapped ``lax.scan`` inside a
single jitted call (``_fit_predict_folds``).  Uneven ``array_split``
shapes used to force one recompile per distinct fold size; now there is
exactly one compile per (n, d, k, n_classes) and one host sync for all
predictions.  Zero-weight padding is exact, not approximate: the weighted
mean over real rows equals the unweighted mean the per-fold path took, so
gradients (and hence the fitted probes) match to float tolerance —
``tests/test_replicas.py`` pins parity against a per-fold reference.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import padding
from repro.optim.adam import paper_adam


def init_logreg(key, n_features: int, n_classes: int) -> dict:
    return {"w": jnp.zeros((n_features, n_classes)),
            "b": jnp.zeros((n_classes,))}


def logreg_logits(params: dict, x: jax.Array) -> jax.Array:
    return x @ params["w"] + params["b"]


def logreg_loss(params: dict, batch: dict) -> jax.Array:
    logits = logreg_logits(params, batch["x"])
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
    l2 = 1e-4 * jnp.sum(jnp.square(params["w"]))
    return jnp.mean(lse - gold) + l2


def _weighted_logreg_loss(params, x, y, w) -> jax.Array:
    """``logreg_loss`` with per-row weights: with 0/1 weights the weighted
    mean over real rows equals the plain mean over those rows exactly, so
    zero-weight padding rows are invisible to the gradients."""
    logits = logreg_logits(params, x)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    l2 = 1e-4 * jnp.sum(jnp.square(params["w"]))
    return jnp.sum((lse - gold) * w) / jnp.maximum(jnp.sum(w), 1.0) + l2


@partial(jax.jit, static_argnames=("n_classes", "steps", "lr"))
def fit_logreg(x, y, n_classes: int, steps: int = 300, lr: float = 0.1):
    """Full-batch Adam logistic regression (fast jit'd probe), on the same
    optimizer the training engine uses (repro.optim.adam)."""
    params = {"w": jnp.zeros((x.shape[1], n_classes), jnp.float32),
              "b": jnp.zeros((n_classes,), jnp.float32)}
    opt = paper_adam(lr)

    def step(carry, _):
        params, state = carry
        g = jax.grad(logreg_loss)(params, {"x": x, "y": y})
        params, state, _ = opt.update(g, state, params)
        return (params, state), None

    (params, _), _ = jax.lax.scan(step, (params, opt.init(params)), None,
                                  length=steps)
    return params


def _fold_fit_predict(x, y, tri, trw, tei, *, n_classes, steps, lr):
    """One fold lane: weighted probe fit on ``x[tri]`` then predictions on
    ``x[tei]`` — the body both vmapped fold runners share."""
    opt = paper_adam(lr)
    xi, yi = x[tri], y[tri]
    params = {"w": jnp.zeros((x.shape[1], n_classes), jnp.float32),
              "b": jnp.zeros((n_classes,), jnp.float32)}

    def step(carry, _):
        p, s = carry
        g = jax.grad(_weighted_logreg_loss)(p, xi, yi, trw)
        p, s, _ = opt.update(g, s, p)
        return (p, s), None

    (params, _), _ = jax.lax.scan(step, (params, opt.init(params)), None,
                                  length=steps)
    return jnp.argmax(logreg_logits(params, x[tei]), axis=-1)


@partial(jax.jit, static_argnames=("n_classes", "steps", "lr"))
def _fit_predict_folds(x, y, tr_idx, tr_w, te_idx, *, n_classes: int,
                       steps: int = 300, lr: float = 0.1):
    """All k probe fits + test-fold predictions as one vmapped scan.

    ``tr_idx``/``te_idx`` are (k, max_tr)/(k, max_te) row indices into
    ``x`` (padded entries point at row 0), ``tr_w`` the matching 0/1 row
    weights.  Returns (k, max_te) predicted labels; padded test slots are
    sliced off by the host caller."""
    fold = partial(_fold_fit_predict, x, y, n_classes=n_classes,
                   steps=steps, lr=lr)
    return jax.vmap(fold)(tr_idx, tr_w, te_idx)


@partial(jax.jit, static_argnames=("n_classes", "steps", "lr"))
def _fit_predict_folds_many(x, y, tr_idx, tr_w, te_idx, *, n_classes: int,
                            steps: int = 300, lr: float = 0.1):
    """S seeds x k folds of probe fits as one doubly-vmapped scan:
    ``x``/``y`` carry a leading seed axis, the index arrays a leading
    (S, k) pair.  Returns (S, k, max_te) predicted labels."""
    def per_seed(xs, ys, tri, trw, tei):
        fold = partial(_fold_fit_predict, xs, ys, n_classes=n_classes,
                       steps=steps, lr=lr)
        return jax.vmap(fold)(tri, trw, tei)

    return jax.vmap(per_seed)(x, y, tr_idx, tr_w, te_idx)


def predict(params: dict, x) -> np.ndarray:
    return np.asarray(jnp.argmax(logreg_logits(params, jnp.asarray(x)),
                                 axis=-1))


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def f1_scores(y_true: np.ndarray, y_pred: np.ndarray, n_classes: int) -> dict:
    """Returns micro/macro/weighted F1 and accuracy.

    One ``np.bincount`` confusion matrix instead of four full-array passes
    per class; ``tests/test_replicas.py`` pins parity against the loop."""
    y_true = np.asarray(y_true)
    y_pred = np.asarray(y_pred)
    cm = np.bincount(y_true * n_classes + y_pred,
                     minlength=n_classes * n_classes)
    cm = cm.reshape(n_classes, n_classes)        # rows: true, cols: pred
    tp = np.diag(cm).astype(np.float64)
    fp = cm.sum(axis=0) - tp
    fn = cm.sum(axis=1) - tp
    support = cm.sum(axis=1).astype(np.float64)
    denom = 2 * tp + fp + fn
    f1c = np.where(denom > 0, 2 * tp / np.maximum(denom, 1), 0.0)
    micro_d = 2 * tp.sum() + fp.sum() + fn.sum()
    return {
        "accuracy": float(tp.sum() / max(len(y_true), 1)),
        "f1_micro": float(2 * tp.sum() / micro_d) if micro_d else 0.0,
        "f1_macro": float(np.mean(f1c)),
        "f1_weighted": float(np.sum(f1c * support) / max(support.sum(), 1)),
        # binary convention (positive class = 1), used for UCI credit card
        "f1_binary": float(f1c[1]) if n_classes == 2 else float(np.mean(f1c)),
    }


def _fold_arrays(n: int, k: int, seed: int):
    """The paper's fold assignment (seeded permutation + ``array_split``)
    as padded index arrays (``core.padding.pad_index_rows`` — the same
    zero-weight-row trick the lane engine uses): (k, max_tr) train indices
    + 0/1 weights (padded slots gather row 0 at zero weight — inert) and
    (k, max_te) test indices, plus the raw folds for host-side metric
    slicing."""
    perm = np.random.RandomState(seed).permutation(n)
    folds = np.array_split(perm, k)
    te_lens = [len(f) for f in folds]
    trs = [np.concatenate([folds[j] for j in range(k) if j != i])
           for i in range(k)]
    tr_idx, tr_w = padding.pad_index_rows(trs)
    te_idx, _ = padding.pad_index_rows(folds)
    return tr_idx, tr_w, te_idx, folds, te_lens


def kfold_cv(x: np.ndarray, y: np.ndarray, n_classes: int, *, k: int = 10,
             seed: int = 0) -> dict:
    """Paper evaluation: 10-fold CV of the logistic probe; mean metrics.

    Fold assignment is the same ``array_split`` as always; the k fits run
    as one vmapped jitted call over zero-weight-padded folds (module
    docstring), with a single host sync for all predictions."""
    x = np.asarray(x)
    y = np.asarray(y)
    tr_idx, tr_w, te_idx, folds, te_lens = _fold_arrays(len(x), k, seed)
    preds = np.asarray(_fit_predict_folds(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(tr_idx),
        jnp.asarray(tr_w), jnp.asarray(te_idx), n_classes=n_classes))
    accs = [f1_scores(y[folds[i]], preds[i, :te_lens[i]], n_classes)
            for i in range(k)]
    return {k_: float(np.mean([a[k_] for a in accs])) for k_ in accs[0]}


def kfold_cv_many(xs, ys, n_classes: int, *, k: int = 10, seeds) -> list:
    """S independent k-fold CVs (one per seed, equal shapes) as ONE jitted
    call: every (seed, fold) pair is a lane of a doubly-vmapped fit — the
    replica-lane treatment of the evaluation stage.  On the 2-core CPU
    container this measures at parity with S ``kfold_cv`` calls (the
    probe is memory-bound), so ``pipeline.run_apcvfl_replicated``
    deliberately does NOT use it; it is the drop-in for accelerator
    backends where lane batching pays.  Returns one metrics dict per
    seed, each matching ``kfold_cv(xs[i], ys[i], ..., seed=seeds[i])``
    within lane-engine tolerance."""
    seeds = list(seeds)
    ys = [np.asarray(y) for y in ys]
    x_s = jnp.stack([jnp.asarray(x) for x in xs])      # (S, n, d)
    y_s = jnp.stack([jnp.asarray(y) for y in ys])
    per_seed = [_fold_arrays(x_s.shape[1], k, s) for s in seeds]
    preds = np.asarray(_fit_predict_folds_many(
        x_s, y_s,
        jnp.asarray(np.stack([p[0] for p in per_seed])),
        jnp.asarray(np.stack([p[1] for p in per_seed])),
        jnp.asarray(np.stack([p[2] for p in per_seed])),
        n_classes=n_classes))                          # (S, k, max_te)
    out = []
    for si, (y, (_, _, _, folds, te_lens)) in enumerate(zip(ys, per_seed)):
        accs = [f1_scores(y[folds[i]], preds[si, i, :te_lens[i]], n_classes)
                for i in range(k)]
        out.append({k_: float(np.mean([a[k_] for a in accs]))
                    for k_ in accs[0]})
    return out
