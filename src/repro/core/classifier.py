"""Logistic-regression probe (paper Sec. 5 "Models": linear probing) +
k-fold cross-validation and F1/accuracy metrics (micro/macro/weighted)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adam import paper_adam


def init_logreg(key, n_features: int, n_classes: int) -> dict:
    return {"w": jnp.zeros((n_features, n_classes)),
            "b": jnp.zeros((n_classes,))}


def logreg_logits(params: dict, x: jax.Array) -> jax.Array:
    return x @ params["w"] + params["b"]


def logreg_loss(params: dict, batch: dict) -> jax.Array:
    logits = logreg_logits(params, batch["x"])
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
    l2 = 1e-4 * jnp.sum(jnp.square(params["w"]))
    return jnp.mean(lse - gold) + l2


@partial(jax.jit, static_argnames=("n_classes", "steps", "lr"))
def fit_logreg(x, y, n_classes: int, steps: int = 300, lr: float = 0.1):
    """Full-batch Adam logistic regression (fast jit'd probe), on the same
    optimizer the training engine uses (repro.optim.adam)."""
    params = {"w": jnp.zeros((x.shape[1], n_classes)),
              "b": jnp.zeros((n_classes,))}
    opt = paper_adam(lr)

    def step(carry, _):
        params, state = carry
        g = jax.grad(logreg_loss)(params, {"x": x, "y": y})
        params, state, _ = opt.update(g, state, params)
        return (params, state), None

    (params, _), _ = jax.lax.scan(step, (params, opt.init(params)), None,
                                  length=steps)
    return params


def predict(params: dict, x) -> np.ndarray:
    return np.asarray(jnp.argmax(logreg_logits(params, jnp.asarray(x)),
                                 axis=-1))


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def f1_scores(y_true: np.ndarray, y_pred: np.ndarray, n_classes: int) -> dict:
    """Returns micro/macro/weighted F1 and accuracy."""
    y_true = np.asarray(y_true)
    y_pred = np.asarray(y_pred)
    tp = np.zeros(n_classes)
    fp = np.zeros(n_classes)
    fn = np.zeros(n_classes)
    support = np.zeros(n_classes)
    for c in range(n_classes):
        tp[c] = np.sum((y_pred == c) & (y_true == c))
        fp[c] = np.sum((y_pred == c) & (y_true != c))
        fn[c] = np.sum((y_pred != c) & (y_true == c))
        support[c] = np.sum(y_true == c)
    denom = 2 * tp + fp + fn
    f1c = np.where(denom > 0, 2 * tp / np.maximum(denom, 1), 0.0)
    micro_d = 2 * tp.sum() + fp.sum() + fn.sum()
    return {
        "accuracy": float(np.mean(y_true == y_pred)),
        "f1_micro": float(2 * tp.sum() / micro_d) if micro_d else 0.0,
        "f1_macro": float(np.mean(f1c)),
        "f1_weighted": float(np.sum(f1c * support) / max(support.sum(), 1)),
        # binary convention (positive class = 1), used for UCI credit card
        "f1_binary": float(f1c[1]) if n_classes == 2 else float(np.mean(f1c)),
    }


def kfold_cv(x: np.ndarray, y: np.ndarray, n_classes: int, *, k: int = 10,
             seed: int = 0) -> dict:
    """Paper evaluation: 10-fold CV of the logistic probe; mean metrics."""
    n = len(x)
    rng = np.random.RandomState(seed)
    perm = rng.permutation(n)
    folds = np.array_split(perm, k)
    accs = []
    for i in range(k):
        te = folds[i]
        tr = np.concatenate([folds[j] for j in range(k) if j != i])
        params = fit_logreg(jnp.asarray(x[tr]), jnp.asarray(y[tr]), n_classes)
        pred = predict(params, x[te])
        accs.append(f1_scores(y[te], pred, n_classes))
    return {k_: float(np.mean([a[k_] for a in accs])) for k_ in accs[0]}
