"""Private set intersection (simulated): salted-hash PSI over ID spaces.

The paper assumes participants run PSI on IDs before training (Sec. 3).
We simulate the ECDH/salted-hash protocol faithfully at the *interface*
level: each party only learns the intersection, and the channel accounting
charges one hashed-ID exchange per party."""
from __future__ import annotations

import hashlib

import numpy as np


def _hash_ids(ids: np.ndarray, salt: bytes) -> dict:
    hashed = {hashlib.sha256(salt + int(i).to_bytes(8, "little")).digest():
              int(i) for i in ids}
    if len(hashed) != len(ids):
        # a dict would silently keep one entry per duplicate, corrupting the
        # idx_a/idx_b alignment downstream — fail loudly instead
        raise ValueError(f"PSI requires unique IDs: got {len(ids)} ids, "
                         f"{len(hashed)} distinct")
    return hashed


def id_positions(ids: np.ndarray) -> dict:
    """Position map ``{id: row}`` for an id vector — the one id -> row
    lookup every alignment/cache consumer shares (ids are unique per
    party; ``_hash_ids`` enforces that at alignment time)."""
    return {int(v): i for i, v in enumerate(np.asarray(ids))}


def psi(ids_a: np.ndarray, ids_b: np.ndarray, *, salt: bytes = b"psi",
        channel=None):
    """Returns (aligned_ids sorted, idx_a, idx_b) such that
    ids_a[idx_a] == ids_b[idx_b] == aligned_ids."""
    ha = _hash_ids(ids_a, salt)
    hb = _hash_ids(ids_b, salt)
    if channel is not None:
        # a = active party by convention: its hashes flow OUT (downlink),
        # the peer's reply flows back toward it (uplink)
        channel.send("psi/hashes_a", len(ids_a) * 32, direction="downlink")
        channel.send("psi/hashes_b", len(ids_b) * 32, direction="uplink")
    common = sorted(ha[h] for h in (set(ha) & set(hb)))
    common = np.asarray(common, dtype=np.int64)
    pos_a = id_positions(ids_a)
    pos_b = id_positions(ids_b)
    idx_a = np.asarray([pos_a[int(c)] for c in common], dtype=np.int64)
    idx_b = np.asarray([pos_b[int(c)] for c in common], dtype=np.int64)
    return common, idx_a, idx_b
