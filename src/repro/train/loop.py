"""Training step factory: LM cross-entropy (all decoder families), masked
frame CE (audio), and the paper's ``apcvfl_distill`` composite objective
(Eq. 5) scaled to arbitrary backbones.

The APC-VFL objective treats the backbone as the student encoder g3: its
mean-pooled final hidden state is the representation z = g3(x).  The batch
carries a per-row ``aligned`` mask and the teacher joint latents ``z_teacher``
(zeros for unaligned rows); the loss is
    L = L_task + lambda * mean_over_aligned ||z - z_teacher||^2
exactly mirroring the tabular Eq. 5 (L_task plays the role of L_enc-dec).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.optim.adam import AdamW


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE; logits (..., V) any dtype, stable fp32 reduction."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def task_loss(params, cfg: ModelConfig, batch: dict):
    lg, aux = M.logits(params, cfg, batch)
    if cfg.family == "audio":
        ce = cross_entropy(lg, batch["labels"])
    else:  # causal LM: next-token prediction
        ce = cross_entropy(lg[:, :-1], batch["tokens"][:, 1:])
    loss = ce + cfg.router_aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


def apcvfl_distill_loss(params, cfg: ModelConfig, batch: dict,
                        lam: float = 0.01, distill: str = "mse"):
    """Paper Eq. 5 on sequence backbones (see module docstring)."""
    h, aux = M.hidden(params, cfg, batch)            # (B, S, d)
    z = jnp.mean(h.astype(jnp.float32), axis=1)      # (B, d) pooled student rep
    lg = jnp.einsum("bsd,dv->bsv",
                    h, params["embed"]["out"].astype(h.dtype))
    ce = cross_entropy(lg[:, :-1], batch["tokens"][:, 1:])
    diff = z - batch["z_teacher"].astype(jnp.float32)
    per_row = (jnp.mean(jnp.abs(diff), axis=-1) if distill == "mae"
               else jnp.mean(diff * diff, axis=-1))  # (B,)
    mask = batch["aligned"].astype(jnp.float32)
    dloss = jnp.sum(per_row * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = ce + lam * dloss + cfg.router_aux_weight * aux
    return loss, {"ce": ce, "distill": dloss, "aux": aux}


class TrainStepFns(NamedTuple):
    init: callable
    step: callable


def make_train_step(cfg: ModelConfig, opt: AdamW = AdamW(),
                    objective: str = "lm", n_micro: int = 1,
                    lr_schedule=None):
    loss_fn = {"lm": task_loss,
               "apcvfl_distill": apcvfl_distill_loss}[objective]

    def init(key):
        from repro.sharding.policy import init_params
        params = init_params(M.schema(cfg), key, jnp.dtype(cfg.dtype))
        return params, opt.init(params)

    from repro.optim.schedule import accumulate_grads
    vag = accumulate_grads(lambda p, b: loss_fn(p, cfg, b), n_micro)

    def step(params, opt_state, batch):
        (loss, metrics), grads = vag(params, batch)
        o = (opt._replace(lr=lr_schedule(opt_state.step + 1))
             if lr_schedule is not None else opt)
        params, opt_state, gnorm = o.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return params, opt_state, metrics

    return TrainStepFns(init, step)
