"""Pallas TPU kernel for the Mamba2 SSD intra-chunk block.

The XLA chunked SSD (models/mamba2.ssd_chunked) materializes the
(Lc x Lc) decay matrix, the (Lc x Lc) score matrix and their elementwise
products in HBM for every (batch, chunk, head) — the dominant HBM traffic
of the zamba2 training step (EXPERIMENTS.md section Perf, pair A). This
kernel keeps the whole intra-chunk computation VMEM-resident, emitting only
y_intra (Lc, P) and the chunk-final state contribution (N, P) per grid cell
— the SSD analogue of flash attention.

Grid: one cell per (batch*chunk, head). VMEM budget at Lc=256, N=64, P=64
(zamba2): B/C 2*64KiB + x 64KiB + decay/score tiles 2*256KiB ~ 0.7MiB.
The inter-chunk O(S/Lc) recurrence stays on the host side (it is tiny).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, c_ref, x_ref, y_ref, st_ref):
    a = a_ref[0].astype(jnp.float32)                       # (Lc,)
    B = b_ref[0].astype(jnp.float32)                       # (Lc, N)
    C = c_ref[0].astype(jnp.float32)                       # (Lc, N)
    x = x_ref[0].astype(jnp.float32)                       # (Lc, P)
    Lc = a.shape[0]

    cs = jnp.cumsum(a)                                     # (Lc,)
    diff = cs[:, None] - cs[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Lc, Lc), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Lc, Lc), 1)
    Lmat = jnp.where(tri, jnp.exp(diff), 0.0)              # VMEM only
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(scores * Lmat, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    decay_end = jnp.exp(cs[-1] - cs)                       # (Lc,)
    st = jax.lax.dot_general(B * decay_end[:, None], x,
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    st_ref[0] = st.astype(st_ref.dtype)                    # (N, P)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(a, B, C, x, *, interpret: bool = False):
    """a: (G, Lc) log-decays; B/C: (G, Lc, N); x: (G, Lc, P) pre-scaled by
    dt. G = batch*chunks*heads flattened. Returns (y (G, Lc, P),
    states (G, N, P))."""
    G, Lc = a.shape
    N = B.shape[-1]
    P = x.shape[-1]
    return pl.pallas_call(
        _kernel,
        grid=(G,),
        in_specs=[
            pl.BlockSpec((1, Lc), lambda i: (i, 0)),
            pl.BlockSpec((1, Lc, N), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, Lc, N), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, Lc, P), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Lc, P), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, N, P), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, Lc, P), jnp.float32),
            jax.ShapeDtypeStruct((G, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(a, B, C, x)
