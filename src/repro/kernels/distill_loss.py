"""Fused APC-VFL composite loss (paper Eq. 5) as a Pallas TPU kernel.

One VMEM-resident pass computes, per row,
    rec_i  = mean_d (x_i - x_hat_i)^2
    dis_i  = mean_m |z_i - zt_i|^p        (p = 2 for MSE, 1 for MAE)
    out_i  = rec_i + lam * aligned_i * dis_i
fusing four elementwise streams + two row reductions that XLA would
otherwise materialize separately in HBM.  Batch rows are tiled 128 at a
time (8-sublane x fp32 tiles); feature dims ride whole in VMEM (tabular
dims here are <= 1024: ~1.5MiB per tile at the defaults).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, xh_ref, z_ref, zt_ref, m_ref, o_ref, *, lam: float,
            kind: str):
    x = x_ref[...].astype(jnp.float32)
    xh = xh_ref[...].astype(jnp.float32)
    z = z_ref[...].astype(jnp.float32)
    zt = zt_ref[...].astype(jnp.float32)
    mask = m_ref[...].astype(jnp.float32)
    rec = jnp.mean(jnp.square(x - xh), axis=-1)
    diff = z - zt
    dis = (jnp.mean(jnp.abs(diff), axis=-1) if kind == "mae"
           else jnp.mean(jnp.square(diff), axis=-1))
    o_ref[...] = rec + lam * mask * dis


@functools.partial(jax.jit, static_argnames=("lam", "kind", "block_b",
                                             "interpret"))
def fused_distill_rows(x, x_hat, z, z_t, mask, *, lam: float = 0.01,
                       kind: str = "mse", block_b: int = 128,
                       interpret: bool = False):
    """Per-row Eq. 5 losses. x/x_hat: (B, D); z/z_t: (B, M); mask: (B,)."""
    B, D = x.shape
    M = z.shape[1]
    pad = (-B) % block_b
    if pad:
        padf = lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
        x, x_hat, z, z_t, mask = map(padf, (x, x_hat, z, z_t, mask))
    Bp = B + pad
    out = pl.pallas_call(
        functools.partial(_kernel, lam=lam, kind=kind),
        grid=(Bp // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, D), lambda i: (i, 0)),
            pl.BlockSpec((block_b, D), lambda i: (i, 0)),
            pl.BlockSpec((block_b, M), lambda i: (i, 0)),
            pl.BlockSpec((block_b, M), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Bp,), jnp.float32),
        interpret=interpret,
    )(x, x_hat, z, z_t, mask)
    return out[:B]
