"""Fused APC-VFL composite loss (paper Eq. 5) as a Pallas TPU kernel.

One VMEM-resident pass computes, per row,
    rec_i  = mean_d (x_i - x_hat_i)^2
    dis_i  = mean_m |z_i - zt_i|^p        (p = 2 for MSE, 1 for MAE)
    out_i  = rec_i + lam * aligned_i * dis_i
fusing four elementwise streams + two row reductions that XLA would
otherwise materialize separately in HBM.  Batch rows are tiled 128 at a
time (8-sublane x fp32 tiles); feature dims ride whole in VMEM (tabular
dims here are <= 1024: ~1.5MiB per tile at the defaults).

The Eq. 5 backward is closed-form, so ``fused_distill_rows`` carries a
``jax.custom_vjp`` whose backward is a second fused Pallas kernel (same
tiling): for row cotangents g_i,
    d x_i    =  g_i * 2 (x_i - xh_i) / D          (d xh_i = -d x_i)
    d z_i    =  g_i * lam * a_i * p |z_i-zt_i|^{p-1} sgn(z_i-zt_i) / M
                                                  (d zt_i = -d z_i)
    d a_i    =  g_i * lam * dis_i
This is what lets ``use_kernel=True`` train under ``jax.value_and_grad``
in the scan engine (the raw ``pallas_call`` has no VJP rule).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, xh_ref, z_ref, zt_ref, m_ref, o_ref, *, lam: float,
            kind: str):
    x = x_ref[...].astype(jnp.float32)
    xh = xh_ref[...].astype(jnp.float32)
    z = z_ref[...].astype(jnp.float32)
    zt = zt_ref[...].astype(jnp.float32)
    mask = m_ref[...].astype(jnp.float32)
    rec = jnp.mean(jnp.square(x - xh), axis=-1)
    diff = z - zt
    dis = (jnp.mean(jnp.abs(diff), axis=-1) if kind == "mae"
           else jnp.mean(jnp.square(diff), axis=-1))
    o_ref[...] = rec + lam * mask * dis


def _bwd_kernel(g_ref, x_ref, xh_ref, z_ref, zt_ref, m_ref,
                dx_ref, dz_ref, dm_ref, *, lam: float, kind: str):
    g = g_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    xh = xh_ref[...].astype(jnp.float32)
    z = z_ref[...].astype(jnp.float32)
    zt = zt_ref[...].astype(jnp.float32)
    mask = m_ref[...].astype(jnp.float32)
    D = x.shape[-1]
    M = z.shape[-1]
    diff = z - zt
    dx_ref[...] = (g[:, None] * (2.0 / D)) * (x - xh)
    if kind == "mae":
        dis = jnp.mean(jnp.abs(diff), axis=-1)
        ddis = jnp.sign(diff) / M
    else:
        dis = jnp.mean(jnp.square(diff), axis=-1)
        ddis = 2.0 * diff / M
    dz_ref[...] = (g * lam * mask)[:, None] * ddis
    dm_ref[...] = g * lam * dis


def _pad_rows(arrs, pad: int):
    if not pad:
        return arrs
    padf = lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
    return tuple(padf(a) for a in arrs)


def _rows_fwd_call(x, x_hat, z, z_t, mask, lam, kind, block_b, interpret):
    B, D = x.shape
    M = z.shape[1]
    pad = (-B) % block_b
    x, x_hat, z, z_t, mask = _pad_rows((x, x_hat, z, z_t, mask), pad)
    Bp = B + pad
    out = pl.pallas_call(
        functools.partial(_kernel, lam=lam, kind=kind),
        grid=(Bp // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, D), lambda i: (i, 0)),
            pl.BlockSpec((block_b, D), lambda i: (i, 0)),
            pl.BlockSpec((block_b, M), lambda i: (i, 0)),
            pl.BlockSpec((block_b, M), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Bp,), jnp.float32),
        interpret=interpret,
    )(x, x_hat, z, z_t, mask)
    return out[:B]


def _rows_bwd_call(g, x, x_hat, z, z_t, mask, lam, kind, block_b, interpret):
    B, D = x.shape
    M = z.shape[1]
    pad = (-B) % block_b
    g, x, x_hat, z, z_t, mask = _pad_rows((g, x, x_hat, z, z_t, mask), pad)
    Bp = B + pad
    dx, dz, dm = pl.pallas_call(
        functools.partial(_bwd_kernel, lam=lam, kind=kind),
        grid=(Bp // block_b,),
        in_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b, D), lambda i: (i, 0)),
            pl.BlockSpec((block_b, D), lambda i: (i, 0)),
            pl.BlockSpec((block_b, M), lambda i: (i, 0)),
            pl.BlockSpec((block_b, M), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, D), lambda i: (i, 0)),
            pl.BlockSpec((block_b, M), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, D), jnp.float32),
            jax.ShapeDtypeStruct((Bp, M), jnp.float32),
            jax.ShapeDtypeStruct((Bp,), jnp.float32),
        ],
        interpret=interpret,
    )(g, x, x_hat, z, z_t, mask)
    return dx[:B], dz[:B], dm[:B]


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _rows(x, x_hat, z, z_t, mask, lam, kind, block_b, interpret):
    return _rows_fwd_call(x, x_hat, z, z_t, mask, lam, kind, block_b,
                          interpret)


def _rows_fwd(x, x_hat, z, z_t, mask, lam, kind, block_b, interpret):
    out = _rows_fwd_call(x, x_hat, z, z_t, mask, lam, kind, block_b,
                         interpret)
    return out, (x, x_hat, z, z_t, mask)


def _rows_bwd(lam, kind, block_b, interpret, res, g):
    x, x_hat, z, z_t, mask = res
    dx, dz, dm = _rows_bwd_call(g, x, x_hat, z, z_t, mask, lam, kind,
                                block_b, interpret)
    cast = lambda d, ref: d.astype(ref.dtype)
    return (cast(dx, x), cast(-dx, x_hat), cast(dz, z), cast(-dz, z_t),
            cast(dm, mask))


_rows.defvjp(_rows_fwd, _rows_bwd)


@functools.partial(jax.jit, static_argnames=("lam", "kind", "block_b",
                                             "interpret"))
def fused_distill_rows(x, x_hat, z, z_t, mask, *, lam: float = 0.01,
                       kind: str = "mse", block_b: int = 128,
                       interpret: bool = False):
    """Per-row Eq. 5 losses. x/x_hat: (B, D); z/z_t: (B, M); mask: (B,).
    Differentiable (closed-form custom VJP, module docstring)."""
    return _rows(x, x_hat, z, z_t, mask, float(lam), str(kind),
                 int(block_b), bool(interpret))
