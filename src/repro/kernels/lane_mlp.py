"""Lane-blocked fused 2-layer MLP step (paper Table 3 encoders/decoders)
as a Pallas kernel pair.

``train_lanes`` spends its time on stacks of small per-lane matmuls —
``selu(x @ w0 + b0) @ w1 + b1`` per lane per batch tile — which XLA
schedules as separate HBM round-trips per layer.  The forward kernel here
keeps one batch tile plus both weight blocks VMEM-resident and emits the
output AND both pre-activations in a single pass; the backward is a
second fused kernel implementing the closed-form chain rule, so the pair
carries a ``jax.custom_vjp`` and trains under ``jax.value_and_grad``
inside the scan engine (a raw ``pallas_call`` has no VJP rule).

Lane blocking comes from the ``pallas_call`` batching rule: the lane
engine evaluates losses under ``jax.vmap``, which prepends the lane axis
as the OUTERMOST grid dimension — the compiled kernel runs on a
(lanes, batch_tiles) lane-major grid with each lane's weight block
resident for its row of tiles.  ``fused_lane_mlp2`` exposes that stacked
form directly (with a ``live`` mask rendering dead lanes inert) for
callers outside the engine and for the benches.

Backward, for upstream cotangent ``g`` (per tile; selu' is evaluated on
the saved pre-activations so gradients match autodiff exactly):

    g2  = g * selu'(a2)   if final_act else  g
    dW1 = selu(a1)^T g2          db1 = sum_rows g2
    g1  = (g2 W1^T) * selu'(a1)
    dW0 = x^T g1                 db0 = sum_rows g1
    dx  = g1 W0^T

Weight gradients are written as PER-TILE partials (leading grid axis)
and reduced outside the kernel: an in-kernel accumulator over
``pl.program_id`` would alias across the vmap-prepended lane axis,
per-tile partials are batching-safe by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# jax.nn.selu constants: selu(x) = SCALE * where(x > 0, x, ALPHA*expm1(x))
_SELU_ALPHA = 1.6732632423543772848170429916717
_SELU_SCALE = 1.0507009873554804934193349852946


def _selu(a):
    return _SELU_SCALE * jnp.where(a > 0, a, _SELU_ALPHA * jnp.expm1(a))


def _dselu(a):
    # exact derivative of the expm1 form autodiff differentiates
    return _SELU_SCALE * jnp.where(a > 0, 1.0, _SELU_ALPHA * jnp.exp(a))


def _fwd_kernel(x_ref, w0_ref, b0_ref, w1_ref, b1_ref,
                out_ref, a1_ref, a2_ref, *, final_act: bool):
    x = x_ref[...].astype(jnp.float32)
    w0 = w0_ref[...].astype(jnp.float32)
    w1 = w1_ref[...].astype(jnp.float32)
    a1 = jnp.dot(x, w0, preferred_element_type=jnp.float32) \
        + b0_ref[...].astype(jnp.float32)
    h1 = _selu(a1)
    a2 = jnp.dot(h1, w1, preferred_element_type=jnp.float32) \
        + b1_ref[...].astype(jnp.float32)
    a1_ref[...] = a1
    a2_ref[...] = a2
    out_ref[...] = _selu(a2) if final_act else a2


def _bwd_kernel(g_ref, x_ref, a1_ref, a2_ref, w0_ref, w1_ref,
                dx_ref, dw0_ref, db0_ref, dw1_ref, db1_ref, *,
                final_act: bool):
    g = g_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    a1 = a1_ref[...].astype(jnp.float32)
    w0 = w0_ref[...].astype(jnp.float32)
    w1 = w1_ref[...].astype(jnp.float32)
    g2 = g * _dselu(a2_ref[...].astype(jnp.float32)) if final_act else g
    h1 = _selu(a1)
    dw1_ref[0] = jnp.dot(h1.T, g2, preferred_element_type=jnp.float32)
    db1_ref[0] = jnp.sum(g2, axis=0)
    g1 = jnp.dot(g2, w1.T, preferred_element_type=jnp.float32) * _dselu(a1)
    dw0_ref[0] = jnp.dot(x.T, g1, preferred_element_type=jnp.float32)
    db0_ref[0] = jnp.sum(g1, axis=0)
    dx_ref[...] = jnp.dot(g1, w0.T, preferred_element_type=jnp.float32)


def _pad_rows(arrs, pad: int):
    if not pad:
        return arrs
    padf = lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
    return tuple(padf(a) for a in arrs)


def _fwd_call(x, w0, b0, w1, b1, final_act, block_b, interpret):
    B, din = x.shape
    h, dz = w0.shape[1], w1.shape[1]
    pad = (-B) % block_b
    (x,) = _pad_rows((x,), pad)
    Bp = B + pad
    full = lambda shp: pl.BlockSpec(shp, lambda i: (0,) * len(shp))
    out, a1, a2 = pl.pallas_call(
        functools.partial(_fwd_kernel, final_act=final_act),
        grid=(Bp // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, din), lambda i: (i, 0)),
            full((din, h)), full((h,)), full((h, dz)), full((dz,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, dz), lambda i: (i, 0)),
            pl.BlockSpec((block_b, h), lambda i: (i, 0)),
            pl.BlockSpec((block_b, dz), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, dz), jnp.float32),
            jax.ShapeDtypeStruct((Bp, h), jnp.float32),
            jax.ShapeDtypeStruct((Bp, dz), jnp.float32),
        ],
        interpret=interpret,
    )(x, w0, b0, w1, b1)
    return out[:B], a1[:B], a2[:B]


def _bwd_call(g, x, a1, a2, w0, w1, final_act, block_b, interpret):
    B, din = x.shape
    h, dz = w0.shape[1], w1.shape[1]
    pad = (-B) % block_b
    g, x, a1, a2 = _pad_rows((g, x, a1, a2), pad)
    Bp = B + pad
    nt = Bp // block_b
    full = lambda shp: pl.BlockSpec(shp, lambda i: (0,) * len(shp))
    dx, dw0p, db0p, dw1p, db1p = pl.pallas_call(
        functools.partial(_bwd_kernel, final_act=final_act),
        grid=(Bp // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, dz), lambda i: (i, 0)),
            pl.BlockSpec((block_b, din), lambda i: (i, 0)),
            pl.BlockSpec((block_b, h), lambda i: (i, 0)),
            pl.BlockSpec((block_b, dz), lambda i: (i, 0)),
            full((din, h)), full((h, dz)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, din), lambda i: (i, 0)),
            pl.BlockSpec((1, din, h), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, h), lambda i: (i, 0)),
            pl.BlockSpec((1, h, dz), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, dz), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, din), jnp.float32),
            jax.ShapeDtypeStruct((nt, din, h), jnp.float32),
            jax.ShapeDtypeStruct((nt, h), jnp.float32),
            jax.ShapeDtypeStruct((nt, h, dz), jnp.float32),
            jax.ShapeDtypeStruct((nt, dz), jnp.float32),
        ],
        interpret=interpret,
    )(g, x, a1, a2, w0, w1)
    return (dx[:B], jnp.sum(dw0p, axis=0), jnp.sum(db0p, axis=0),
            jnp.sum(dw1p, axis=0), jnp.sum(db1p, axis=0))


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _mlp2(x, w0, b0, w1, b1, final_act, block_b, interpret):
    out, _, _ = _fwd_call(x, w0, b0, w1, b1, final_act, block_b, interpret)
    return out


def _mlp2_fwd(x, w0, b0, w1, b1, final_act, block_b, interpret):
    out, a1, a2 = _fwd_call(x, w0, b0, w1, b1, final_act, block_b,
                            interpret)
    return out, (x, a1, a2, w0, b0, w1, b1)


def _mlp2_bwd(final_act, block_b, interpret, res, g):
    x, a1, a2, w0, b0, w1, b1 = res
    dx, dw0, db0, dw1, db1 = _bwd_call(g, x, a1, a2, w0, w1, final_act,
                                       block_b, interpret)
    cast = lambda d, ref: d.astype(ref.dtype)
    return (cast(dx, x), cast(dw0, w0), cast(db0, b0), cast(dw1, w1),
            cast(db1, b1))


_mlp2.defvjp(_mlp2_fwd, _mlp2_bwd)


@functools.partial(jax.jit, static_argnames=("final_act", "block_b",
                                             "interpret"))
def fused_mlp2(x, w0, b0, w1, b1, *, final_act: bool = False,
               block_b: int = 128, interpret: bool = False):
    """Fused ``selu(x @ w0 + b0) @ w1 + b1`` (optionally selu'd).
    x: (B, din); w0: (din, h); w1: (h, dz).  Differentiable (closed-form
    custom VJP, module docstring); lane axis enters via ``jax.vmap``."""
    return _mlp2(x, w0, b0, w1, b1, bool(final_act), int(block_b),
                 bool(interpret))


@functools.partial(jax.jit, static_argnames=("final_act", "block_b",
                                             "interpret"))
def fused_lane_mlp2(xs, w0s, b0s, w1s, b1s, live, *,
                    final_act: bool = False, block_b: int = 128,
                    interpret: bool = False):
    """Explicit lane-stacked form: xs (L, B, din), per-lane weight stacks,
    ``live`` (L,) 0/1 mask.  One lane-major (L, batch_tiles) kernel grid
    (vmap batching rule); dead lanes produce exact zeros."""
    out = jax.vmap(
        lambda x, w0, b0, w1, b1: _mlp2(x, w0, b0, w1, b1,
                                        bool(final_act), int(block_b),
                                        bool(interpret))
    )(xs, w0s, b0s, w1s, b1s)
    return out * live.astype(out.dtype)[:, None, None]
