"""Fused int8-dequant matmul for the quantized serving path.

The int8 export (``serve.quant``) stores every weight matrix as
per-output-channel symmetric int8 (``w_q`` int8 + ``scale`` fp32, one
scale per column).  Serving then needs ``x @ (w_q * scale) + b`` — naively
that materializes a dequantized fp32 copy of the weights in HBM before
the matmul.  This kernel fuses the dequant into the matmul tile: the int8
weight block is upcast and scaled in registers, multiplied, and never
written back, so the weight traffic stays at 1 byte/param (the whole
point of int8 serving on a memory-bound host).

Grid is row-blocked over the batch like ``kernels.lane_mlp``; the weight
(and its scale row) ride along as full blocks.  An optional fused SELU
covers the hidden layer of the Table-3 2-layer encoders so the quantized
``head(g3(x))`` path is two kernel launches + one head launch with no
elementwise pass between them.  Semantics pinned by
``kernels.ref.int8_matmul_ref`` (+ ``jax.nn.selu`` for ``act='selu'``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SELU_ALPHA = 1.6732632423543772848170429916717
_SELU_SCALE = 1.0507009873554804934193349852946


def _selu(a):
    return _SELU_SCALE * jnp.where(a > 0, a, _SELU_ALPHA * jnp.expm1(a))


def _int8_kernel(x_ref, wq_ref, scale_ref, b_ref, o_ref, *, act):
    x = x_ref[...].astype(jnp.float32)
    # dequantize the weight tile in registers: int8 -> fp32 * column scale
    w = wq_ref[...].astype(jnp.float32) * scale_ref[...][None, :]
    out = jnp.dot(x, w, preferred_element_type=jnp.float32) + b_ref[...]
    o_ref[...] = _selu(out) if act == "selu" else out


@functools.partial(jax.jit,
                   static_argnames=("act", "block_b", "interpret"))
def int8_matmul(x, w_q, scale, b, *, act: str = "none",
                block_b: int = 128, interpret: bool = False):
    """``x @ dequant(w_q, scale) + b`` with the dequant fused into the
    matmul tile.  x: (B, d) fp32; w_q: (d, c) int8; scale/b: (c,) fp32;
    ``act='selu'`` fuses the hidden-layer activation.  Inference-only
    (the quantized path never trains), so no custom VJP."""
    if act not in ("none", "selu"):
        raise ValueError(f"int8_matmul: unknown act {act!r}")
    if w_q.dtype != jnp.int8:
        raise TypeError(f"int8_matmul: w_q must be int8, got {w_q.dtype}")
    B, d = x.shape
    c = w_q.shape[1]
    bb = min(int(block_b), B) if B else 1
    pad = (-B) % bb
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    nt = (B + pad) // bb
    full = lambda shp: pl.BlockSpec(shp, lambda i: (0,) * len(shp))
    out = pl.pallas_call(
        functools.partial(_int8_kernel, act=act),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
            full((d, c)), full((c,)), full((c,)),
        ],
        out_specs=pl.BlockSpec((bb, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nt * bb, c), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), w_q, scale.astype(jnp.float32),
      b.astype(jnp.float32))
    return out[:B]
