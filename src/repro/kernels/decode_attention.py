"""Pallas TPU kernel for one-token decode attention against a (possibly
sliding-window) KV cache — the decode_32k/long_500k hot spot.

Per (batch*head) grid cell the query is a single row; the cache streams
through VMEM in ``block_w`` slot tiles with online-softmax accumulation, so
the (W,) score vector never reaches HBM and invalid slots (slot_pos < 0,
future, or out-of-window) are masked inside the tile.  The GQA expansion
happens at the wrapper level (kv heads broadcast to q heads), matching
``models/attention.decode_attention`` semantics exactly.

VMEM per step at defaults (block_w=512, hd=128): k/v tiles 2*128KiB +
q 0.5KiB + scalars — trivially resident; the cache stream is the whole
traffic, which is the roofline lower bound for decode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, sp_ref, pos_ref, o_ref, m_ref, l_ref,
            acc_ref, *, scale: float, block_w: int, window: int, n_w: int):
    wj = pl.program_id(1)

    @pl.when(wj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)               # (1, hd)
    k = k_ref[0].astype(jnp.float32)                 # (bw, hd)
    v = v_ref[0].astype(jnp.float32)
    sp = sp_ref[...]                                 # (bw,) int32 slot pos
    pos = pos_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)[0] * scale
    ok = (sp >= 0) & (sp <= pos)
    if window:
        ok &= sp > pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[0]
    m_cur = jnp.maximum(m_prev, jnp.max(s))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.where(ok, jnp.exp(s - m_cur), 0.0)       # (bw,)
    l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p[None, :], v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[0] = m_cur

    @pl.when(wj == n_w - 1)
    def _done():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[0], 1e-30)).astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_w",
                                             "interpret"))
def decode_attention(q, k, v, slot_pos, pos, *, window: int = 0,
                     block_w: int = 512, interpret: bool = False):
    """q: (BH, hd) one query row per batch*head; k/v: (BH, W, hd);
    slot_pos: (W,) int32; pos: scalar int32. Returns (BH, hd)."""
    BH, hd = q.shape
    W = k.shape[1]
    bw = min(block_w, W)
    assert W % bw == 0
    n_w = W // bw
    kern = functools.partial(_kernel, scale=1.0 / np.sqrt(hd), block_w=bw,
                             window=window, n_w=n_w)
    return pl.pallas_call(
        kern,
        grid=(BH, n_w),
        in_specs=[
            pl.BlockSpec((1, hd), lambda b, j: (b, 0)),
            pl.BlockSpec((1, bw, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bw, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((bw,), lambda b, j: (j,)),
            pl.BlockSpec((1,), lambda b, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, hd), lambda b, j: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, slot_pos, pos[None].astype(jnp.int32))
