"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q/k/v: (B, H, S, hd) -> (B, H, S, hd). fp32 softmax."""
    S = q.shape[2]
    hd = q.shape[-1]
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= j <= i
    if window:
        ok &= (i - j) < window
    scores = jnp.where(ok, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", probs.astype(q.dtype), v)


def fused_distill_loss_ref(x, x_hat, z, z_t, mask, *, lam: float = 0.01,
                           kind: str = "mse"):
    """Paper Eq. 5, mean over the batch. All fp32 math."""
    x = x.astype(jnp.float32)
    x_hat = x_hat.astype(jnp.float32)
    z = z.astype(jnp.float32)
    z_t = z_t.astype(jnp.float32)
    rec = jnp.mean(jnp.square(x - x_hat), axis=-1)
    diff = z - z_t
    if kind == "mae":
        dis = jnp.mean(jnp.abs(diff), axis=-1)
    else:
        dis = jnp.mean(jnp.square(diff), axis=-1)
    return jnp.mean(rec + lam * dis * mask.astype(jnp.float32))


def mlp2_ref(x, w0, b0, w1, b1, *, final_act: bool = False):
    """2-layer SELU MLP oracle for ``kernels.lane_mlp.fused_mlp2`` —
    exactly ``core.autoencoder.mlp_apply`` on a {w0,b0,w1,b1} dict."""
    h = jax.nn.selu(x @ w0 + b0)
    out = h @ w1 + b1
    return jax.nn.selu(out) if final_act else out


def probe_grad_ref(w, b, x, y, rw, *, l2: float = 1e-4):
    """Closed-form gradient oracle for the weighted softmax-CE probe
    (``classifier._weighted_logreg_loss``): returns (loss, dW, db)."""
    logits = x @ w + b
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    denom = jnp.maximum(jnp.sum(rw), 1.0)
    loss = jnp.sum((lse - gold) * rw) / denom + l2 * jnp.sum(jnp.square(w))
    p = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(y, w.shape[1], dtype=p.dtype)
    g = (p - onehot) * (rw / denom)[:, None]
    return loss, x.T @ g + 2.0 * l2 * w, jnp.sum(g, axis=0)


def int8_matmul_ref(x, w_q, scale, b):
    """Weight-only int8 oracle: dequantize per output channel, matmul."""
    return x @ (w_q.astype(jnp.float32) * scale[None, :]) + b


def ssd_chunk_ref(x, dt, A, Bm, Cm):
    """Sequential (step-by-step) SSD oracle.
    x: (B,S,H,P), dt: (B,S,H), A: (H,), Bm/Cm: (B,S,G,N) with G dividing H.
    Returns y: (B,S,H,P)."""
    B_, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)
    Ch = jnp.repeat(Cm, rep, axis=2)

    def step(h, t):
        xt, dtt, Bt, Ct = t
        decay = jnp.exp(dtt * A)[..., None, None]       # (B,H,1,1)
        upd = jnp.einsum("bh,bhn,bhp->bhnp", dtt, Bt, xt)
        h = h * decay + upd
        y = jnp.einsum("bhn,bhnp->bhp", Ct, h)
        return h, y

    h0 = jnp.zeros((B_, H, N, P), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1)
