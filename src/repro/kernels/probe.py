"""Fused weighted softmax-CE probe step (the k-fold CV logreg) as a
Pallas kernel.

The CV probe (``classifier``) runs 300 Adam steps per fold whose body is
two GEMV-shaped matmuls (``x @ w`` then ``x.T @ g``) plus a softmax —
memory-bound on re-reading ``x``.  This kernel fuses the whole gradient
step: one pass over a batch tile produces the weighted-CE loss partial,
``dW`` partial and ``db`` partial together, so ``x`` is read once per
step instead of once per op.

Fold/seed lanes enter through ``jax.vmap`` exactly as in
``kernels.lane_mlp``: the ``pallas_call`` batching rule prepends the
vmapped axis as the OUTERMOST grid dimension, so all k folds x S seeds
run as rows of one lane-major (lanes, batch_tiles) grid.  The full-row
weight formulation makes that possible — every fold sees the SAME
``x``/``y`` and differs only in its 0/1 row-weight vector (zero for the
fold's own test rows and padding), so dead rows are exactly inert.

Per-tile partials (loss, dW, db) are written on the leading grid axis
and reduced outside the kernel — batching-safe by construction, like the
lane-MLP backward.  Row weights arrive PRE-normalized (the wrapper
divides by ``max(sum(rw), 1)``) so tiles need no global reduction; the
L2 term is added outside.  Matches ``kernels.ref.probe_grad_ref``, i.e.
the autodiff gradient of ``classifier._weighted_logreg_loss``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _probe_kernel(x_ref, y_ref, rwn_ref, w_ref, b_ref,
                  loss_ref, dw_ref, db_ref):
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    rwn = rwn_ref[...].astype(jnp.float32)
    logits = jnp.dot(x, w, preferred_element_type=jnp.float32) \
        + b_ref[...].astype(jnp.float32)
    # stable logsumexp + softmax sharing one max/exp evaluation
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    se = jnp.sum(e, axis=-1, keepdims=True)
    lse = jnp.log(se[:, 0]) + m[:, 0]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
              == y_ref[...][:, None]).astype(jnp.float32)
    gold = jnp.sum(logits * onehot, axis=-1)
    loss_ref[0, 0] = jnp.sum((lse - gold) * rwn)
    g = (e / se - onehot) * rwn[:, None]
    dw_ref[0] = jnp.dot(x.T, g, preferred_element_type=jnp.float32)
    db_ref[0] = jnp.sum(g, axis=0)


def _probe_call(x, y, rwn, w, b, block_b: int, interpret: bool):
    B, d = x.shape
    c = w.shape[1]
    pad = (-B) % block_b
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        y = jnp.pad(y, ((0, pad),))
        rwn = jnp.pad(rwn, ((0, pad),))  # zero weight -> padded rows inert
    Bp = B + pad
    nt = Bp // block_b
    full = lambda shp: pl.BlockSpec(shp, lambda i: (0,) * len(shp))
    lossp, dwp, dbp = pl.pallas_call(
        _probe_kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            full((d, c)), full((c,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, d, c), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, c), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nt, 1), jnp.float32),
            jax.ShapeDtypeStruct((nt, d, c), jnp.float32),
            jax.ShapeDtypeStruct((nt, c), jnp.float32),
        ],
        interpret=interpret,
    )(x, y, rwn, w, b)
    return jnp.sum(lossp), jnp.sum(dwp, axis=0), jnp.sum(dbp, axis=0)


@functools.partial(jax.jit, static_argnames=("l2", "block_b", "interpret"))
def probe_grad_step(w, b, x, y, rw, *, l2: float = 1e-4,
                    block_b: int = 128, interpret: bool = False):
    """One fused probe gradient step: returns (loss, dW, db).

    w: (d, C); b: (C,); x: (n, d); y: (n,) int labels; rw: (n,)
    row weights (0 disables a row exactly).  Semantics pinned by
    ``kernels.ref.probe_grad_ref``.  Fold/seed lanes via ``jax.vmap``
    with ``in_axes=(0, 0, None, None, 0)``."""
    denom = jnp.maximum(jnp.sum(rw), 1.0)
    rwn = (rw / denom).astype(jnp.float32)
    loss, dw, db = _probe_call(x, y.astype(jnp.int32), rwn, w, b,
                               int(block_b), bool(interpret))
    loss = loss + l2 * jnp.sum(jnp.square(w))
    return loss, dw + 2.0 * l2 * w.astype(jnp.float32), db
