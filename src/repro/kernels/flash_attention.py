"""Pallas TPU flash attention (causal / sliding-window) with online softmax.

Grid: (batch*heads, q_blocks, kv_blocks); the kv dimension is the innermost
(sequential, "arbitrary") axis — fp32 running max / denominator / output
accumulator live in VMEM scratch across kv steps.  Block sizes default to
128x128 (MXU tile aligned); the head dim rides whole in VMEM.

VMEM budget per step (defaults, hd=128, fp32 scratch):
  q/k/v blocks 3 * 128*128*2B = 96KiB, acc 128*128*4B = 64KiB,
  m/l 2*128*4B = 1KiB  -> ~161KiB of ~16MiB VMEM: safely resident, leaving
room for double-buffered HBM->VMEM pipelining of the k/v streams.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, block_q: int, block_k: int, causal: bool,
                 window: int, n_kv: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                       # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                       # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    cols = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        ok &= cols <= rows
    if window:
        ok &= (rows - cols) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]                                    # (bq,)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(ok, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(kj == n_kv - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q/k/v: (B, H, S, hd) -> (B, H, S, hd)."""
    B, H, S, hd = q.shape
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k
    qf = q.reshape(B * H, S, hd)
    kf = k.reshape(B * H, S, hd)
    vf = v.reshape(B * H, S, hd)

    kern = functools.partial(
        _attn_kernel, scale=1.0 / np.sqrt(hd), block_q=block_q,
        block_k=block_k, causal=causal, window=window, n_kv=nk)
    out = pl.pallas_call(
        kern,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),        # running max
            pltpu.VMEM((block_q,), jnp.float32),        # denominator
            pltpu.VMEM((block_q, hd), jnp.float32),     # output acc
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd)
