# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.


def tpu_compiler_params(**kwargs):
    """Version-portable ``pltpu.*CompilerParams`` constructor: jax <= 0.4.x
    ships ``TPUCompilerParams``, newer releases renamed it ``CompilerParams``.
    Raises a descriptive error instead of a NoneType crash inside
    ``pallas_call`` when neither exists."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or \
        getattr(pltpu, "TPUCompilerParams", None)
    if cls is None:
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
            "TPUCompilerParams; this jax version is unsupported by "
            "repro.kernels")
    return cls(**kwargs)
