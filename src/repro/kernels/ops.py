"""Jit'd public wrappers around the Pallas kernels.

On the CPU container the kernels execute via ``interpret=True`` (Pallas
TPU lowering needs real TPUs); on TPU set ``repro.kernels.ops.INTERPRET =
False`` (or leave the default auto-detection) for compiled execution.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import distill_loss as _dl
from repro.kernels import flash_attention as _fa

INTERPRET = jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    """q/k/v: (B, S, H, hd) [model layout] -> (B, S, H, hd)."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    S = qt.shape[2]
    bq = min(block_q, S)
    bk = min(block_k, S)
    out = _fa.flash_attention(qt, kt, vt, causal=causal, window=window,
                              block_q=bq, block_k=bk, interpret=INTERPRET)
    return jnp.swapaxes(out, 1, 2)


def fused_distill_rows(x, x_hat, z, z_t, mask, *, lam: float = 0.01,
                       kind: str = "mse"):
    """Per-row Eq. 5 losses (differentiable; closed-form custom VJP)."""
    return _dl.fused_distill_rows(x, x_hat, z, z_t, mask, lam=lam, kind=kind,
                                  interpret=INTERPRET)


def fused_distill_loss(x, x_hat, z, z_t, mask, *, lam: float = 0.01,
                       kind: str = "mse"):
    return jnp.mean(fused_distill_rows(x, x_hat, z, z_t, mask, lam=lam,
                                       kind=kind))


def fused_mlp2(x, w0, b0, w1, b1, *, final_act: bool = False,
               block_b: int = 128):
    """Fused 2-layer SELU MLP step (differentiable; closed-form custom
    VJP).  Lane axis enters the kernel grid via ``jax.vmap``."""
    from repro.kernels import lane_mlp as _lm
    return _lm.fused_mlp2(x, w0, b0, w1, b1, final_act=final_act,
                          block_b=block_b, interpret=INTERPRET)


def fused_lane_mlp2(xs, w0s, b0s, w1s, b1s, live, *,
                    final_act: bool = False, block_b: int = 128):
    """Explicit lane-stacked fused MLP: (L, B, din) on a lane-major grid;
    dead lanes (live=0) produce exact zeros."""
    from repro.kernels import lane_mlp as _lm
    return _lm.fused_lane_mlp2(xs, w0s, b0s, w1s, b1s, live,
                               final_act=final_act, block_b=block_b,
                               interpret=INTERPRET)


def probe_grad_step(w, b, x, y, rw, *, l2: float = 1e-4,
                    block_b: int = 128):
    """Fused weighted softmax-CE probe step: (loss, dW, db) in one pass."""
    from repro.kernels import probe as _pr
    return _pr.probe_grad_step(w, b, x, y, rw, l2=l2, block_b=block_b,
                               interpret=INTERPRET)


def int8_matmul(x, w_q, scale, b, *, act: str = "none",
                block_b: int = 128):
    """Weight-only int8 matmul with fused per-channel dequant (+ optional
    fused SELU) — the quantized serving path's GEMM."""
    from repro.kernels import int8_matmul as _i8
    return _i8.int8_matmul(x, w_q, scale, b, act=act, block_b=block_b,
                           interpret=INTERPRET)


def decode_attention(q, k, v, slot_pos, pos, *, window: int = 0,
                     block_w: int = 512):
    """One-token cache attention. q: (B, H, hd); k/v: (B, W, H, hd) with kv
    heads already GQA-expanded; slot_pos: (W,); pos: scalar."""
    from repro.kernels import decode_attention as _da
    B, H, hd = q.shape
    W = k.shape[1]
    qf = q.reshape(B * H, hd)
    kf = jnp.swapaxes(k, 1, 2).reshape(B * H, W, hd)
    vf = jnp.swapaxes(v, 1, 2).reshape(B * H, W, hd)
    out = _da.decode_attention(qf, kf, vf, slot_pos, pos, window=window,
                               block_w=min(block_w, W), interpret=INTERPRET)
    return out.reshape(B, H, hd)
