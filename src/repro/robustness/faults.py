"""Seeded fault plans: operational failures of the passive party,
injectable into BOTH halves of the system that depend on it.

APC-VFL's one-shot exchange makes the passive party a single point of
failure in two distinct regimes:

* **training time** — the exchange itself degrades: the passive party
  drops out before sending (``dropout`` -> the protocol's active-only
  ablation), sends latents from an OLD checkpoint (``stale``, ``epochs``
  deep into training instead of converged), or its features have drifted
  since alignment (``drift`` — latent-space perturbation scaled to the
  latents' RMS).  ``run_faulted_apcvfl`` maps a plan's
  ``stage="exchange"`` events onto the pipeline's ``exchange=`` hook (or
  ``ablation=True``), so a faulted run IS a normal run with a different
  transform — same engine, same accounting.

* **serving time** — the trained system is live and the passive party
  vanishes mid-stream: ``t_ms``-stamped events ride the versioned
  ``RepresentationCache`` lifecycle (``serve.runtime``): dropout/stale/
  drift invalidate the tenant's cache at the virtual timestamp (every
  subsequent lookup misses -> the engine serves its active-only fallback,
  NEVER stale latents), ``recover`` re-installs the bundle's latents with
  a version bump.  ``ServingRuntime.run(stream, faults=plan)`` applies
  events at dispatch boundaries and reports per-tenant fault accounting;
  ``robustbench`` gates on zero collaborative dispatches while faulted.

A ``FaultPlan`` is a seeded, JSON-round-trippable value
(``examples/faults/*.json``, ``launch.serve_vfl --fault plan.json``), so
a fault scenario is as declarative and reproducible as an experiment
spec.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.apcvfl_paper import TABULAR as HP
from repro.core import autoencoder as ae
from repro.core import comm, pipeline, training
from repro.core.psi import psi
from repro.experiments.results import RunResult

FAULT_KINDS = ("dropout", "stale", "drift", "recover")
TRAIN_STAGES = ("exchange",)

# domain separator for drift noise (distinct from defense.EXCHANGE_SALT:
# a drifted AND defended exchange must not reuse noise)
DRIFT_SALT = 0xD217


@dataclass(frozen=True)
class FaultEvent:
    """One failure: serving-time when ``t_ms`` is set (virtual clock),
    training-time when ``stage`` is set.  ``tenant`` routes serving
    events; ``epochs`` parameterizes ``stale`` (how far the stale
    checkpoint got); ``drift`` the drift magnitude (fraction of latent
    RMS)."""
    kind: str
    t_ms: Optional[float] = None
    stage: Optional[str] = None
    tenant: Optional[str] = None
    epochs: Optional[int] = None
    drift: Optional[float] = None

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"fault kind must be one of {FAULT_KINDS}, "
                             f"got {self.kind!r}")
        if (self.t_ms is None) == (self.stage is None):
            raise ValueError(
                f"FaultEvent({self.kind!r}) needs exactly one trigger: "
                f"t_ms (serving) or stage (training)")
        if self.stage is not None and self.stage not in TRAIN_STAGES:
            raise ValueError(f"fault stage must be one of {TRAIN_STAGES}, "
                             f"got {self.stage!r}")
        if self.stage is not None and self.kind == "recover":
            raise ValueError("recover is a serving-time event (t_ms); the "
                             "one-shot exchange has nothing to recover to")

    def to_dict(self) -> dict:
        out = {"kind": self.kind}
        for k in ("t_ms", "stage", "tenant", "epochs", "drift"):
            v = getattr(self, k)
            if v is not None:
                out[k] = v
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "FaultEvent":
        allowed = {"kind", "t_ms", "stage", "tenant", "epochs", "drift"}
        unknown = set(d) - allowed
        if unknown:
            raise ValueError(f"FaultEvent: unknown keys {sorted(unknown)}")
        if "kind" not in d:
            raise ValueError("FaultEvent: missing 'kind'")
        return cls(**d)


@dataclass(frozen=True)
class FaultPlan:
    """A named, seeded sequence of fault events (JSON round-trippable)."""
    name: str
    seed: int = 0
    events: Tuple[FaultEvent, ...] = ()

    def serving_events(self) -> List[FaultEvent]:
        return sorted((e for e in self.events if e.t_ms is not None),
                      key=lambda e: e.t_ms)

    def training_events(self) -> List[FaultEvent]:
        return [e for e in self.events if e.stage is not None]

    def to_dict(self) -> dict:
        return {"name": self.name, "seed": self.seed,
                "events": [e.to_dict() for e in self.events]}

    @classmethod
    def from_dict(cls, d: dict) -> "FaultPlan":
        unknown = set(d) - {"name", "seed", "events"}
        if unknown:
            raise ValueError(f"FaultPlan: unknown keys {sorted(unknown)}")
        return cls(name=d.get("name", "plan"), seed=int(d.get("seed", 0)),
                   events=tuple(FaultEvent.from_dict(e)
                                for e in d.get("events", [])))

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        return cls.from_dict(json.loads(text))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path) as f:
            return cls.from_json(f.read())


# ---------------------------------------------------------------------------
# training-time injection: faults as exchange transforms
# ---------------------------------------------------------------------------

class StaleExchange:
    """The passive party sends latents from an OLD checkpoint: the wire
    carries ``z_stale`` (same shape, same fp32 bytes) instead of the
    converged latents the protocol expects."""

    def __init__(self, z_stale):
        self.z_stale = jnp.asarray(z_stale, jnp.float32)

    def exchange(self, channel: comm.Channel, what: str, z, *,
                 seed: int = 0, link: int = 0,
                 direction: str = comm.UPLINK):
        if self.z_stale.shape != z.shape:
            raise ValueError(
                f"StaleExchange: stale latents {self.z_stale.shape} do "
                f"not match the live exchange {z.shape}")
        channel.send_array(what, self.z_stale, direction=direction)
        return self.z_stale


class DriftExchange:
    """Feature drift since alignment, modeled in latent space: the sent
    latents are perturbed by seeded Gaussian noise at ``magnitude`` times
    their RMS (deterministic per run seed and passive link)."""

    def __init__(self, magnitude: float = 0.5):
        if magnitude < 0:
            raise ValueError(f"drift magnitude must be >= 0, "
                             f"got {magnitude}")
        self.magnitude = float(magnitude)

    def exchange(self, channel: comm.Channel, what: str, z, *,
                 seed: int = 0, link: int = 0,
                 direction: str = comm.UPLINK):
        z = jnp.asarray(z, jnp.float32)
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(seed), DRIFT_SALT), link)
        rms = jnp.sqrt(jnp.mean(jnp.square(z)) + 1e-12)
        zd = z + self.magnitude * rms * jax.random.normal(key, z.shape,
                                                          jnp.float32)
        channel.send_array(what, zd, direction=direction)
        return zd


def _stale_passive_latents(sc, *, epochs: int, seed: int,
                           batch_size: int, lr: float) -> np.ndarray:
    """Latents of the aligned rows from a short-run (``epochs``) twin of
    the passive g1 — same init key and lane seed as the pipeline's
    g1_passive, stopped early: an honest 'old checkpoint'."""
    xp = np.asarray(sc.passive.x)
    key = jax.random.split(jax.random.PRNGKey(seed), 4)[1]   # g1_passive's
    params = ae.init_autoencoder(key, ae.table3_encoder("g1_passive",
                                                        xp.shape[1]))
    r = training.train(params, {"x": xp}, ae.recon_loss,
                       batch_size=batch_size, max_epochs=epochs,
                       patience=epochs, lr=lr, seed=seed + 1)
    _, _, idx_p = psi(sc.active.ids, sc.passive.ids)
    return np.asarray(ae.encode(r.params, jnp.asarray(xp[idx_p])))


def run_faulted_apcvfl(sc, plan: FaultPlan, *, lam: float = HP.lam,
                       kind: str = HP.kind, seed: int = 0,
                       batch_size: int = HP.batch_size,
                       max_epochs: int = HP.max_epochs,
                       patience: int = HP.patience, lr: float = HP.lr,
                       use_kernel: bool = False) -> RunResult:
    """The full protocol under the plan's training-time (``stage=
    "exchange"``) events.  Severity order when a plan stacks kinds:
    ``dropout`` (no exchange happens — the run IS the active-only
    ablation, the engine's fallback) > ``stale`` > ``drift``.  Metrics
    carry ``fault_*`` flags so degraded runs are self-describing in tidy
    records."""
    events = plan.training_events()
    flags = {"fault_dropout": 0.0, "fault_stale": 0.0, "fault_drift": 0.0}
    transform = None
    if any(e.kind == "dropout" for e in events):
        flags["fault_dropout"] = 1.0
        res = pipeline.run_apcvfl(sc, seed=seed, lam=lam, kind=kind,
                                  batch_size=batch_size,
                                  max_epochs=max_epochs, patience=patience,
                                  lr=lr, use_kernel=use_kernel,
                                  ablation=True)
    else:
        stale = [e for e in events if e.kind == "stale"]
        drift = [e for e in events if e.kind == "drift"]
        if stale:
            flags["fault_stale"] = 1.0
            z_stale = _stale_passive_latents(
                sc, epochs=int(stale[0].epochs or 1), seed=seed,
                batch_size=batch_size, lr=lr)
            transform = StaleExchange(z_stale)
        elif drift:
            flags["fault_drift"] = 1.0
            transform = DriftExchange(float(drift[0].drift
                                            if drift[0].drift is not None
                                            else 0.5))
        res = pipeline.run_apcvfl(sc, seed=seed, lam=lam, kind=kind,
                                  batch_size=batch_size,
                                  max_epochs=max_epochs, patience=patience,
                                  lr=lr, use_kernel=use_kernel,
                                  exchange=transform)
    res.method = "apcvfl_faulted"
    res.metrics = dict(res.metrics)
    res.metrics.update(flags)
    return res
