"""Robustness & privacy subsystem: hardened exchange defenses
(``defense``), the attack registry (``attacks``), and seeded fault plans
(``faults``) — all keyed on APC-VFL's single latent exchange and the
serving cache lifecycle it feeds."""
from repro.robustness import attacks, defense, faults  # noqa: F401
from repro.robustness.attacks import (  # noqa: F401
    AttackReport, AttackSurface, available_attacks, build_surface,
    build_surfaces, get_attack, leakage_profile, register_attack,
    run_attack)
from repro.robustness.defense import (  # noqa: F401
    Chain, ClippedNoise, ExchangeTransform, Quantize, dp_frontier,
    make_transform, run_apcvfl_dp, run_apcvfl_dp_replicated)
from repro.robustness.faults import (  # noqa: F401
    DriftExchange, FaultEvent, FaultPlan, StaleExchange,
    run_faulted_apcvfl)
