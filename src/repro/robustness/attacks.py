"""Attack registry: the adversarial side of the utility-vs-leakage
frontier.

``core.privacy`` pioneered one attack (representation inversion); this
module generalizes it into a registry of honest-but-curious attacks that
all consume the same ``AttackSurface`` — everything the active party (or
an eavesdropper on the one exchange) actually observes — and all emit the
same ``AttackReport`` schema with a normalized ``leakage`` in [0, 1]
(0 = chance level, 1 = total disclosure).  One schema means one frontier:
``benchmarks/robustbench.py`` plots any attack's leakage against any
defense's utility without per-attack glue.

Attacks (each also wrapped as a ``@register_method`` experiment runner so
spec JSONs can sweep them):

* ``inversion`` — port of ``core.privacy``: invert the exchanged latents
  back to private features with an n_aux-pair auxiliary budget; leakage =
  clamped mean R^2.
* ``label_leak`` — label leakage against the distillation targets: fit a
  probe z -> y on n_aux labeled rows of the teacher latents (or the raw
  exchange) and measure advantage over the majority class; leakage =
  (acc - majority) / (1 - majority).
* ``membership`` — alignment-membership inference: distinguish aligned
  from non-aligned passive rows by distance to the exchanged latent
  table; leakage = 2*AUC - 1.  Undefended this is ~total (aligned rows
  match their own latents exactly), making it the sharpest probe of how
  fast a defense closes the exchange.

``build_surfaces`` constructs the surface per defense the lane way: the
transform-independent g1 encoders train ONCE (2 lanes), then every
defense's g2 teacher trains as one lane of a single ``train_lanes``
dispatch — a whole sigma grid of surfaces for one compile per stage.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.apcvfl_paper import TABULAR as HP
from repro.core import autoencoder as ae
from repro.core import classifier as clf
from repro.core import comm, privacy, training
from repro.core.psi import psi
from repro.experiments.results import RunResult
from repro.robustness import defense


# ---------------------------------------------------------------------------
# shared schema
# ---------------------------------------------------------------------------

@dataclass
class AttackReport:
    """One attack's outcome in the shared leakage-metric schema."""
    attack: str
    leakage: float       # normalized [0,1]: 0 = chance / safe, 1 = total
    success: float       # the attack's raw statistic (R^2, accuracy, AUC)
    baseline: float      # that statistic's chance level
    n_aux: int           # attacker's auxiliary budget actually used
    extras: Dict[str, float] = field(default_factory=dict)

    def metrics(self) -> Dict[str, float]:
        out = {"leakage": float(self.leakage),
               "success": float(self.success),
               "baseline": float(self.baseline),
               "n_aux": float(self.n_aux)}
        for k, v in self.extras.items():
            out[k] = float(v)
        return out


@dataclass
class AttackSurface:
    """What the adversary sees after one (possibly defended) run: the
    exchanged latents, the teacher latents distilled from them, the
    passive party's full local latent pool (for membership ground truth),
    and the private targets the attacks try to recover."""
    z_exch: np.ndarray            # (n_al, M) latents as RECEIVED
    x_priv: np.ndarray            # (n_al, D_p) private passive features
    y: np.ndarray                 # (n_al,) active-party labels
    z_pool: np.ndarray            # (n_p, M) clean passive latents, all rows
    member_mask: np.ndarray       # (n_p,) bool: row aligned (exchanged)?
    n_classes: int
    z_teacher: Optional[np.ndarray] = None   # (n_al, M2) g2 latents
    channel: Optional[comm.Channel] = None   # byte-parity with run_apcvfl
    seed: int = 0


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_ATTACKS: Dict[str, Callable[..., AttackReport]] = {}


def register_attack(name: str):
    def deco(fn):
        if name in _ATTACKS:
            raise ValueError(f"attack {name!r} already registered")
        _ATTACKS[name] = fn
        return fn
    return deco


def available_attacks() -> tuple:
    return tuple(sorted(_ATTACKS))


def get_attack(name: str) -> Callable[..., AttackReport]:
    if name not in _ATTACKS:
        raise KeyError(f"unknown attack {name!r}; available: "
                       f"{', '.join(available_attacks())}")
    return _ATTACKS[name]


def run_attack(name: str, surface: AttackSurface, **kw) -> AttackReport:
    return get_attack(name)(surface, **kw)


# ---------------------------------------------------------------------------
# surface construction (lane-batched across defenses)
# ---------------------------------------------------------------------------

def build_surfaces(sc, transforms: Sequence, *, seed: int = 0,
                   include_teacher: bool = True,
                   batch_size: int = HP.batch_size,
                   max_epochs: int = HP.max_epochs,
                   patience: int = HP.patience,
                   lr: float = HP.lr) -> List[AttackSurface]:
    """One ``AttackSurface`` per exchange transform (``None`` = the
    undefended paper protocol).  The g1 encoders — identical across
    defenses — train once as 2 lanes; each defense then gets its own
    channel (PSI + transformed exchange, byte-parity with ``run_apcvfl``)
    and, when ``include_teacher``, its g2 teacher trains as one lane of a
    single ``train_lanes`` dispatch over the whole defense grid."""
    xa, xp = np.asarray(sc.active.x), np.asarray(sc.passive.x)
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, _ = jax.random.split(key, 4)     # pipeline's key layout
    train_kw = dict(batch_size=batch_size, max_epochs=max_epochs,
                    patience=patience, lr=lr)

    ra, rp = training.train_lanes(
        [training.LaneSpec(
            ae.init_autoencoder(k1, ae.table3_encoder("g1_active",
                                                      xa.shape[1])),
            {"x": xa}, seed),
         training.LaneSpec(
            ae.init_autoencoder(k2, ae.table3_encoder("g1_passive",
                                                      xp.shape[1])),
            {"x": xp}, seed + 1)],
        ae.masked_recon_loss, **train_kw)

    z_pool = np.asarray(ae.encode(rp.params, jnp.asarray(xp)),
                        dtype=np.float32)

    cells = []                       # (channel, idx_a, idx_p, z_received)
    for t in transforms:
        ch = comm.Channel()
        _, idx_a, idx_p = psi(sc.active.ids, sc.passive.ids, channel=ch)
        z_clean = jnp.asarray(z_pool[idx_p])
        z_recv = comm.exchange_array(ch, "step1/Z_passive_aligned",
                                     z_clean, transform=t, seed=seed)
        cells.append((ch, idx_a, idx_p, np.asarray(z_recv,
                                                   dtype=np.float32)))

    teachers: List[Optional[np.ndarray]] = [None] * len(cells)
    if include_teacher and cells:
        za_lanes, zj_lanes = [], []
        for (_, idx_a, _, z_recv) in cells:
            za_al = ae.encode(ra.params, jnp.asarray(xa[idx_a]))
            zj_lanes.append(jnp.concatenate(
                [za_al, jnp.asarray(z_recv)], axis=1).astype(jnp.float32))
        g2 = training.train_lanes(
            [training.LaneSpec(
                ae.init_autoencoder(jax.random.fold_in(k3, j),
                                    ae.table3_encoder("g2", zj.shape[1])),
                {"x": zj}, seed + 2)
             for j, zj in enumerate(zj_lanes)],
            ae.masked_recon_loss, **train_kw)
        teachers = [np.asarray(ae.encode(r2.params, zj), dtype=np.float32)
                    for r2, zj in zip(g2, zj_lanes)]

    surfaces = []
    for (ch, idx_a, idx_p, z_recv), z_t in zip(cells, teachers):
        member_mask = np.zeros(len(xp), dtype=bool)
        member_mask[idx_p] = True
        surfaces.append(AttackSurface(
            z_exch=z_recv, x_priv=xp[idx_p], y=np.asarray(sc.active.y)[idx_a],
            z_pool=z_pool, member_mask=member_mask,
            n_classes=sc.n_classes, z_teacher=z_t, channel=ch, seed=seed))
    return surfaces


def build_surface(sc, transform=None, **kw) -> AttackSurface:
    (surface,) = build_surfaces(sc, [transform], **kw)
    return surface


# ---------------------------------------------------------------------------
# the attacks
# ---------------------------------------------------------------------------

@register_attack("inversion")
def attack_inversion(surface: AttackSurface, *, n_aux: int = 64,
                     hidden: int = 128, max_epochs: int = 120,
                     seed: int = 0) -> AttackReport:
    """Representation inversion (``core.privacy`` ported to the shared
    schema): train z -> x_hat on n_aux paired rows, measure held-out mean
    R^2.  leakage = R^2 clamped to [0, 1] (negative R^2 — worse than
    predicting the mean — is the safe regime)."""
    eff = privacy.effective_n_aux(n_aux, len(surface.z_exch))
    rep = privacy.inversion_attack(surface.z_exch, surface.x_priv,
                                   n_aux=eff, hidden=hidden,
                                   max_epochs=max_epochs, seed=seed)
    leak = float(np.clip(rep.r2_mean, 0.0, 1.0))
    return AttackReport(
        attack="inversion", leakage=leak, success=float(rep.r2_mean),
        baseline=0.0, n_aux=eff,
        extras={"r2_mean": rep.r2_mean, "attack_mse": rep.attack_mse,
                "baseline_mse": rep.baseline_mse,
                "n_aux_requested": float(n_aux)})


@register_attack("label_leak")
def attack_label_leak(surface: AttackSurface, *, n_aux: int = 64,
                      target: str = "teacher", steps: int = 300,
                      seed: int = 0) -> AttackReport:
    """Label leakage against the distillation targets: an adversary who
    observes the teacher latents (``target="teacher"`` — what g3 distills
    toward) or the raw exchange (``target="exchange"``) and holds n_aux
    labeled rows fits a logistic probe z -> y; advantage over the
    majority class on held-out rows, normalized, is the leakage."""
    if target not in ("teacher", "exchange"):
        raise ValueError(f"label_leak target must be 'teacher' or "
                         f"'exchange', got {target!r}")
    z = (surface.z_teacher if target == "teacher"
         and surface.z_teacher is not None else surface.z_exch)
    y = surface.y
    eff = privacy.effective_n_aux(n_aux, len(z))
    rng = np.random.RandomState(seed)
    perm = rng.permutation(len(z))
    aux, ev = perm[:eff], perm[eff:]
    params = clf.fit_logreg(jnp.asarray(z[aux]), jnp.asarray(y[aux]),
                            surface.n_classes, steps=steps)
    pred = clf.predict(params, z[ev])
    acc = float((pred == y[ev]).mean())
    majority = float(np.bincount(y[ev],
                                 minlength=surface.n_classes).max()
                     / len(ev))
    adv = (acc - majority) / max(1.0 - majority, 1e-9)
    leak = float(np.clip(adv, 0.0, 1.0))
    return AttackReport(
        attack="label_leak", leakage=leak, success=acc, baseline=majority,
        n_aux=eff, extras={"accuracy": acc, "majority": majority,
                           "n_aux_requested": float(n_aux)})


@register_attack("membership")
def attack_membership(surface: AttackSurface, *, sample: int = 256,
                      seed: int = 0) -> AttackReport:
    """Alignment-membership inference: which of the passive party's rows
    are in the aligned (exchanged) set?  The adversary scores a candidate
    row by the negative distance from its clean latent to the nearest
    exchanged latent — aligned rows sit at distance ~0 when the exchange
    is undefended, so leakage starts near 1 and a working defense must
    pull the row's latent off the exchanged table.  leakage = 2*AUC - 1
    (rank-based AUC over balanced member/non-member samples)."""
    rng = np.random.RandomState(seed)
    mem_idx = np.nonzero(surface.member_mask)[0]
    non_idx = np.nonzero(~surface.member_mask)[0]
    if len(mem_idx) == 0 or len(non_idx) == 0:
        raise ValueError(
            f"attack_membership needs both aligned and non-aligned "
            f"passive rows (got {len(mem_idx)} aligned, {len(non_idx)} "
            f"non-aligned)")
    k = min(int(sample), len(mem_idx), len(non_idx))
    mem = surface.z_pool[rng.choice(mem_idx, k, replace=False)]
    non = surface.z_pool[rng.choice(non_idx, k, replace=False)]

    def scores(c):
        d = ((c[:, None, :] - surface.z_exch[None, :, :]) ** 2).sum(-1)
        return -np.sqrt(d.min(axis=1))

    s_mem, s_non = scores(mem), scores(non)
    diff = s_mem[:, None] - s_non[None, :]
    auc = float((diff > 0).mean() + 0.5 * (diff == 0).mean())
    leak = float(np.clip(2.0 * auc - 1.0, 0.0, 1.0))
    return AttackReport(
        attack="membership", leakage=leak, success=auc, baseline=0.5,
        n_aux=k, extras={"auc": auc, "n_members": float(len(mem_idx))})


# ---------------------------------------------------------------------------
# spec-runnable wrappers (registered in repro.experiments.methods)
# ---------------------------------------------------------------------------

def _attacked_surface(sc, *, sigma, mechanism, clip, quantize, seed,
                      include_teacher, batch_size, max_epochs, patience,
                      lr) -> AttackSurface:
    t = defense.make_transform(sigma=sigma, mechanism=mechanism, clip=clip,
                               quantize=quantize)
    return build_surface(sc, t, seed=seed, include_teacher=include_teacher,
                         batch_size=batch_size, max_epochs=max_epochs,
                         patience=patience, lr=lr)


def _attack_result(name: str, surface: AttackSurface, rep: AttackReport,
                   *, sigma: float, seed: int) -> RunResult:
    metrics = rep.metrics()
    metrics["dp_sigma"] = float(sigma)
    ch = surface.channel
    return RunResult(method=name, metrics=metrics, rounds=1, epochs={},
                     comm=ch.summary(), seed=seed,
                     z_dim=surface.z_exch.shape[1], channels=(ch,))


def run_attack_inversion(sc, *, sigma: float = 0.0,
                         mechanism: str = "gaussian",
                         clip: Optional[float] = None,
                         quantize: Optional[str] = None, n_aux: int = 64,
                         hidden: int = 128,
                         batch_size: int = HP.batch_size,
                         max_epochs: int = HP.max_epochs,
                         patience: int = HP.patience, lr: float = HP.lr,
                         seed: int = 0) -> RunResult:
    s = _attacked_surface(sc, sigma=sigma, mechanism=mechanism, clip=clip,
                          quantize=quantize, seed=seed,
                          include_teacher=False, batch_size=batch_size,
                          max_epochs=max_epochs, patience=patience, lr=lr)
    rep = attack_inversion(s, n_aux=n_aux, hidden=hidden,
                           max_epochs=max_epochs, seed=seed)
    return _attack_result("attack_inversion", s, rep, sigma=sigma, seed=seed)


def run_attack_label_leak(sc, *, sigma: float = 0.0,
                          mechanism: str = "gaussian",
                          clip: Optional[float] = None,
                          quantize: Optional[str] = None, n_aux: int = 64,
                          target: str = "teacher", steps: int = 300,
                          batch_size: int = HP.batch_size,
                          max_epochs: int = HP.max_epochs,
                          patience: int = HP.patience, lr: float = HP.lr,
                          seed: int = 0) -> RunResult:
    s = _attacked_surface(sc, sigma=sigma, mechanism=mechanism, clip=clip,
                          quantize=quantize, seed=seed,
                          include_teacher=(target == "teacher"),
                          batch_size=batch_size, max_epochs=max_epochs,
                          patience=patience, lr=lr)
    rep = attack_label_leak(s, n_aux=n_aux, target=target, steps=steps,
                            seed=seed)
    return _attack_result("attack_label_leak", s, rep, sigma=sigma,
                          seed=seed)


def run_attack_membership(sc, *, sigma: float = 0.0,
                          mechanism: str = "gaussian",
                          clip: Optional[float] = None,
                          quantize: Optional[str] = None,
                          sample: int = 256,
                          batch_size: int = HP.batch_size,
                          max_epochs: int = HP.max_epochs,
                          patience: int = HP.patience, lr: float = HP.lr,
                          seed: int = 0) -> RunResult:
    s = _attacked_surface(sc, sigma=sigma, mechanism=mechanism, clip=clip,
                          quantize=quantize, seed=seed,
                          include_teacher=False, batch_size=batch_size,
                          max_epochs=max_epochs, patience=patience, lr=lr)
    rep = attack_membership(s, sample=sample, seed=seed)
    return _attack_result("attack_membership", s, rep, sigma=sigma,
                          seed=seed)


def leakage_profile(sc, transforms: Sequence, *, seed: int = 0,
                    n_aux: int = 64,
                    batch_size: int = HP.batch_size,
                    max_epochs: int = HP.max_epochs,
                    patience: int = HP.patience,
                    lr: float = HP.lr) -> List[Dict[str, AttackReport]]:
    """Every registered attack against every defense: one dict of
    ``AttackReport`` per transform, surfaces built lane-batched.  The
    leakage half of ``robustbench``'s frontier."""
    surfaces = build_surfaces(sc, transforms, seed=seed,
                              include_teacher=True, batch_size=batch_size,
                              max_epochs=max_epochs, patience=patience,
                              lr=lr)
    out = []
    for s in surfaces:
        out.append({
            "inversion": attack_inversion(s, n_aux=n_aux, seed=seed),
            "label_leak": attack_label_leak(s, n_aux=n_aux, seed=seed),
            "membership": attack_membership(s, seed=seed),
        })
    return out
