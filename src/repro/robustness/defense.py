"""Hardened one-shot exchange: composable ``ExchangeTransform``s applied
at APC-VFL's single latent exchange (``comm.exchange_array``).

APC-VFL's privacy surface is exactly one message: the passive party's
aligned-row latents.  Every defense therefore composes at that one point —
the transform runs at the SENDER, the channel accounts the transformed
wire bytes (per dtype — an int8 payload is 1 B/element, a sign payload
1 bit), and the active party only ever consumes what the transform
returns (the receiver's reconstruction).  Three building blocks:

* ``ClippedNoise`` — per-row norm clipping (L2 for the Gaussian
  mechanism, L1 for Laplace) followed by additive noise with scale
  ``sigma * clip`` (``clip=None`` skips clipping; sensitivity 1): the
  standard clipped-DP shape for representation perturbation.
* ``Quantize`` — per-feature symmetric quantization: ``"int8"`` (scale =
  absmax/127, 4x smaller wire) or ``"sign"`` (1-bit sign times the
  per-feature mean magnitude, ~32x smaller).
* ``Chain`` — stages applied in order at the sender; only the LAST
  stage's wire form is sent (earlier stages are local pre-processing),
  e.g. clip+noise THEN int8 = a DP'd quantized exchange.

``make_transform`` builds the chain from plain keyword knobs and returns
``None`` when every defense is off — so ``run_apcvfl_dp(sigma=0)`` takes
the exact ``exchange=None`` code path of ``run_apcvfl`` and is
bit-identical to it (pinned in ``tests/test_robustness.py``).

Noise randomness derives from ``fold_in(PRNGKey(seed), SALT)`` plus the
passive-link index — a pure function of the run's seed, never of lane
position — so the replicated lane paths reproduce the sequential runs
exactly, and ``dp_frontier`` can run a WHOLE sigma grid as lanes of one
vmapped scan per protocol stage (the transforms differ only in the cheap
eager exchange between stages).
"""
from __future__ import annotations

from math import ceil
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.apcvfl_paper import TABULAR as HP
from repro.core import comm, multiparty, pipeline
from repro.core.multiparty import VFLScenarioK
from repro.experiments.results import RunResult

# domain separator for exchange randomness: keyed off the run seed so the
# sequential and replicated paths derive identical noise for a given seed
EXCHANGE_SALT = 0xD0_5E

MECHANISMS = ("gaussian", "laplace")
QUANT_MODES = ("int8", "sign")


class ExchangeTransform:
    """Base: subclasses implement ``apply(z, key) -> (received, wire)``
    where ``received`` is the fp32 array the active party reconstructs
    and ``wire`` lists the actually-transmitted parts as ``(name_suffix,
    nbytes, dtype)``.  ``exchange`` (the ``comm.exchange_array`` hook)
    derives the deterministic key, accounts the wire parts, and returns
    the received array."""

    def apply(self, z, key) -> Tuple[jnp.ndarray, List[tuple]]:
        raise NotImplementedError

    def exchange(self, channel: comm.Channel, what: str, z, *,
                 seed: int = 0, link: int = 0,
                 direction: str = comm.UPLINK):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(seed), EXCHANGE_SALT),
            link)
        received, wire = self.apply(jnp.asarray(z, jnp.float32), key)
        for suffix, nbytes, dtype in wire:
            channel.send(what + suffix, nbytes, direction=direction,
                         dtype=dtype)
        return received.astype(jnp.float32)


class ClippedNoise(ExchangeTransform):
    """Row-norm clipping + additive DP noise on the exchanged latents.

    ``clip`` bounds each row's L2 (gaussian) or L1 (laplace) norm — the
    per-row sensitivity — and the noise scale is ``sigma * clip``
    (``clip=None``: no clipping, sensitivity taken as 1.0).  The wire
    form stays fp32 (noise does not compress)."""

    def __init__(self, sigma: float = 0.0, mechanism: str = "gaussian",
                 clip: Optional[float] = None):
        if mechanism not in MECHANISMS:
            raise ValueError(f"mechanism must be one of {MECHANISMS}, "
                             f"got {mechanism!r}")
        if sigma < 0:
            raise ValueError(f"sigma must be >= 0, got {sigma}")
        if clip is not None and clip <= 0:
            raise ValueError(f"clip must be positive, got {clip}")
        self.sigma = float(sigma)
        self.mechanism = mechanism
        self.clip = None if clip is None else float(clip)

    def apply(self, z, key):
        if self.clip is not None:
            ord_ = 2 if self.mechanism == "gaussian" else 1
            norms = jnp.linalg.norm(z, ord=ord_, axis=1, keepdims=True)
            z = z * jnp.minimum(jnp.float32(1.0),
                                self.clip / jnp.maximum(norms, 1e-12))
        if self.sigma > 0.0:
            scale = self.sigma * (1.0 if self.clip is None else self.clip)
            draw = (jax.random.normal if self.mechanism == "gaussian"
                    else jax.random.laplace)
            z = z + scale * draw(key, z.shape, jnp.float32)
        return z, [("", int(z.size) * 4, "float32")]


class Quantize(ExchangeTransform):
    """Per-feature symmetric quantization of the exchanged latents.

    ``"int8"``: scale_j = absmax_j / 127, payload one int8 per element
    plus fp32 scales.  ``"sign"``: 1 bit per element (packed —
    ceil(n*m/8) wire bytes, dtype ``"sign1"``) times the per-feature mean
    magnitude.  The receiver consumes the dequantized fp32 array."""

    def __init__(self, mode: str = "int8"):
        if mode not in QUANT_MODES:
            raise ValueError(f"quantize mode must be one of {QUANT_MODES}, "
                             f"got {mode!r}")
        self.mode = mode

    def apply(self, z, key):
        del key                                  # deterministic transform
        n, m = z.shape
        if self.mode == "int8":
            scale = jnp.maximum(jnp.max(jnp.abs(z), axis=0),
                                1e-12) / 127.0
            q = jnp.clip(jnp.round(z / scale), -127.0, 127.0)
            deq = q.astype(jnp.float32) * scale
            wire = [("/q8", n * m, "int8"), ("/scale", m * 4, "float32")]
        else:
            scale = jnp.mean(jnp.abs(z), axis=0)
            deq = jnp.sign(z) * scale
            wire = [("/sign", ceil(n * m / 8), "sign1"),
                    ("/scale", m * 4, "float32")]
        return deq, wire


class Chain(ExchangeTransform):
    """Stages applied in order at the sender; the LAST stage's wire parts
    are what actually crosses the link (earlier stages are local)."""

    def __init__(self, stages: Sequence[ExchangeTransform]):
        if len(stages) < 2:
            raise ValueError("Chain needs >= 2 stages; use the stage "
                             "directly otherwise")
        self.stages = tuple(stages)

    def apply(self, z, key):
        wire = [("", int(z.size) * 4, "float32")]
        for j, stage in enumerate(self.stages):
            z, wire = stage.apply(z, jax.random.fold_in(key, j))
        return z, wire


def make_transform(*, sigma: float = 0.0, mechanism: str = "gaussian",
                   clip: Optional[float] = None,
                   quantize: Optional[str] = None
                   ) -> Optional[ExchangeTransform]:
    """Build the defense chain from plain knobs; ``None`` when every
    defense is off — the identity path, so a sigma-0 run stays
    bit-identical to the undefended protocol."""
    stages: List[ExchangeTransform] = []
    if sigma > 0.0 or clip is not None:
        stages.append(ClippedNoise(sigma, mechanism, clip))
    elif mechanism not in MECHANISMS:      # validate even when unused
        raise ValueError(f"mechanism must be one of {MECHANISMS}, "
                         f"got {mechanism!r}")
    if quantize is not None:
        stages.append(Quantize(quantize))
    if not stages:
        return None
    return stages[0] if len(stages) == 1 else Chain(stages)


# ---------------------------------------------------------------------------
# the defended protocol as a registered method
# ---------------------------------------------------------------------------

def _tag_dp(res: RunResult, *, sigma: float) -> RunResult:
    res.method = "apcvfl_dp"
    res.metrics = dict(res.metrics)
    res.metrics["dp_sigma"] = float(sigma)
    res.metrics["exchange_bytes"] = float(
        res.comm.get("by_stage", {}).get("step1", 0))
    return res


def run_apcvfl_dp(sc, *, sigma: float = 0.0, mechanism: str = "gaussian",
                  clip: Optional[float] = None,
                  quantize: Optional[str] = None, lam: float = HP.lam,
                  kind: str = HP.kind, seed: int = 0,
                  batch_size: int = HP.batch_size,
                  max_epochs: int = HP.max_epochs,
                  patience: int = HP.patience, lr: float = HP.lr,
                  use_kernel: bool = False,
                  ablation: bool = False) -> RunResult:
    """The full protocol with a hardened exchange (``@register_method
    ("apcvfl_dp")``): same training surface as ``apcvfl`` plus the
    defense knobs.  Routes K-party scenarios to ``run_apcvfl_k`` (every
    passive link gets the same transform, link-separated noise).  With
    every defense off this IS ``run_apcvfl`` bit-for-bit."""
    t = make_transform(sigma=sigma, mechanism=mechanism, clip=clip,
                       quantize=quantize)
    kw = dict(lam=lam, kind=kind, batch_size=batch_size,
              max_epochs=max_epochs, patience=patience, lr=lr,
              use_kernel=use_kernel, ablation=ablation, exchange=t)
    if isinstance(sc, VFLScenarioK):
        res = multiparty.run_apcvfl_k(sc, seed=seed, **kw)
    else:
        res = pipeline.run_apcvfl(sc, seed=seed, **kw)
    return _tag_dp(res, sigma=sigma)


def run_apcvfl_dp_replicated(scenarios, *, seeds, sigma: float = 0.0,
                             mechanism: str = "gaussian",
                             clip: Optional[float] = None,
                             quantize: Optional[str] = None,
                             lam: float = HP.lam, kind: str = HP.kind,
                             batch_size: int = HP.batch_size,
                             max_epochs: int = HP.max_epochs,
                             patience: int = HP.patience, lr: float = HP.lr,
                             use_kernel: bool = False,
                             ablation: bool = False,
                             mesh=None) -> List[RunResult]:
    """Seed replicas of one defended grid cell through the replica-lane
    engine: the transform is shared across seeds (per-seed noise keys),
    every protocol stage S lanes of one vmapped scan."""
    t = make_transform(sigma=sigma, mechanism=mechanism, clip=clip,
                       quantize=quantize)
    kw = dict(seeds=seeds, lam=lam, kind=kind, batch_size=batch_size,
              max_epochs=max_epochs, patience=patience, lr=lr,
              use_kernel=use_kernel, ablation=ablation, exchange=t,
              mesh=mesh)
    if scenarios and isinstance(scenarios[0], VFLScenarioK):
        results = multiparty.run_apcvfl_k_replicated(scenarios, **kw)
    else:
        results = pipeline.run_apcvfl_replicated(scenarios, **kw)
    return [_tag_dp(r, sigma=sigma) for r in results]


def dp_frontier(sc, sigmas: Sequence[float], *,
                mechanism: str = "gaussian", clip: Optional[float] = None,
                quantize: Optional[str] = None, seed: int = 0,
                lam: float = HP.lam, kind: str = HP.kind,
                batch_size: int = HP.batch_size,
                max_epochs: int = HP.max_epochs,
                patience: int = HP.patience, lr: float = HP.lr,
                use_kernel: bool = False, mesh=None) -> List[RunResult]:
    """The utility side of the utility-vs-leakage frontier: run the WHOLE
    sigma grid as replica lanes of one protocol — one ``RunResult`` per
    sigma, each stage (2S g1 lanes, S g2 lanes, S g3 lanes) a single
    vmapped dispatch, the per-lane exchanges differing only in their
    (cheap, eager) transform.  All lanes share the run seed, so the
    sigma=0 lane reproduces the undefended ``run_apcvfl(sc, seed=seed)``
    within replica-lane tolerance."""
    transforms = [make_transform(sigma=float(s), mechanism=mechanism,
                                 clip=clip, quantize=quantize)
                  for s in sigmas]
    results = pipeline.run_apcvfl_replicated(
        sc, seeds=[seed] * len(transforms), lam=lam, kind=kind,
        batch_size=batch_size, max_epochs=max_epochs, patience=patience,
        lr=lr, use_kernel=use_kernel, exchange=transforms, mesh=mesh)
    return [_tag_dp(r, sigma=float(s)) for r, s in zip(results, sigmas)]
