"""Online VFL inference: trained-model export, representation cache, and a
batched serving engine for the APC-VFL protocol.

The paper's deployment story (Eq. 5 / Fig. 2) is that after the ONE
communication step the active participant predicts **alone**: the distilled
student g3 maps its local features straight into the joint-representation
space, so online inference needs no passive party in the loop.  This module
turns a finished training run into that serving path:

* ``export_bundle`` captures everything the active party holds after
  training — its encoders (g1_active, g2, g3), a serving classifier head
  fit once on the training representations, the feature scaler, and the
  passive latents it RECEIVED for the PSI-aligned rows (never the passive
  party's model) — into a ``ModelBundle`` that round-trips through
  ``checkpoint.ckpt`` (save -> load -> bit-identical predictions).

* ``VFLServingEngine`` serves a bundle with two jit-compiled predict
  paths:

  - **active-only** (the paper's headline mode): ``logits =
    head(g3_enc(x))`` — any user the active party can feature-ize,
    zero communication;
  - **collaborative**: for requests whose row id is PSI-aligned, the
    engine looks the id up in an on-device *representation cache* of the
    passive latents captured at export time and predicts from the joint
    teacher representation ``head_joint(g2_enc([g1a_enc(x), z_p]))`` —
    the online analogue of FedCVT-style aligned/unaligned handling, still
    with zero *online* communication (the latents were already paid for
    by training's single exchange).

* Arbitrary request sizes hit a handful of compiled shapes: a padded
  power-of-two **batch bucketer** (the same zero-pad trick as the lane
  engine — padding rows are inert through row-wise MLPs and are sliced
  off before anything is returned) routes every micro-batch onto one of
  ``DEFAULT_BUCKETS`` shapes, so a 10k-request mixed stream compiles
  ~5 shapes per path instead of one per distinct request size.

* ``serve_stream`` is the simulated request-stream driver: it coalesces
  queued requests into micro-batches up to the largest bucket, routes
  rows per-request-id between the two paths, and reports throughput,
  service-time latency percentiles, cache hit-rate and compile counts
  (``benchmarks/servebench.py`` turns this into ``BENCH_serve.json``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.core import autoencoder as ae
from repro.core import classifier as clf
from repro.core.psi import id_positions
from repro.serve.metrics import ServeStats

DEFAULT_BUCKETS = (16, 32, 64, 128, 256)

# filler for rows without an identity: real row ids are the non-negative
# dataset ids PSI aligned on, so this can never hit the cache
ANON_ID = -1


# ---------------------------------------------------------------------------
# the exported model
# ---------------------------------------------------------------------------

@dataclass
class ModelBundle:
    """Everything the active party needs to serve a trained APC-VFL model.

    ``g3`` + ``head_active`` are the minimum (the paper's independent-
    inference mode).  ``g1_active``/``g2``/``head_joint`` plus the cache
    arrays enable the collaborative path for PSI-aligned users; they are
    optional (an ablation run exports only the student).  ``x_mean`` /
    ``x_scale`` standardize incoming request features; export defaults
    them to the identity because the training pipeline consumes
    pre-standardized features — pass explicit stats when requests arrive
    in raw units."""
    meta: Dict
    g3: dict
    head_active: dict
    x_mean: np.ndarray
    x_scale: np.ndarray
    g1_active: Optional[dict] = None
    g2: Optional[dict] = None
    head_joint: Optional[dict] = None
    cache_ids: Optional[np.ndarray] = None       # (n_al,) int64 row ids
    cache_z: Optional[np.ndarray] = None         # (n_al, z_p) fp32 latents

    @property
    def supports_collaborative(self) -> bool:
        return all(v is not None for v in (self.g1_active, self.g2,
                                           self.head_joint, self.cache_ids,
                                           self.cache_z))

    def tree(self) -> dict:
        """The flat-dict pytree persisted by ``save`` (dict-only, so it
        reloads prototype-free via ``ckpt.load_tree``)."""
        t = {"g3": self.g3, "head_active": self.head_active,
             "scaler": {"mean": np.asarray(self.x_mean),
                        "scale": np.asarray(self.x_scale)}}
        if self.supports_collaborative:
            t["g1_active"] = self.g1_active
            t["g2"] = self.g2
            t["head_joint"] = self.head_joint
            t["cache"] = {"ids": np.asarray(self.cache_ids),
                          "z": np.asarray(self.cache_z)}
        return t

    def save(self, path: str) -> None:
        ckpt.save(path, self.tree(), meta=dict(self.meta))

    @classmethod
    def load(cls, path: str) -> "ModelBundle":
        tree, side = ckpt.load_tree(path)
        dev = lambda t: jax.tree.map(jnp.asarray, t)
        return cls(
            meta=side.get("meta", {}),
            g3=dev(tree["g3"]),
            head_active=dev(tree["head_active"]),
            x_mean=tree["scaler"]["mean"],
            x_scale=tree["scaler"]["scale"],
            g1_active=dev(tree["g1_active"]) if "g1_active" in tree else None,
            g2=dev(tree["g2"]) if "g2" in tree else None,
            head_joint=(dev(tree["head_joint"])
                        if "head_joint" in tree else None),
            cache_ids=(tree["cache"]["ids"].astype(np.int64)
                       if "cache" in tree else None),
            cache_z=tree["cache"]["z"] if "cache" in tree else None,
        )


def export_bundle(result, sc, *, x_mean=None, x_scale=None,
                  head_steps: int = 300) -> ModelBundle:
    """Capture a finished ``run_apcvfl`` / ``run_apcvfl_k`` run (its
    ``RunResult`` plus the scenario that trained it) as a ``ModelBundle``.

    The serving head is fit ONCE on the full enhanced dataset
    ``g3_enc(X_active)`` with the active party's labels (the k-fold CV of
    training is an evaluation protocol, not a deployable classifier); when
    the run carries the collaborative artifacts, a joint head is fit the
    same way on the teacher representations of the aligned rows."""
    if result.params is None or "g3" not in result.params:
        raise ValueError("export_bundle needs a RunResult with trained g3 "
                         "params (run_apcvfl / run_apcvfl_k)")
    xa = np.asarray(sc.active.x, np.float32)
    y = np.asarray(sc.active.y)
    n_classes = int(sc.n_classes)
    g3 = result.params["g3"]
    z_all = ae.encode(g3, jnp.asarray(xa))
    head_active = clf.fit_logreg(z_all, jnp.asarray(y), n_classes,
                                 steps=head_steps)

    g1a = result.params.get("g1_active")
    g2 = result.params.get("g2")
    head_joint = cache_ids = cache_z = None
    if g1a is not None and g2 is not None and result.artifacts:
        cache_ids = np.asarray(result.artifacts["aligned_ids"],
                               dtype=np.int64)
        cache_z = np.asarray(result.artifacts["z_passive_aligned"],
                             np.float32)
        pos = id_positions(sc.active.ids)
        idx_a = np.asarray([pos[int(i)] for i in cache_ids], np.int64)
        za = ae.encode(g1a, jnp.asarray(xa[idx_a]))
        zj = jnp.concatenate([za, jnp.asarray(cache_z)],
                             axis=1).astype(jnp.float32)
        z2 = ae.encode(g2, zj)
        head_joint = clf.fit_logreg(z2, jnp.asarray(y[idx_a]), n_classes,
                                    steps=head_steps)

    d = xa.shape[1]
    meta = {"method": result.method, "dataset": getattr(sc, "name", ""),
            "n_classes": n_classes, "z_dim": result.z_dim,
            "n_features_active": d, "seed": result.seed,
            "n_cached": 0 if cache_ids is None else int(len(cache_ids))}
    return ModelBundle(
        meta=meta, g3=g3, head_active=head_active,
        x_mean=(np.zeros(d, np.float32) if x_mean is None
                else np.asarray(x_mean, np.float32)),
        x_scale=(np.ones(d, np.float32) if x_scale is None
                 else np.asarray(x_scale, np.float32)),
        g1_active=g1a, g2=g2, head_joint=head_joint,
        cache_ids=cache_ids, cache_z=cache_z)


# ---------------------------------------------------------------------------
# batch bucketing
# ---------------------------------------------------------------------------

class BatchBucketer:
    """Map arbitrary micro-batch row counts onto a small fixed set of
    padded shapes so the jitted predict paths compile once per bucket.
    ``split(n)`` chunks an oversized batch into max-bucket pieces plus one
    tail bucket — every dispatch shape is a member of ``buckets``."""

    def __init__(self, buckets: Sequence[int] = DEFAULT_BUCKETS):
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"buckets must be positive, got {buckets!r}")
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))

    @property
    def max(self) -> int:
        return self.buckets[-1]

    def fit(self, n: int) -> int:
        """Smallest bucket >= n (n must not exceed the largest bucket)."""
        for b in self.buckets:
            if n <= b:
                return b
        raise ValueError(f"batch of {n} rows exceeds largest bucket "
                         f"{self.max}; use split()")

    def split(self, n: int) -> List[Tuple[int, int, int]]:
        """Chunk n rows into dispatches: [(start, rows, bucket), ...].
        ``n = 0`` is a valid empty batch -> no dispatches; negative row
        counts are a caller bug and raise instead of emitting a bogus
        negative-row dispatch."""
        if n < 0:
            raise ValueError(f"split: negative row count {n}")
        out, start = [], 0
        while n - start > self.max:
            out.append((start, self.max, self.max))
            start += self.max
        tail = n - start
        if tail:
            out.append((start, tail, self.fit(tail)))
        return out


# ---------------------------------------------------------------------------
# representation cache
# ---------------------------------------------------------------------------

class RepresentationCache:
    """On-device passive-latent cache keyed by row id: the Z_p rows the
    active party received for the PSI-aligned users, gathered per request
    without any host round-trip for the latents themselves (only the
    id -> slot lookup is host-side).

    The cache is **versioned** for the lifecycle a long-lived server
    needs: a fresh training round re-exports latents -> ``refresh``
    installs the new arrays and bumps ``version``; a passive party that
    drops out or is known to have drifted -> ``invalidate`` marks the
    cache stale WITHOUT discarding version history.  A stale cache never
    serves: every lookup misses (and is counted as a miss), so the engine
    degrades to the active-only path — the survey's dropout scenario —
    instead of silently predicting from old latents."""

    def __init__(self, ids: np.ndarray, z, *, version: int = 1):
        self.version = int(version)
        self.stale = False
        self.hits = 0
        self.misses = 0
        self._install(ids, z)

    def _install(self, ids: np.ndarray, z) -> None:
        ids = np.asarray(ids, np.int64)
        self._slot = id_positions(ids)
        self.z = jnp.asarray(z, jnp.float32)       # (n, z_p), uploaded once

    def __len__(self) -> int:
        return len(self._slot)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def refresh(self, ids: np.ndarray, z) -> int:
        """Install a newly exported latent set (a fresh training round's
        ``cache_ids``/``cache_z``), clear staleness, bump + return the
        version.  Hit/miss counters survive — they describe the serving
        stream, not one latent generation."""
        self._install(ids, z)
        self.stale = False
        self.version += 1
        return self.version

    def invalidate(self) -> None:
        """Mark every cached latent stale (passive dropout / drift): all
        lookups miss until the next ``refresh``."""
        self.stale = True

    def lookup(self, ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(hit_mask bool (n,), slot idx int32 (n,) — 0 where missed).
        Stale caches miss everything by construction."""
        ids = np.asarray(ids)
        if self.stale:
            self.misses += len(ids)
            return (np.zeros(len(ids), bool),
                    np.zeros(len(ids), np.int32))
        idx = np.fromiter((self._slot.get(int(i), -1) for i in ids),
                          np.int64, count=len(ids))
        hit = idx >= 0
        self.hits += int(hit.sum())
        self.misses += int((~hit).sum())
        return hit, np.where(hit, idx, 0).astype(np.int32)

    def gather(self, idx: np.ndarray):
        return self.z[jnp.asarray(idx)]


# ---------------------------------------------------------------------------
# the serving engine
# ---------------------------------------------------------------------------

# the two predict bodies as PURE functions of (params, batch): jitting a
# pure function instead of a bound method means the compiled executable is
# keyed on param *shapes*, not param *values* — so a TenantRegistry can put
# many tenants' bundles behind ONE shared jit cache (same architecture =
# same executable), and a tenant served there is bit-identical to a solo
# engine jitting the very same function on its own.

def _standardize(p: dict, x):
    return (x - p["mean"]) * p["inv_scale"]


def _active_apply(p: dict, x):
    """Paper headline mode: the distilled student alone."""
    z = ae.encode(p["g3"], _standardize(p, x))
    return clf.logreg_logits(p["head"], z)


def _collab_apply(p: dict, x, zp):
    """Joint-teacher mode for cached (PSI-aligned) users."""
    za = ae.encode(p["g1a"], _standardize(p, x))
    zj = jnp.concatenate([za, zp], axis=1).astype(jnp.float32)
    return clf.logreg_logits(p["head_joint"], ae.encode(p["g2"], zj))


class VFLServingEngine:
    """Batched online inference over a ``ModelBundle`` (module docstring).

    ``predict(x, ids=None)`` routes rows between the two jitted paths —
    ids found in the representation cache go collaborative, everything
    else (and every row when ``ids`` is omitted or the bundle has no
    collaborative artifacts) goes active-only — pads each group to a
    bucket shape, and reassembles logits in request-row order.  All
    compiled state is keyed on bucket shape: ``compiled_shapes()`` reports
    every distinct (path, batch-rows) pair dispatched so far and
    ``jit_cache_sizes()`` the XLA-level executable counts."""

    def __init__(self, bundle: ModelBundle, *,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 bucketer: Optional[BatchBucketer] = None,
                 jit_fns: Optional[Tuple] = None,
                 quantize: Optional[str] = None):
        """``bucketer``/``jit_fns`` inject SHARED infrastructure (one
        bucketer + one pair of jitted apply functions across many
        tenants' engines — see ``runtime.TenantRegistry``); by default
        each engine owns a private pair, which compiles to the same
        executables (same pure functions, same shapes).

        ``quantize="int8"`` serves the active path from per-channel
        symmetric int8 weights (``serve.quant``).  On this interpret-mode
        host the engine pre-dequantizes ONCE at init into the fp32
        pytree shape, so the quantized tenant rides the SAME jitted
        executables (and throughput) as fp32 — only the quantization
        error differs, and ``serve.quant.parity_report`` pins it.  The
        fused int8 kernel path stays available as
        ``quant.int8_active_apply(engine.quant_params, x)``."""
        self.bundle = bundle
        self.bucketer = bucketer if bucketer is not None \
            else BatchBucketer(buckets)
        self.stats = ServeStats()
        self._shapes: set = set()
        dev = lambda t: jax.tree.map(jnp.asarray, t)
        scale = np.asarray(bundle.x_scale, np.float32)
        if not np.all(np.isfinite(scale)) or np.any(scale == 0.0):
            raise ValueError("bundle x_scale must be finite and nonzero "
                             "(a constant feature's std is 0 — clamp it "
                             "to 1 before export)")
        self._mean = jnp.asarray(bundle.x_mean, jnp.float32)
        self._inv_scale = 1.0 / jnp.asarray(scale)
        if quantize not in (None, "int8"):
            raise ValueError(f"quantize must be None or 'int8', "
                             f"got {quantize!r}")
        self.quantize = quantize
        self.quant_params = None
        self.quant_meta = None
        if quantize == "int8":
            from repro.serve import quant
            self.quant_params = quant.quantize_active_path(bundle)
            self.quant_meta = self.quant_params["meta"]
            self._p_active = quant.dequantized_active_params(
                self.quant_params)
            if "dec" in bundle.g3:
                # keep the pytree structure identical to the fp32 path
                # (decoder rides along untouched, unused by serving) so
                # the shared jit cache reuses the fp32 executables
                self._p_active["g3"]["dec"] = dev(bundle.g3["dec"])
            self._head = self._p_active["head"]
        else:
            self._head = dev(bundle.head_active)
            self._p_active = {"g3": dev(bundle.g3), "head": self._head,
                              "mean": self._mean,
                              "inv_scale": self._inv_scale}
        if jit_fns is not None:
            self._active_fn, shared_collab = jit_fns
        else:
            self._active_fn, shared_collab = (jax.jit(_active_apply),
                                              jax.jit(_collab_apply))
        self.cache: Optional[RepresentationCache] = None
        self._collab_fn = None
        self._p_collab = None
        if bundle.supports_collaborative:
            self.cache = RepresentationCache(bundle.cache_ids,
                                             bundle.cache_z)
            self._head_joint = dev(bundle.head_joint)
            self._p_collab = {"g1a": dev(bundle.g1_active),
                              "g2": dev(bundle.g2),
                              "head_joint": self._head_joint,
                              "mean": self._mean,
                              "inv_scale": self._inv_scale}
            self._collab_fn = shared_collab

    # --- representation-cache lifecycle ------------------------------------

    def refresh_cache(self, ids: np.ndarray, z) -> int:
        """Install freshly re-exported passive latents (a new training
        round's ``bundle.cache_ids``/``cache_z``); returns the bumped
        cache version.  Only meaningful on a collaborative engine."""
        if self.cache is None:
            raise ValueError("refresh_cache: this bundle has no "
                             "collaborative path (no cache to refresh)")
        return self.cache.refresh(ids, z)

    def invalidate_cache(self) -> None:
        """Degrade to active-only for cached ids (passive dropout): the
        cache goes stale, every lookup misses until ``refresh_cache``."""
        if self.cache is not None:
            self.cache.invalidate()

    @property
    def cache_version(self) -> Optional[int]:
        return None if self.cache is None else self.cache.version

    # --- dispatch ----------------------------------------------------------

    def _dispatch(self, path: str, x: np.ndarray,
                  zp_idx: Optional[np.ndarray] = None) -> np.ndarray:
        """Bucket-pad one row group and run it through ``path``; returns
        unpadded logits.  Oversized groups are split into max-bucket
        chunks (every dispatched shape is a bucket member)."""
        n = len(x)
        if n == 0:
            head = self._head if path == "active" else self._head_joint
            return np.zeros((0, head["w"].shape[1]), np.float32)
        outs = []
        for start, rows, bucket in self.bucketer.split(n):
            xb = np.zeros((bucket, x.shape[1]), np.float32)
            xb[:rows] = x[start:start + rows]
            self._shapes.add((path, bucket))
            self.stats.dispatches[path] = \
                self.stats.dispatches.get(path, 0) + 1
            self.stats.padded_rows += bucket - rows
            if path == "collab":
                ib = np.zeros((bucket,), np.int32)
                ib[:rows] = zp_idx[start:start + rows]
                zp = self.cache.gather(ib)
                logits = self._collab_fn(self._p_collab, jnp.asarray(xb),
                                         zp)
            else:
                logits = self._active_fn(self._p_active, jnp.asarray(xb))
            # the ONE sanctioned device->host sync per dispatch — explicit
            # jax.device_get so analysis.guards.no_host_sync can account
            # it (an implicit np.asarray would trip the guard as a stray)
            outs.append(jax.device_get(logits)[:rows])
        return np.concatenate(outs) if len(outs) > 1 else outs[0]

    def predict_active(self, x) -> np.ndarray:
        """Active-only logits for (n, D) features — no ids needed."""
        x = np.asarray(x, np.float32)
        self.stats.rows += len(x)
        return self._dispatch("active", x)

    def predict(self, x, ids=None) -> np.ndarray:
        """Route rows by id between the cache-backed collaborative path
        and the active-only path; logits come back in input-row order."""
        x = np.asarray(x, np.float32)
        if ids is None or self.cache is None:
            return self.predict_active(x)
        if len(ids) != len(x):
            raise ValueError(f"predict: {len(ids)} ids for {len(x)} rows")
        self.stats.rows += len(x)
        hit, slot = self.cache.lookup(ids)
        if not hit.any():
            return self._dispatch("active", x)
        logits = np.empty((len(x), self._head["w"].shape[1]), np.float32)
        hi = np.nonzero(hit)[0]
        logits[hi] = self._dispatch("collab", x[hi], slot[hi])
        mi = np.nonzero(~hit)[0]
        if len(mi):
            logits[mi] = self._dispatch("active", x[mi])
        return logits

    # --- warmup / introspection --------------------------------------------

    def warmup(self) -> None:
        """Dispatch every bucket shape once through each available path so
        the serving loop never pays a compile (the shapes a stream can hit
        are exactly the bucket set).  Counters touched by the warmup are
        cleared via ``reset_stats``; the compiled-shape record is kept —
        it IS the compile count the bucketer promises to bound."""
        d = int(self._mean.shape[0])
        for b in self.bucketer.buckets:
            xb = np.zeros((b, d), np.float32)
            self._dispatch("active", xb)
            if self._collab_fn is not None:
                self._dispatch("collab", xb, np.zeros(b, np.int32))
        self.reset_stats()

    def reset_stats(self) -> None:
        self.stats = ServeStats()
        if self.cache is not None:
            self.cache.hits = 0
            self.cache.misses = 0

    def compiled_shapes(self) -> dict:
        """Distinct dispatched (path, batch-rows) pairs and the number of
        distinct batch shapes across paths (the bucketer's promise: stays
        within ``len(buckets)`` whatever the request-size mix)."""
        by_path: dict = {}
        for path, bucket in sorted(self._shapes):
            by_path.setdefault(path, []).append(bucket)
        return {"by_path": by_path,
                "distinct_batch_shapes":
                    len({b for _, b in self._shapes})}

    def jit_cache_sizes(self) -> dict:
        out = {}
        for name, fn in (("active", self._active_fn),
                         ("collab", self._collab_fn)):
            if fn is not None and hasattr(fn, "_cache_size"):
                out[name] = int(fn._cache_size())
        return out


# ---------------------------------------------------------------------------
# simulated request stream
# ---------------------------------------------------------------------------

@dataclass
class ServeRequest:
    rid: int
    x: np.ndarray                        # (n, D) feature rows
    ids: Optional[np.ndarray] = None     # (n,) row ids (None = anonymous)
    logits: Optional[np.ndarray] = None
    latency_ms: float = 0.0              # service time of the batch
    queue_ms: float = 0.0                # wait before that batch dispatched

    @property
    def labels(self) -> np.ndarray:
        return np.argmax(self.logits, axis=-1)


def make_request_stream(x_pool: np.ndarray, ids_pool: np.ndarray,
                        n_requests: int, *, seed: int = 0,
                        max_rows: int = 64, p_known: float = 0.5
                        ) -> List[ServeRequest]:
    """A mixed stream: request sizes uniform in [1, max_rows] (every size
    appears — the naive per-size-jit baseline compiles once per distinct
    size), rows drawn from the feature pool, and each request's ids kept
    real with probability ``p_known`` (cache candidates) or replaced by
    unseen ids (forced active-only)."""
    rng = np.random.RandomState(seed)
    x_pool = np.asarray(x_pool, np.float32)
    ids_pool = np.asarray(ids_pool, np.int64)
    reqs = []
    for rid in range(n_requests):
        n = int(rng.randint(1, max_rows + 1))
        rows = rng.randint(0, len(x_pool), n)
        ids = ids_pool[rows].copy()
        unknown = rng.rand(n) >= p_known
        ids[unknown] = -1 - rng.randint(0, 1 << 30, int(unknown.sum()))
        reqs.append(ServeRequest(rid, x_pool[rows], ids))
    return reqs


def serve_stream(engine: VFLServingEngine, requests: List[ServeRequest], *,
                 coalesce: bool = True) -> dict:
    """Drive a request list through the engine and return stream stats.

    ``coalesce=True`` greedily packs consecutive requests into one
    micro-batch up to the largest bucket (the batched-serving mode);
    ``False`` dispatches one request per engine call (still bucketed).
    Two latency series are recorded per request (``serve.metrics``
    schema, shared with the arrival-clocked runtime): *service time* —
    the wall-clock of the micro-batch that completed it — and *queueing
    time* — how long it waited in the backlog before that batch
    dispatched (every request of a static list is treated as arriving at
    stream start, so queueing here measures backlog drain; the
    Poisson/bursty arrival clock lives in ``serve.runtime``)."""
    t_start = time.perf_counter()
    max_rows = engine.bucketer.max
    i = 0
    while i < len(requests):
        group = [requests[i]]
        rows = len(requests[i].x)
        i += 1
        if coalesce:
            while i < len(requests) and \
                    rows + len(requests[i].x) <= max_rows:
                group.append(requests[i])
                rows += len(requests[i].x)
                i += 1
        t0 = time.perf_counter()
        wait_ms = (t0 - t_start) * 1e3
        x = np.concatenate([r.x for r in group])
        if any(r.ids is not None for r in group):
            # anonymous requests ride along under the never-matching
            # filler id, so an id-carrying neighbor keeps its cache
            # routing whatever it was coalesced with
            ids = np.concatenate([
                r.ids if r.ids is not None
                else np.full(len(r.x), ANON_ID, np.int64) for r in group])
        else:
            ids = None
        logits = engine.predict(x, ids)
        dt_ms = (time.perf_counter() - t0) * 1e3
        off = 0
        for r in group:
            r.logits = logits[off:off + len(r.x)]
            off += len(r.x)
            r.latency_ms = dt_ms
            r.queue_ms = wait_ms
            engine.stats.record(wait_ms, dt_ms)
        engine.stats.requests += len(group)
    wall_s = time.perf_counter() - t_start
    total_rows = int(sum(len(r.x) for r in requests))
    return {
        "requests": len(requests),
        "rows": total_rows,
        "wall_s": round(wall_s, 4),
        "rows_per_s": round(total_rows / max(wall_s, 1e-9), 1),
        "requests_per_s": round(len(requests) / max(wall_s, 1e-9), 1),
        "latency_ms_p50": round(engine.stats.percentile_ms(50), 3),
        "latency_ms_p99": round(engine.stats.percentile_ms(99), 3),
        # queueing and service as separate percentile series — the one
        # stats schema servebench and loadbench share (serve.metrics)
        "latency_ms": engine.stats.latency_summary(),
        "cache_hit_rate": (round(engine.cache.hit_rate, 4)
                           if engine.cache else None),
        "dispatches": dict(engine.stats.dispatches),
        "padded_rows": engine.stats.padded_rows,
        "compiled": engine.compiled_shapes(),
        "jit_cache_sizes": engine.jit_cache_sizes(),
    }
