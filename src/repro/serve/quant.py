"""Int8-quantized serving for the active-only path ``head(g3(x))``.

The paper's headline deployment mode is the active party predicting alone
from its distilled student — a 2-layer Table-3 encoder plus a logreg
head.  At serving scale those weights dominate the memory traffic, so
this module gives the ``ModelBundle`` an int8 export:

* **per-channel symmetric quantization** — each weight matrix ``w`` is
  stored as ``w_q = round(w / scale)`` in int8 with one fp32 ``scale``
  per OUTPUT channel (``scale[c] = max|w[:, c]| / 127``).  Symmetric
  (no zero point) keeps dequant a single multiply; per-channel keeps the
  quantization error of a wide column from leaking into narrow ones.
  Biases and the feature scaler stay fp32 (they are O(channels), not
  O(d x channels)).

* **fused int8 kernel path** — ``int8_active_apply`` runs the whole
  quantized predict through ``kernels.int8_matmul``: the dequant happens
  inside the matmul tile (weights cross memory at 1 byte/param) and the
  hidden-layer SELU is fused into the first launch.

* **CPU fast path** — on hosts where Pallas runs interpreted (this
  container), ``dequantized_active_params`` pre-dequantizes ONCE at
  engine init into the exact pytree ``vfl._active_apply`` consumes, so
  ``VFLServingEngine(..., quantize="int8")`` shares the fp32 engine's
  jitted executables (same shapes -> same jit cache) and its throughput:
  the quantization error is paid, the interpret-mode overhead is not.

The parity cost is PINNED, not hoped for: ``parity_report`` measures the
max logit delta, prediction flip rate and F1 delta of the quantized path
against fp32 on real rows; ``tests/test_serve_quant.py`` asserts the
bounds and ``benchmarks/servebench.py`` records them in
``BENCH_serve.json``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

# pinned int8-vs-fp32 agreement bounds.  The Table-3 serving stack is 3
# matmuls deep and 7-bit weights carry ~0.4% per-layer relative error;
# measured on bcw bundles across seeds 0-2 at 2/15/30 training epochs the
# worst logit delta is 0.41 absolute / 5.9% of the logit range, F1-macro
# delta <= 0.020 and flip rate <= 5.3% (under-trained 2-epoch smoke
# bundles are the worst case — their logits sit near the decision
# boundary).  The bounds below give ~2x headroom over those
# measurements; tests, servebench and loadbench assert them.
MAX_LOGIT_DELTA = 0.8
MAX_REL_LOGIT_DELTA = 0.12
MAX_F1_DELTA = 0.04


def quantize_weight(w) -> Tuple[np.ndarray, np.ndarray]:
    """Per-output-channel symmetric int8: (w_q int8 (d, c), scale (c,)).

    All-zero columns get scale 1.0 (they dequantize back to exact zeros
    rather than dividing by zero)."""
    w = np.asarray(w, np.float32)
    if w.ndim != 2:
        raise ValueError(f"quantize_weight: expected a 2-D weight, "
                         f"got shape {w.shape}")
    scale = np.abs(w).max(axis=0) / 127.0
    scale = np.where(scale > 0, scale, 1.0).astype(np.float32)
    w_q = np.clip(np.round(w / scale[None, :]), -127, 127).astype(np.int8)
    return w_q, scale


def dequantize_weight(w_q, scale) -> np.ndarray:
    # host-side numpy on purpose: this runs at engine init, and jax ops
    # here would cost one-time convert/multiply XLA compiles that break
    # the registry's zero-compile int8-twin promise
    return (np.asarray(w_q).astype(np.float32)
            * np.asarray(scale, np.float32)[None, :])


def _enc_layers(g3: dict) -> dict:
    enc = g3["enc"] if "enc" in g3 else g3
    n = len([k for k in enc if k.startswith("w")])
    if n != 2:
        raise ValueError(f"int8 serving supports the 2-layer Table-3 "
                         f"student; this g3 encoder has {n} layers")
    return enc


def quantize_active_path(bundle) -> Dict:
    """Quantize the active-only serving params (g3 encoder + head) of a
    ``ModelBundle`` into a flat dict of int8 weights + fp32 scales/biases,
    with the feature scaler carried along.  The decoder and the
    collaborative-path params are not serving-path weights and are left
    out entirely."""
    enc = _enc_layers(bundle.g3)
    w0_q, w0_s = quantize_weight(enc["w0"])
    w1_q, w1_s = quantize_weight(enc["w1"])
    hw_q, hw_s = quantize_weight(bundle.head_active["w"])
    scale = np.asarray(bundle.x_scale, np.float32)
    fp32_bytes = sum(int(np.asarray(v).size) * 4
                     for v in (enc["w0"], enc["w1"],
                               bundle.head_active["w"]))
    int8_bytes = w0_q.size + w1_q.size + hw_q.size \
        + 4 * (w0_s.size + w1_s.size + hw_s.size)
    return {
        "w0_q": jnp.asarray(w0_q), "w0_scale": jnp.asarray(w0_s),
        "b0": jnp.asarray(enc["b0"], jnp.float32),
        "w1_q": jnp.asarray(w1_q), "w1_scale": jnp.asarray(w1_s),
        "b1": jnp.asarray(enc["b1"], jnp.float32),
        "head_w_q": jnp.asarray(hw_q), "head_w_scale": jnp.asarray(hw_s),
        "head_b": jnp.asarray(bundle.head_active["b"], jnp.float32),
        "mean": jnp.asarray(bundle.x_mean, jnp.float32),
        "inv_scale": jnp.asarray(1.0 / scale, jnp.float32),
        "meta": {"scheme": "int8-symmetric-per-channel",
                 "weight_bytes_fp32": fp32_bytes,
                 "weight_bytes_int8": int(int8_bytes),
                 "compression": round(fp32_bytes / int8_bytes, 2)},
    }


def int8_active_apply(qp: Dict, x):
    """The quantized ``head(g3(x))`` through the fused int8 kernels:
    standardize -> int8 matmul + fused SELU -> int8 matmul (linear
    latent) -> int8 head matmul.  Weights cross memory as int8; dequant
    happens in-tile (``kernels.int8_matmul``)."""
    from repro.kernels import ops as kops
    x = (x - qp["mean"]) * qp["inv_scale"]
    h = kops.int8_matmul(x, qp["w0_q"], qp["w0_scale"], qp["b0"],
                         act="selu")
    z = kops.int8_matmul(h, qp["w1_q"], qp["w1_scale"], qp["b1"])
    return kops.int8_matmul(z, qp["head_w_q"], qp["head_w_scale"],
                            qp["head_b"])


def dequantized_active_params(qp: Dict) -> Dict:
    """Pre-dequantize a quantized active path back into the pytree
    ``vfl._active_apply`` consumes ({g3: {enc}, head, mean, inv_scale}).
    Same shapes as the fp32 path -> the engine's shared jit cache serves
    it with zero extra compiles; predictions equal the int8 kernel path
    (both compute ``x @ (w_q * scale) + b`` in fp32)."""
    return {
        "g3": {"enc": {
            "w0": jnp.asarray(dequantize_weight(qp["w0_q"], qp["w0_scale"])),
            "b0": qp["b0"],
            "w1": jnp.asarray(dequantize_weight(qp["w1_q"], qp["w1_scale"])),
            "b1": qp["b1"],
        }},
        "head": {"w": jnp.asarray(dequantize_weight(qp["head_w_q"],
                                                    qp["head_w_scale"])),
                 "b": qp["head_b"]},
        "mean": qp["mean"], "inv_scale": qp["inv_scale"],
    }


def parity_report(bundle, x, y: Optional[np.ndarray] = None,
                  *, n_classes: Optional[int] = None) -> Dict:
    """Measure the int8-vs-fp32 serving gap on real feature rows: max /
    mean absolute logit delta, prediction flip rate, and (when labels are
    given) the F1/accuracy delta.  This is the number the tests pin and
    the benchmarks record — the quantized path ships WITH its error bar."""
    from repro.core import classifier as clf
    from repro.serve.vfl import VFLServingEngine

    x = np.asarray(x, np.float32)
    fp32 = VFLServingEngine(bundle)
    q = VFLServingEngine(bundle, quantize="int8")
    lf = fp32.predict_active(x)
    lq = q.predict_active(x)
    pf = np.argmax(lf, axis=-1)
    pq = np.argmax(lq, axis=-1)
    d = np.abs(lf - lq)
    logit_range = max(float(np.abs(lf).max()), 1e-9)
    report = {
        "scheme": q.quant_meta["scheme"],
        "compression": q.quant_meta["compression"],
        "rows": int(len(x)),
        "max_abs_logit_delta": float(d.max()),
        "mean_abs_logit_delta": float(d.mean()),
        "rel_logit_delta": float(d.max() / logit_range),
        "pred_flip_rate": float(np.mean(pf != pq)),
        "max_logit_delta_bound": MAX_LOGIT_DELTA,
        "rel_logit_delta_bound": MAX_REL_LOGIT_DELTA,
    }
    if y is not None:
        y = np.asarray(y)
        nc = int(n_classes if n_classes is not None else y.max() + 1)
        mf = clf.f1_scores(y, pf, nc)
        mq = clf.f1_scores(y, pq, nc)
        report.update({
            "f1_macro_fp32": mf["f1_macro"], "f1_macro_int8": mq["f1_macro"],
            "f1_macro_delta": abs(mf["f1_macro"] - mq["f1_macro"]),
            "accuracy_fp32": mf["accuracy"], "accuracy_int8": mq["accuracy"],
            "accuracy_delta": abs(mf["accuracy"] - mq["accuracy"]),
            "max_f1_delta_bound": MAX_F1_DELTA,
        })
    return report
