"""Batched serving engine with continuous batching (slot scheduler).

A fixed pool of ``batch`` slots decodes in lockstep against a shared KV
cache; finished sequences (max-tokens or EOS) are retired and their slot is
refilled from the request queue by prefilling the new prompt into that
slot's cache rows. Prefill uses the cache-emitting forward
(``decoder_prefill_with_cache``), decode is the one-token jitted step —
the standard disaggregated-serving structure, CPU-sized here.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import model as M
from repro.models.transformer import decoder_prefill_with_cache
from repro.serve.decode import make_decode_step


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new: int = 16
    generated: list = field(default_factory=list)
    done: bool = False


@dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    completed: int = 0
    tokens_out: int = 0


class Engine:
    """Greedy continuous-batching engine for dense/MoE decoder families."""

    def __init__(self, params, cfg: ModelConfig, *, batch: int,
                 n_slots: int, eos_id: Optional[int] = None,
                 prefill_len: int = 32):
        assert cfg.family in ("dense", "moe"), \
            "engine supports KV-cache families; SSM/hybrid use decode()"
        self.params, self.cfg = params, cfg
        self.batch, self.n_slots = batch, n_slots
        self.eos_id = eos_id
        # prompts are right-padded (repeat last token) to a fixed prefill
        # length so every slot's cache has the same filled prefix — the
        # shared slot_pos vector then masks identically for all slots.
        self.prefill_len = prefill_len
        self.cache = M.init_cache(params, cfg, batch, n_slots)
        self.pos = np.zeros(batch, np.int32)          # next position per slot
        self.cur = np.zeros(batch, np.int32)          # last token per slot
        self.slots: List[Optional[Request]] = [None] * batch
        self.queue: List[Request] = []
        self.stats = EngineStats()
        self._decode = jax.jit(make_decode_step(cfg, 0))
        self._prefill = jax.jit(
            lambda p, t: decoder_prefill_with_cache(p, cfg, t, n_slots))

    def submit(self, req: Request):
        self.queue.append(req)

    def _fill_slot(self, i: int, req: Request):
        P = self.prefill_len
        prompt = np.asarray(req.prompt, np.int32)[:P]
        if len(prompt) < P:
            prompt = np.concatenate(
                [prompt, np.full(P - len(prompt), prompt[-1], np.int32)])
        tokens = jnp.asarray(prompt)[None, :]
        logits, cache1 = self._prefill(self.params, tokens)
        # graft the prefilled rows into slot i of the shared cache (rows
        # beyond P arrive zeroed from the prefill pad)
        k = self.cache.k.at[:, i].set(cache1.k[:, 0])
        v = self.cache.v.at[:, i].set(cache1.v[:, 0])
        # slot_pos is shared across the batch: take the union so slots that
        # already decoded past P keep their rows visible. A slot refilled
        # mid-stream attends zeroed K rows between P and the global position
        # — a documented approximation; per-slot positions / paged KV would
        # remove it (production follow-up).
        self.cache = attn.KVCache(
            k, v, jnp.maximum(self.cache.slot_pos, cache1.slot_pos))
        self.slots[i] = req
        self.pos[i] = P
        self.cur[i] = int(jnp.argmax(logits[0]))
        req.generated.append(int(self.cur[i]))
        self.stats.tokens_out += 1      # the prefill emits the first token
        self.stats.prefills += 1

    def _retire(self, i: int):
        req = self.slots[i]
        req.done = True
        self.stats.completed += 1
        self.slots[i] = None

    def step(self):
        """One engine tick: refill free slots, then one decode step."""
        for i in range(self.batch):
            if self.slots[i] is None and self.queue:
                self._fill_slot(i, self.queue.pop(0))
        active = [i for i in range(self.batch) if self.slots[i] is not None]
        if not active:
            return False
        # lockstep decode: positions differ per slot; cache layout uses the
        # max position for slot_pos (causal mask handles shorter rows)
        pos = int(self.pos.max())
        tok = jnp.asarray(self.cur, jnp.int32)
        nxt, self.cache = self._decode(self.params, tok, self.cache,
                                       jnp.int32(pos))
        self.stats.decode_steps += 1
        nxt_np = np.asarray(nxt)
        for i in active:
            self.cur[i] = nxt_np[i]
            self.pos[i] += 1
            req = self.slots[i]
            req.generated.append(int(nxt_np[i]))
            self.stats.tokens_out += 1
            hit_eos = self.eos_id is not None and int(nxt_np[i]) == self.eos_id
            if len(req.generated) >= req.max_new or hit_eos or \
                    self.pos[i] >= self.n_slots - 1:
                self._retire(i)
        return True

    def run(self, max_ticks: int = 10_000) -> EngineStats:
        for _ in range(max_ticks):
            if not self.step() and not self.queue:
                break
        return self.stats
