"""Live multi-tenant VFL serving runtime: arrival simulation, SLO-aware
continuous micro-batching, admission control, and the representation-cache
lifecycle — layered on the bucketed ``VFLServingEngine`` of ``serve.vfl``.

``serve_stream`` (PR 5) drains a static request list: every request is
already there, so it can only measure service time and throughput.  A
live server faces a different problem — requests ARRIVE, queue behind
each other, and wait for a micro-batch to fill — and its two latency
components must be measured separately (``serve.metrics``).  This module
adds that missing half:

* **Arrival simulation** — seeded, fully deterministic request streams
  with virtual arrival timestamps: ``poisson_arrivals`` (memoryless
  steady traffic) and ``bursty_arrivals`` (on/off modulated Poisson —
  flash crowds alternating with lulls).  ``make_timed_stream`` wraps the
  existing request generator with a clock; ``merge_streams`` interleaves
  tenants into one global arrival order.

* **Continuous micro-batching with admission control** —
  ``ServingRuntime.run`` is a discrete-event loop over a virtual clock:
  arrivals enqueue per tenant (a request that would push its tenant's
  queue past ``max_queue_rows`` is SHED at admission, never silently
  dropped mid-flight), a tenant dispatches when its queued rows fill the
  largest warm bucket OR its head-of-line request has waited the queueing
  budget (``max_wait_ms``, default half the SLO — service gets the other
  half), and the clock advances by the measured wall-clock of each
  dispatch (single-executor model: arrivals during a dispatch queue up
  behind it).  Queueing latency (dispatch start - arrival) and service
  latency (dispatch duration) are recorded per request as separate
  series; SLO attainment is judged on their sum.  For deterministic
  scheduler tests a ``service_model`` can drive the clock instead of the
  wall — dispatches still execute for real, only timing is modeled.

* **Multi-tenant registry** — ``TenantRegistry`` puts many
  ``ModelBundle``s behind ONE ``BatchBucketer`` and ONE pair of jitted
  apply functions (``vfl._active_apply`` / ``vfl._collab_apply`` are pure
  in their params, so same-architecture tenants share XLA executables —
  registering tenant N+1 costs zero compiles).  Per-tenant ``ServeStats``
  keep accounting isolated, and ``verify_dispatch_parity`` replays every
  dispatched micro-batch through a fresh SOLO engine per tenant to prove
  the shared-cache engine is bit-identical to dedicated serving.

* **Representation-cache lifecycle** — the versioned
  ``vfl.RepresentationCache``: ``engine.refresh_cache`` installs a new
  training round's re-exported latents (version bump),
  ``engine.invalidate_cache`` models passive-party dropout — stale
  caches miss every lookup, so affected requests degrade to the
  active-only path instead of being served old latents.

``benchmarks/loadbench.py`` drives Poisson + bursty multi-tenant load
through this runtime into ``BENCH_load.json``; the CLI entry point is
``repro.launch.serve_vfl --arrival poisson|bursty``.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.serve import vfl as sv
from repro.serve.metrics import series_summary, slo_report


# ---------------------------------------------------------------------------
# arrival processes (virtual clocks, milliseconds, fully seeded)
# ---------------------------------------------------------------------------

def poisson_arrivals(n: int, rate_rps: float, *, seed: int = 0,
                     t0_ms: float = 0.0) -> np.ndarray:
    """n arrival timestamps (ms) of a homogeneous Poisson process at
    ``rate_rps`` requests/second: iid exponential inter-arrival gaps."""
    if n < 0:
        raise ValueError(f"poisson_arrivals: negative n {n}")
    if rate_rps <= 0:
        raise ValueError(f"poisson_arrivals: rate must be positive, "
                         f"got {rate_rps}")
    rng = np.random.RandomState(seed)
    gaps = rng.exponential(1000.0 / rate_rps, size=n)
    return t0_ms + np.cumsum(gaps)


def bursty_arrivals(n: int, *, rate_on_rps: float, rate_off_rps: float,
                    on_ms: float, off_ms: float, seed: int = 0,
                    t0_ms: float = 0.0) -> np.ndarray:
    """n arrival timestamps (ms) of an on/off modulated Poisson process:
    alternating ON windows (``on_ms`` long, rate ``rate_on_rps``) and OFF
    windows (``off_ms``, ``rate_off_rps`` — 0 allowed: a true lull).
    Starts in an ON window.  Memorylessness lets the gap simply be
    redrawn at each window boundary."""
    if n < 0:
        raise ValueError(f"bursty_arrivals: negative n {n}")
    if rate_on_rps <= 0 or rate_off_rps < 0:
        raise ValueError("bursty_arrivals: rate_on must be positive and "
                         "rate_off non-negative")
    if on_ms <= 0 or off_ms <= 0:
        raise ValueError("bursty_arrivals: window lengths must be positive")
    rng = np.random.RandomState(seed)
    out: List[float] = []
    t = float(t0_ms)
    on = True
    window_end = t + on_ms
    while len(out) < n:
        rate = rate_on_rps if on else rate_off_rps
        if rate <= 0:
            t = window_end
            on = not on
            window_end = t + (on_ms if on else off_ms)
            continue
        gap = rng.exponential(1000.0 / rate)
        if t + gap > window_end:
            t = window_end
            on = not on
            window_end = t + (on_ms if on else off_ms)
            continue
        t += gap
        out.append(t)
    return np.asarray(out)


@dataclass
class TimedRequest:
    """A ``ServeRequest`` with an arrival clock and a tenant label."""
    req: sv.ServeRequest
    tenant: str
    t_arrival_ms: float
    t_dispatch_ms: float = -1.0          # set when its micro-batch started
    shed: bool = False                   # refused at admission

    @property
    def rows(self) -> int:
        return len(self.req.x)

    @property
    def e2e_ms(self) -> float:
        return self.req.queue_ms + self.req.latency_ms


def make_timed_stream(x_pool, ids_pool, n_requests: int, *,
                      tenant: str = "t0", arrivals: str = "poisson",
                      rate_rps: float = 200.0, burst: Optional[dict] = None,
                      seed: int = 0, max_rows: int = 16,
                      p_known: float = 0.5, t0_ms: float = 0.0
                      ) -> List[TimedRequest]:
    """The PR-5 mixed request generator plus a virtual arrival clock.
    ``arrivals``: ``"poisson"`` at ``rate_rps``, or ``"bursty"`` with the
    on/off parameters in ``burst`` (defaults: 4x ``rate_rps`` ON for
    200 ms, ``rate_rps``/4 OFF for 200 ms)."""
    reqs = sv.make_request_stream(x_pool, ids_pool, n_requests, seed=seed,
                                  max_rows=max_rows, p_known=p_known)
    if arrivals == "poisson":
        times = poisson_arrivals(n_requests, rate_rps, seed=seed + 7919,
                                 t0_ms=t0_ms)
    elif arrivals == "bursty":
        kw = {"rate_on_rps": 4.0 * rate_rps,
              "rate_off_rps": rate_rps / 4.0,
              "on_ms": 200.0, "off_ms": 200.0}
        kw.update(burst or {})
        times = bursty_arrivals(n_requests, seed=seed + 7919, t0_ms=t0_ms,
                                **kw)
    else:
        raise ValueError(f"unknown arrival process {arrivals!r} "
                         f"(poisson | bursty)")
    return [TimedRequest(r, tenant, float(t))
            for r, t in zip(reqs, times)]


def merge_streams(*streams: Sequence[TimedRequest]) -> List[TimedRequest]:
    """Interleave per-tenant streams into one global arrival order
    (stable: simultaneous arrivals keep their input order)."""
    merged = [tr for s in streams for tr in s]
    merged.sort(key=lambda tr: tr.t_arrival_ms)
    return merged


# ---------------------------------------------------------------------------
# multi-tenant bundle registry (one bucketer, one jit cache)
# ---------------------------------------------------------------------------

class TenantRegistry:
    """Many tenants' ``ModelBundle``s served behind ONE shared
    ``BatchBucketer`` and ONE pair of jitted apply functions.

    The engine's predict bodies are pure functions of ``(params, batch)``
    (``vfl._active_apply`` / ``_collab_apply``), so the shared jit cache
    keys executables on parameter SHAPES: tenants with the same
    architecture reuse each other's compiles — registering and warming
    tenant N+1 costs zero XLA compilations (pinned by tests and by
    loadbench's steady-state compile gate)."""

    def __init__(self, *, buckets: Sequence[int] = sv.DEFAULT_BUCKETS):
        self.bucketer = sv.BatchBucketer(buckets)
        self._jit_fns = (jax.jit(sv._active_apply),
                        jax.jit(sv._collab_apply))
        self.engines: Dict[str, sv.VFLServingEngine] = {}

    def register(self, name: str, bundle: sv.ModelBundle, *,
                 quantize: Optional[str] = None) -> sv.VFLServingEngine:
        """``quantize="int8"`` registers the tenant on the quantized
        serving path (``serve.quant``); its pre-dequantized params keep
        the fp32 pytree shape, so it shares the registry's jit cache —
        mixing fp32 and int8 tenants costs zero extra compiles."""
        if name in self.engines:
            raise ValueError(f"tenant {name!r} already registered")
        engine = sv.VFLServingEngine(bundle, bucketer=self.bucketer,
                                     jit_fns=self._jit_fns,
                                     quantize=quantize)
        self.engines[name] = engine
        return engine

    def __getitem__(self, name: str) -> sv.VFLServingEngine:
        return self.engines[name]

    def __contains__(self, name: str) -> bool:
        return name in self.engines

    def __len__(self) -> int:
        return len(self.engines)

    def names(self) -> List[str]:
        return list(self.engines)

    def warmup(self) -> None:
        """Warm every bucket shape of every tenant through both paths;
        with the shared jit cache only the FIRST tenant of each distinct
        architecture actually compiles."""
        for engine in self.engines.values():
            engine.warmup()

    def reset_stats(self) -> None:
        for engine in self.engines.values():
            engine.reset_stats()

    def jit_cache_sizes(self) -> dict:
        """Executable counts of the SHARED jit cache (all tenants)."""
        out = {}
        for name, fn in zip(("active", "collab"), self._jit_fns):
            if hasattr(fn, "_cache_size"):
                out[name] = int(fn._cache_size())
        return out

    def compiled_shapes(self) -> dict:
        """Union of dispatched (path, bucket) pairs across tenants."""
        shapes = set()
        for engine in self.engines.values():
            shapes |= engine._shapes
        by_path: dict = {}
        for path, bucket in sorted(shapes):
            by_path.setdefault(path, []).append(bucket)
        return {"by_path": by_path,
                "distinct_batch_shapes": len({b for _, b in shapes})}


# ---------------------------------------------------------------------------
# the scheduler
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RuntimeConfig:
    """SLO and admission knobs for ``ServingRuntime``.

    ``slo_ms`` is the end-to-end (queue + service) latency objective;
    ``max_wait_ms`` is the queueing budget that forces a partial batch
    out (default: half the SLO, leaving the other half for service);
    ``max_queue_rows`` is the per-tenant admission bound — an arriving
    request that would push its tenant's queued rows past it is shed."""
    slo_ms: float = 100.0
    max_wait_ms: Optional[float] = None
    max_queue_rows: int = 4096

    @property
    def wait_budget_ms(self) -> float:
        return (0.5 * self.slo_ms if self.max_wait_ms is None
                else float(self.max_wait_ms))


@dataclass
class DispatchRecord:
    """One micro-batch the runtime executed (kept for parity replay)."""
    tenant: str
    t_dispatch_ms: float
    service_ms: float
    group: List[TimedRequest] = field(repr=False, default_factory=list)

    @property
    def rows(self) -> int:
        return sum(tr.rows for tr in self.group)


def _merge_ids(reqs: List[sv.ServeRequest]) -> Optional[np.ndarray]:
    """Same coalescing rule as ``serve_stream``: anonymous requests ride
    along under the never-matching filler id so an id-carrying neighbor
    keeps its cache routing."""
    if not any(r.ids is not None for r in reqs):
        return None
    return np.concatenate([
        r.ids if r.ids is not None
        else np.full(len(r.x), sv.ANON_ID, np.int64) for r in reqs])


class ServingRuntime:
    """Discrete-event serving loop over a ``TenantRegistry`` (module
    docstring).  ``service_model(rows) -> ms`` replaces the measured
    dispatch wall-clock on the VIRTUAL clock only — dispatches always
    execute for real — making scheduler behavior deterministic for
    tests."""

    def __init__(self, registry: TenantRegistry,
                 config: RuntimeConfig = RuntimeConfig(), *,
                 service_model: Optional[Callable[[int], float]] = None):
        self.registry = registry
        self.config = config
        self.service_model = service_model
        self.dispatch_log: List[DispatchRecord] = []

    # --- the event loop ----------------------------------------------------

    def run(self, stream: Sequence[TimedRequest], *,
            faults=None) -> dict:
        """Serve a merged timed stream to completion; returns the report
        dict (shared ``serve.metrics`` schema, per-tenant + overall).

        ``faults`` is a ``robustness.faults.FaultPlan`` (duck-typed: any
        object whose ``serving_events()`` yields ``t_ms``-stamped
        events).  Events fire at dispatch boundaries once the virtual
        clock passes their timestamp: ``dropout``/``stale``/``drift``
        invalidate the tenant's representation cache (subsequent lookups
        miss, requests degrade to the active-only path — never stale
        latents), ``recover`` re-installs the bundle's latents with a
        version bump.  The report gains a ``"faults"`` block with
        per-tenant accounting, including ``collab_dispatches_while_
        faulted`` — the runtime's stale-serving violation counter, which
        must stay 0."""
        cfg = self.config
        unknown = {tr.tenant for tr in stream} - set(self.registry.engines)
        if unknown:
            raise ValueError(f"stream names unregistered tenants "
                             f"{sorted(unknown)}")
        fault_events: List = []
        fault_state: Dict[str, dict] = {}
        if faults is not None:
            fault_events = list(faults.serving_events())
            bad = {e.tenant for e in fault_events} \
                - set(self.registry.engines)
            if bad:
                raise ValueError(f"fault plan names unregistered tenants "
                                 f"{sorted(str(t) for t in bad)}")
            for e in fault_events:
                fault_state.setdefault(e.tenant, {
                    "faulted": False, "kinds": [], "faulted_at_ms": None,
                    "recovered_at_ms": None,
                    "collab_dispatches_while_faulted": 0})
        fi = 0

        def apply_faults(t: float) -> None:
            nonlocal fi
            while fi < len(fault_events) and fault_events[fi].t_ms <= t:
                ev = fault_events[fi]
                fi += 1
                engine = self.registry.engines[ev.tenant]
                st = fault_state[ev.tenant]
                if ev.kind == "recover":
                    bundle = engine.bundle
                    if bundle.supports_collaborative:
                        engine.refresh_cache(bundle.cache_ids,
                                             bundle.cache_z)
                    st["faulted"] = False
                    st["recovered_at_ms"] = float(ev.t_ms)
                else:                      # dropout | stale | drift
                    engine.invalidate_cache()
                    if not st["faulted"]:
                        st["faulted_at_ms"] = float(ev.t_ms)
                    st["faulted"] = True
                    st["kinds"].append(ev.kind)

        self.dispatch_log = []
        stream = sorted(stream, key=lambda tr: tr.t_arrival_ms)
        queues: Dict[str, deque] = {n: deque() for n in self.registry.names()}
        queued_rows = {n: 0 for n in queues}
        max_rows = self.registry.bucketer.max
        wait_budget = cfg.wait_budget_ms
        served: List[TimedRequest] = []
        shed: List[TimedRequest] = []
        i, n = 0, len(stream)
        now = stream[0].t_arrival_ms if stream else 0.0
        t_first = now
        wall_t0 = time.perf_counter()

        def admit_until(t: float) -> None:
            nonlocal i
            while i < n and stream[i].t_arrival_ms <= t:
                tr = stream[i]
                i += 1
                if queued_rows[tr.tenant] + tr.rows > cfg.max_queue_rows:
                    tr.shed = True
                    eng = self.registry.engines[tr.tenant]
                    eng.stats.shed_requests += 1
                    eng.stats.shed_rows += tr.rows
                    shed.append(tr)
                else:
                    queues[tr.tenant].append(tr)
                    queued_rows[tr.tenant] += tr.rows

        while i < n or any(queues.values()):
            admit_until(now)
            apply_faults(now)
            # pick the dispatchable tenant with the oldest head-of-line
            # request: full bucket, queueing budget exhausted, or nothing
            # left to wait for (drain)
            drain = i >= n
            ready: Optional[str] = None
            for name, q in queues.items():
                if not q:
                    continue
                full = queued_rows[name] >= max_rows
                # deadline spelled EXACTLY like the idle-jump candidates
                # below: (t + w) - t can float-round below w, so comparing
                # `now - t >= w` after jumping to `t + w` would livelock
                urgent = now >= q[0].t_arrival_ms + wait_budget
                if full or urgent or drain:
                    if ready is None or \
                            q[0].t_arrival_ms < queues[ready][0].t_arrival_ms:
                        ready = name
            if ready is None:
                # idle: jump the clock to the next event (an arrival or
                # the earliest head-of-line deadline)
                candidates = [q[0].t_arrival_ms + wait_budget
                              for q in queues.values() if q]
                if i < n:
                    candidates.append(stream[i].t_arrival_ms)
                now = max(now, min(candidates))
                continue
            # coalesce FIFO up to the largest warm bucket
            q = queues[ready]
            group = [q.popleft()]
            rows = group[0].rows
            while q and rows + q[0].rows <= max_rows:
                tr = q.popleft()
                group.append(tr)
                rows += tr.rows
            queued_rows[ready] -= rows
            engine = self.registry.engines[ready]
            x = np.concatenate([tr.req.x for tr in group])
            ids = _merge_ids([tr.req for tr in group])
            c0 = engine.stats.dispatches.get("collab", 0)
            t0 = time.perf_counter()
            logits = engine.predict(x, ids)
            measured_ms = (time.perf_counter() - t0) * 1e3
            st = fault_state.get(ready)
            if st is not None and st["faulted"] and \
                    engine.stats.dispatches.get("collab", 0) > c0:
                # a faulted tenant served cached (stale) latents —
                # the invariant robustbench gates on
                st["collab_dispatches_while_faulted"] += 1
            service_ms = (measured_ms if self.service_model is None
                          else float(self.service_model(rows)))
            off = 0
            for tr in group:
                tr.req.logits = logits[off:off + tr.rows]
                off += tr.rows
                tr.t_dispatch_ms = now
                tr.req.queue_ms = now - tr.t_arrival_ms
                tr.req.latency_ms = service_ms
                engine.stats.record(tr.req.queue_ms, service_ms)
                served.append(tr)
            engine.stats.requests += len(group)
            self.dispatch_log.append(DispatchRecord(
                ready, now, service_ms, group))
            # single executor: the clock is busy for the whole dispatch
            now += service_ms
        # events stamped beyond the last dispatch still take effect (the
        # cache state must reflect the WHOLE plan, not just the served
        # window)
        apply_faults(float("inf"))
        wall_s = time.perf_counter() - wall_t0
        report = self._report(served, shed, t_first, now, wall_s)
        if faults is not None:
            tenants_block = {}
            for name, st in fault_state.items():
                engine = self.registry.engines[name]
                tenants_block[name] = {
                    **st,
                    "cache_stale": bool(engine.cache is not None
                                        and engine.cache.stale),
                    "cache_version": engine.cache_version,
                }
            report["faults"] = {
                "plan": getattr(faults, "name", "plan"),
                "events_applied": fi,
                "tenants": tenants_block,
            }
        return report

    # --- reporting ---------------------------------------------------------

    def _report(self, served: List[TimedRequest], shed: List[TimedRequest],
                t_first: float, t_end: float, wall_s: float) -> dict:
        cfg = self.config
        elapsed_ms = max(t_end - t_first, 1e-9)
        tenants = {}
        for name in self.registry.names():
            mine = [tr for tr in served if tr.tenant == name]
            mine_shed = [tr for tr in shed if tr.tenant == name]
            rows = int(sum(tr.rows for tr in mine))
            disp = [d for d in self.dispatch_log if d.tenant == name]
            tenants[name] = {
                "requests": len(mine),
                "rows": rows,
                "shed_requests": len(mine_shed),
                "shed_rows": int(sum(tr.rows for tr in mine_shed)),
                "dispatches": len(disp),
                "mean_batch_rows": round(
                    rows / len(disp), 2) if disp else 0.0,
                "rows_per_s": round(rows / (elapsed_ms / 1e3), 1),
                "latency_ms": {
                    "queue": series_summary(
                        [tr.req.queue_ms for tr in mine]),
                    "service": series_summary(
                        [tr.req.latency_ms for tr in mine]),
                    "end_to_end": series_summary(
                        [tr.e2e_ms for tr in mine]),
                },
                "slo": slo_report([tr.e2e_ms for tr in mine], cfg.slo_ms,
                                  offered=len(mine) + len(mine_shed)),
            }
        rows = int(sum(tr.rows for tr in served))
        offered = len(served) + len(shed)
        return {
            "config": {"slo_ms": cfg.slo_ms,
                       "max_wait_ms": cfg.wait_budget_ms,
                       "max_queue_rows": cfg.max_queue_rows,
                       "buckets": list(self.registry.bucketer.buckets)},
            "requests": offered,
            "served": len(served),
            "shed_requests": len(shed),
            "shed_rate": round(len(shed) / offered, 4) if offered else 0.0,
            "rows": rows,
            "dispatches": len(self.dispatch_log),
            "mean_batch_rows": round(
                rows / len(self.dispatch_log), 2) if self.dispatch_log
                else 0.0,
            "virtual_elapsed_ms": round(elapsed_ms, 3),
            "measured_wall_s": round(wall_s, 4),
            "rows_per_s": round(rows / (elapsed_ms / 1e3), 1),
            "requests_per_s": round(len(served) / (elapsed_ms / 1e3), 1),
            "latency_ms": {
                "queue": series_summary(
                    [tr.req.queue_ms for tr in served]),
                "service": series_summary(
                    [tr.req.latency_ms for tr in served]),
                "end_to_end": series_summary(
                    [tr.e2e_ms for tr in served]),
            },
            "slo": slo_report([tr.e2e_ms for tr in served], cfg.slo_ms,
                              offered=offered),
            "tenants": tenants,
            "compiled": self.registry.compiled_shapes(),
            "jit_cache_sizes": self.registry.jit_cache_sizes(),
        }


def verify_dispatch_parity(runtime: ServingRuntime,
                           bundles: Dict[str, sv.ModelBundle]) -> dict:
    """Replay every micro-batch the runtime dispatched through a FRESH
    solo ``VFLServingEngine`` per tenant (private jit cache, same bucket
    set) and compare logits bit-for-bit.  This is the multi-tenant
    isolation proof: serving behind the shared bucketer/jit cache must
    equal dedicated per-tenant serving exactly."""
    out = {}
    buckets = runtime.registry.bucketer.buckets
    for tenant, bundle in bundles.items():
        # the solo engine must mirror the tenant's quantization mode —
        # an int8 tenant's dedicated-serving twin is also int8
        q = runtime.registry[tenant].quantize \
            if tenant in runtime.registry else None
        solo = sv.VFLServingEngine(bundle, buckets=buckets, quantize=q)
        identical = True
        max_abs = 0.0
        batches = 0
        for rec in runtime.dispatch_log:
            if rec.tenant != tenant:
                continue
            reqs = [tr.req for tr in rec.group]
            x = np.concatenate([r.x for r in reqs])
            want = solo.predict(x, _merge_ids(reqs))
            got = np.concatenate([r.logits for r in reqs])
            identical = identical and np.array_equal(got, want)
            if len(got):
                max_abs = max(max_abs,
                              float(np.max(np.abs(got - want))))
            batches += 1
        out[tenant] = {"batches": batches, "bit_identical": bool(identical),
                       "max_abs_diff": max_abs}
    return out
