"""Serving steps: prefill (full forward -> last-token logits) and one-token
greedy decode against a (possibly sliding-window) KV cache / recurrent state.

``cache_pspecs`` auto-shards cache pytrees: batch dim -> dp, then the largest
mesh-divisible non-batch dim -> model (for GQA caches whose kv-head count is
smaller than the tp axis this picks the slots dim — a sequence-parallel
cache, the TPU analogue of paged/ring KV sharding).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as M
from repro.sharding.policy import batch_pspec


def prefill_step(params, cfg: ModelConfig, inputs: dict):
    lg, _ = M.logits(params, cfg, inputs)
    return lg[:, -1]


def make_decode_step(cfg: ModelConfig, window: int = 0):
    def step(params, token, cache, pos):
        logits, cache = M.decode(params, cfg, token, cache, pos, window)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache
    return step


def decode_window(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Sliding-window slots for the given decode shape (0 = full cache)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm",):
        return cfg.long_context_window
    return cfg.sliding_window


def n_cache_slots(cfg: ModelConfig, shape: ShapeConfig) -> int:
    w = decode_window(cfg, shape)
    return min(shape.seq_len, w) if w else shape.seq_len


def _batch_dim(shape: tuple, batch: int) -> int:
    for i, s in enumerate(shape):
        if s == batch:
            return i
    return -1


def cache_pspecs(cache, mesh, batch: int):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_n = sizes.get("model", 1)
    dp_axes = ("pod", "data") if "pod" in sizes else ("data",)
    dp_n = int(np.prod([sizes[a] for a in dp_axes]))
    dp = batch_pspec(mesh.axis_names)

    def spec(x):
        sh = x.shape
        ent = [None] * len(sh)
        b = _batch_dim(sh, batch)
        if b >= 0 and sh[b] % dp_n == 0 and batch > 1:
            ent[b] = dp
        # largest remaining dim divisible by the model axis
        cand = [(s, i) for i, s in enumerate(sh)
                if i != b and s % model_n == 0 and s >= model_n]
        if cand and model_n > 1:
            _, i = max(cand)
            ent[i] = "model"
        return P(*ent)

    return jax.tree.map(spec, cache)
