"""Shared serving-statistics schema for the VFL inference subsystem.

Both stream drivers — the backlog-drain ``vfl.serve_stream`` (feeds
``benchmarks/servebench.py`` -> BENCH_serve.json) and the arrival-clocked
``runtime.ServingRuntime`` (feeds ``benchmarks/loadbench.py`` ->
BENCH_load.json) — report latency through the SAME structures defined
here, so the two artifacts stay schema-compatible:

* **queueing latency** — how long a request sat in a queue (or backlog)
  before its micro-batch began executing, and
* **service latency** — the wall-clock of the micro-batch dispatch that
  completed it,

recorded as separate per-request series (a server can hide slow service
behind deep queues and vice versa — one end-to-end number cannot tell
load shedding apart from a slow kernel).  ``series_summary`` is the one
percentile block every JSON artifact embeds; ``ServeStats`` is the
per-engine (and per-tenant) accumulator; ``slo_report`` folds an
end-to-end series against a latency SLO into attainment numbers.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

#: percentiles every latency block reports (BENCH_serve / BENCH_load)
SERIES_PERCENTILES = (50, 90, 99)


def series_summary(values_ms: List[float]) -> dict:
    """The shared percentile block: count, mean, max and p50/p90/p99 of a
    latency series in milliseconds (all zeros for an empty series)."""
    if not values_ms:
        return {"count": 0, "mean": 0.0, "max": 0.0,
                **{f"p{q}": 0.0 for q in SERIES_PERCENTILES}}
    arr = np.asarray(values_ms, dtype=np.float32)
    out = {"count": int(arr.size),
           "mean": round(float(arr.mean()), 3),
           "max": round(float(arr.max()), 3)}
    for q in SERIES_PERCENTILES:
        out[f"p{q}"] = round(float(np.percentile(arr, q)), 3)
    return out


def slo_report(e2e_ms: List[float], slo_ms: float, *,
               offered: Optional[int] = None) -> dict:
    """SLO attainment over an end-to-end latency series.

    ``attainment`` is the fraction of SERVED requests inside the SLO;
    ``goodput_frac`` re-bases it on ``offered`` (served + shed) so load
    shedding cannot inflate the headline number."""
    served = len(e2e_ms)
    within = int(sum(1 for v in e2e_ms if v <= slo_ms))
    offered = served if offered is None else int(offered)
    return {
        "slo_ms": float(slo_ms),
        "served": served,
        "within_slo": within,
        "attainment": round(within / served, 4) if served else 0.0,
        "offered": offered,
        "goodput_frac": round(within / offered, 4) if offered else 0.0,
    }


@dataclass
class ServeStats:
    """Per-engine (and, in the multi-tenant runtime, per-tenant)
    accumulator.  ``queue_ms``/``service_ms`` are parallel per-request
    series appended together by the stream drivers; ``latencies_ms``
    aliases the service series for older callers of the PR-5 schema."""
    requests: int = 0
    rows: int = 0
    shed_requests: int = 0
    shed_rows: int = 0
    dispatches: Dict[str, int] = field(default_factory=dict)
    padded_rows: int = 0                 # rows of bucket padding dispatched
    queue_ms: List[float] = field(default_factory=list)
    service_ms: List[float] = field(default_factory=list)

    @property
    def latencies_ms(self) -> List[float]:
        return self.service_ms

    def record(self, queue_ms: float, service_ms: float) -> None:
        self.queue_ms.append(float(queue_ms))
        self.service_ms.append(float(service_ms))

    def e2e_ms(self) -> List[float]:
        """Per-request end-to-end latency (queue + service); requires the
        two series to be appended pairwise, which both drivers do."""
        if len(self.queue_ms) != len(self.service_ms):
            raise ValueError(
                f"queue/service series diverged "
                f"({len(self.queue_ms)} vs {len(self.service_ms)}) — "
                f"record() them pairwise")
        return [q + s for q, s in zip(self.queue_ms, self.service_ms)]

    def percentile_ms(self, q: float) -> float:
        """Service-latency percentile (the PR-5 meaning of 'latency')."""
        return float(np.percentile(self.service_ms, q)) \
            if self.service_ms else 0.0

    def latency_summary(self) -> dict:
        """The shared BENCH_serve/BENCH_load latency block: queueing and
        service as SEPARATE percentile series plus their pairwise sum."""
        return {"queue": series_summary(self.queue_ms),
                "service": series_summary(self.service_ms),
                "end_to_end": series_summary(self.e2e_ms())}

    def summary(self) -> dict:
        """Flat JSON-ready view (embedded per tenant by loadbench)."""
        return {
            "requests": self.requests,
            "rows": self.rows,
            "shed_requests": self.shed_requests,
            "shed_rows": self.shed_rows,
            "dispatches": dict(self.dispatches),
            "padded_rows": self.padded_rows,
            "latency_ms": self.latency_summary(),
        }
