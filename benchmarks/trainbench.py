"""Training-engine and experiment-harness throughput.

Default mode measures the device-resident scan engine
(``training.train``) on the paper's g1-sized autoencoder workload
(Table 3 g1_active: D -> 64 -> 128, symmetric decoder) across batch
sizes.  The retired per-batch host loop measured 6.5x slower at bs=32
and ~2.5x at bs=128 on a 2-core CPU container (PR 1); the engine's
semantics are now pinned by the stored-trace oracle
(``tests/data/train_trace.json``) instead of a live parity run.

K-party mode (``--kparty``) benchmarks the batched multi-party engine
(``training.train_many``: all K parties' g1 stages as ONE vmapped scan —
one dispatch + one host sync per epoch total) against K sequential
``training.train`` calls (K dispatch chains, K syncs per epoch), for
K in {2, 4, 8} with uneven per-party feature widths (exercising the
padded-stack layout).

Sweep mode (``--sweep``) times the declarative experiment harness: the
built-in smoke ``ExperimentSpec`` through ``repro.experiments.sweep`` —
per-method wall time for the whole protocol (PSI + training + CV), i.e.
the end-to-end cost one sweep cell pays per method.

Run:  PYTHONPATH=src python benchmarks/trainbench.py [--rows 4096]
      [--features 30] [--epochs 20] [--batches 32,64,128] [--csv]
      [--kparty] [--ks 2,4,8] [--sweep]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import autoencoder as ae
from repro.core import training


def _steps_per_sec(params, data, *, batch_size, epochs) -> float:
    kw = dict(batch_size=batch_size, max_epochs=epochs, patience=epochs,
              seed=0)
    training.train(params, data, ae.recon_loss,
                   **dict(kw, max_epochs=2))                       # warm
    t0 = time.time()
    r = training.train(params, data, ae.recon_loss, **kw)
    return r.steps_run / (time.time() - t0)


def run(rows: int = 4096, features: int = 30, epochs: int = 20,
        batch_sizes=(32, 64, 128), csv: bool = True) -> list:
    x = np.random.RandomState(0).randn(rows, features).astype(np.float32)
    params = ae.init_autoencoder(jax.random.PRNGKey(0),
                                 ae.table3_encoder("g1_active", features))
    rows_out = []
    for bs in batch_sizes:
        scan = _steps_per_sec(params, {"x": x}, batch_size=bs, epochs=epochs)
        rec = {"name": f"trainbench/g1/n{rows}/bs{bs}",
               "scan_steps_per_s": scan}
        rows_out.append(rec)
        if csv:
            print(f"{rec['name']},{1e6 / scan:.0f},scan={scan:.0f}sps",
                  flush=True)
    return rows_out


def _kparty_specs(k: int, rows: int, features: int):
    """K parties with uneven feature widths (features, features+1, ...)."""
    specs = []
    for i in range(k):
        d = features + i
        x = np.random.RandomState(i).randn(rows, d).astype(np.float32)
        params = ae.init_autoencoder(jax.random.PRNGKey(i),
                                     ae.table3_encoder("g1_passive", d))
        specs.append(training.PartySpec(params, {"x": x}, seed=i))
    return specs


def run_kparty(rows: int = 2048, features: int = 24, epochs: int = 10,
               batch_size: int = 32, ks=(2, 4, 8), csv: bool = True) -> list:
    """train_many (one vmapped scan for all K parties) vs K sequential
    training.train calls, total steps/s across parties."""
    rows_out = []
    for k in ks:
        specs = _kparty_specs(k, rows, features)
        kw = dict(batch_size=batch_size, max_epochs=epochs, patience=epochs)

        def seq():
            return [training.train(s.params, s.data, ae.recon_loss,
                                   seed=s.seed, **kw) for s in specs]

        def batched():
            return training.train_many(specs, ae.masked_recon_loss, **kw)

        for fn in (seq, batched):          # warm both compile caches
            fn()
        t0 = time.time()
        r_seq = seq()
        t_seq = time.time() - t0
        t0 = time.time()
        r_bat = batched()
        t_bat = time.time() - t0
        steps = sum(r.steps_run for r in r_seq)
        assert steps == sum(r.steps_run for r in r_bat)
        rec = {"name": f"trainbench/kparty/K{k}/n{rows}/bs{batch_size}",
               "vmapped_steps_per_s": steps / t_bat,
               "sequential_steps_per_s": steps / t_seq,
               "speedup": t_seq / t_bat}
        rows_out.append(rec)
        if csv:
            print(f"{rec['name']},{1e6 * t_bat / steps:.0f},"
                  f"vmapped={rec['vmapped_steps_per_s']:.0f}sps|"
                  f"sequential={rec['sequential_steps_per_s']:.0f}sps|"
                  f"speedup={rec['speedup']:.1f}x", flush=True)
    return rows_out


def run_sweep(epochs: int = 5, csv: bool = True) -> list:
    """Per-method wall time of one sweep cell on the built-in smoke spec
    (whole protocol: PSI + all training stages + k-fold CV).  ``epochs``
    caps every method's training budget; use a small value (<= 5) unless
    you mean to benchmark near-converged runs.

    The scenario is built ONCE outside the timed region (as in a real
    sweep cell, where all methods share it), so each row measures only
    the method's own protocol cost."""
    from dataclasses import replace

    from repro.experiments import build_scenario, get_method, sweep
    from repro.launch.experiment import smoke_spec

    spec = replace(smoke_spec(), overrides={"max_epochs": epochs})
    sweep(spec)                   # validate + warm all compile caches
    scenario = build_scenario(next(iter(spec.scenarios())))
    seed = spec.seeds[0]
    rows_out = []
    for m in spec.methods:
        mspec = replace(m, params={**spec.overrides, **m.params})
        entry = get_method(m.method)
        t0 = time.time()
        result = entry.fn(scenario, mspec, seed=seed)
        us = (time.time() - t0) * 1e6
        rec = {"name": f"trainbench/sweep/{m.row_label}/e{epochs}",
               "wall_s": us / 1e6, "accuracy": result.metrics["accuracy"]}
        rows_out.append(rec)
        if csv:
            print(f"{rec['name']},{us:.0f},"
                  f"wall={rec['wall_s']:.2f}s|acc={rec['accuracy']:.4f}",
                  flush=True)
    return rows_out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=4096)
    ap.add_argument("--features", type=int, default=30)
    ap.add_argument("--epochs", type=int, default=None,
                    help="training budget (default: 20 for the engine "
                         "modes, 5 for --sweep)")
    ap.add_argument("--batches", default="32,64,128")
    ap.add_argument("--kparty", action="store_true",
                    help="run the K-party train_many vs sequential sweep")
    ap.add_argument("--ks", default="2,4,8")
    ap.add_argument("--sweep", action="store_true",
                    help="time the declarative experiment harness "
                         "(smoke spec, per-method wall time)")
    args = ap.parse_args()
    if args.sweep:
        run_sweep(epochs=args.epochs if args.epochs is not None else 5)
    elif args.kparty:
        run_kparty(rows=args.rows, features=args.features,
                   epochs=args.epochs if args.epochs is not None else 20,
                   batch_size=int(args.batches.split(",")[0]),
                   ks=[int(k) for k in args.ks.split(",") if k])
    else:
        run(rows=args.rows, features=args.features,
            epochs=args.epochs if args.epochs is not None else 20,
            batch_sizes=[int(b) for b in args.batches.split(",") if b])


if __name__ == "__main__":
    main()
