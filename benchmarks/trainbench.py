"""Training-engine throughput: the device-resident scan engine
(``training.train``) vs the legacy per-batch host loop
(``training.train_legacy``) on the paper's g1-sized autoencoder workload
(Table 3 g1_active: D -> 64 -> 128, symmetric decoder).

Both engines run the identical model/optimizer/early-stopping math; the
legacy loop pays a per-batch device upload + a ``float(loss)`` sync every
step, the scan engine one dispatch + one sync per epoch.  Small batches are
therefore overhead-dominated (where the speedup is largest); at batch 128 a
CPU-only container is close to compute-bound and the gap narrows — on a real
accelerator every row below is far past 5x.

K-party mode (``--kparty``) benchmarks the batched multi-party engine
(``training.train_many``: all K parties' g1 stages as ONE vmapped scan —
one dispatch + one host sync per epoch total) against K sequential
``training.train`` calls (K dispatch chains, K syncs per epoch), for
K in {2, 4, 8} with uneven per-party feature widths (exercising the
padded-stack layout).

Run:  PYTHONPATH=src python benchmarks/trainbench.py [--rows 4096]
      [--features 30] [--epochs 20] [--batches 32,64,128] [--csv]
      [--kparty] [--ks 2,4,8]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import autoencoder as ae
from repro.core import training


def _steps_per_sec(train_fn, params, data, *, batch_size, epochs) -> float:
    kw = dict(batch_size=batch_size, max_epochs=epochs, patience=epochs,
              seed=0)
    train_fn(params, data, ae.recon_loss, **dict(kw, max_epochs=2))  # warm
    t0 = time.time()
    r = train_fn(params, data, ae.recon_loss, **kw)
    return r.steps_run / (time.time() - t0)


def run(rows: int = 4096, features: int = 30, epochs: int = 20,
        batch_sizes=(32, 64, 128), csv: bool = True) -> list:
    x = np.random.RandomState(0).randn(rows, features).astype(np.float32)
    params = ae.init_autoencoder(jax.random.PRNGKey(0),
                                 ae.table3_encoder("g1_active", features))
    rows_out = []
    for bs in batch_sizes:
        scan = _steps_per_sec(training.train, params, {"x": x},
                              batch_size=bs, epochs=epochs)
        legacy = _steps_per_sec(training.train_legacy, params, {"x": x},
                                batch_size=bs, epochs=epochs)
        rec = {"name": f"trainbench/g1/n{rows}/bs{bs}",
               "scan_steps_per_s": scan, "legacy_steps_per_s": legacy,
               "speedup": scan / legacy}
        rows_out.append(rec)
        if csv:
            print(f"{rec['name']},{1e6 / scan:.0f},"
                  f"scan={scan:.0f}sps|legacy={legacy:.0f}sps|"
                  f"speedup={rec['speedup']:.1f}x", flush=True)
    return rows_out


def _kparty_specs(k: int, rows: int, features: int):
    """K parties with uneven feature widths (features, features+1, ...)."""
    specs = []
    for i in range(k):
        d = features + i
        x = np.random.RandomState(i).randn(rows, d).astype(np.float32)
        params = ae.init_autoencoder(jax.random.PRNGKey(i),
                                     ae.table3_encoder("g1_passive", d))
        specs.append(training.PartySpec(params, {"x": x}, seed=i))
    return specs


def run_kparty(rows: int = 2048, features: int = 24, epochs: int = 10,
               batch_size: int = 32, ks=(2, 4, 8), csv: bool = True) -> list:
    """train_many (one vmapped scan for all K parties) vs K sequential
    training.train calls, total steps/s across parties."""
    rows_out = []
    for k in ks:
        specs = _kparty_specs(k, rows, features)
        kw = dict(batch_size=batch_size, max_epochs=epochs, patience=epochs)

        def seq():
            return [training.train(s.params, s.data, ae.recon_loss,
                                   seed=s.seed, **kw) for s in specs]

        def batched():
            return training.train_many(specs, ae.masked_recon_loss, **kw)

        for fn in (seq, batched):          # warm both compile caches
            fn()
        t0 = time.time()
        r_seq = seq()
        t_seq = time.time() - t0
        t0 = time.time()
        r_bat = batched()
        t_bat = time.time() - t0
        steps = sum(r.steps_run for r in r_seq)
        assert steps == sum(r.steps_run for r in r_bat)
        rec = {"name": f"trainbench/kparty/K{k}/n{rows}/bs{batch_size}",
               "vmapped_steps_per_s": steps / t_bat,
               "sequential_steps_per_s": steps / t_seq,
               "speedup": t_seq / t_bat}
        rows_out.append(rec)
        if csv:
            print(f"{rec['name']},{1e6 * t_bat / steps:.0f},"
                  f"vmapped={rec['vmapped_steps_per_s']:.0f}sps|"
                  f"sequential={rec['sequential_steps_per_s']:.0f}sps|"
                  f"speedup={rec['speedup']:.1f}x", flush=True)
    return rows_out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=4096)
    ap.add_argument("--features", type=int, default=30)
    ap.add_argument("--epochs", type=int, default=20)
    ap.add_argument("--batches", default="32,64,128")
    ap.add_argument("--kparty", action="store_true",
                    help="run the K-party train_many vs sequential sweep")
    ap.add_argument("--ks", default="2,4,8")
    args = ap.parse_args()
    if args.kparty:
        run_kparty(rows=args.rows, features=args.features,
                   epochs=args.epochs,
                   batch_size=int(args.batches.split(",")[0]),
                   ks=[int(k) for k in args.ks.split(",") if k])
    else:
        run(rows=args.rows, features=args.features, epochs=args.epochs,
            batch_sizes=[int(b) for b in args.batches.split(",") if b])


if __name__ == "__main__":
    main()
