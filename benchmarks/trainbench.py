"""Training-engine and experiment-harness throughput.

Default mode measures the device-resident scan engine
(``training.train``) on the paper's g1-sized autoencoder workload
(Table 3 g1_active: D -> 64 -> 128, symmetric decoder) across batch
sizes.  The retired per-batch host loop measured 6.5x slower at bs=32
and ~2.5x at bs=128 on a 2-core CPU container (PR 1); the engine's
semantics are now pinned by the stored-trace oracle
(``tests/data/train_trace.json``) instead of a live parity run.

K-party mode (``--kparty``) benchmarks the batched multi-party engine
(``training.train_many``: all K parties' g1 stages as ONE vmapped scan —
one dispatch + one host sync per epoch total) against K sequential
``training.train`` calls (K dispatch chains, K syncs per epoch), for
K in {2, 4, 8} with uneven per-party feature widths (exercising the
padded-stack layout).

Sweep mode (``--sweep``) benchmarks the replica-lane sweep engine: one
grid cell x S seed replicas of the full APC-VFL protocol, replicated
(every stage S stacked lanes of one vmapped scan, via
``run_apcvfl_replicated``) vs sequential (S independent protocol runs),
plus the per-method wall time of the smoke spec.  Writes a
machine-readable ``BENCH_sweep.json`` (wall-clock per path, engine
steps/s, per-stage lane occupancy) so the perf trajectory accrues across
PRs; CI uploads it as an artifact.

Scale mode (``--scale``) is the million-row device-count sweep: a
10^6-row x 8-party x multi-seed synthetic vertical partition
(``data.scale.make_scale_lanes``, built device-resident) trained through
the mesh-sharded fused lane engine (``train_lanes(..., mesh=...)``) at
increasing device counts.  Each device count runs in a SUBPROCESS with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the flag must be
set before jax initializes), writing one cell; the parent aggregates the
scaling curve into ``BENCH_scale.json``.  ``--smoke`` shrinks the grid
for CI.  On a single physical CPU the fake devices share cores, so the
curve demonstrates the sharding mechanism and its overhead, not a true
speedup; on real multi-device hosts the same flag-free path shards
across accelerators.

Run:  PYTHONPATH=src python benchmarks/trainbench.py [--rows 4096]
      [--features 30] [--epochs 20] [--batches 32,64,128] [--csv]
      [--kparty] [--ks 2,4,8] [--sweep] [--seeds 5]
      [--out BENCH_sweep.json]
      [--scale [--smoke] [--devices-list 1,2,4,8] [--parties 8]
       [--scale-seeds 2] [--scale-bs 8192] [--dp 1]
       [--scale-out BENCH_scale.json]]
"""
from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
import tempfile
import time

import jax
import numpy as np

from repro.core import autoencoder as ae
from repro.core import training


def _steps_per_sec(params, data, *, batch_size, epochs) -> float:
    kw = dict(batch_size=batch_size, max_epochs=epochs, patience=epochs,
              seed=0)
    training.train(params, data, ae.recon_loss,
                   **dict(kw, max_epochs=2))                       # warm
    t0 = time.time()
    r = training.train(params, data, ae.recon_loss, **kw)
    return r.steps_run / (time.time() - t0)


def run(rows: int = 4096, features: int = 30, epochs: int = 20,
        batch_sizes=(32, 64, 128), csv: bool = True) -> list:
    x = np.random.RandomState(0).randn(rows, features).astype(np.float32)
    params = ae.init_autoencoder(jax.random.PRNGKey(0),
                                 ae.table3_encoder("g1_active", features))
    rows_out = []
    for bs in batch_sizes:
        scan = _steps_per_sec(params, {"x": x}, batch_size=bs, epochs=epochs)
        rec = {"name": f"trainbench/g1/n{rows}/bs{bs}",
               "scan_steps_per_s": scan}
        rows_out.append(rec)
        if csv:
            print(f"{rec['name']},{1e6 / scan:.0f},scan={scan:.0f}sps",
                  flush=True)
    return rows_out


def _kparty_specs(k: int, rows: int, features: int):
    """K parties with uneven feature widths (features, features+1, ...)."""
    specs = []
    for i in range(k):
        d = features + i
        x = np.random.RandomState(i).randn(rows, d).astype(np.float32)
        params = ae.init_autoencoder(jax.random.PRNGKey(i),
                                     ae.table3_encoder("g1_passive", d))
        specs.append(training.PartySpec(params, {"x": x}, seed=i))
    return specs


def run_kparty(rows: int = 2048, features: int = 24, epochs: int = 10,
               batch_size: int = 32, ks=(2, 4, 8), csv: bool = True) -> list:
    """train_many (one vmapped scan for all K parties) vs K sequential
    training.train calls, total steps/s across parties."""
    rows_out = []
    for k in ks:
        specs = _kparty_specs(k, rows, features)
        kw = dict(batch_size=batch_size, max_epochs=epochs, patience=epochs)

        def seq():
            return [training.train(s.params, s.data, ae.recon_loss,
                                   seed=s.seed, **kw) for s in specs]

        def batched():
            return training.train_many(specs, ae.masked_recon_loss, **kw)

        for fn in (seq, batched):          # warm both compile caches
            fn()
        t0 = time.time()
        r_seq = seq()
        t_seq = time.time() - t0
        t0 = time.time()
        r_bat = batched()
        t_bat = time.time() - t0
        steps = sum(r.steps_run for r in r_seq)
        assert steps == sum(r.steps_run for r in r_bat)
        rec = {"name": f"trainbench/kparty/K{k}/n{rows}/bs{batch_size}",
               "vmapped_steps_per_s": steps / t_bat,
               "sequential_steps_per_s": steps / t_seq,
               "speedup": t_seq / t_bat}
        rows_out.append(rec)
        if csv:
            print(f"{rec['name']},{1e6 * t_bat / steps:.0f},"
                  f"vmapped={rec['vmapped_steps_per_s']:.0f}sps|"
                  f"sequential={rec['sequential_steps_per_s']:.0f}sps|"
                  f"speedup={rec['speedup']:.1f}x", flush=True)
    return rows_out


def _cell_steps(epochs: dict, stage_rows: dict, bs: int) -> int:
    """Total engine steps one protocol run took, reconstructed from its
    per-stage epoch counts and the engine's batching contract
    (``n_batches = n_tr // bs`` after the shared lane-group clamp;
    identical for the sequential and the replica-lane path at equal
    shapes).  ``stage_rows``: stage -> (rows, lane_group) where stages in
    one group share the batch-size clamp."""
    def n_tr(n):
        return n - max(int(n * 0.1), 1)

    groups: dict = {}
    for n, g in stage_rows.values():
        groups.setdefault(g, []).append(n_tr(n))
    bs_g = {g: max(min([bs] + v), 1) for g, v in groups.items()}
    return sum(epochs.get(st, 0) * (n_tr(n) // bs_g[g])
               for st, (n, g) in stage_rows.items())


def _stage_rows(method: str, scenario) -> dict:
    n_a, n_p = len(scenario.active.x), len(scenario.passive.x)
    n_al = scenario.n_aligned
    if method == "apcvfl":
        return {"g1_active": (n_a, "g1"), "g1_passive": (n_p, "g1"),
                "g2": (n_al, "g2"), "g3": (n_a, "g3")}
    return {"g1_active": (n_al, "g1"), "g1_passive": (n_al, "g1"),
            "g2": (n_al, "g2")}              # aligned-only variant


def _lane_occupancy(results) -> dict:
    """Per-stage lane occupancy of a replica group: mean over lanes of
    (own epochs / slowest lane's epochs) — 1.0 means no lane idled behind
    a slower sibling, lower means early-stopped lanes spent epochs
    frozen-stepping."""
    out = {}
    for stage, lanes in (("g1", ["g1_active", "g1_passive"]),
                         ("g2", ["g2"]), ("g3", ["g3"])):
        eps = [r.epochs[k] for r in results for k in lanes
               if k in r.epochs]
        if eps:
            out[stage] = float(np.mean(eps) / max(eps))
    return out


# PR 5's recorded sweep speedups (replicated / sequential wall) on the
# 2-core CI container — the yardstick each re-run reports its delta
# against.  On a SINGLE-core host the ratio's ceiling is ~1.06: lane
# batching removes dispatch overhead but the lanes' arithmetic still
# shares one core, so a lower ratio there is expected, not a regression
# (BENCH_scale.json demonstrates the same engine scaling with real
# device counts).
_SWEEP_BASELINE_SPEEDUP = {"apcvfl": 0.82, "apcvfl_aligned_only": 1.14}


def _median_wall(fn, repeats: int):
    """Median warm wall-clock of ``fn`` over ``repeats`` runs, plus the
    LAST run's result and the total compile count (snapshotted — the
    tally is a live property)."""
    from repro.analysis import guards

    walls, res = [], None
    with guards.compile_counter() as tally:
        for _ in range(repeats):
            t0 = time.perf_counter()
            res = fn()
            walls.append(time.perf_counter() - t0)
    compiles = tally.count
    return float(np.median(walls)), res, compiles


def run_sweep(epochs: int = 30, seeds: int = 5, repeats: int = 3,
              out_json="BENCH_sweep.json", csv: bool = True) -> dict:
    """Replica-lane sweep engine vs sequential per-seed execution: one
    grid cell x ``seeds`` replicas for each method with a replicated
    runner (full apcvfl protocol + the aligned-only adaptation), plus the
    smoke spec's per-method wall times; writes ``out_json``.

    Methodology: both paths are compile-warmed first, then timed
    ``repeats`` times each and the MEDIAN wall is reported (single warm
    runs on a shared CPU container jitter by 10-20%, which used to
    swallow the effect being measured).  Each record carries the PR 5
    baseline speedup and this run's delta against it, plus a machine
    note — on a 1-core host the ratio is dispatch-overhead-only (see
    ``_SWEEP_BASELINE_SPEEDUP``).

    ``bs=32`` keeps the stages in the dispatch-bound regime the lane
    engine targets (PR 2's K-party setting).  Expect the aligned-only
    grid to show the larger win: both of its stages (g1, g2) batch well,
    while full apcvfl is diluted by the compute-bound g3 and the
    memory-bound k-fold probe, which lane-batching cannot speed up on
    CPU."""
    from dataclasses import replace

    from repro.experiments import (ExperimentSpec, MethodSpec,
                                   build_scenario, get_method, sweep)
    from repro.launch.experiment import smoke_spec

    try:
        n_cpu = len(os.sched_getaffinity(0))
    except AttributeError:          # non-linux fallback
        n_cpu = os.cpu_count() or 1

    # --- replicated vs sequential, per replicable method ------------------
    bs = 32
    replicas = {}
    grids = (MethodSpec("apcvfl"),
             MethodSpec("apcvfl_aligned_only", params={"test_size": 40}))
    for m in grids:
        spec = ExperimentSpec(
            name=f"bench-{m.method}", dataset="bcw", aligned=(150,),
            seeds=tuple(range(seeds)), methods=(m,),
            overrides={"max_epochs": epochs, "patience": epochs,
                       "batch_size": bs})
        seq_spec = replace(spec, replicate=False)
        for s in (seq_spec, spec):        # warm both compile caches
            sweep(s)
        t_seq, seq_res, seq_compiles = _median_wall(
            lambda: sweep(seq_spec), repeats)
        t_rep, rep_res, rep_compiles = _median_wall(
            lambda: sweep(spec), repeats)

        cell = build_scenario(next(iter(spec.scenarios())))
        steps = sum(_cell_steps(r.epochs, _stage_rows(m.method, cell), bs)
                    for r in seq_res)
        baseline = _SWEEP_BASELINE_SPEEDUP.get(m.method)
        speedup = round(t_seq / t_rep, 3)
        bench = {
            "name": f"trainbench/sweep/{m.method}/S{seeds}/e{epochs}",
            "grid": {"dataset": "bcw", "aligned": 150, "seeds": seeds,
                     "method": m.method, "max_epochs": epochs,
                     "batch_size": bs},
            "total_steps": steps,
            "repeats": repeats,
            "sequential_wall_s": round(t_seq, 3),
            "replicated_wall_s": round(t_rep, 3),
            "speedup": speedup,
            "baseline_speedup": baseline,
            "speedup_delta_vs_baseline":
                round(speedup - baseline, 3) if baseline else None,
            "cpus_visible": n_cpu,
            "machine_note": (
                "medians of warm repeats; on a 1-core host the "
                "replicated/sequential ratio measures dispatch overhead "
                "only (ceiling ~1.06) — the PR 5 baseline was a 2-core "
                "container" if n_cpu <= 1 else
                "medians of warm repeats on a multi-core host"),
            "sequential_steps_per_s": round(steps / t_seq, 1),
            "replicated_steps_per_s": round(steps / t_rep, 1),
            "lane_occupancy": _lane_occupancy(rep_res),
            # warmed runs: compile stability proof (0 = jit caches held)
            "xla_compiles_warm_sequential": seq_compiles,
            "xla_compiles_warm_replicated": rep_compiles,
        }
        replicas[m.method] = bench
        if csv:
            print(f"{bench['name']},{1e6 * t_rep / max(steps, 1):.0f},"
                  f"replicated={bench['replicated_steps_per_s']:.0f}sps|"
                  f"sequential={bench['sequential_steps_per_s']:.0f}sps|"
                  f"speedup={bench['speedup']:.2f}x|"
                  f"baseline={baseline}x", flush=True)

    # --- per-method wall time of one smoke-spec cell ----------------------
    mspec_all = replace(smoke_spec(), overrides={"max_epochs": epochs})
    sweep(mspec_all)              # validate + warm remaining compiles
    scenario = build_scenario(next(iter(mspec_all.scenarios())))
    seed = mspec_all.seeds[0]
    rows_out = []
    for m in mspec_all.methods:
        mspec = replace(m, params={**mspec_all.overrides, **m.params})
        entry = get_method(m.method)
        t0 = time.time()
        result = entry.fn(scenario, mspec, seed=seed)
        us = (time.time() - t0) * 1e6
        rec = {"name": f"trainbench/sweep/{m.row_label}/e{epochs}",
               "wall_s": us / 1e6, "accuracy": result.metrics["accuracy"]}
        rows_out.append(rec)
        if csv:
            print(f"{rec['name']},{us:.0f},"
                  f"wall={rec['wall_s']:.2f}s|acc={rec['accuracy']:.4f}",
                  flush=True)

    payload = {"replicas": replicas, "per_method": rows_out}
    if out_json:
        with open(out_json, "w") as fh:
            json.dump(payload, fh, indent=1)
        if csv:
            print(f"# wrote {out_json}", flush=True)
    return payload


# ---------------------------------------------------------------------------
# scale mode: million-row device-count sweep (BENCH_scale.json)
# ---------------------------------------------------------------------------

def run_scale_cell(*, devices: int, rows: int, parties: int, seeds: int,
                   features: int, epochs: int, batch_size: int, dp: int,
                   cell_out: str) -> dict:
    """One device count, measured inside the subprocess that owns the
    matching ``XLA_FLAGS``: generate the lanes device-resident, train all
    party x seed lanes through the mesh-sharded fused engine, record
    cold (compile+run) and warm wall clock."""
    from repro.data.scale import make_scale_lanes
    from repro.launch.mesh import make_lane_mesh

    assert devices % dp == 0, (devices, dp)
    mesh = make_lane_mesh(lane=devices // dp, data=dp)
    t0 = time.time()
    lanes = make_scale_lanes(rows, parties, n_features=features,
                             seeds=tuple(range(seeds)), mesh=mesh)
    jax.block_until_ready([sp.data["x"] for sp in lanes])
    gen_s = time.time() - t0

    kw = dict(batch_size=batch_size, max_epochs=epochs, patience=epochs,
              mesh=mesh, shard_rows=dp > 1)
    t0 = time.time()
    results = training.train_lanes(lanes, ae.masked_recon_loss, **kw)
    cold_s = time.time() - t0
    t0 = time.time()
    results = training.train_lanes(lanes, ae.masked_recon_loss, **kw)
    warm_s = time.time() - t0

    steps = int(sum(r.steps_run for r in results))
    cell = {
        "devices": devices,
        "jax_device_count": jax.device_count(),
        "mesh": {"lane": devices // dp, "data": dp},
        "lanes": len(lanes),
        "gen_s": round(gen_s, 3),
        "train_cold_s": round(cold_s, 3),
        "train_warm_s": round(warm_s, 3),
        "steps": steps,
        "steps_per_s_warm": round(steps / warm_s, 2),
        "rows_per_s_warm": round(steps * batch_size / warm_s, 1),
        "final_train_loss": float(np.mean([r.train_loss[-1]
                                           for r in results])),
    }
    with open(cell_out, "w") as fh:
        json.dump(cell, fh)
    return cell


def _cell_env(devices: int) -> dict:
    """Child env with exactly one force_host_platform_device_count flag."""
    env = dict(os.environ)
    flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                   env.get("XLA_FLAGS", "")).strip()
    env["XLA_FLAGS"] = (f"{flags} --xla_force_host_platform_device_count="
                        f"{devices}").strip()
    return env


def run_scale(*, rows: int = 1_000_000, parties: int = 8, seeds: int = 2,
              features: int = 16, epochs: int = 2, batch_size: int = 8192,
              dp: int = 1, device_counts=(1, 2, 4, 8),
              out_json: str = "BENCH_scale.json", csv: bool = True) -> dict:
    """Parent of the device-count sweep: one subprocess per device count
    (``XLA_FLAGS`` must exist before jax initializes, so in-process
    re-meshing is impossible), aggregated into ``out_json``."""
    cells = []
    for n in device_counts:
        with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as fh:
            cell_out = fh.name
        cmd = [sys.executable, os.path.abspath(__file__), "--scale-cell",
               "--cell-devices", str(n), "--rows", str(rows),
               "--parties", str(parties), "--scale-seeds", str(seeds),
               "--features", str(features), "--epochs", str(epochs),
               "--scale-bs", str(batch_size), "--dp", str(dp),
               "--cell-out", cell_out]
        t0 = time.time()
        proc = subprocess.run(cmd, env=_cell_env(n))
        if proc.returncode != 0:
            raise RuntimeError(f"scale cell devices={n} failed "
                               f"(exit {proc.returncode})")
        with open(cell_out) as fh:
            cell = json.load(fh)
        os.unlink(cell_out)
        cell["subprocess_s"] = round(time.time() - t0, 3)
        cells.append(cell)
        if csv:
            print(f"trainbench/scale/dev{n},"
                  f"{1e6 * cell['train_warm_s'] / max(cell['steps'], 1):.0f},"
                  f"warm={cell['train_warm_s']:.2f}s|"
                  f"cold={cell['train_cold_s']:.2f}s|"
                  f"{cell['rows_per_s_warm']:.0f}rows/s", flush=True)

    base = cells[0]["train_warm_s"]
    payload = {
        "grid": {"rows": rows, "parties": parties, "seeds": seeds,
                 "lanes": parties * seeds, "features": features,
                 "epochs": epochs, "batch_size": batch_size, "dp": dp,
                 "device_counts": list(device_counts)},
        "cells": cells,
        "speedup_vs_1dev": {str(c["devices"]): round(base
                                                     / c["train_warm_s"], 3)
                            for c in cells},
    }
    if out_json:
        with open(out_json, "w") as fh:
            json.dump(payload, fh, indent=1)
        if csv:
            print(f"# wrote {out_json}", flush=True)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=4096)
    ap.add_argument("--features", type=int, default=30)
    ap.add_argument("--epochs", type=int, default=None,
                    help="training budget (default: 20; 30 for --sweep)")
    ap.add_argument("--batches", default="32,64,128")
    ap.add_argument("--kparty", action="store_true",
                    help="run the K-party train_many vs sequential sweep")
    ap.add_argument("--ks", default="2,4,8")
    ap.add_argument("--sweep", action="store_true",
                    help="benchmark the replica-lane sweep engine "
                         "(replicated vs sequential seeds) and the "
                         "per-method harness; writes --out")
    ap.add_argument("--seeds", type=int, default=5,
                    help="seed replicas for the --sweep benchmark")
    ap.add_argument("--out", default="BENCH_sweep.json",
                    help="--sweep JSON output path ('' to skip)")
    ap.add_argument("--scale", action="store_true",
                    help="million-row device-count sweep through the "
                         "mesh-sharded lane engine; writes --scale-out")
    ap.add_argument("--smoke", action="store_true",
                    help="shrink the --scale grid for CI")
    ap.add_argument("--devices-list", default="",
                    help="--scale device counts (default 1,2,4,8; "
                         "smoke 1,2)")
    ap.add_argument("--parties", type=int, default=None)
    ap.add_argument("--scale-seeds", type=int, default=2)
    ap.add_argument("--scale-bs", type=int, default=None)
    ap.add_argument("--dp", type=int, default=1,
                    help="row-sharding (data axis) devices per lane group")
    ap.add_argument("--scale-out", default="BENCH_scale.json")
    ap.add_argument("--scale-cell", action="store_true",
                    help="internal: run one device-count cell in this "
                         "process")
    ap.add_argument("--cell-devices", type=int, default=1)
    ap.add_argument("--cell-out", default="")
    args = ap.parse_args()
    if args.scale_cell:
        run_scale_cell(devices=args.cell_devices, rows=args.rows,
                       parties=args.parties or 8, seeds=args.scale_seeds,
                       features=args.features, epochs=args.epochs or 2,
                       batch_size=args.scale_bs or 8192, dp=args.dp,
                       cell_out=args.cell_out)
    elif args.scale:
        smoke = args.smoke
        devs = ([int(d) for d in args.devices_list.split(",") if d]
                or ([1, 2] if smoke else [1, 2, 4, 8]))
        run_scale(
            rows=args.rows if args.rows != 4096 else
            (16_384 if smoke else 1_000_000),
            parties=args.parties or (4 if smoke else 8),
            seeds=args.scale_seeds,
            features=args.features if args.features != 30 else 16,
            epochs=args.epochs or 2,
            batch_size=args.scale_bs or (512 if smoke else 8192),
            dp=args.dp, device_counts=devs, out_json=args.scale_out)
    elif args.sweep:
        run_sweep(epochs=args.epochs if args.epochs is not None else 30,
                  seeds=args.seeds, out_json=args.out)
    elif args.kparty:
        run_kparty(rows=args.rows, features=args.features,
                   epochs=args.epochs if args.epochs is not None else 20,
                   batch_size=int(args.batches.split(",")[0]),
                   ks=[int(k) for k in args.ks.split(",") if k])
    else:
        run(rows=args.rows, features=args.features,
            epochs=args.epochs if args.epochs is not None else 20,
            batch_sizes=[int(b) for b in args.batches.split(",") if b])


if __name__ == "__main__":
    main()
