"""Online-serving benchmark for the VFL inference subsystem
(``repro.serve.vfl``): bucketed batched engine vs naive per-request jit
dispatch, over a mixed-size request stream.

Trains a small APC-VFL model, exports its ``ModelBundle``, warms the
engine's bucket shapes, then drives a 10k-request stream whose sizes are
uniform in [1, max_rows] — the worst case for naive dispatch, which jits
once per DISTINCT request size, while the bucketer keeps every dispatch on
one of ~5 padded power-of-two shapes.  The naive baseline runs the same
jitted predict body per request at its exact shape (measured on a subset,
throughput extrapolates linearly: every request is an independent
dispatch).

Writes ``BENCH_serve.json``: throughput (rows/s, req/s), p50/p99
service-time latency PLUS the shared ``serve.metrics`` latency block
(queueing — here backlog-drain wait — and service as separate percentile
series, the same schema ``BENCH_load.json`` uses), cache hit-rate,
per-path dispatch and compile counts, an **int8 section** (the same
stream through ``VFLServingEngine(quantize="int8")`` with the pinned
``serve.quant.parity_report`` vs fp32), and the acceptance block
(distinct batch shapes <= 6, bucketed throughput >= 5x naive, int8
throughput >= 0.9x fp32 inside the parity bounds).  The live
arrival-clocked load benchmark is ``benchmarks/loadbench.py``.

Run:  PYTHONPATH=src python benchmarks/servebench.py [--smoke]
      [--requests 10000] [--max-rows 100] [--epochs 15] [--naive-sample
      400] [--out BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from repro.core import pipeline
from repro.data.synthetic import make_dataset
from repro.data.vertical import make_scenario
from repro.serve import vfl as sv

MAX_BATCH_SHAPES = 6          # acceptance: distinct compiled batch shapes
MIN_SPEEDUP = 5.0             # acceptance: bucketed vs naive throughput


def run(*, requests: int = 10_000, max_rows: int = 100, epochs: int = 15,
        aligned: int = 150, naive_sample: int = 400, seed: int = 0,
        p_known: float = 0.5, out_json: str = "BENCH_serve.json") -> dict:
    ds = make_dataset("bcw", seed=seed)
    sc = make_scenario(ds, n_active_features=5, n_aligned=aligned,
                       seed=seed)
    t0 = time.time()
    result = pipeline.run_apcvfl(sc, seed=seed, max_epochs=epochs)
    train_s = time.time() - t0
    bundle = sv.export_bundle(result, sc)
    print(f"# trained apcvfl in {train_s:.1f}s "
          f"(acc={result.metrics['accuracy']:.4f}); bundle: "
          f"{bundle.meta['n_cached']} cached latents", flush=True)

    stream = sv.make_request_stream(sc.active.x, sc.active.ids, requests,
                                    seed=seed + 1, max_rows=max_rows,
                                    p_known=p_known)

    # --- bucketed batched engine (warm: compiles happen per bucket) -------
    from repro.analysis import guards
    engine = sv.VFLServingEngine(bundle)
    with guards.compile_counter() as warm_tally:
        engine.warmup()
    with guards.compile_counter() as stream_tally:
        bucketed = sv.serve_stream(engine, stream)
    bucketed["xla_compiles_warmup"] = warm_tally.count
    bucketed["xla_compiles_stream"] = stream_tally.count
    print(f"servebench/bucketed/r{requests},"
          f"{1e6 * bucketed['wall_s'] / max(bucketed['rows'], 1):.1f},"
          f"rows_per_s={bucketed['rows_per_s']:.0f}|"
          f"p50={bucketed['latency_ms_p50']}ms|"
          f"p99={bucketed['latency_ms_p99']}ms|"
          f"hit_rate={bucketed['cache_hit_rate']}", flush=True)

    # --- naive per-request jit dispatch (one compile per distinct size) ---
    import jax
    naive_fn = jax.jit(sv._active_apply)      # fresh jit: separate cache
    sample = stream[:min(naive_sample, len(stream))]
    t0 = time.perf_counter()
    for r in sample:
        np.asarray(naive_fn(engine._p_active, jnp.asarray(r.x, jnp.float32)))
    naive_s = time.perf_counter() - t0
    naive_rows = int(sum(len(r.x) for r in sample))
    naive = {
        "requests": len(sample),
        "rows": naive_rows,
        "wall_s": round(naive_s, 4),
        "rows_per_s": round(naive_rows / max(naive_s, 1e-9), 1),
        "requests_per_s": round(len(sample) / max(naive_s, 1e-9), 1),
        "compiles": (int(naive_fn._cache_size())
                     if hasattr(naive_fn, "_cache_size") else None),
    }
    print(f"servebench/naive/r{len(sample)},"
          f"{1e6 * naive_s / max(naive_rows, 1):.1f},"
          f"rows_per_s={naive['rows_per_s']:.0f}|"
          f"compiles={naive['compiles']}", flush=True)

    # --- int8 quantized path: same stream, pinned fp32 parity -------------
    from repro.serve import quant
    q_engine = sv.VFLServingEngine(bundle, quantize="int8")
    q_engine.warmup()
    q_stream = sv.make_request_stream(sc.active.x, sc.active.ids, requests,
                                      seed=seed + 1, max_rows=max_rows,
                                      p_known=p_known)
    with guards.compile_counter() as q_tally:
        quantized = sv.serve_stream(q_engine, q_stream)
    quantized["xla_compiles_stream"] = q_tally.count
    parity = quant.parity_report(bundle, sc.active.x, sc.active.y,
                                 n_classes=sc.n_classes)
    quantized["parity"] = parity
    print(f"servebench/int8/r{requests},"
          f"{1e6 * quantized['wall_s'] / max(quantized['rows'], 1):.1f},"
          f"rows_per_s={quantized['rows_per_s']:.0f}|"
          f"max_dlogit={parity['max_abs_logit_delta']:.4f}|"
          f"flip_rate={parity['pred_flip_rate']:.4f}|"
          f"f1_delta={parity['f1_macro_delta']:.4f}|"
          f"compression={parity['compression']}x", flush=True)

    speedup = bucketed["rows_per_s"] / max(naive["rows_per_s"], 1e-9)
    shapes = bucketed["compiled"]["distinct_batch_shapes"]
    acceptance = {
        "distinct_batch_shapes": shapes,
        "max_batch_shapes": MAX_BATCH_SHAPES,
        "shapes_ok": shapes <= MAX_BATCH_SHAPES,
        "throughput_speedup_vs_naive": round(speedup, 2),
        "min_speedup": MIN_SPEEDUP,
        "speedup_ok": speedup >= MIN_SPEEDUP,
        "xla_compiles_stream": bucketed["xla_compiles_stream"],
        "stream_compiles_ok": bucketed["xla_compiles_stream"] == 0,
        # int8 acceptance: no slower than fp32 (pre-dequantized serving
        # params keep the jitted fp32 path; 0.9 absorbs runner noise) and
        # inside the pinned parity bounds of serve.quant
        "int8_rows_per_s": quantized["rows_per_s"],
        "int8_throughput_ratio": round(
            quantized["rows_per_s"] / max(bucketed["rows_per_s"], 1e-9), 3),
        "int8_throughput_ok":
            quantized["rows_per_s"] >= 0.9 * bucketed["rows_per_s"],
        "int8_parity_ok": (
            parity["max_abs_logit_delta"] <= quant.MAX_LOGIT_DELTA
            and parity["rel_logit_delta"] <= quant.MAX_REL_LOGIT_DELTA
            and parity["f1_macro_delta"] <= quant.MAX_F1_DELTA),
    }
    print(f"# acceptance: {shapes} batch shapes "
          f"(<= {MAX_BATCH_SHAPES}: {acceptance['shapes_ok']}), "
          f"{speedup:.1f}x naive throughput "
          f"(>= {MIN_SPEEDUP}x: {acceptance['speedup_ok']}), "
          f"{bucketed['xla_compiles_stream']} warmed-stream compiles "
          f"(== 0: {acceptance['stream_compiles_ok']}), "
          f"int8 {acceptance['int8_throughput_ratio']}x fp32 "
          f"(ok: {acceptance['int8_throughput_ok']}), "
          f"int8 parity ok: {acceptance['int8_parity_ok']}", flush=True)

    payload = {
        "name": f"servebench/bcw/r{requests}/mr{max_rows}",
        "train": {"epochs": epochs, "wall_s": round(train_s, 2),
                  "accuracy": result.metrics["accuracy"]},
        "stream": {"requests": requests, "max_rows": max_rows,
                   "p_known": p_known, "seed": seed},
        "bucketed": bucketed,
        "naive": naive,
        "int8": quantized,
        "acceptance": acceptance,
    }
    if out_json:
        with open(out_json, "w") as fh:
            json.dump(payload, fh, indent=1)
        print(f"# wrote {out_json}", flush=True)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=10_000)
    ap.add_argument("--max-rows", type=int, default=100)
    ap.add_argument("--epochs", type=int, default=15)
    ap.add_argument("--aligned", type=int, default=150)
    ap.add_argument("--naive-sample", type=int, default=400)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--p-known", type=float, default=0.5)
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: 2 training epochs, naive sample 200 "
                         "(the 10k-request stream is kept — it IS the "
                         "acceptance workload)")
    ap.add_argument("--out", default="BENCH_serve.json",
                    help="JSON output path ('' to skip)")
    args = ap.parse_args()
    if args.smoke:
        args.epochs = min(args.epochs, 2)
        args.naive_sample = min(args.naive_sample, 200)
    run(requests=args.requests, max_rows=args.max_rows, epochs=args.epochs,
        aligned=args.aligned, naive_sample=args.naive_sample,
        seed=args.seed, p_known=args.p_known, out_json=args.out)


if __name__ == "__main__":
    main()
