"""Kernel microbench: wall-time of the jitted jnp reference paths on CPU
(the Pallas kernels themselves are TPU-target; interpret mode timing is
not meaningful for perf, so the CSV reports the XLA-compiled reference
and the kernel/oracle max-abs-error as the derived column).

Every fused kernel gets a row: flash attention, the Eq. 5 distill loss,
the lane-MLP forward AND its closed-form VJP, the fused probe step
(loss/dW/db), and the int8 dequant matmul.  The errors are the point —
each row carries a pinned bound (``ERROR_BOUNDS``) and the run writes
``BENCH_kernels.json`` with per-kernel ``ok`` flags; CI gates on the
aggregate (``acceptance.ok``), so a kernel whose math drifts from its
oracle fails the build, not just a local test run.

Run:  PYTHONPATH=src python benchmarks/kernelbench.py
      [--out BENCH_kernels.json]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.ref import (flash_attention_ref, fused_distill_loss_ref,
                               int8_matmul_ref, mlp2_ref, probe_grad_ref)

# pinned max-abs-error bound per kernel row (vs the jnp oracle, fp32).
# lane_mlp/probe/int8 are closed-form identical math — their error is
# pure float reassociation, orders of magnitude under these bounds.
ERROR_BOUNDS = {
    "flash_attention": 1e-4,
    "fused_distill": 1e-5,
    "lane_mlp_fwd": 1e-4,
    "lane_mlp_grad": 1e-5,     # relative (see the grad row below)
    "probe_step": 1e-4,
    "int8_matmul": 1e-5,
}


def _time(f, *args, n=5):
    f(*args)  # compile + warm
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def _maxerr(*pairs) -> float:
    return max(float(jnp.max(jnp.abs(a - b))) for a, b in pairs)


def run(csv=True, out_json: str = "BENCH_kernels.json"):
    if csv:
        print("name,us_per_call,derived")
    key = jax.random.PRNGKey(0)
    rows = []

    B, H, S, hd = 2, 4, 512, 64
    q, k, v = [jax.random.normal(kk, (B, H, S, hd))
               for kk in jax.random.split(key, 3)]
    ref = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
    us = _time(ref, q, k, v)
    kern = ops.flash_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                               jnp.swapaxes(v, 1, 2), causal=True)
    err = _maxerr((jnp.swapaxes(kern, 1, 2), ref(q, k, v)))
    rows.append(("flash_attention", us, err))

    Bd, D, M = 4096, 32, 256
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (Bd, D))
    xh = jax.random.normal(ks[1], (Bd, D))
    z = jax.random.normal(ks[2], (Bd, M))
    zt = jax.random.normal(ks[3], (Bd, M))
    mask = (jax.random.uniform(ks[4], (Bd,)) > 0.5).astype(jnp.float32)
    ref2 = jax.jit(lambda *a: fused_distill_loss_ref(*a, lam=0.01))
    us2 = _time(ref2, x, xh, z, zt, mask)
    err2 = _maxerr((ops.fused_distill_loss(x, xh, z, zt, mask),
                    ref2(x, xh, z, zt, mask)))
    rows.append(("fused_distill", us2, err2))

    # --- lane MLP: fused 2-layer forward + closed-form VJP ---------------
    km = jax.random.split(key, 6)
    Bm, din, dh, dout = 256, 30, 64, 128
    mx = jax.random.normal(km[0], (Bm, din))
    w0 = jax.random.normal(km[1], (din, dh)) / jnp.sqrt(din)
    b0 = jax.random.normal(km[2], (dh,)) * 0.1
    w1 = jax.random.normal(km[3], (dh, dout)) / jnp.sqrt(dh)
    b1 = jax.random.normal(km[4], (dout,)) * 0.1
    mref = jax.jit(mlp2_ref)
    us3 = _time(mref, mx, w0, b0, w1, b1)
    err3 = _maxerr((ops.fused_mlp2(mx, w0, b0, w1, b1),
                    mref(mx, w0, b0, w1, b1)))
    rows.append(("lane_mlp_fwd", us3, err3))

    # grad row: RELATIVE error (sum-of-squares grads scale with the
    # output magnitude; absolute error would track that scale, not the
    # kernel's accuracy)
    loss_k = lambda *a: jnp.sum(jnp.square(ops.fused_mlp2(*a)))
    loss_r = lambda *a: jnp.sum(jnp.square(mlp2_ref(*a)))
    gref = jax.jit(jax.grad(loss_r, argnums=(0, 1, 2, 3, 4)))
    us4 = _time(gref, mx, w0, b0, w1, b1)
    gk = jax.grad(loss_k, argnums=(0, 1, 2, 3, 4))(mx, w0, b0, w1, b1)
    gr = gref(mx, w0, b0, w1, b1)
    err4 = max(
        float(jnp.max(jnp.abs(a - b)) / jnp.maximum(jnp.max(jnp.abs(b)),
                                                    1.0))
        for a, b in zip(gk, gr))
    rows.append(("lane_mlp_grad", us4, err4))

    # --- fused probe step: loss/dW/db in one pass ------------------------
    kp = jax.random.split(key, 4)
    n, d, c = 512, 128, 4
    px = jax.random.normal(kp[0], (n, d))
    pw = jax.random.normal(kp[1], (d, c)) * 0.1
    pb = jax.random.normal(kp[2], (c,)) * 0.1
    py = jax.random.randint(kp[3], (n,), 0, c)
    prw = (jax.random.uniform(key, (n,)) > 0.3).astype(jnp.float32)
    pref = jax.jit(probe_grad_ref)
    us5 = _time(pref, pw, pb, px, py, prw)
    got = ops.probe_grad_step(pw, pb, px, py, prw)
    want = pref(pw, pb, px, py, prw)
    err5 = _maxerr(*zip(got, want))
    rows.append(("probe_step", us5, err5))

    # --- int8 dequant matmul (the quantized serving GEMM) ----------------
    ki = jax.random.split(key, 3)
    xi = jax.random.normal(ki[0], (256, 128))
    wf = jax.random.normal(ki[1], (128, 64))
    scale = jnp.max(jnp.abs(wf), axis=0) / 127.0
    wq = jnp.clip(jnp.round(wf / scale[None, :]), -127, 127).astype(jnp.int8)
    bi = jax.random.normal(ki[2], (64,)) * 0.1
    iref = jax.jit(int8_matmul_ref)
    us6 = _time(iref, xi, wq, scale, bi)
    err6 = _maxerr((ops.int8_matmul(xi, wq, scale, bi),
                    iref(xi, wq, scale, bi)))
    rows.append(("int8_matmul", us6, err6))

    recs = []
    for name, us, err in rows:
        bound = ERROR_BOUNDS[name]
        recs.append({"kernel": name, "ref_us_per_call": round(us, 1),
                     "max_abs_err": err, "bound": bound,
                     "ok": err <= bound})
        print(f"kernel/{name}_ref_cpu,{us:.1f},maxerr={err:.2e}|"
              f"bound={bound:.0e}|ok={err <= bound}", flush=True)
    payload = {
        "name": "kernelbench/cpu-interpret",
        "backend": jax.default_backend(),
        "kernels": recs,
        "acceptance": {"all_within_bounds": all(r["ok"] for r in recs),
                       "ok": all(r["ok"] for r in recs)},
    }
    if out_json:
        with open(out_json, "w") as fh:
            json.dump(payload, fh, indent=1)
        print(f"# wrote {out_json}", flush=True)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_kernels.json",
                    help="JSON output path ('' to skip)")
    args = ap.parse_args()
    run(out_json=args.out)


if __name__ == "__main__":
    main()
