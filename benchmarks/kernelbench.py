"""Kernel microbench: wall-time of the jitted jnp reference paths on CPU
(the Pallas kernels themselves are TPU-target; interpret mode timing is not
meaningful for perf, so the CSV reports the XLA-compiled reference and the
kernel/oracle max-abs-error as the derived column)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.ref import flash_attention_ref, fused_distill_loss_ref


def _time(f, *args, n=5):
    f(*args)  # compile + warm
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run(csv=True):
    if csv:
        print("name,us_per_call,derived")
    key = jax.random.PRNGKey(0)
    rows = []

    B, H, S, hd = 2, 4, 512, 64
    q, k, v = [jax.random.normal(kk, (B, H, S, hd))
               for kk in jax.random.split(key, 3)]
    ref = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
    us = _time(ref, q, k, v)
    kern = ops.flash_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                               jnp.swapaxes(v, 1, 2), causal=True)
    err = float(jnp.max(jnp.abs(jnp.swapaxes(kern, 1, 2) - ref(q, k, v))))
    rows.append(("kernel/flash_attention_ref_cpu", us, f"maxerr={err:.2e}"))

    Bd, D, M = 4096, 32, 256
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (Bd, D))
    xh = jax.random.normal(ks[1], (Bd, D))
    z = jax.random.normal(ks[2], (Bd, M))
    zt = jax.random.normal(ks[3], (Bd, M))
    mask = (jax.random.uniform(ks[4], (Bd,)) > 0.5).astype(jnp.float32)
    ref2 = jax.jit(lambda *a: fused_distill_loss_ref(*a, lam=0.01))
    us2 = _time(ref2, x, xh, z, zt, mask)
    err2 = float(jnp.abs(ops.fused_distill_loss(x, xh, z, zt, mask)
                         - ref2(x, xh, z, zt, mask)))
    rows.append(("kernel/fused_distill_ref_cpu", us2, f"maxerr={err2:.2e}"))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    run()
