"""Multi-tenant load benchmark for the live VFL serving runtime
(``repro.serve.runtime``) — the "millions of users" artifact.

Trains one small APC-VFL model per tenant, registers every exported
``ModelBundle`` behind ONE ``TenantRegistry`` (shared bucketer + shared
jit cache — warming tenant N+1 must cost zero XLA compiles), then drives
three load segments through the SLO-aware micro-batching scheduler:

* **poisson** — steady memoryless traffic per tenant;
* **bursty**  — on/off modulated flash-crowd traffic;
* **overload** — a short burst far past capacity against a small
  admission bound, proving load shedding engages (shed rate > 0) while
  admitted requests still complete;
* **int8** — every tenant re-registered as a quantized twin
  (``serve.quant``) behind the SAME registry: warming the twins must
  cost zero compiles (pre-dequantized params keep the fp32 pytree
  shape), the segment's rows/s must hold >= 0.9x the fp32 poisson
  segment, and each tenant's fp32-vs-int8 parity must sit inside the
  pinned ``serve.quant`` bounds.

Each segment reports queueing latency and service latency as SEPARATE
percentile series (the ``serve.metrics`` schema BENCH_serve.json also
uses), per-tenant rows/s, SLO attainment, and shed rate — and replays
every dispatched micro-batch through a fresh solo ``VFLServingEngine``
per tenant to prove bit-identical parity with dedicated serving.

Writes ``BENCH_load.json`` with the acceptance block gated in CI:
SLO attainment >= the ``load_stream.slo_attainment_min`` budget
(``ANALYSIS_budgets.json``) under Poisson AND bursty arrivals, zero
steady-state XLA compiles (via ``analysis.guards.compile_counter``),
zero incremental compiles registering same-architecture tenants,
bit-identical per-tenant parity, and shedding exercised under overload.

Run:  PYTHONPATH=src python benchmarks/loadbench.py [--smoke]
      [--tenants 3] [--requests 2000] [--rate-rps 400] [--slo-ms 100]
      [--epochs 15] [--out BENCH_load.json]
"""
from __future__ import annotations

import argparse
import json
import time

from repro.analysis import guards
from repro.core import pipeline
from repro.data.synthetic import make_dataset
from repro.data.vertical import make_scenario
from repro.serve import runtime as rt
from repro.serve import vfl as sv


def _segment(registry, bundles, scenarios, *, arrivals: str,
             requests: int, rate_rps: float, slo_ms: float,
             max_queue_rows: int, max_rows: int, seed: int,
             burst: dict | None = None,
             names: list | None = None) -> dict:
    """One load segment: per-tenant timed streams -> merged -> runtime,
    with steady-state compiles counted and dispatch parity replayed.
    ``names`` restricts the segment to a tenant subset (the int8 segment
    drives only the quantized twins)."""
    streams = []
    for k, name in enumerate(names if names is not None
                             else registry.names()):
        sc = scenarios[name]
        streams.append(rt.make_timed_stream(
            sc.active.x, sc.active.ids, requests, tenant=name,
            arrivals=arrivals, rate_rps=rate_rps, burst=burst,
            seed=seed + 101 * k, max_rows=max_rows))
    runtime = rt.ServingRuntime(
        registry, rt.RuntimeConfig(slo_ms=slo_ms,
                                   max_queue_rows=max_queue_rows))
    registry.reset_stats()
    with guards.compile_counter() as steady:
        report = runtime.run(rt.merge_streams(*streams))
    report["xla_compiles_stream"] = steady.count
    report["parity"] = rt.verify_dispatch_parity(runtime, bundles)
    return report


def run(*, tenants: int = 3, requests: int = 2000, rate_rps: float = 400.0,
        slo_ms: float = 100.0, max_rows: int = 24, max_queue_rows: int = 4096,
        epochs: int = 15, aligned: int = 150, seed: int = 0,
        out_json: str = "BENCH_load.json") -> dict:
    if tenants < 3:
        raise ValueError("loadbench is a multi-tenant benchmark: "
                         "--tenants must be >= 3")
    budgets = guards.load_budgets()["load_stream"]

    # --- one trained model per tenant (distinct seeds = distinct params) --
    bundles, scenarios, train_log = {}, {}, []
    t0 = time.time()
    for k in range(tenants):
        name = f"tenant{k}"
        ds = make_dataset("bcw", seed=seed + k)
        sc = make_scenario(ds, n_active_features=5, n_aligned=aligned,
                           seed=seed + k)
        result = pipeline.run_apcvfl(sc, seed=seed + k, max_epochs=epochs)
        bundles[name] = sv.export_bundle(result, sc)
        scenarios[name] = sc
        train_log.append({"tenant": name, "seed": seed + k,
                          "accuracy": result.metrics["accuracy"]})
        print(f"# trained {name} (seed {seed + k}): "
              f"acc={result.metrics['accuracy']:.4f}", flush=True)
    train_s = time.time() - t0

    # --- registry: many bundles, ONE bucketer, ONE jit cache ---------------
    registry = rt.TenantRegistry()
    first = next(iter(bundles))
    registry.register(first, bundles[first])
    with guards.compile_counter() as warm0:
        registry[first].warmup()
    with guards.compile_counter() as warm_rest:
        for name, b in bundles.items():
            if name != first:
                registry.register(name, b)
                registry[name].warmup()
    # snapshot now: CompileTally.count is LIVE (global counter minus
    # start), so reading it after later segments would inflate it
    warm0_compiles = warm0.count
    incr_compiles = warm_rest.count
    print(f"# warmup: {warm0_compiles} compiles for {first}, "
          f"{incr_compiles} incremental for the other "
          f"{tenants - 1} tenants (shared jit cache)", flush=True)

    seg_kw = dict(requests=requests, rate_rps=rate_rps, slo_ms=slo_ms,
                  max_queue_rows=max_queue_rows, max_rows=max_rows,
                  seed=seed + 1)
    segments = {}
    for mode in ("poisson", "bursty"):
        rep = _segment(registry, bundles, scenarios, arrivals=mode,
                       **seg_kw)
        segments[mode] = rep
        lat = rep["latency_ms"]
        print(f"loadbench/{mode}/t{tenants}x{requests},"
              f"rows_per_s={rep['rows_per_s']:.0f}|"
              f"queue_p50={lat['queue']['p50']}ms|"
              f"queue_p99={lat['queue']['p99']}ms|"
              f"service_p50={lat['service']['p50']}ms|"
              f"service_p99={lat['service']['p99']}ms|"
              f"slo={rep['slo']['attainment']}|"
              f"shed={rep['shed_rate']}|"
              f"compiles={rep['xla_compiles_stream']}", flush=True)

    # --- overload: prove admission control sheds instead of melting -------
    overload = _segment(
        registry, bundles, scenarios, arrivals="bursty",
        requests=max(50, requests // 4), rate_rps=rate_rps * 20,
        slo_ms=slo_ms, max_queue_rows=max(registry.bucketer.max, 128),
        max_rows=max_rows, seed=seed + 2,
        burst={"rate_on_rps": rate_rps * 40, "rate_off_rps": rate_rps,
               "on_ms": 100.0, "off_ms": 50.0})
    segments["overload"] = overload
    print(f"loadbench/overload,shed_rate={overload['shed_rate']}|"
          f"served={overload['served']}|"
          f"slo={overload['slo']['attainment']}", flush=True)

    # --- int8: quantized twins behind the SAME registry -------------------
    # each tenant gets an int8 twin (serve.quant); pre-dequantized params
    # keep the fp32 pytree shape, so warming the twins must cost zero
    # compiles — a mixed fp32/int8 fleet shares one jit cache.
    from repro.serve import quant
    int8_names, bundles_int8 = [], {}
    with guards.compile_counter() as warm_int8:
        for name in list(bundles):
            twin = f"{name}-int8"
            registry.register(twin, bundles[name], quantize="int8")
            registry[twin].warmup()
            scenarios[twin] = scenarios[name]
            bundles_int8[twin] = bundles[name]
            int8_names.append(twin)
    int8_warm_compiles = warm_int8.count        # snapshot (live counter)
    int8_seg = _segment(registry, bundles_int8, scenarios,
                        arrivals="poisson", names=int8_names, **seg_kw)
    parity_bounds = {}
    for name in bundles:
        sc = scenarios[name]
        parity_bounds[name] = quant.parity_report(
            bundles[name], sc.active.x, sc.active.y,
            n_classes=sc.n_classes)
    int8_seg["quant_parity"] = parity_bounds
    int8_seg["warm_compiles"] = int8_warm_compiles
    segments["int8"] = int8_seg
    worst_dlogit = max(p["max_abs_logit_delta"]
                       for p in parity_bounds.values())
    worst_f1 = max(p["f1_macro_delta"] for p in parity_bounds.values())
    print(f"loadbench/int8/t{tenants}x{requests},"
          f"rows_per_s={int8_seg['rows_per_s']:.0f}|"
          f"warm_compiles={int8_warm_compiles}|"
          f"max_dlogit={worst_dlogit:.4f}|"
          f"max_f1_delta={worst_f1:.4f}|"
          f"slo={int8_seg['slo']['attainment']}", flush=True)

    parity_ok = all(
        t["bit_identical"]
        for mode in ("poisson", "bursty")
        for t in segments[mode]["parity"].values())
    acceptance = {
        "tenants": tenants,
        "slo_ms": slo_ms,
        "slo_attainment_min": budgets["slo_attainment_min"],
        "slo_attainment_poisson": segments["poisson"]["slo"]["attainment"],
        "slo_attainment_bursty": segments["bursty"]["slo"]["attainment"],
        "slo_ok": all(
            segments[m]["slo"]["attainment"] >= budgets["slo_attainment_min"]
            for m in ("poisson", "bursty")),
        "stream_compiles": [segments[m]["xla_compiles_stream"]
                            for m in ("poisson", "bursty")],
        "stream_compiles_ok": all(
            segments[m]["xla_compiles_stream"] <= budgets["warm_compiles"]
            for m in ("poisson", "bursty")),
        "tenant_incremental_compiles": incr_compiles,
        "shared_jit_ok": incr_compiles == 0,
        "parity_bit_identical": parity_ok,
        "shed_exercised": overload["shed_rate"] > 0.0,
        # int8 twins: zero extra compiles, throughput at parity with the
        # fp32 poisson segment, quantization error inside serve.quant's
        # pinned bounds, dispatch bit-identical to dedicated int8 serving
        "int8_warm_compiles": int8_warm_compiles,
        "int8_shared_jit_ok": int8_warm_compiles == 0,
        "int8_rows_per_s": int8_seg["rows_per_s"],
        "int8_throughput_ratio": round(
            int8_seg["rows_per_s"]
            / max(segments["poisson"]["rows_per_s"], 1e-9), 3),
        "int8_throughput_ok": int8_seg["rows_per_s"]
            >= 0.9 * segments["poisson"]["rows_per_s"],
        "int8_parity_bound_ok": (
            worst_dlogit <= quant.MAX_LOGIT_DELTA
            and worst_f1 <= quant.MAX_F1_DELTA),
        "int8_dispatch_bit_identical": all(
            t["bit_identical"] for t in int8_seg["parity"].values()),
    }
    acceptance["ok"] = all((
        acceptance["slo_ok"], acceptance["stream_compiles_ok"],
        acceptance["shared_jit_ok"], acceptance["parity_bit_identical"],
        acceptance["shed_exercised"], acceptance["int8_shared_jit_ok"],
        acceptance["int8_parity_bound_ok"],
        acceptance["int8_dispatch_bit_identical"]))
    print(f"# acceptance: slo_ok={acceptance['slo_ok']} "
          f"({acceptance['slo_attainment_poisson']}/"
          f"{acceptance['slo_attainment_bursty']} >= "
          f"{budgets['slo_attainment_min']}), "
          f"stream_compiles_ok={acceptance['stream_compiles_ok']}, "
          f"shared_jit_ok={acceptance['shared_jit_ok']}, "
          f"parity={parity_ok}, "
          f"shed_exercised={acceptance['shed_exercised']}, "
          f"int8: shared_jit={acceptance['int8_shared_jit_ok']} "
          f"throughput={acceptance['int8_throughput_ratio']}x "
          f"parity_bound={acceptance['int8_parity_bound_ok']} "
          f"bit_identical={acceptance['int8_dispatch_bit_identical']}",
          flush=True)

    payload = {
        "name": f"loadbench/bcw/t{tenants}/r{requests}/rps{rate_rps:g}",
        "train": {"epochs": epochs, "wall_s": round(train_s, 2),
                  "tenants": train_log},
        "warmup": {"first_tenant_compiles": warm0_compiles,
                   "incremental_tenant_compiles": incr_compiles},
        "config": {"tenants": tenants, "requests_per_tenant": requests,
                   "rate_rps_per_tenant": rate_rps, "slo_ms": slo_ms,
                   "max_rows": max_rows, "max_queue_rows": max_queue_rows,
                   "seed": seed},
        "poisson": segments["poisson"],
        "bursty": segments["bursty"],
        "overload": segments["overload"],
        "int8": segments["int8"],
        "acceptance": acceptance,
    }
    if out_json:
        with open(out_json, "w") as fh:
            json.dump(payload, fh, indent=1)
        print(f"# wrote {out_json}", flush=True)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--requests", type=int, default=2000,
                    help="requests per tenant per segment")
    ap.add_argument("--rate-rps", type=float, default=400.0,
                    help="per-tenant Poisson rate (bursty modulates it)")
    ap.add_argument("--slo-ms", type=float, default=100.0)
    ap.add_argument("--max-rows", type=int, default=24,
                    help="largest request size in the streams")
    ap.add_argument("--queue-rows", type=int, default=4096,
                    help="per-tenant admission bound (rows)")
    ap.add_argument("--epochs", type=int, default=15)
    ap.add_argument("--aligned", type=int, default=150)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: 2 training epochs, 400 requests per "
                         "tenant, 200 ms SLO (generous for the noisy "
                         "2-core runner)")
    ap.add_argument("--out", default="BENCH_load.json",
                    help="JSON output path ('' to skip)")
    args = ap.parse_args()
    if args.smoke:
        args.epochs = min(args.epochs, 2)
        args.requests = min(args.requests, 400)
        args.rate_rps = min(args.rate_rps, 200.0)
        args.slo_ms = max(args.slo_ms, 200.0)
    run(tenants=args.tenants, requests=args.requests,
        rate_rps=args.rate_rps, slo_ms=args.slo_ms, max_rows=args.max_rows,
        max_queue_rows=args.queue_rows, epochs=args.epochs,
        aligned=args.aligned, seed=args.seed, out_json=args.out)


if __name__ == "__main__":
    main()
