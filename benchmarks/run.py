# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
#   comm_footprint  -> paper Fig. 6 + Table 2 communication columns
#   kernelbench     -> Pallas kernel oracle checks + CPU ref timings
#   trainbench      -> scan training engine / K-party vmapped throughput
#   roofline        -> VFL-stage FLOPs/bytes via compiled cost_analysis
#   accuracy        -> paper Fig. 5 (quick subset) + Table 2 metric columns
#
# ``--full`` runs the complete 48-scenario accuracy sweep (hours on 1 CPU).
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--max-epochs", type=int, default=40)
    ap.add_argument("--skip-accuracy", action="store_true")
    args = ap.parse_args()

    from benchmarks import (accuracy, comm_footprint, kernelbench, roofline,
                            trainbench)

    print("name,us_per_call,derived")
    for row in comm_footprint.rows():
        tag = f"comm/{row['dataset']}/{row['aligned']}"
        print(f"{tag},0,apcvfl={row['apcvfl_MB']:.2f}MB|"
              f"vfedtrans={row['vfedtrans_MB']:.2f}MB|"
              f"splitnn={row['splitnn_MB']:.2f}MB|"
              f"xVFT={row['saving_vs_vfedtrans']:.1f}|"
              f"xSplitNN={row['saving_vs_splitnn']:.1f}")
    sys.stdout.flush()

    kernelbench.run(csv=False)
    sys.stdout.flush()

    trainbench.run(rows=2048, epochs=10)
    sys.stdout.flush()

    roofline.run(csv=False, out_json="BENCH_roofline.json")
    sys.stdout.flush()

    if not args.skip_accuracy:
        accuracy.run(quick=not args.full, max_epochs=args.max_epochs)


if __name__ == '__main__':
    main()
