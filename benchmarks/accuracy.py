"""Paper Fig. 5 / Table 2 accuracy benchmarks on the synthetic datasets.

Default is --quick (one dataset, two scenarios) so ``benchmarks.run`` stays
CPU-tractable; the full 48-scenario sweep is ``--full`` (hours on 1 core).
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core import pipeline, splitnn, vfedtrans
from repro.data.synthetic import (ALIGNED_SCENARIOS, PAPER_METRIC,
                                  make_dataset)
from repro.data.vertical import make_scenario


def bench_scenarios(dataset: str, aligns, feats, max_epochs: int,
                    seed: int = 0, csv=True):
    ds = make_dataset(dataset, seed=seed)
    metric = PAPER_METRIC[dataset]
    rows = []
    for n_al in aligns:
        for a in feats:
            sc = make_scenario(ds, n_active_features=a, n_aligned=n_al,
                               seed=seed)
            t0 = time.time()
            loc = pipeline.run_local_baseline(sc, seed=seed)[metric]
            ab = pipeline.run_apcvfl(sc, ablation=True,
                                     max_epochs=max_epochs).metrics[metric]
            r = pipeline.run_apcvfl(sc, max_epochs=max_epochs)
            vt = vfedtrans.run_vfedtrans(sc, max_epochs=max_epochs)
            us = (time.time() - t0) * 1e6
            derived = (f"local={loc:.4f}|ablation={ab:.4f}|"
                       f"apcvfl={r.metrics[metric]:.4f}|"
                       f"vfedtrans={vt.metrics[metric]:.4f}|"
                       f"apcvfl_MB={r.channel.total_mb():.2f}|"
                       f"vfedtrans_MB={vt.channel.total_mb():.2f}")
            name = f"accuracy/{dataset}/al{n_al}/a{a}"
            if csv:
                print(f"{name},{us:.0f},{derived}", flush=True)
            rows.append({"name": name, "metric": metric, "local": loc,
                         "ablation": ab, "apcvfl": r.metrics[metric],
                         "vfedtrans": vt.metrics[metric],
                         "apcvfl_MB": r.channel.total_mb(),
                         "vfedtrans_MB": vt.channel.total_mb()})
    return rows


def bench_splitnn(dataset: str, aligns, max_epochs: int, seed=0, csv=True):
    """Table 2: classical fully-aligned comparison."""
    ds = make_dataset(dataset, seed=seed)
    metric = PAPER_METRIC[dataset]
    test_size = 50 if dataset == "bcw" else 500
    rows = []
    for n_al in aligns:
        sc = make_scenario(ds, n_active_features=5, n_aligned=n_al, seed=seed)
        t0 = time.time()
        sn = splitnn.run_splitnn(sc, max_epochs=max_epochs,
                                 test_size=test_size, seed=seed)
        apc = pipeline.run_apcvfl_aligned_only(sc, max_epochs=max_epochs,
                                               test_size=test_size, seed=seed)
        us = (time.time() - t0) * 1e6
        derived = (f"splitnn={sn.metrics[metric]:.4f}|"
                   f"apcvfl={apc['metrics'][metric]:.4f}|"
                   f"splitnn_rounds={sn.rounds}|apcvfl_rounds=1|"
                   f"splitnn_MB={sn.comm_bytes/2**20:.2f}|"
                   f"apcvfl_MB={apc['channel'].total_mb():.2f}")
        name = f"table2/{dataset}/al{n_al}"
        if csv:
            print(f"{name},{us:.0f},{derived}", flush=True)
        rows.append({"name": name, "splitnn": sn.metrics[metric],
                     "apcvfl": apc["metrics"][metric],
                     "splitnn_rounds": sn.rounds,
                     "splitnn_MB": sn.comm_bytes / 2**20})
    return rows


def run(quick=True, max_epochs=40, csv=True):
    rows = []
    if quick:
        rows += bench_scenarios("bcw", [250, 100], [5, 2], max_epochs, csv=csv)
        rows += bench_splitnn("bcw", [250, 100], max_epochs, csv=csv)
    else:
        for dsname in ["mimic3", "bcw", "credit"]:
            rows += bench_scenarios(dsname, ALIGNED_SCENARIOS[dsname],
                                    [5, 4, 3, 2], max_epochs, csv=csv)
            rows += bench_splitnn(dsname, ALIGNED_SCENARIOS[dsname],
                                  max_epochs, csv=csv)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--max-epochs", type=int, default=40)
    args = ap.parse_args()
    run(quick=not args.full, max_epochs=args.max_epochs)
