"""Paper Fig. 5 / Table 2 accuracy benchmarks on the synthetic datasets,
run through the declarative experiment API (one ExperimentSpec per grid,
``repro.experiments.sweep`` executes it).

Default is --quick (one dataset, two scenarios) so ``benchmarks.run`` stays
CPU-tractable; the full 48-scenario sweep is ``--full`` (hours on 1 core).
"""
from __future__ import annotations

import argparse
import time

from repro.data.synthetic import ALIGNED_SCENARIOS, PAPER_METRIC
from repro.experiments import ExperimentSpec, MethodSpec, sweep


def _by_cell(results):
    """Group a sweep's results back into (n_aligned -> {label: RunResult})."""
    cells: dict = {}
    for r in results:
        cells.setdefault(r.scenario["n_aligned"], {})[r.method] = r
    return cells


def bench_scenarios(dataset: str, aligns, feats, max_epochs: int,
                    seed: int = 0, csv=True):
    """Fig. 5 grid: local / ablation / apcvfl / vfedtrans per (aligned, a).

    One single-cell spec per (aligned, a) so each CSV row reports its OWN
    measured wall time (large n_aligned cells are genuinely slower);
    within a cell the sweep still shares one built scenario across all
    methods."""
    metric = PAPER_METRIC[dataset]
    rows = []
    for a in feats:
        for n_al in aligns:
            spec = ExperimentSpec(
                name=f"accuracy/{dataset}/al{n_al}/a{a}", dataset=dataset,
                aligned=(n_al,), n_active_features=a, seeds=(seed,),
                methods=(MethodSpec("local"),
                         MethodSpec("apcvfl", label="ablation",
                                    params={"ablation": True}),
                         MethodSpec("apcvfl"),
                         MethodSpec("vfedtrans")),
                overrides={"max_epochs": max_epochs})
            t0 = time.time()
            (by,) = _by_cell(sweep(spec)).values()
            us = (time.time() - t0) * 1e6
            r, vt = by["apcvfl"], by["vfedtrans"]
            derived = (f"local={by['local'].metrics[metric]:.4f}|"
                       f"ablation={by['ablation'].metrics[metric]:.4f}|"
                       f"apcvfl={r.metrics[metric]:.4f}|"
                       f"vfedtrans={vt.metrics[metric]:.4f}|"
                       f"apcvfl_MB={r.comm['total_mb']:.2f}|"
                       f"vfedtrans_MB={vt.comm['total_mb']:.2f}")
            if csv:
                print(f"{spec.name},{us:.0f},{derived}", flush=True)
            rows.append({"name": spec.name, "metric": metric,
                         "local": by["local"].metrics[metric],
                         "ablation": by["ablation"].metrics[metric],
                         "apcvfl": r.metrics[metric],
                         "vfedtrans": vt.metrics[metric],
                         "apcvfl_MB": r.comm["total_mb"],
                         "vfedtrans_MB": vt.comm["total_mb"]})
    return rows


def bench_splitnn(dataset: str, aligns, max_epochs: int, seed=0, csv=True):
    """Table 2: classical fully-aligned comparison (one single-cell spec
    per alignment level, so each row's wall time is its own)."""
    metric = PAPER_METRIC[dataset]
    test_size = 50 if dataset == "bcw" else 500
    rows = []
    for n_al in aligns:
        spec = ExperimentSpec(
            name=f"table2/{dataset}/al{n_al}", dataset=dataset,
            aligned=(n_al,), n_active_features=5, seeds=(seed,),
            methods=(MethodSpec("splitnn", params={"test_size": test_size}),
                     MethodSpec("apcvfl_aligned_only",
                                params={"test_size": test_size})),
            overrides={"max_epochs": max_epochs})
        t0 = time.time()
        (by,) = _by_cell(sweep(spec)).values()
        us = (time.time() - t0) * 1e6
        sn, apc = by["splitnn"], by["apcvfl_aligned_only"]
        derived = (f"splitnn={sn.metrics[metric]:.4f}|"
                   f"apcvfl={apc.metrics[metric]:.4f}|"
                   f"splitnn_rounds={sn.rounds}|apcvfl_rounds={apc.rounds}|"
                   f"splitnn_MB={sn.comm['by_stage']['train']/2**20:.2f}|"
                   f"apcvfl_MB={apc.comm['total_mb']:.2f}")
        if csv:
            print(f"{spec.name},{us:.0f},{derived}", flush=True)
        rows.append({"name": spec.name, "splitnn": sn.metrics[metric],
                     "apcvfl": apc.metrics[metric],
                     "splitnn_rounds": sn.rounds,
                     "splitnn_MB": sn.comm["by_stage"]["train"] / 2**20})
    return rows


def run(quick=True, max_epochs=40, csv=True):
    rows = []
    if quick:
        rows += bench_scenarios("bcw", [250, 100], [5, 2], max_epochs, csv=csv)
        rows += bench_splitnn("bcw", [250, 100], max_epochs, csv=csv)
    else:
        for dsname in ["mimic3", "bcw", "credit"]:
            rows += bench_scenarios(dsname, ALIGNED_SCENARIOS[dsname],
                                    [5, 4, 3, 2], max_epochs, csv=csv)
            rows += bench_splitnn(dsname, ALIGNED_SCENARIOS[dsname],
                                  max_epochs, csv=csv)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--max-epochs", type=int, default=40)
    args = ap.parse_args()
    run(quick=not args.full, max_epochs=args.max_epochs)
