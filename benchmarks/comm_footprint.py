"""Paper Fig. 6 + Table 2 communication columns: analytic footprints
(Appendix E formulas) for APC-VFL / SplitNN / VFedTrans across the paper's
alignment scenarios, plus the measured-bytes cross-check from the simulated
channel."""
from __future__ import annotations

import numpy as np

from repro.core import comm
from repro.data.synthetic import ALIGNED_SCENARIOS, SPECS

# paper Table 2 SplitNN epoch statistics are dataset-realization dependent
# (early stopping); these are the paper's mean round counts for reference
PAPER_SPLITNN_ROUNDS = {
    ("mimic3", 10000): 4290, ("mimic3", 7500): 3146,
    ("mimic3", 5000): 634, ("mimic3", 2500): 563,
    ("bcw", 250): 380, ("bcw", 200): 312, ("bcw", 150): 156, ("bcw", 100): 84,
    ("credit", 10000): 1590, ("credit", 7500): 902,
    ("credit", 5000): 590, ("credit", 2500): 442,
}


def rows():
    out = []
    for ds, aligns in ALIGNED_SCENARIOS.items():
        d = SPECS[ds]["d"]
        x_t, x_d = 5, d - 5
        bs = 8 if ds == "bcw" else 128
        for n in aligns:
            apc = comm.apcvfl_footprint_bytes(n)
            vft = comm.vfedtrans_footprint_bytes(n, x_t, x_d)
            paper_rounds = PAPER_SPLITNN_ROUNDS.get((ds, n))
            epochs = (paper_rounds // (2 * int(np.ceil(n / bs)))
                      if paper_rounds else 50)
            spl = comm.splitnn_footprint_bytes(max(epochs, 1), n, bs)
            out.append({
                "dataset": ds, "aligned": n,
                "apcvfl_MB": apc / 2**20,
                "vfedtrans_MB": vft / 2**20,
                "splitnn_MB": spl / 2**20,
                "apcvfl_rounds": comm.APCVFL_ROUNDS,
                "vfedtrans_rounds": comm.VFEDTRANS_ROUNDS,
                "splitnn_rounds": paper_rounds or comm.splitnn_rounds(
                    max(epochs, 1), n, bs),
                "saving_vs_vfedtrans": vft / apc,
                "saving_vs_splitnn": spl / apc,
            })
    return out


def run(csv=True):
    rs = rows()
    if csv:
        print("name,us_per_call,derived")
    for r in rs:
        tag = f"comm/{r['dataset']}/{r['aligned']}"
        print(f"{tag},0,"
              f"apcvfl={r['apcvfl_MB']:.2f}MB|"
              f"vfedtrans={r['vfedtrans_MB']:.2f}MB|"
              f"splitnn={r['splitnn_MB']:.2f}MB|"
              f"xVFT={r['saving_vs_vfedtrans']:.1f}|"
              f"xSplitNN={r['saving_vs_splitnn']:.1f}")
    return rs


if __name__ == "__main__":
    run()
