"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md section
"Roofline").

Per (arch x shape x mesh) JSON produced by ``repro.launch.dryrun``:
  compute term    = HLO_FLOPs / (chips * 197 TFLOP/s)      [bf16 v5e]
  memory term     = HLO_bytes / (chips * 819 GB/s)
  collective term = collective_bytes / (chips * 3 links * 50 GB/s)
(the walker reports per-device numbers, so the chip division is implicit:
term = per_device_quantity / per_chip_rate).

Also reports MODEL_FLOPS = 6*N(_active)*D against compiled HLO FLOPs —
the useful-compute fraction that catches remat/redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs.base import HBM_BW, ICI_BW, INPUT_SHAPES, PEAK_FLOPS_BF16

N_LINKS = 3   # ICI links per v5e chip usable concurrently (2D torus + wrap)


def model_flops(rec: dict) -> float:
    """6*N*D for train (fwd+bwd), 2*N*D for inference, per STEP (global)."""
    shape = INPUT_SHAPES[rec["shape"]]
    n = rec["active_params"]
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: one token per row


def analyze_record(rec: dict) -> dict:
    chips = rec["n_chips"]
    t_comp = rec["hlo_flops_per_device"] / PEAK_FLOPS_BF16
    t_mem = rec["hlo_bytes_per_device"] / HBM_BW
    t_coll = rec["collective_bytes_per_device"] / (N_LINKS * ICI_BW)
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec)
    hlo_global = rec["hlo_flops_per_device"] * chips
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "objective": rec.get("objective", "lm"),
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "bottleneck": dom,
        "model_flops": mf,
        "useful_fraction": mf / hlo_global if hlo_global else 0.0,
        "mem_temp_gb": rec["mem_temp_bytes"] / 1e9,
        "mem_args_gb": rec["mem_argument_bytes"] / 1e9,
        "fits_hbm16": (rec["mem_temp_bytes"] + rec["mem_argument_bytes"])
        < 16e9,
        "step_time_bound_s": max(terms.values()),
    }


def run(dryrun_dir: str = "experiments/dryrun", csv: bool = True,
        mesh_filter: str = "16x16"):
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as fh:
            rec = json.load(fh)
        if mesh_filter and rec.get("mesh") != mesh_filter:
            continue
        recs.append(analyze_record(rec))
    if csv:
        print("name,us_per_call,derived")
        for r in recs:
            tag = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
            print(f"{tag},{r['step_time_bound_s']*1e6:.0f},"
                  f"bound={r['bottleneck']}|"
                  f"Tc={r['t_compute_s']:.3e}|Tm={r['t_memory_s']:.3e}|"
                  f"Tx={r['t_collective_s']:.3e}|"
                  f"useful={r['useful_fraction']:.2f}|"
                  f"fits16G={'Y' if r['fits_hbm16'] else 'N'}")
    return recs


def markdown_table(recs: list) -> str:
    lines = ["| arch | shape | mesh | compute s | memory s | collective s | "
             "bottleneck | useful | args GB | temp GB | fits 16G |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        arch = r["arch"] + (" (distill)" if r.get("objective") not in
                            (None, "lm") else "")
        lines.append(
            f"| {arch} | {r['shape']} | {r['mesh']} | "
            f"{r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} | "
            f"{r['t_collective_s']:.3e} | {r['bottleneck']} | "
            f"{r['useful_fraction']:.2f} | {r['mem_args_gb']:.1f} | "
            f"{r['mem_temp_gb']:.1f} | {'Y' if r['fits_hbm16'] else 'N'} |")
    return "\n".join(lines)


if __name__ == "__main__":
    run()
