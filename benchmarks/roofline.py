"""Roofline analysis of the APC-VFL pipeline stages.

For each serving/training stage actually run by this repo — the g1
autoencoder steps, the g2 joint step, the g3 distillation step, the CV
probe step, and the two serving paths — the compiled HLO's FLOPs and
bytes come straight from ``jit(fn).lower(...).compile().cost_analysis()``
(no hand-derived counts): arithmetic intensity = flops / bytes, compared
against the machine balance point (ridge) ``PEAK_FLOPS_BF16 / HBM_BW`` of
the v5e hardware model in ``repro.configs.base``.  Stages left of the
ridge are memory-bound — the ones the fused Pallas kernels
(``kernels.lane_mlp`` / ``kernels.probe`` / ``kernels.int8_matmul``)
exist to help, by collapsing per-op HBM round-trips into one pass.

The int8 serving stage is derived from the fp32 serve cost analytically
(same FLOPs; weight traffic divided by 4, the whole point of
``serve.quant``) because the int8 GEMM lives in a Pallas kernel that the
CPU backend only runs interpreted — its ``source`` field says so.

Writes ``BENCH_roofline.json`` and prints the repo's
``name,us_per_call,derived`` CSV.

The pre-VFL dry-run mode (per arch x shape x mesh JSONs produced by
``repro.launch.dryrun`` for the transformer stack) survives as
``--mode dryrun`` / ``run_dryrun()``; it now FAILS LOUDLY when the
artifact directory is empty or a record references a shape missing from
``INPUT_SHAPES`` instead of silently analyzing nothing.

Run:  PYTHONPATH=src python benchmarks/roofline.py [--batch 32]
      [--serve-batch 256] [--out BENCH_roofline.json]
      PYTHONPATH=src python benchmarks/roofline.py --mode dryrun \
          [--dryrun-dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HBM_BW, ICI_BW, INPUT_SHAPES, PEAK_FLOPS_BF16

N_LINKS = 3   # ICI links per v5e chip usable concurrently (2D torus + wrap)

RIDGE = PEAK_FLOPS_BF16 / HBM_BW          # flops/byte at machine balance


# ---------------------------------------------------------------------------
# VFL-stage mode (default): cost_analysis over the real pipeline stages
# ---------------------------------------------------------------------------

def _cost(fn, *args) -> dict:
    """FLOPs / bytes of the compiled HLO for ``fn(*args)``.  Fails with a
    named error if the backend's cost model omits the keys (rather than
    writing zeros that would classify every stage as infinitely
    compute-bound)."""
    ca = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    if not ca or "flops" not in ca or "bytes accessed" not in ca:
        raise RuntimeError(
            f"cost_analysis on backend {jax.default_backend()!r} did not "
            f"report flops/bytes (got keys {sorted(ca or {})}); the "
            f"roofline needs a backend with an XLA cost model")
    return {"flops": float(ca["flops"]),
            "bytes": float(ca["bytes accessed"])}


def _classify(stage: str, flops: float, nbytes: float, *,
              source: str = "cost_analysis", note: str = "") -> dict:
    intensity = flops / max(nbytes, 1.0)
    t_comp = flops / PEAK_FLOPS_BF16
    t_mem = nbytes / HBM_BW
    rec = {
        "stage": stage,
        "flops": flops,
        "bytes": nbytes,
        "intensity_flops_per_byte": round(intensity, 3),
        "ridge_flops_per_byte": round(RIDGE, 1),
        "bound": "compute" if intensity >= RIDGE else "memory",
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "step_time_bound_s": max(t_comp, t_mem),
        "source": source,
    }
    if note:
        rec["note"] = note
    return rec


def vfl_stages(batch: int = 32, serve_batch: int = 256,
               probe_rows: int = 512, seed: int = 0) -> list:
    """Cost records for the pipeline stages at bcw-like shapes: active
    d=5, passive d=25, Table-3 widths, g3 latent 256, binary head."""
    from repro.core import autoencoder as ae
    from repro.core import distill
    from repro.kernels import ref
    from repro.serve import vfl as sv

    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    rng = np.random.RandomState(seed)
    f32 = lambda *shp: jnp.asarray(rng.randn(*shp).astype(np.float32))

    recs = []

    # --- training steps: value+grad of each stage's loss ------------------
    ae_stages = [
        ("g1_active_step", ae.init_autoencoder(k1, [5, 64, 128]), 5),
        ("g1_passive_step", ae.init_autoencoder(k2, [25, 128, 256]), 25),
        ("g2_step", ae.init_autoencoder(k3, [384, 256, 256]), 384),
    ]
    grad_recon = jax.value_and_grad(ae.recon_loss)
    for name, params, d in ae_stages:
        c = _cost(grad_recon, params, {"x": f32(batch, d)})
        recs.append(_classify(name, c["flops"], c["bytes"]))

    g3 = ae.init_autoencoder(k4, [5, 256, 256])
    dbatch = {"x": f32(batch, 5), "z_teacher": f32(batch, 256),
              "aligned": jnp.ones((batch,), jnp.float32)}
    c = _cost(jax.value_and_grad(distill.distill_loss), g3, dbatch)
    recs.append(_classify("g3_distill_step", c["flops"], c["bytes"]))

    # --- probe step: the fused-kernel semantics via its jnp oracle --------
    w = f32(256, 2)
    b = f32(2)
    px = f32(probe_rows, 256)
    py = jnp.asarray(rng.randint(0, 2, probe_rows), jnp.int32)
    prw = jnp.ones((probe_rows,), jnp.float32)
    c = _cost(ref.probe_grad_ref, w, b, px, py, prw)
    recs.append(_classify("probe_step", c["flops"], c["bytes"]))

    # --- serving: head(g3(x)) at the largest bucket shape -----------------
    p_active = {
        "g3": {"enc": g3["enc"]},
        "head": {"w": w, "b": b},
        "mean": jnp.zeros((5,), jnp.float32),
        "inv_scale": jnp.ones((5,), jnp.float32),
    }
    sx = f32(serve_batch, 5)
    c = _cost(sv._active_apply, p_active, sx)
    recs.append(_classify("serve_active", c["flops"], c["bytes"]))

    # int8 serving: identical FLOPs, weight traffic / 4 (1 byte/param +
    # one fp32 scale per output channel instead of 4 bytes/param)
    w_params = 5 * 256 + 256 * 256 + 256 * 2
    w_bytes_fp32 = 4.0 * w_params
    w_bytes_int8 = 1.0 * w_params + 4.0 * (256 + 256 + 2)
    recs.append(_classify(
        "serve_int8", c["flops"],
        c["bytes"] - w_bytes_fp32 + w_bytes_int8,
        source="analytic-int8",
        note="fp32 serve cost with weight traffic at 1 byte/param "
             "(kernels.int8_matmul dequantizes in-tile)"))
    return recs


def run(batch: int = 32, serve_batch: int = 256, probe_rows: int = 512,
        seed: int = 0, csv: bool = True,
        out_json: str = "BENCH_roofline.json") -> list:
    recs = vfl_stages(batch=batch, serve_batch=serve_batch,
                      probe_rows=probe_rows, seed=seed)
    if csv:
        print("name,us_per_call,derived")
    for r in recs:
        print(f"roofline/{r['stage']},{r['step_time_bound_s']*1e6:.2f},"
              f"bound={r['bound']}|"
              f"ai={r['intensity_flops_per_byte']:.1f}|"
              f"ridge={r['ridge_flops_per_byte']:.0f}|"
              f"flops={r['flops']:.3e}|bytes={r['bytes']:.3e}",
              flush=True)
    if out_json:
        payload = {
            "name": f"roofline/vfl/b{batch}/sb{serve_batch}",
            "machine": {"peak_flops_bf16": PEAK_FLOPS_BF16,
                        "hbm_bw": HBM_BW,
                        "ridge_flops_per_byte": round(RIDGE, 1)},
            "config": {"batch": batch, "serve_batch": serve_batch,
                       "probe_rows": probe_rows, "seed": seed,
                       "backend": jax.default_backend()},
            "stages": recs,
        }
        with open(out_json, "w") as fh:
            json.dump(payload, fh, indent=1)
        print(f"# wrote {out_json}", flush=True)
    return recs


# ---------------------------------------------------------------------------
# legacy dry-run mode: per (arch x shape x mesh) transformer artifacts
# ---------------------------------------------------------------------------

def model_flops(rec: dict) -> float:
    """6*N*D for train (fwd+bwd), 2*N*D for inference, per STEP (global)."""
    if rec["shape"] not in INPUT_SHAPES:
        raise KeyError(
            f"dry-run record references shape {rec['shape']!r} which is "
            f"not in repro.configs.base.INPUT_SHAPES "
            f"({sorted(INPUT_SHAPES)}); the artifact is stale — "
            f"regenerate it with repro.launch.dryrun")
    shape = INPUT_SHAPES[rec["shape"]]
    n = rec["active_params"]
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: one token per row

def analyze_record(rec: dict) -> dict:
    chips = rec["n_chips"]
    t_comp = rec["hlo_flops_per_device"] / PEAK_FLOPS_BF16
    t_mem = rec["hlo_bytes_per_device"] / HBM_BW
    t_coll = rec["collective_bytes_per_device"] / (N_LINKS * ICI_BW)
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec)
    hlo_global = rec["hlo_flops_per_device"] * chips
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "objective": rec.get("objective", "lm"),
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "bottleneck": dom,
        "model_flops": mf,
        "useful_fraction": mf / hlo_global if hlo_global else 0.0,
        "mem_temp_gb": rec["mem_temp_bytes"] / 1e9,
        "mem_args_gb": rec["mem_argument_bytes"] / 1e9,
        "fits_hbm16": (rec["mem_temp_bytes"] + rec["mem_argument_bytes"])
        < 16e9,
        "step_time_bound_s": max(terms.values()),
    }


def run_dryrun(dryrun_dir: str = "experiments/dryrun", csv: bool = True,
               mesh_filter: str = "16x16"):
    paths = sorted(glob.glob(os.path.join(dryrun_dir, "*.json")))
    if not paths:
        raise FileNotFoundError(
            f"no dry-run artifacts under {dryrun_dir!r} — this mode "
            f"analyzes per (arch x shape x mesh) JSONs written by "
            f"repro.launch.dryrun; for the VFL pipeline roofline run "
            f"the default mode (no --mode dryrun) instead")
    recs = []
    for path in paths:
        with open(path) as fh:
            rec = json.load(fh)
        if mesh_filter and rec.get("mesh") != mesh_filter:
            continue
        recs.append(analyze_record(rec))
    if not recs:
        raise ValueError(
            f"{len(paths)} dry-run artifacts under {dryrun_dir!r} but "
            f"none match mesh_filter={mesh_filter!r}; pass "
            f"mesh_filter='' to analyze all meshes")
    if csv:
        print("name,us_per_call,derived")
        for r in recs:
            tag = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
            print(f"{tag},{r['step_time_bound_s']*1e6:.0f},"
                  f"bound={r['bottleneck']}|"
                  f"Tc={r['t_compute_s']:.3e}|Tm={r['t_memory_s']:.3e}|"
                  f"Tx={r['t_collective_s']:.3e}|"
                  f"useful={r['useful_fraction']:.2f}|"
                  f"fits16G={'Y' if r['fits_hbm16'] else 'N'}")
    return recs


def markdown_table(recs: list) -> str:
    lines = ["| arch | shape | mesh | compute s | memory s | collective s | "
             "bottleneck | useful | args GB | temp GB | fits 16G |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        arch = r["arch"] + (" (distill)" if r.get("objective") not in
                            (None, "lm") else "")
        lines.append(
            f"| {arch} | {r['shape']} | {r['mesh']} | "
            f"{r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} | "
            f"{r['t_collective_s']:.3e} | {r['bottleneck']} | "
            f"{r['useful_fraction']:.2f} | {r['mem_args_gb']:.1f} | "
            f"{r['mem_temp_gb']:.1f} | {'Y' if r['fits_hbm16'] else 'N'} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["vfl", "dryrun"], default="vfl")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--serve-batch", type=int, default=256)
    ap.add_argument("--probe-rows", type=int, default=512)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_roofline.json",
                    help="JSON output path ('' to skip; vfl mode only)")
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--mesh-filter", default="16x16")
    args = ap.parse_args()
    if args.mode == "dryrun":
        run_dryrun(args.dryrun_dir, mesh_filter=args.mesh_filter)
    else:
        run(batch=args.batch, serve_batch=args.serve_batch,
            probe_rows=args.probe_rows, seed=args.seed, out_json=args.out)


if __name__ == "__main__":
    main()
