"""Robustness benchmark: the utility-vs-leakage frontier of the hardened
exchange, and SLO attainment of the serving runtime under mid-stream
passive-party faults.  The subsystem's two claims in one artifact:

* **frontier** — the defense grid (Gaussian sigma sweep + int8/sign
  quantization points) run twice: utility via
  ``robustness.defense.dp_frontier`` (the WHOLE sigma grid as replica
  lanes of one protocol — one compile per stage), leakage via
  ``robustness.attacks.leakage_profile`` (every registered attack against
  every defense, surfaces lane-batched).  CI gates that leakage is
  NON-INCREASING in sigma for the inversion and membership attacks
  (membership starts at ~1.0 undefended — aligned rows match their own
  exchanged latents exactly — so the frontier must visibly close).

* **faulted serving** — multi-tenant Poisson load with a seeded
  ``FaultPlan`` injected mid-stream: one tenant's passive party drops
  out (never recovers), another goes stale then recovers.  Gates: SLO
  attainment >= the ``robust_stream`` budget, ZERO steady-state XLA
  compiles (the degrade path reuses warmed active-path executables),
  zero collaborative dispatches while faulted (degraded tenants serve
  the active-only fallback — NEVER stale latents), the recovered tenant
  resumes with a bumped cache version, and unfaulted tenants stay
  bit-identical to dedicated serving (parity replay; faulted tenants are
  excluded — a fresh solo engine has a fresh cache, so divergence there
  is the DEFENSE working, not a bug).

* **training faults** — ``run_faulted_apcvfl`` under dropout / stale /
  drift exchange events: every degraded run completes and reports its
  ``fault_*`` flags; dropout is exactly the active-only ablation
  (0 data rounds).

Writes ``BENCH_robust.json`` with the acceptance block gated in CI.

Run:  PYTHONPATH=src python benchmarks/robustbench.py [--smoke]
      [--epochs 15] [--requests 1200] [--out BENCH_robust.json]
"""
from __future__ import annotations

import argparse
import json
import time
import warnings

from repro.analysis import guards
from repro.core import pipeline
from repro.data.synthetic import make_dataset
from repro.data.vertical import make_scenario
from repro.robustness import attacks, defense, faults
from repro.serve import runtime as rt
from repro.serve import vfl as sv

SIGMAS = (0.0, 0.5, 2.0, 8.0)
MONOTONE_TOL = 0.05      # attacks are trained estimators; small jitter ok


def _monotone_nonincreasing(xs, tol: float = MONOTONE_TOL) -> bool:
    return all(b <= a + tol for a, b in zip(xs, xs[1:]))


def run_frontier(*, sigmas=SIGMAS, epochs: int = 15, aligned: int = 150,
                 n_aux: int = 64, seed: int = 0) -> dict:
    ds = make_dataset("bcw", seed=seed)
    sc = make_scenario(ds, n_active_features=5, n_aligned=aligned,
                       seed=seed)
    t0 = time.time()

    # utility: the whole sigma grid as replica lanes of one protocol
    util = defense.dp_frontier(sc, list(sigmas), seed=seed,
                               max_epochs=epochs)
    # quantization points (distinct wire dtypes, accounted per dtype)
    quant_points = {}
    for mode in ("int8", "sign"):
        r = defense.run_apcvfl_dp(sc, quantize=mode, seed=seed,
                                  max_epochs=epochs)
        quant_points[mode] = {
            "accuracy": r.metrics["accuracy"],
            "f1_macro": r.metrics["f1_macro"],
            "exchange_bytes": r.metrics["exchange_bytes"],
            "by_dtype": r.comm["by_dtype"],
        }

    # leakage: every registered attack against every sigma, lane-batched
    transforms = [defense.make_transform(sigma=float(s)) for s in sigmas]
    with warnings.catch_warnings():
        # n_aux clamping on small aligned sets is expected here; the
        # effective budget is recorded in each report
        warnings.simplefilter("ignore", RuntimeWarning)
        profile = attacks.leakage_profile(sc, transforms, seed=seed,
                                          n_aux=n_aux, max_epochs=epochs)

    points = []
    for s, r, reps in zip(sigmas, util, profile):
        points.append({
            "sigma": float(s),
            "accuracy": r.metrics["accuracy"],
            "f1_macro": r.metrics["f1_macro"],
            "exchange_bytes": r.metrics["exchange_bytes"],
            "leakage": {name: rep.metrics()
                        for name, rep in reps.items()},
        })
        print(f"robustbench/frontier,sigma={s:g}|"
              f"acc={r.metrics['accuracy']:.4f}|"
              + "|".join(f"{n}={rep.leakage:.3f}"
                         for n, rep in sorted(reps.items())), flush=True)

    leak = {name: [p["leakage"][name]["leakage"] for p in points]
            for name in profile[0]}
    gates = {
        "inversion_monotone": _monotone_nonincreasing(leak["inversion"]),
        "membership_monotone": _monotone_nonincreasing(leak["membership"]),
        "membership_open_undefended": leak["membership"][0] >= 0.9,
        "membership_closed_at_max_sigma": leak["membership"][-1]
            <= 0.5 * leak["membership"][0],
        "inversion_closed_at_max_sigma": leak["inversion"][-1]
            <= max(0.5 * leak["inversion"][0], 0.05),
    }
    return {"sigmas": list(sigmas), "points": points,
            "quantized": quant_points, "gates": gates,
            "wall_s": round(time.time() - t0, 2)}


def run_faulted_serving(*, tenants: int = 3, requests: int = 1200,
                        rate_rps: float = 300.0, slo_ms: float = 100.0,
                        max_rows: int = 24, epochs: int = 15,
                        aligned: int = 150, seed: int = 0) -> dict:
    if tenants < 3:
        raise ValueError("robustbench needs >= 3 tenants: one dropout, "
                         "one stale+recover, one healthy control")
    budgets = guards.load_budgets()["robust_stream"]
    bundles, scenarios = {}, {}
    for k in range(tenants):
        name = f"tenant{k}"
        ds = make_dataset("bcw", seed=seed + k)
        sc = make_scenario(ds, n_active_features=5, n_aligned=aligned,
                           seed=seed + k)
        result = pipeline.run_apcvfl(sc, seed=seed + k, max_epochs=epochs)
        bundles[name] = sv.export_bundle(result, sc)
        scenarios[name] = sc

    registry = rt.TenantRegistry()
    for name, b in bundles.items():
        registry.register(name, b)
    with guards.compile_counter() as warm:
        registry.warmup()
    warm_compiles = warm.count          # snapshot: the tally is live

    streams = []
    for k, name in enumerate(registry.names()):
        sc = scenarios[name]
        streams.append(rt.make_timed_stream(
            sc.active.x, sc.active.ids, requests, tenant=name,
            arrivals="poisson", rate_rps=rate_rps, seed=seed + 101 * k,
            max_rows=max_rows))
    merged = rt.merge_streams(*streams)
    # faults land mid-stream: dropout at ~1/3, stale at ~1/2 with a
    # recovery at ~3/4 of the arrival horizon
    horizon = merged[-1].t_arrival_ms
    plan = faults.FaultPlan(name="robustbench-midstream", seed=seed, events=(
        faults.FaultEvent(kind="dropout", t_ms=horizon / 3,
                          tenant="tenant1"),
        faults.FaultEvent(kind="stale", t_ms=horizon / 2,
                          tenant="tenant2"),
        faults.FaultEvent(kind="recover", t_ms=0.75 * horizon,
                          tenant="tenant2"),
    ))

    runtime = rt.ServingRuntime(
        registry, rt.RuntimeConfig(slo_ms=slo_ms))
    registry.reset_stats()
    with guards.compile_counter() as steady:
        report = runtime.run(merged, faults=plan)
    report["xla_compiles_stream"] = steady.count
    # parity replay ONLY for unfaulted tenants: a fresh solo engine has a
    # fresh (non-invalidated) cache, so faulted tenants' active-only
    # logits rightly differ from dedicated serving — that divergence is
    # the degrade path working
    faulted = {e.tenant for e in plan.events if e.kind != "recover"}
    healthy = {n: b for n, b in bundles.items() if n not in faulted}
    report["parity"] = rt.verify_dispatch_parity(runtime, healthy)

    fb = report["faults"]["tenants"]
    stats = {n: registry[n].stats for n in registry.names()}
    gates = {
        "slo_attainment": report["slo"]["attainment"],
        "slo_ok": report["slo"]["attainment"]
            >= budgets["slo_attainment_min"],
        "stream_compiles": report["xla_compiles_stream"],
        "stream_compiles_ok": report["xla_compiles_stream"]
            <= budgets["warm_compiles"],
        "no_stale_serving": all(
            fb[n]["collab_dispatches_while_faulted"] == 0 for n in fb),
        "dropout_degraded": (
            fb["tenant1"]["cache_stale"]
            and stats["tenant1"].dispatches.get("active", 0) > 0),
        "dropout_had_collab_before_fault":
            stats["tenant1"].dispatches.get("collab", 0) > 0,
        "recovered_resumed": (
            not fb["tenant2"]["cache_stale"]
            and fb["tenant2"]["cache_version"] >= 2),
        "healthy_collab_served":
            stats["tenant0"].dispatches.get("collab", 0) > 0,
        "healthy_parity_bit_identical": all(
            t["bit_identical"] for t in report["parity"].values()),
    }
    print(f"robustbench/faulted/t{tenants}x{requests},"
          f"slo={gates['slo_attainment']}|"
          f"compiles={gates['stream_compiles']}|"
          f"stale_serving_violations="
          f"{sum(fb[n]['collab_dispatches_while_faulted'] for n in fb)}|"
          f"dropout_degraded={gates['dropout_degraded']}|"
          f"recovered={gates['recovered_resumed']}", flush=True)
    return {"plan": plan.to_dict(), "warm_compiles": warm_compiles,
            "report": report, "gates": gates}


def run_training_faults(*, epochs: int = 15, aligned: int = 150,
                        seed: int = 0) -> dict:
    ds = make_dataset("bcw", seed=seed)
    sc = make_scenario(ds, n_active_features=5, n_aligned=aligned,
                       seed=seed)
    clean = pipeline.run_apcvfl(sc, seed=seed, max_epochs=epochs)
    out = {"clean_accuracy": clean.metrics["accuracy"], "runs": {}}
    plans = {
        "dropout": faults.FaultPlan("dropout", events=(
            faults.FaultEvent(kind="dropout", stage="exchange"),)),
        "stale": faults.FaultPlan("stale", events=(
            faults.FaultEvent(kind="stale", stage="exchange", epochs=1),)),
        "drift": faults.FaultPlan("drift", events=(
            faults.FaultEvent(kind="drift", stage="exchange", drift=0.5),)),
    }
    for name, plan in plans.items():
        r = faults.run_faulted_apcvfl(sc, plan, seed=seed,
                                      max_epochs=epochs)
        out["runs"][name] = {
            "accuracy": r.metrics["accuracy"],
            "rounds": r.rounds,
            "flags": {k: v for k, v in r.metrics.items()
                      if k.startswith("fault_")},
        }
        print(f"robustbench/trainfault/{name},"
              f"acc={r.metrics['accuracy']:.4f}|rounds={r.rounds}",
              flush=True)
    out["gates"] = {
        "dropout_is_ablation": out["runs"]["dropout"]["rounds"] == 0,
        "all_complete": all(v["accuracy"] > 0.5
                            for v in out["runs"].values()),
    }
    return out


def run(*, epochs: int = 15, requests: int = 1200, rate_rps: float = 300.0,
        slo_ms: float = 100.0, aligned: int = 150, seed: int = 0,
        out_json: str = "BENCH_robust.json") -> dict:
    frontier = run_frontier(epochs=epochs, aligned=aligned, seed=seed)
    serving = run_faulted_serving(requests=requests, rate_rps=rate_rps,
                                  slo_ms=slo_ms, epochs=epochs,
                                  aligned=aligned, seed=seed)
    training = run_training_faults(epochs=epochs, aligned=aligned,
                                   seed=seed)
    acceptance = {
        **{f"frontier_{k}": v for k, v in frontier["gates"].items()},
        **{f"serving_{k}": v for k, v in serving["gates"].items()
           if isinstance(v, bool)},
        **{f"training_{k}": v for k, v in training["gates"].items()},
    }
    acceptance["ok"] = all(acceptance.values())
    print(f"# acceptance: ok={acceptance['ok']} " + " ".join(
        f"{k}={v}" for k, v in acceptance.items() if k != "ok"),
        flush=True)
    payload = {
        "name": f"robustbench/bcw/e{epochs}/r{requests}",
        "config": {"epochs": epochs, "requests": requests,
                   "rate_rps": rate_rps, "slo_ms": slo_ms,
                   "aligned": aligned, "seed": seed},
        "frontier": frontier,
        "faulted_serving": serving,
        "training_faults": training,
        "acceptance": acceptance,
    }
    if out_json:
        with open(out_json, "w") as fh:
            json.dump(payload, fh, indent=1)
        print(f"# wrote {out_json}", flush=True)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=15)
    ap.add_argument("--requests", type=int, default=1200,
                    help="requests per tenant in the faulted segment")
    ap.add_argument("--rate-rps", type=float, default=300.0)
    ap.add_argument("--slo-ms", type=float, default=100.0)
    ap.add_argument("--aligned", type=int, default=150)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: 2 training epochs, 300 requests per "
                         "tenant, 200 ms SLO")
    ap.add_argument("--out", default="BENCH_robust.json",
                    help="JSON output path ('' to skip)")
    args = ap.parse_args()
    if args.smoke:
        args.epochs = min(args.epochs, 2)
        args.requests = min(args.requests, 300)
        args.rate_rps = min(args.rate_rps, 200.0)
        args.slo_ms = max(args.slo_ms, 200.0)
    run(epochs=args.epochs, requests=args.requests, rate_rps=args.rate_rps,
        slo_ms=args.slo_ms, aligned=args.aligned, seed=args.seed,
        out_json=args.out)


if __name__ == "__main__":
    main()
