"""Batched greedy decoding with a KV cache (full and sliding-window),
demonstrating the serving path on a reduced config.

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch internlm2-20b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.models import model as M
from repro.serve.decode import make_decode_step, prefill_step
from repro.sharding.policy import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-20b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--window", type=int, default=0,
                    help=">0 enables the sliding-window KV cache")
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    key = jax.random.PRNGKey(0)
    params = init_params(M.schema(cfg), key, jnp.float32)
    B, P = args.batch, args.prompt_len
    prompt = jax.random.randint(key, (B, P), 0, cfg.vocab_size)

    # prefill: teacher-forced pass over the prompt via per-token decode
    # (the jitted full-forward prefill_step is used for last-token logits)
    n_slots = args.window or (P + args.new_tokens)
    img = (jax.random.normal(key, (B, cfg.n_image_tokens, cfg.d_model))
           if cfg.family == "vlm" else None)
    cache = M.init_cache(params, cfg, B, n_slots, image_embeds=img)
    step = jax.jit(make_decode_step(cfg, args.window))

    t0 = time.time()
    tok = prompt[:, 0]
    for t in range(P - 1):
        tok, cache = step(params, prompt[:, t], cache, jnp.int32(t))
    generated = []
    tok = prompt[:, -1]
    for t in range(P - 1, P + args.new_tokens - 1):
        tok, cache = step(params, tok, cache, jnp.int32(t))
        generated.append(tok)
    gen = jnp.stack(generated, axis=1)
    dt = time.time() - t0
    total_steps = P - 1 + args.new_tokens
    print(f"arch={cfg.name} (reduced) window={args.window or 'full'}")
    print(f"decoded {args.new_tokens} tokens x batch {B} "
          f"in {dt:.2f}s ({1e3*dt/total_steps:.1f} ms/step)")
    print("sample:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
