"""Quickstart: the paper's headline comparison through the declarative
experiment API — one ExperimentSpec, one sweep() call, uniform results.

Run:  PYTHONPATH=src python examples/quickstart.py

Every method trains on the device-resident scan engine
(repro.core.training); the spec's empty params reproduce the paper's
hyperparameters (configs.apcvfl_paper.TABULAR), here capped at 60 epochs
so the example finishes in minutes on CPU.
"""
import time

from repro.experiments import ExperimentSpec, MethodSpec, sweep

# 1. declare the experiment: a synthetic Breast-Cancer-Wisconsin-shaped
#    VFL scenario (active holds 5 of 30 features + labels, 250 of ~570
#    records aligned) and the methods to compare on it
spec = ExperimentSpec(
    name="quickstart",
    dataset="bcw",
    aligned=(250,),
    n_active_features=5,
    seeds=(0,),
    methods=(MethodSpec("local"),            # raw-feature probe baseline
             MethodSpec("apcvfl"),           # the paper's full protocol
             MethodSpec("vfedtrans")),       # FedSVD-based prior work
    overrides={"max_epochs": 60},
)

# 2. run it: scenarios are built once per grid cell and shared by every
#    method; each run returns the same uniform RunResult shape
t0 = time.time()
results = sweep(spec)
print(f"\n{len(results)} runs in {time.time() - t0:.1f}s")

# 3. read the comparison straight off the records
for r in results:
    print(f"{r.method:>10}: accuracy={r.metrics['accuracy']:.3f} "
          f"rounds={r.rounds} comm={r.comm['total_mb']:.2f}MB "
          f"epochs={r.epochs}")

# 4. the active participant can now run inference fully independently:
#    z = g3(x_active) -> classifier, no collaborator required
#    (the trained encoder is in the apcvfl result's params["g3"]).
apcvfl = next(r for r in results if r.method == "apcvfl")
print(f"\nAPC-VFL needed ONE communication round "
      f"({apcvfl.comm['total_bytes']:,} bytes incl. PSI); "
      f"g3 params ready for local inference: {sorted(apcvfl.params)}")
