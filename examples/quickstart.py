"""Quickstart: the full APC-VFL protocol end-to-end on a synthetic
Breast-Cancer-Wisconsin-shaped VFL scenario (2 participants, partial
alignment). This is the paper's pipeline in ~20 lines of public API.

Run:  PYTHONPATH=src python examples/quickstart.py

All four training stages run on the device-resident scan engine
(repro.core.training): data uploaded once per stage, whole epochs as one
jitted scan, one host sync per epoch.
"""
import time

from repro.core import pipeline
from repro.data.synthetic import make_dataset
from repro.data.vertical import make_scenario

# 1. a vertically-partitioned scenario: active holds 5 of 30 features +
#    labels; 250 of ~570 records are aligned between the parties
ds = make_dataset("bcw", seed=0)
sc = make_scenario(ds, n_active_features=5, n_aligned=250, seed=0)
print(f"active: {sc.active.x.shape}, passive: {sc.passive.x.shape}, "
      f"aligned: {sc.n_aligned}")

# 2. baselines: raw-feature local probe
local = pipeline.run_local_baseline(sc)
print(f"local probe accuracy:   {local['accuracy']:.3f}")

# 3. APC-VFL: local representation learning -> ONE exchange ->
#    joint representation -> distillation -> classifier
t0 = time.time()
res = pipeline.run_apcvfl(sc, lam=0.01, kind="mse")
print(f"APC-VFL accuracy:       {res.metrics['accuracy']:.3f} "
      f"(trained in {time.time() - t0:.1f}s)")
print(f"communication rounds:   {res.rounds} (SplitNN needs hundreds)")
print(f"bytes exchanged:        {res.channel.total_bytes:,} "
      f"({res.channel.total_mb():.2f} MB, incl. PSI hashes)")
print(f"stage epochs:           {res.epochs}")

# 4. the active participant can now run inference fully independently:
#    z = g3(x_active) -> classifier, no collaborator required.
