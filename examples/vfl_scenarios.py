"""Paper Figure 5/6 style sweep through the declarative experiment API:
APC-VFL vs Local vs Ablation vs VFedTrans across alignment levels (and
SplitNN + the aligned-only adaptation with ``--splitnn``), with
communication accounting — one ExperimentSpec, one sweep() call.

Run:  PYTHONPATH=src python examples/vfl_scenarios.py [--dataset bcw]
      [--alignments 250,150] [--features 5] [--seeds 0] [--max-epochs 60]
      [--splitnn] [--out results.json]
"""
import argparse
import json

from repro.data.synthetic import ALIGNED_SCENARIOS, PAPER_METRIC
from repro.experiments import ExperimentSpec, MethodSpec, sweep, tidy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="bcw",
                    choices=["bcw", "mimic3", "credit"])
    ap.add_argument("--alignments", default="")
    ap.add_argument("--features", type=int, default=5)
    ap.add_argument("--seeds", default="0")
    ap.add_argument("--max-epochs", type=int, default=60)
    ap.add_argument("--splitnn", action="store_true",
                    help="add the fully-aligned Table-2 comparison "
                         "(SplitNN vs APC-VFL aligned-only)")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    metric = PAPER_METRIC[args.dataset]
    aligns = tuple(int(x) for x in args.alignments.split(",") if x) \
        or tuple(ALIGNED_SCENARIOS[args.dataset][-2:])
    methods = [MethodSpec("local"),
               MethodSpec("apcvfl"),
               MethodSpec("apcvfl", label="ablation",
                          params={"ablation": True}),
               MethodSpec("vfedtrans")]
    if args.splitnn:
        test_size = 50 if args.dataset == "bcw" else 500
        methods += [MethodSpec("splitnn", params={"test_size": test_size}),
                    MethodSpec("apcvfl_aligned_only",
                               params={"test_size": test_size})]

    spec = ExperimentSpec(
        name=f"fig5/{args.dataset}",
        dataset=args.dataset,
        aligned=aligns,
        n_active_features=args.features,
        seeds=tuple(int(s) for s in args.seeds.split(",") if s),
        methods=tuple(methods),
        overrides={"max_epochs": args.max_epochs},
    )
    records = tidy(sweep(spec, progress=print))

    print(f"\n=== {spec.name} summary (metric: {metric}) ===")
    hdr = ("aligned", "seed", "method", metric, "rounds", "MB")
    print(" ".join(f"{h:>12}" for h in hdr))
    for r in records:
        print(f"{r['n_aligned']:>12} {r['seed']:>12} {r['method']:>12} "
              f"{r[metric]:>12.4f} {r['rounds']:>12} {r['comm_mb']:>12.3f}")
    if args.out:
        with open(args.out, "w") as fh:
            json.dump({"spec": spec.to_dict(), "records": records}, fh,
                      indent=1)


if __name__ == "__main__":
    main()
