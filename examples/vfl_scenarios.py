"""Paper Figure 5/6 style sweep: APC-VFL vs Local vs Ablation vs VFedTrans
across alignment levels (and SplitNN in the fully-aligned adaptation),
with communication accounting.

Run:  PYTHONPATH=src python examples/vfl_scenarios.py [--dataset bcw]
      [--alignments 250,150] [--features 5,2] [--max-epochs 60]
"""
import argparse
import json
import time

from repro.core import comm, pipeline, splitnn, vfedtrans
from repro.data.synthetic import ALIGNED_SCENARIOS, PAPER_METRIC, make_dataset
from repro.data.vertical import make_scenario


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="bcw",
                    choices=["bcw", "mimic3", "credit"])
    ap.add_argument("--alignments", default="")
    ap.add_argument("--features", default="5,2")
    ap.add_argument("--max-epochs", type=int, default=60)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    ds = make_dataset(args.dataset, seed=args.seed)
    metric = PAPER_METRIC[args.dataset]
    aligns = ([int(x) for x in args.alignments.split(",") if x]
              or ALIGNED_SCENARIOS[args.dataset][-2:])
    feats = [int(x) for x in args.features.split(",") if x]

    rows = []
    for n_al in aligns:
        for a in feats:
            sc = make_scenario(ds, n_active_features=a, n_aligned=n_al,
                               seed=args.seed)
            t0 = time.time()
            loc = pipeline.run_local_baseline(sc, seed=args.seed)
            ab = pipeline.run_apcvfl(sc, ablation=True,
                                     max_epochs=args.max_epochs)
            ap_ = pipeline.run_apcvfl(sc, max_epochs=args.max_epochs)
            vt = vfedtrans.run_vfedtrans(sc, max_epochs=args.max_epochs)
            row = {
                "aligned": n_al, "active_features": a,
                "local": loc[metric],
                "ablation": ab.metrics[metric],
                "apcvfl": ap_.metrics[metric],
                "vfedtrans": vt.metrics[metric],
                "apcvfl_MB": ap_.channel.total_mb(),
                "vfedtrans_MB": vt.channel.total_mb(),
                "apcvfl_rounds": ap_.rounds,
                "vfedtrans_rounds": vt.rounds,
                "secs": round(time.time() - t0, 1),
            }
            rows.append(row)
            print(json.dumps(row), flush=True)

    print("\n=== summary (metric: %s) ===" % metric)
    hdr = ("aligned", "a", "local", "ablation", "apcvfl", "vfedtrans",
           "apcvfl_MB", "vfedtrans_MB")
    print(" ".join(f"{h:>12}" for h in hdr))
    for r in rows:
        print(f"{r['aligned']:>12} {r['active_features']:>12} "
              f"{r['local']:>12.4f} {r['ablation']:>12.4f} "
              f"{r['apcvfl']:>12.4f} {r['vfedtrans']:>12.4f} "
              f"{r['apcvfl_MB']:>12.3f} {r['vfedtrans_MB']:>12.3f}")
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(rows, fh, indent=1)


if __name__ == "__main__":
    main()
