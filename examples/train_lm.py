"""Train a (reduced) assigned-architecture LM on the synthetic Markov token
stream — the distributed-runtime path of the framework, CPU-sized.

Run:  PYTHONPATH=src python examples/train_lm.py --arch qwen3-moe-30b-a3b
"""
import subprocess
import sys

if __name__ == "__main__":
    arch = "internlm2-1.8b"
    if "--arch" in sys.argv:
        arch = sys.argv[sys.argv.index("--arch") + 1]
    subprocess.run([sys.executable, "-m", "repro.launch.train",
                    "--arch", arch, "--smoke", "--steps", "40",
                    "--batch", "8", "--seq", "128",
                    "--ckpt", "/tmp/repro_lm_ckpt.npz"], check=True)
